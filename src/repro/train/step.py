"""Training step: loss, grads, optimizer update; microbatch accumulation.

``make_train_step`` closes over static configs and returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with in/out shardings — the object the dry-run lowers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    microbatches: int = 1           # gradient accumulation steps
    z_loss: float = 0.0             # optional logit regularizer
    moe_aux_weight: float = 0.01


def cross_entropy(
    cfg: ModelConfig, logits: jnp.ndarray, labels: jnp.ndarray, z_loss: float = 0.0
):
    """Mean CE over tokens; padded-vocab lanes masked out."""
    vp = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if vp != cfg.vocab_size:
        lane = jnp.arange(vp)
        lf = jnp.where(lane < cfg.vocab_size, lf, -1e30)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - gold)
    if z_loss > 0:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig):
    def loss_fn(params, batch):
        logits = lm.forward(
            cfg, params, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            enc_embeds=batch.get("enc_embeds"),
        )
        labels = batch["labels"][:, : logits.shape[1]]
        return cross_entropy(cfg, logits, labels, tcfg.z_loss)

    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    loss_fn = make_loss_fn(cfg, tcfg)

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            # gradient accumulation: scan over microbatch slices so peak
            # activation memory is 1/microbatches of the full batch
            mb = tcfg.microbatches

            def slice_mb(x, i):
                per = x.shape[0] // mb
                return jax.lax.dynamic_slice_in_dim(x, i * per, per, 0)

            def acc(carry, i):
                loss_acc, grad_acc = carry
                mbatch = jax.tree.map(lambda x: slice_mb(x, i), batch)
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                return (loss_acc + l, jax.tree.map(jnp.add, grad_acc, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), zeros),
                jnp.arange(mb),
            )
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        params, opt_state, om = adamw.update(tcfg.optimizer, grads, opt_state, params)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, tcfg: Optional[TrainConfig] = None):
    loss_fn = make_loss_fn(cfg, tcfg or TrainConfig())

    def eval_step(params, batch):
        return loss_fn(params, batch)

    return eval_step
