"""Graceful-degradation backend ladder for trace replay.

Backend selection becomes a supervised fallback chain instead of a silent
``if``: the replay is attempted on the fastest rung and descends on
failure, with every descent recorded as a :mod:`repro.robust.events` event
naming the rung abandoned, the rung taken, and why.

Rungs, fastest first (DESIGN.md §10/§13/§14):

  1. ``pallas-resident-l1l2`` — the hierarchical megakernel (VMEM L1 over
     HBM L2).  Opt-in: attempted only when a ``hierarchy`` with
     ``l1_sets > 0`` is passed; skipped (``vmem_budget``) when even the L1
     exceeds the budget, and (``backend_unsupported``) with TinyLFU —
     admission has no per-tier semantics yet.
  2. ``pallas-resident`` — the flat whole-trace megakernel, ALL state
     lanes pinned in VMEM.  Skipped (``vmem_budget``) when the footprint
     exceeds ``RESIDENT_VMEM_BUDGET``; abandoned (``kernel_failure``)
     when the launch raises.
  3. ``pallas-scan`` — chunked ``lax.scan`` through the Pallas probe
     kernel.
  4. ``jnp-scan`` — pure-XLA chunked scan; always available, the floor.

The three FLAT rungs are pinned bit-identical by the differential suite,
so a descent among them costs throughput, never correctness.  The L1L2
rung runs different (hierarchical, sequential-lane) semantics: it is
pinned bit-identical to its OWN jnp twin
(``core/hierarchy.replay_l1_over_l2``) and band-equivalent to the flat
rungs on hit ratio — a descent from it trades capacity-scaling throughput
for the flat semantics.  After each rung the final state is validated
(:mod:`repro.robust.invariants`; both tiers + exclusivity for the L1L2
rung); a dirty state triggers a descent — ``stale_served`` when the
violation is an expiry bit (``expired_hit``/``expired_resident``,
DESIGN.md §15: the rung's output may have served expired entries),
``validator_alarm`` otherwise.  The replay is functional (state in →
state out), so the next rung re-runs from the same initial state.  An
alarm on the last rung is unrecoverable and raises.

Configurations the Pallas backend refuses outright (sampled policies,
``ways > LANES``) skip both Pallas rungs with a ``backend_unsupported``
event rather than erroring.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import kway
from repro.core.kway import KWayConfig
from repro.robust import events
from repro.robust.invariants import (check_cache, check_hier, explain_cache,
                                     explain_hier, sketch_bits)

__all__ = ["RUNGS", "ReplayOutcome", "resilient_replay"]

#: fallback order, fastest first (the L1L2 rung is opt-in via
#: ``hierarchy``; without it the ladder starts at ``pallas-resident``)
RUNGS = ("pallas-resident-l1l2", "pallas-resident", "pallas-scan",
         "jnp-scan")

_COMPONENT = "ladder.replay"


@dataclasses.dataclass(frozen=True)
class ReplayOutcome:
    """Result of a supervised replay: the usual replay outputs plus which
    rung produced them and what was attempted along the way."""

    hits: jnp.ndarray            # int32 [steps]
    evs: jnp.ndarray             # int32 [steps]
    state: object                # KWayState (flat rungs) | HierState (l1l2)
    sketch: object               # TinyLFUState | None
    rung: str                    # the rung that produced the result
    attempts: tuple              # ((rung, "ok"|reason), ...) in order


def _default_validate(cfg: KWayConfig, tinylfu, vals_mode: str,
                      hierarchy=None):
    def validate(state, sketch) -> tuple[bool, str]:
        from repro.core import hierarchy as hier_mod
        if hierarchy is not None and isinstance(state, hier_mod.HierState):
            # L1L2 rung: both tiers + exclusivity must be clean.  check_hier
            # salts the L1 set seed and uses lazy expiry mode (the
            # hierarchy scrubs rows on touch, so untouched rows may retain
            # expired — unreachable — entries legitimately).
            rep = check_hier(cfg, hierarchy, state, vals_mode=vals_mode)
            if not rep.clean():
                return False, "; ".join(explain_hier(rep, limit=4))
            return True, ""
        rep = check_cache(cfg, state, vals_mode=vals_mode)
        if not rep.clean():
            return False, "; ".join(explain_cache(rep, limit=4))
        if tinylfu is not None and sketch is not None:
            if int(sketch_bits(tinylfu, sketch)) != 0:
                return False, "tinylfu sketch bounds violated"
        return True, ""
    return validate


def resilient_replay(cfg: KWayConfig, chunks, enabled, tinylfu=None,
                     state: kway.KWayState | None = None, *,
                     hierarchy=None, validate: bool = True,
                     validate_fn=None,
                     vals_mode: str = "key", ttls=None) -> ReplayOutcome:
    """Replay ``chunks``/``enabled`` (the ``router.pad_chunks`` layout,
    payload ``val == key``) down the degradation ladder.

    ``hierarchy`` (a ``HierarchyConfig`` with ``l1_sets > 0``) opts into
    the ``pallas-resident-l1l2`` top rung; its descent target is the flat
    ``pallas-resident`` rung (same trace, flat semantics).

    ``ttls`` (int32 [steps, B], optional) replays with per-request TTLs
    (DESIGN.md §15) on every rung; a rung whose output trips an expiry
    validator bit descends with reason ``stale_served``.  Excludes
    ``tinylfu``.

    ``validate_fn(state, sketch) -> (ok, why)`` overrides the invariant
    check per rung (the chaos tests use this to force alarms);
    ``validate=False`` skips post-rung validation entirely.
    """
    from repro.core import backend as backend_mod

    if ttls is not None:
        if tinylfu is not None:
            raise ValueError(
                "per-request TTLs and TinyLFU admission are mutually "
                "exclusive (the sketch has no expiry-aware semantics)")
        ttls = jnp.asarray(ttls, jnp.int32)
    if hierarchy is not None and not hierarchy.enabled:
        hierarchy = None
    if state is None:
        state = kway.make_cache(cfg, ttl=ttls is not None)
    check = None
    if validate:
        check = validate_fn or _default_validate(cfg, tinylfu, vals_mode,
                                                 hierarchy=hierarchy)

    attempts: list = []

    def _attempt(rung: str, run) -> ReplayOutcome | None:
        try:
            hits, evs, st, sk = run()
        except Exception as exc:  # noqa: BLE001 — any kernel fault descends
            attempts.append((rung, "kernel_failure"))
            events.record(
                component=_COMPONENT, reason="kernel_failure",
                fallback_from=rung, fallback_to=_next(rung),
                detail=f"{type(exc).__name__}: {exc}")
            return None
        if check is not None:
            ok, why = check(st, sk)
            if not ok:
                # an expiry-bit violation means the rung may have served
                # expired entries — name the descent for what it is
                reason = ("stale_served"
                          if "expired_hit" in why or "expired_resident" in why
                          else "validator_alarm")
                attempts.append((rung, reason))
                events.record(
                    component=_COMPONENT, reason=reason,
                    fallback_from=rung, fallback_to=_next(rung), detail=why)
                if rung == RUNGS[-1]:
                    raise RuntimeError(
                        f"replay state invalid on the last ladder rung "
                        f"{rung!r}: {why}")
                return None
        attempts.append((rung, "ok"))
        return ReplayOutcome(hits=hits, evs=evs, state=st, sketch=sk,
                             rung=rung, attempts=tuple(attempts))

    # ---- pallas rungs ----------------------------------------------------
    try:
        pallas = backend_mod.make_backend("pallas", cfg)
    except ValueError as exc:
        pallas = None
        if hierarchy is not None:
            attempts.append(("pallas-resident-l1l2", "backend_unsupported"))
        attempts.append(("pallas-resident", "backend_unsupported"))
        attempts.append(("pallas-scan", "backend_unsupported"))
        events.record(
            component=_COMPONENT, reason="backend_unsupported",
            fallback_from="pallas-resident", fallback_to="jnp-scan",
            detail=str(exc))

    if pallas is not None and hierarchy is not None:
        if tinylfu is not None:
            attempts.append(("pallas-resident-l1l2", "backend_unsupported"))
            events.record(
                component=_COMPONENT, reason="backend_unsupported",
                fallback_from="pallas-resident-l1l2",
                fallback_to="pallas-resident",
                detail="hierarchical replay does not support TinyLFU "
                       "admission")
        elif pallas.hier_fits(hierarchy):
            from repro.core import hierarchy as hier_mod
            from repro.kernels import ops

            hst = hier_mod.as_hier_state(cfg, hierarchy, state,
                                         ttl=ttls is not None)
            out = _attempt(
                "pallas-resident-l1l2",
                lambda: ops.replay_hierarchical(cfg, hierarchy, hst,
                                                chunks, enabled, ttls=ttls))
            if out is not None:
                return out
        else:
            attempts.append(("pallas-resident-l1l2", "vmem_budget"))
            events.record(
                component=_COMPONENT, reason="vmem_budget",
                fallback_from="pallas-resident-l1l2",
                fallback_to="pallas-resident",
                detail=(f"l1_sets={hierarchy.l1_sets} exceeds the resident "
                        f"budget even for the L1 tier; descending to the "
                        f"flat ladder"))

    if pallas is not None:
        if pallas.resident_fits():
            from repro.kernels import ops

            out = _attempt(
                "pallas-resident",
                lambda: ops.replay_resident(cfg, state, chunks, enabled,
                                            tinylfu=tinylfu, ttls=ttls))
            if out is not None:
                return out
        else:
            attempts.append(("pallas-resident", "vmem_budget"))
            events.record(
                component=_COMPONENT, reason="vmem_budget",
                fallback_from="pallas-resident", fallback_to="pallas-scan",
                detail=(f"num_sets={cfg.num_sets} exceeds resident budget; "
                        f"falling back to pallas-scan (the "
                        f"pallas-resident-l1l2 rung via "
                        f"HierarchyConfig(l1_sets>0) keeps a VMEM L1 over "
                        f"the HBM L2 at this capacity)"))

        out = _attempt(
            "pallas-scan",
            lambda: pallas.replay_scan(state, chunks, enabled,
                                       tinylfu=tinylfu, ttls=ttls))
        if out is not None:
            return out

    # ---- floor -----------------------------------------------------------
    jnp_be = backend_mod.make_backend("jnp", cfg)
    out = _attempt(
        "jnp-scan",
        lambda: jnp_be.replay(state, chunks, enabled, tinylfu=tinylfu,
                              ttls=ttls))
    if out is not None:
        return out
    raise RuntimeError(
        f"all ladder rungs failed for replay: {attempts}")


def _next(rung: str) -> str:
    i = RUNGS.index(rung)
    return RUNGS[i + 1] if i + 1 < len(RUNGS) else "none"
