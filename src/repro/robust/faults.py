"""Deterministic fault injector — every fault reproducible from
``(seed, site, step)``.

Chaos testing is only useful if a failing run can be replayed exactly, so
every injector here derives its randomness from
``numpy.random.default_rng([seed, step, crc32(site)])`` — no global RNG, no
process-dependent ``hash()`` (Python string hashing is salted per process).
Calling the same injector with the same arguments on the same state always
flips the same bit in the same lane.

Fault classes (ISSUE/DESIGN.md §13 fault model):

  * :func:`flip_bit` — single-event upset in a cache lane (``keys`` /
    ``fprint`` / ``vals`` / ``meta_a`` / ``meta_b``) of an occupied slot.
    Metadata flips are confined to the high bits (24..31) so the corruption
    is out-of-bounds *detectable* rather than a silent policy nudge.
  * :func:`inject_nan` — NaN dropped into a KV pool tensor.
  * :func:`double_book_page` — a slot's page-table entry redirected onto a
    private page already booked elsewhere (referential-integrity break).
  * :func:`stale_owner` — a private page's owner lane orphaned or pointed
    at an inactive slot.
  * :func:`crashed_save` — checkpoint written but never committed (kill
    between the leaf write and the atomic rename), via
    ``ckpt.manager.save(commit=False)``.
  * :func:`corrupt_trace` — request-stream faults: duplicated submits and
    poison keys (the reserved ``EMPTY_KEY`` sentinel and 0), which the
    stack must *survive*, not detect.
  * :func:`clock_skew` — the replay clock jumps forward past the nearest
    live deadline (DESIGN.md §15): entries that were valid a step ago are
    now expired-but-resident, the exact state a real cache reaches when a
    node's clock source steps.
  * :func:`stale_entry` — one occupied lane's deadline rewritten to its
    own last-touch timestamp, forging the "hit served at/after expiry"
    signature the ``expired_hit`` validator bit detects.
  * :func:`double_resident` — one L1-resident entry copied back into a
    free way of its L2 home set, breaking the hierarchy's tier-exclusivity
    invariant (the lost-update interleaving ``check_hier`` detects).

Injectors are host-side (they pull the arrays once); all return
``(mutated, FaultReport)`` so a test can assert exactly what was injected.
"""
from __future__ import annotations

import dataclasses
import zlib

import jax.numpy as jnp
import numpy as np

from repro.core.hashing import EMPTY_KEY
from repro.core.kway import NO_EXPIRY, KWayState

__all__ = ["FaultReport", "rng_for", "flip_bit", "inject_nan",
           "double_book_page", "stale_owner", "crashed_save",
           "corrupt_trace", "clock_skew", "stale_entry", "double_resident"]

#: cache-lane sites accepted by flip_bit
LANE_SITES = ("keys", "fprint", "vals", "meta_a", "meta_b")


@dataclasses.dataclass(frozen=True)
class FaultReport:
    """What was injected, precisely enough to assert detection against."""

    kind: str          # "bit_flip" | "nan" | "double_book" | ...
    site: str          # lane/tensor name or stream kind
    index: tuple       # coordinates of the mutated element(s)
    bit: int           # flipped bit position (-1 when not a bit flip)
    before: float      # prior value (as float for uniformity)
    after: float       # mutated value
    seed: int
    step: int


def rng_for(seed: int, site: str, step: int = 0) -> np.random.Generator:
    """The (seed, site, step) → RNG contract all injectors share."""
    return np.random.default_rng([seed, step, zlib.crc32(site.encode())])


def flip_bit(state: KWayState, site: str, seed: int,
             step: int = 0) -> tuple[KWayState, FaultReport]:
    """Flip one bit in an *occupied* lane of ``site``.  Raises
    ``ValueError`` on an empty cache (nothing to corrupt) or unknown site.
    """
    if site not in LANE_SITES:
        raise ValueError(f"flip_bit site must be one of {LANE_SITES}, "
                         f"got {site!r}")
    rng = rng_for(seed, site, step)
    keys = np.asarray(state.keys)
    occ = np.argwhere(keys != np.uint32(EMPTY_KEY))
    if occ.size == 0:
        raise ValueError("flip_bit: cache has no occupied lanes")
    s, w = (int(v) for v in occ[rng.integers(len(occ))])
    if site in ("meta_a", "meta_b"):
        bit = int(rng.integers(24, 32))   # out-of-bounds-detectable range
    else:
        bit = int(rng.integers(0, 32))
    arr = np.array(getattr(state, site))
    before = int(arr[s, w])
    arr[s, w] = np.asarray(
        np.uint32(arr[s, w]) ^ np.uint32(1 << bit)).astype(arr.dtype)
    report = FaultReport(kind="bit_flip", site=site, index=(s, w), bit=bit,
                         before=float(before), after=float(int(arr[s, w])),
                         seed=seed, step=step)
    return dataclasses.replace(state, **{site: jnp.asarray(arr)}), report


def clock_skew(state: KWayState, seed: int,
               step: int = 0) -> tuple[KWayState, FaultReport]:
    """Jump the replay clock forward onto a live deadline, turning the
    entry holding it (and every earlier deadline) expired-but-resident —
    the ``expired_resident`` validator bit must fire.  Requires a TTL
    state with at least one occupied lane whose deadline is still ahead
    of the clock; raises ``ValueError`` otherwise."""
    if state.expiry is None:
        raise ValueError("clock_skew needs a TTL state (expiry lane)")
    rng = rng_for(seed, "clock", step)
    keys = np.asarray(state.keys)
    exp = np.asarray(state.expiry)
    clock = int(state.clock)
    live = np.argwhere((keys != np.uint32(EMPTY_KEY))
                       & (exp != NO_EXPIRY) & (exp > clock))
    if live.size == 0:
        raise ValueError("clock_skew: no occupied lane with a live deadline")
    s, w = (int(v) for v in live[rng.integers(len(live))])
    after = int(exp[s, w])    # clock == deadline ⇒ exp <= clock ⇒ expired
    report = FaultReport(kind="clock_skew", site="clock", index=(s, w),
                         bit=-1, before=float(clock), after=float(after),
                         seed=seed, step=step)
    return dataclasses.replace(state, clock=jnp.int32(after)), report


def stale_entry(state: KWayState, seed: int,
                step: int = 0) -> tuple[KWayState, FaultReport]:
    """Rewrite one occupied lane's deadline to its own last-touch
    timestamp — the forged signature of a hit served on an expired entry,
    which the ``expired_hit`` validator bit detects (``meta_a >= exp``).
    Requires a TTL state; raises ``ValueError`` on an empty cache."""
    if state.expiry is None:
        raise ValueError("stale_entry needs a TTL state (expiry lane)")
    rng = rng_for(seed, "expiry", step)
    keys = np.asarray(state.keys)
    occ = np.argwhere(keys != np.uint32(EMPTY_KEY))
    if occ.size == 0:
        raise ValueError("stale_entry: cache has no occupied lanes")
    s, w = (int(v) for v in occ[rng.integers(len(occ))])
    exp = np.array(state.expiry)
    before = int(exp[s, w])
    after = int(np.asarray(state.meta_a)[s, w])
    exp[s, w] = after
    report = FaultReport(kind="stale_entry", site="expiry", index=(s, w),
                         bit=-1, before=float(before), after=float(after),
                         seed=seed, step=step)
    return dataclasses.replace(state, expiry=jnp.asarray(exp)), report


def double_resident(cfg, state, seed: int, step: int = 0):
    """Copy one L1-resident entry into a way of its L2 home set — the
    lost-update interleaving that breaks tier exclusivity, detected by
    ``check_hier``'s ``double_resident`` bit.  ``cfg`` is the L2
    ``KWayConfig``, ``state`` a ``HierState``; raises ``ValueError`` when
    no L1 entry is absent from its L2 home row (nothing to duplicate)."""
    from repro.core import hashing

    rng = rng_for(seed, "l2.keys", step)
    l1, l2 = state.l1, state.l2
    k1 = np.asarray(l1.keys)
    k2 = np.asarray(l2.keys)
    home = np.asarray(hashing.set_index(
        jnp.asarray(k1, jnp.uint32), cfg.num_sets, cfg.seed))
    occ = np.argwhere(k1 != np.uint32(EMPTY_KEY))
    cands = [(int(s), int(w)) for s, w in occ
             if int(k1[s, w]) not in k2[home[s, w]].tolist()]
    if not cands:
        raise ValueError(
            "double_resident: every L1 entry already shares its L2 home "
            "row (or L1 is empty)")
    s1, w1 = cands[rng.integers(len(cands))]
    s2 = int(home[s1, w1])
    row = k2[s2]
    empties = np.flatnonzero(row == np.uint32(EMPTY_KEY))
    w2 = int(empties[0]) if empties.size else int(rng.integers(cfg.ways))
    before = int(row[w2])

    def patch(arr, src):
        a = np.array(arr)
        a[s2, w2] = src
        return jnp.asarray(a)

    l2 = dataclasses.replace(
        l2,
        keys=patch(l2.keys, k1[s1, w1]),
        fprint=patch(l2.fprint, np.asarray(l1.fprint)[s1, w1]),
        vals=patch(l2.vals, np.asarray(l1.vals)[s1, w1]),
        meta_a=patch(l2.meta_a, np.asarray(l1.meta_a)[s1, w1]),
        meta_b=patch(l2.meta_b, np.asarray(l1.meta_b)[s1, w1]),
        expiry=(None if l2.expiry is None else
                patch(l2.expiry,
                      np.asarray(l1.expiry)[s1, w1]
                      if l1.expiry is not None else NO_EXPIRY)))
    report = FaultReport(kind="double_resident", site="l2.keys",
                         index=(s2, w2), bit=-1, before=float(before),
                         after=float(int(k1[s1, w1])), seed=seed, step=step)
    return dataclasses.replace(state, l2=l2), report


def inject_nan(pool, seed: int, step: int = 0,
               site: str = "pool_k") -> tuple[jnp.ndarray, FaultReport]:
    """Set one element of a (floating) KV pool tensor to NaN."""
    rng = rng_for(seed, site, step)
    arr = np.array(jnp.asarray(pool, jnp.float32))
    flat = int(rng.integers(arr.size))
    idx = np.unravel_index(flat, arr.shape)
    before = float(arr[idx])
    arr[idx] = np.nan
    report = FaultReport(kind="nan", site=site,
                         index=tuple(int(i) for i in idx), bit=-1,
                         before=before, after=float("nan"),
                         seed=seed, step=step)
    return jnp.asarray(arr).astype(jnp.asarray(pool).dtype), report


def _active_private_entries(ecfg, st) -> np.ndarray:
    """[n, 3] rows (slot, entry_index, page_id) of valid private-page
    page-table entries of active slots."""
    shared = ecfg.num_sets * ecfg.ways
    tbl = np.asarray(st.page_tbl)
    n_pages = np.asarray(st.n_pages)
    active = np.asarray(st.active)
    rows = []
    for slot in np.flatnonzero(active):
        for j in range(int(n_pages[slot])):
            pg = int(tbl[slot, j])
            if pg >= shared:
                rows.append((int(slot), j, pg))
    return np.asarray(rows, np.int64).reshape(-1, 3)


def double_book_page(ecfg, st, seed: int, step: int = 0):
    """Redirect one valid page-table entry onto a *different* private page
    that is already booked — two slots (or two rows of one slot) now claim
    the same private KV page.  Raises ``ValueError`` when fewer than two
    private bookings exist to collide."""
    rng = rng_for(seed, "page_tbl", step)
    entries = _active_private_entries(ecfg, st)
    if len(entries) < 2:
        raise ValueError("double_book_page: need >= 2 booked private pages")
    i, j = rng.choice(len(entries), size=2, replace=False)
    victim_slot, victim_entry, before_pg = (int(v) for v in entries[i])
    target_pg = int(entries[j][2])
    tbl = np.array(st.page_tbl)
    tbl[victim_slot, victim_entry] = target_pg
    report = FaultReport(kind="double_book", site="page_tbl",
                         index=(victim_slot, victim_entry), bit=-1,
                         before=float(before_pg), after=float(target_pg),
                         seed=seed, step=step)
    return dataclasses.replace(st, page_tbl=jnp.asarray(tbl)), report


def stale_owner(ecfg, st, seed: int, step: int = 0):
    """Corrupt the owner lane of one booked private page: orphan it
    (``owner = -1``) or point it at a different slot.  Raises
    ``ValueError`` when no private page is booked."""
    rng = rng_for(seed, "owner", step)
    owner = np.array(st.owner)
    booked = np.flatnonzero(owner >= 0)
    if booked.size == 0:
        raise ValueError("stale_owner: no booked private pages")
    p = int(booked[rng.integers(booked.size)])
    before = int(owner[p])
    wrong = int(rng.integers(-1, ecfg.max_batch))
    if wrong == before:   # ensure the fault is a fault
        wrong = -1 if before != -1 else (before + 1) % ecfg.max_batch
    owner[p] = wrong
    report = FaultReport(kind="stale_owner", site="owner", index=(p,),
                         bit=-1, before=float(before), after=float(wrong),
                         seed=seed, step=step)
    return dataclasses.replace(st, owner=jnp.asarray(owner)), report


def crashed_save(tree, root, step: int) -> str:
    """Simulate a crash between the checkpoint write and its commit: all
    leaves land on disk under ``step_N.tmp`` but the atomic rename never
    happens, so ``latest_step``/``restore`` must ignore it.  Returns the
    orphaned tmp path."""
    from repro.ckpt import manager
    return manager.save(root, step, tree, commit=False)


def corrupt_trace(trace, kind: str, seed: int, step: int = 0,
                  n: int = 4) -> tuple[np.ndarray, FaultReport]:
    """Request-stream faults the stack must survive.

    ``kind="dup"``: ``n`` entries overwritten with their predecessor
    (duplicate submits).  ``kind="poison"``: ``n`` entries set to reserved
    keys — alternating ``EMPTY_KEY`` (must be folded by ``sanitize_keys``,
    never stored raw) and 0.
    """
    if kind not in ("dup", "poison"):
        raise ValueError(f"corrupt_trace kind must be 'dup'|'poison', "
                         f"got {kind!r}")
    rng = rng_for(seed, f"trace.{kind}", step)
    out = np.array(trace, np.uint32)
    if out.size < 2:
        raise ValueError("corrupt_trace: trace too short")
    pos = rng.choice(np.arange(1, out.size), size=min(n, out.size - 1),
                     replace=False)
    if kind == "dup":
        out[pos] = out[pos - 1]
    else:
        out[pos] = np.where(np.arange(pos.size) % 2 == 0,
                            np.uint32(EMPTY_KEY), np.uint32(0))
    report = FaultReport(kind=kind, site="trace",
                         index=tuple(int(p) for p in np.sort(pos)), bit=-1,
                         before=float("nan"), after=float("nan"),
                         seed=seed, step=step)
    return out, report
