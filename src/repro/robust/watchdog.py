"""Bounded retry/backoff around host↔device sync points.

The serving engine and the showdown harness each have exactly one blocking
host↔device rendezvous per tick (``jax.device_get`` of the emitted-token
block; worker ``Future.result()`` joins).  A wedged device or a deadlocked
worker turns that into an unbounded hang — the one failure mode a test
suite cannot observe from the inside.  ``watch`` puts a timeout on the
*wait*, not on the work: the function runs once in a daemon thread, and on
each timeout expiry we record a ``sync_timeout`` degradation event and
re-wait with exponential backoff.  Only after the retry budget is spent do
we raise :class:`WatchdogTimeout`.

We deliberately never re-invoke ``fn`` — a device sync is not idempotent
(re-issuing a ``device_get`` against a wedged runtime just stacks a second
hang), so the retries extend patience, observably, instead of duplicating
work.
"""
from __future__ import annotations

import threading

from repro.robust import events

__all__ = ["WatchdogTimeout", "watch"]


class WatchdogTimeout(TimeoutError):
    """A watched call failed to complete within the retry/backoff budget."""


def watch(fn, *, timeout_s: float, retries: int = 2, backoff: float = 2.0,
          component: str = "watchdog"):
    """Run ``fn()`` once, waiting at most ``timeout_s`` (then ``timeout_s *
    backoff``, ... for ``retries`` extra waits).  Returns ``fn``'s result or
    re-raises its exception.  Each expired wait records a ``sync_timeout``
    event; exhausting the budget raises :class:`WatchdogTimeout`.

    ``timeout_s <= 0`` disables the watchdog and calls ``fn`` inline.
    """
    if timeout_s <= 0:
        return fn()

    box: dict = {}
    done = threading.Event()

    def _run() -> None:
        try:
            box["result"] = fn()
        except BaseException as exc:  # propagate to the caller below
            box["error"] = exc
        finally:
            done.set()

    thread = threading.Thread(target=_run, daemon=True,
                              name=f"watchdog:{component}")
    thread.start()

    wait = float(timeout_s)
    total = 0.0
    for attempt in range(retries + 1):
        if done.wait(wait):
            break
        total += wait
        events.record(
            component=component, reason="sync_timeout",
            detail=(f"wait {attempt + 1}/{retries + 1} expired after "
                    f"{wait:.3g}s (total {total:.3g}s)"))
        wait *= backoff
    else:
        raise WatchdogTimeout(
            f"{component}: no completion after {retries + 1} waits "
            f"({total:.3g}s total); device sync presumed wedged")

    if "error" in box:
        raise box["error"]
    return box["result"]
