"""Recovery paths: scrub-and-invalidate repair and engine checkpoint/restore.

Two ways back from a fault, matched to what the validator can see:

* **Scrub** (:func:`scrub`) — structural corruption inside the cache is
  repairable *in place* because limited associativity localizes damage: a
  bad lane can only poison its own set, so the repair resets the damaged
  sets to ``EMPTY_KEY`` (tallied as *forced evictions*) and the replay
  continues.  The cost is a bounded hit-ratio dip — re-inserting the
  scrubbed keys — which the chaos suite pins inside a committed band.

* **Checkpoint/restore** (:func:`save_engine` / :func:`restore_engine` /
  :class:`CheckpointedEngine`) — faults the validator cannot repair (a
  crashed tick, NaN KV pools) roll back to the last *committed* checkpoint
  written through ``ckpt/manager.py``'s atomic-rename protocol.  The
  device ``ServeState`` rides as the pytree; the host-side queues
  (waiting/running/finished requests) serialize into the manifest's
  ``extra`` — together they are the engine's whole replayable state, so a
  restored engine re-emits bit-identical tokens (greedy argmax, and seeded
  sampling is keyed on the checkpointed ``decode_steps`` counter).

:func:`validated_replay` fuses the cache validator into the replay scan at
a configurable cadence — the thing ``benchmarks/robustness.py`` times to
hold the <5% overhead target.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import EMPTY_KEY
from repro.core.kway import NO_EXPIRY, KWayConfig, KWayState
from repro.robust import events
from repro.robust.invariants import cache_lane_bits, hier_lane_bits

__all__ = ["scrub", "scrub_hier", "validated_replay", "save_engine",
           "restore_engine", "CheckpointedEngine"]


# ---------------------------------------------------------------------------
# scrub-and-invalidate
# ---------------------------------------------------------------------------

# Expiry violations (expired_hit / expired_resident) and double_resident
# are lane-local: an expired or duplicated entry cannot shadow its
# neighbours' probes, so the repair clears just that lane.  Everything
# else (flipped keys/fprints/meta) can poison the whole set's probe and
# is wiped set-granular.
_LANE_LOCAL_BITS = (1 << 6) | (1 << 7) | (1 << 8)


def _scrub_lanes(state: KWayState, lane_bits):
    """Clear violating lanes: set-granular for structural bits,
    lane-granular for the lane-local (expiry / double-resident) bits.
    Returns (state', forced_evictions)."""
    structural = lane_bits & jnp.uint32(~_LANE_LOCAL_BITS & 0xFFFFFFFF)
    bad_set = jnp.any(structural != 0, axis=1)[:, None]      # [S, 1]
    bad = bad_set | (lane_bits != 0)
    occupied = state.keys != EMPTY_KEY
    forced = jnp.sum((occupied & bad).astype(jnp.int32))
    state = dataclasses.replace(
        state,
        keys=jnp.where(bad, jnp.uint32(EMPTY_KEY), state.keys),
        fprint=jnp.where(bad, jnp.uint32(0), state.fprint),
        vals=jnp.where(bad, jnp.int32(0), state.vals),
        meta_a=jnp.where(bad, jnp.int32(0), state.meta_a),
        meta_b=jnp.where(bad, jnp.int32(0), state.meta_b),
        expiry=(None if state.expiry is None else
                jnp.where(bad, jnp.int32(NO_EXPIRY), state.expiry)),
    )
    return state, forced


@partial(jax.jit, static_argnums=0,
         static_argnames=("vals_mode", "expiry_mode"))
def scrub(cfg: KWayConfig, state: KWayState, *, vals_mode: str = "any",
          expiry_mode: str = "strict"):
    """Reset every violating region of the cache to empty.

    Structural corruption has set-granular blast radius (a flipped key can
    shadow probes of its whole set), so those repairs invalidate the set;
    expiry violations (``expired_hit``/``expired_resident``, DESIGN.md
    §15) are lane-local and clear just the lane, parking ``NO_EXPIRY`` in
    its expiry slot.  Returns ``(state', forced_evictions, lane_bits)``
    with ``forced_evictions`` counting the occupied lanes cleared and
    ``lane_bits`` the pre-repair violation bitmap.  The clock is untouched
    — scrubbed lanes look like cold sets, and policy metadata bounds stay
    valid for subsequent inserts.  A clean state passes through unchanged
    with a zero tally.
    """
    lane_bits = cache_lane_bits(cfg, state, vals_mode, expiry_mode)
    state, forced = _scrub_lanes(state, lane_bits)
    return state, forced, lane_bits


@partial(jax.jit, static_argnums=(0, 1), static_argnames=("vals_mode",))
def scrub_hier(cfg: KWayConfig, hier, state, *, vals_mode: str = "any"):
    """Scrub both tiers of a ``HierState``: the per-tier lane catalogue
    (lazy expiry mode — see ``invariants.hier_lane_bits``) plus the
    ``double_resident`` exclusivity bit, repaired by clearing the L1 copy
    (the L2 row keeps the entry, so no data is lost).  Returns
    ``(state', forced_evictions, (l1_bits, l2_bits))`` with the forced
    tally summed over both tiers."""
    l1_bits, l2_bits, dbits = hier_lane_bits(cfg, hier, state, vals_mode)
    l1, f1 = _scrub_lanes(state.l1, l1_bits | dbits)
    l2, f2 = _scrub_lanes(state.l2, l2_bits)
    state = dataclasses.replace(state, l1=l1, l2=l2)
    return state, f1 + f2, (l1_bits | dbits, l2_bits)


# ---------------------------------------------------------------------------
# replay with the validator fused into the scan
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _validated_replay_fn(cfg: KWayConfig, backend: str, interval: int,
                         tinylfu, vals_mode: str, ttl: bool = False):
    from repro.core import admission
    from repro.core.backend import make_backend

    be = make_backend(backend, cfg)

    def fn(state, chunks, enabled, sketch, ttls):
        def step(carry, xs):
            cache, sk, alarm = carry
            if ttl:
                i, keys, en, tt = xs
            else:
                i, keys, en = xs
            admit = None
            if tinylfu is not None:
                sk = admission.record(tinylfu, sk, keys, enabled=en)
                vk, vv = be.peek_victims(cache, keys)
                admit = admission.admit(tinylfu, sk, keys, vk, vv)
            cache, hit, _, _, ev = be.access(
                cache, keys, keys.astype(jnp.int32), admit, en,
                **({"ttls": tt} if ttl else {}))
            bits = jax.lax.cond(
                i % interval == 0,
                lambda c: jnp.bitwise_or.reduce(
                    cache_lane_bits(cfg, c, vals_mode), axis=(0, 1)),
                lambda c: jnp.uint32(0),
                cache)
            return (cache, sk, alarm | bits), (
                jnp.sum(hit.astype(jnp.int32)), jnp.sum(ev.astype(jnp.int32)))

        steps = chunks.shape[0]
        idx = jnp.arange(steps, dtype=jnp.int32)
        xs = (idx, chunks, enabled) + ((ttls,) if ttl else ())
        (state, sk, alarm), (hits, evs) = jax.lax.scan(
            step, (state, sketch, jnp.uint32(0)), xs)
        return hits, evs, state, sk, alarm

    return jax.jit(fn)


def validated_replay(cfg: KWayConfig, chunks, enabled, *,
                     backend: str = "jnp", interval: int = 1, tinylfu=None,
                     state: KWayState | None = None, vals_mode: str = "key",
                     ttls=None):
    """Chunked-scan replay with the invariant check fused in every
    ``interval`` chunks — the violation word rides the scan carry, so
    validation adds zero host syncs.

    ``ttls`` (int32 [steps, B], optional) replays with per-request TTLs
    (DESIGN.md §15) — the fused check then also covers the expiry bits
    (``expired_hit``/``expired_resident``), which must stay silent on a
    healthy replay (the eager scrub enforces ``occupied ⇒ deadline >
    clock``).  Excludes ``tinylfu``.

    Returns ``(hits [steps], evs [steps], state', sketch'|None,
    alarm_bits uint32[])``; ``alarm_bits != 0`` means some checked chunk
    left the cache structurally invalid.  Jitted once per
    ``(cfg, backend, interval, tinylfu, vals_mode, ttl)``.
    """
    from repro.core import admission, kway

    if interval < 1:
        raise ValueError(f"interval must be >= 1, got {interval}")
    if ttls is not None and tinylfu is not None:
        raise ValueError(
            "per-request TTLs and TinyLFU admission are mutually exclusive")
    if state is None:
        state = kway.make_cache(cfg, ttl=ttls is not None)
    sketch = (admission.make_sketch(tinylfu) if tinylfu is not None
              else jnp.zeros((), jnp.int32))
    fn = _validated_replay_fn(cfg, backend, interval, tinylfu, vals_mode,
                              ttls is not None)
    hits, evs, state, sk, alarm = fn(
        state, jnp.asarray(chunks, jnp.uint32),
        jnp.asarray(enabled, jnp.bool_), sketch,
        (jnp.zeros((), jnp.int32) if ttls is None
         else jnp.asarray(ttls, jnp.int32)))
    return hits, evs, state, (sk if tinylfu is not None else None), alarm


# ---------------------------------------------------------------------------
# engine checkpoint / restore
# ---------------------------------------------------------------------------

_REQ_FIELDS = ("rid", "max_new", "generated", "pos", "prefix_hits",
               "prefix_lookups", "done")


def _pack_request(req) -> dict:
    d = {f: getattr(req, f) for f in _REQ_FIELDS}
    d["prompt"] = [int(t) for t in np.asarray(req.prompt)]
    d["generated"] = [int(t) for t in req.generated]
    return d


def _unpack_request(d):
    from repro.serve.engine import Request

    return Request(
        rid=int(d["rid"]), prompt=np.asarray(d["prompt"], np.int32),
        max_new=int(d["max_new"]), generated=list(d["generated"]),
        pos=int(d["pos"]), prefix_hits=int(d["prefix_hits"]),
        prefix_lookups=int(d["prefix_lookups"]), done=bool(d["done"]))


def _require_jitted(eng, what: str):
    if not eng.ecfg.jitted:
        raise ValueError(
            f"{what} supports the jitted engine only (its whole device "
            "state is the ServeState pytree); the host-loop engine keeps "
            "state in Python objects — set EngineConfig(jitted=True)")


def save_engine(eng, root: str, step: int, *, keep_last: int = 3,
                commit: bool = True) -> str:
    """Checkpoint a jitted engine: ``ServeState`` as the pytree, host
    queues in the manifest.  ``commit=False`` is the chaos hook — leaves
    land on disk but the atomic rename is skipped, simulating a crash
    mid-tick between write and commit."""
    _require_jitted(eng, "save_engine")
    from repro.ckpt import manager

    extra = {
        "kind": "repro.serve.engine",
        "next_rid": eng._next_rid,
        "waiting": [_pack_request(r) for r in eng.waiting],
        "running": [_pack_request(r) for r in eng.running.values()],
        "finished": [_pack_request(r) for r in eng.finished.values()],
    }
    return manager.save(root, step, eng._sstate, extra=extra,
                        keep_last=keep_last, commit=commit)


def restore_engine(eng, root: str, step: int | None = None) -> int:
    """Restore a jitted engine from the last *committed* checkpoint (or an
    explicit ``step``).  Uncommitted ``.tmp`` writes are ignored — that is
    the crash-mid-tick guarantee.  Returns the step restored."""
    _require_jitted(eng, "restore_engine")
    from repro.ckpt import manager

    if step is None:
        step = manager.latest_step(root)
        if step is None:
            raise ValueError(
                f"restore_engine: no committed checkpoint under {root!r} "
                "(an uncommitted .tmp from a crashed save does not count)")
    tree, extra = manager.restore(root, step, eng._sstate)
    if extra.get("kind") != "repro.serve.engine":
        raise ValueError(
            f"checkpoint step {step} under {root!r} is not an engine "
            f"checkpoint (kind={extra.get('kind')!r})")
    eng._sstate = tree
    eng._next_rid = int(extra["next_rid"])
    eng.waiting = [_unpack_request(d) for d in extra["waiting"]]
    eng.running = {r.rid: r for r in
                   (_unpack_request(d) for d in extra["running"])}
    eng.finished = {r.rid: r for r in
                    (_unpack_request(d) for d in extra["finished"])}
    return step


class CheckpointedEngine:
    """Checkpoint-cadence wrapper: every ``every`` ticks the engine state
    is committed under ``root``.  On any tick the process can die; restart
    with :func:`restore_engine` (or ``.restore()``) and continue — the
    chaos suite pins the resumed token streams bit-identical.

    Cadence cost is one host→disk serialization of the ServeState pytree
    per ``every`` ticks (the KV pools dominate; see DESIGN.md §13), so
    ``every`` trades recovery distance against throughput.
    """

    def __init__(self, eng, root: str, *, every: int = 1,
                 keep_last: int = 3):
        _require_jitted(eng, "CheckpointedEngine")
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        self.eng = eng
        self.root = root
        self.every = every
        self.keep_last = keep_last
        self.tick = 0
        self.last_committed: int | None = None

    def step(self) -> None:
        self.eng.step()
        self.tick += 1
        if self.tick % self.every == 0:
            save_engine(self.eng, self.root, self.tick,
                        keep_last=self.keep_last)
            self.last_committed = self.tick

    def run(self, max_steps: int = 10_000):
        steps = 0
        while ((self.eng.waiting or self.eng._any_running())
               and steps < max_steps):
            self.step()
            steps += 1
        return self.eng.finished

    def restore(self, step: int | None = None) -> int:
        step = restore_engine(self.eng, self.root, step)
        self.tick = step
        self.last_committed = step
        events.record(component="engine.checkpoint", reason="restore",
                      detail=f"resumed from committed tick {step}")
        return step
