"""Jittable structural invariants over cache and serving state.

The k-way cache is dense, fixed-shape state with explicit metadata — the
paper's simplicity argument — which means "is this state well-formed?" is
one vectorized pass, not a pointer walk.  This module encodes the invariant
catalogue (DESIGN.md §13) as pure functions returning **violation
bitmaps**: a ``uint32`` word per lane/slot/page whose bits name the failed
checks, plus an OR-reduced scalar so a replay loop can carry "anything
wrong yet?" as one word.  Host-side ``explain_*`` functions turn a report
into strings naming set/way/slot/page and the violated invariant.

Everything here is read-only and jit-safe; the scrub repair that *acts* on
a report lives in :mod:`repro.robust.recovery`.

Invariants over ``KWayState`` (per lane, given the frozen ``core/hashing``
contract):

  * ``fprint_mismatch`` — an occupied lane's stored fingerprint must equal
    ``hashing.fingerprint(key)`` (soa layout only; aos keeps the lane
    unused);
  * ``empty_lane_dirty`` — an ``EMPTY_KEY`` lane must be fully zeroed
    (fprint, vals, meta_a, meta_b): inserts never un-occupy a lane, so a
    dirty empty lane is corruption, not wear;
  * ``wrong_set`` — an occupied key must live in ``set_index(key)``'s row;
  * ``dup_key_in_set`` — a key may occupy at most one way of its set;
  * ``meta_bounds`` — policy metadata must be in range (e.g. LRU/FIFO
    timestamps in ``[0, clock)``, LFU counts in ``[1, clock]``, RANDOM
    metadata identically zero, Hyperbolic ``t0`` before ``clock``);
  * ``vals_convention`` — optional payload check: replay paths store
    ``val == key`` (``vals_mode="key"``), the serving engine stores
    ``val == set*ways + way`` (``vals_mode="slot"``);
  * ``expired_hit`` — TTL states only (DESIGN.md §15): an occupied lane's
    last-touch timestamp must precede its deadline.  The scrub-before-probe
    discipline reclaims every lane whose deadline falls inside the next
    batch window *before* any query probes it, so a timestamp at or past
    the deadline proves an expired entry was served as a hit;
  * ``expired_resident`` — TTL states only, ``expiry_mode="strict"``: an
    occupied lane's deadline must exceed the clock.  Eagerly-scrubbed
    replays (the flat jnp/pallas/sharded paths) uphold this after every
    batch; the hierarchy scrubs lazily — only rows a chunk touches — so
    its tiers legitimately retain expired entries in untouched rows and
    are checked with ``expiry_mode="lazy"`` (the bit is skipped; an
    expired entry there is still unreachable, because any access fetching
    the row scrubs it first);
  * an empty lane must park the ``NO_EXPIRY`` sentinel in the expiry lane
    (folded into ``empty_lane_dirty``).

Invariants over ``HierState`` (``check_hier``): both tiers get the full
per-lane catalogue above (the L1 routes with ``seed ^ L1_SEED_SALT``, tiers
use ``expiry_mode="lazy"``), plus

  * ``double_resident`` — L1/L2 exclusivity: an L1-resident key must not
    also occupy its L2 home set (promotion removes from L2, demotion
    removes from L1; a key in both tiers means a lost-update interleaving).

Invariants over the TinyLFU sketch:

  * ``additions`` in ``[0, sample)`` — ``record`` ages at ``sample``;
  * ``popcount(door) <= additions`` — each addition sets at most one door
    bit and aging clears both.

Invariants over ``ServeState`` (slot/queue referential integrity):

  * per slot: ``pos``/``n_gen``/``n_pages`` ranges, ``pos`` covered by the
    allocated pages, page-table entries in ``[0, total_pages)`` and
    pairwise distinct within the valid prefix;
  * per private page: booked by at most one slot, the booking slot matches
    the ``owner`` lane, owners point at active slots and stay in range;
  * global: no NaN in the KV pools, stat counters non-negative with
    ``prefix_hits <= prefix_lookups``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.hashing import EMPTY_KEY
from repro.core.kway import NO_EXPIRY, KWayConfig, KWayState
from repro.core.policies import Policy

# ---------------------------------------------------------------------------
# bit catalogues — explain_* and the chaos tests key off these names
# ---------------------------------------------------------------------------

CACHE_CHECKS = {
    0: "fprint_mismatch",
    1: "empty_lane_dirty",
    2: "wrong_set",
    3: "dup_key_in_set",
    4: "meta_bounds",
    5: "vals_convention",
    6: "expired_hit",
    7: "expired_resident",
    8: "double_resident",
}
CACHE_GLOBAL_CHECKS = {0: "clock_negative"}
SKETCH_CHECKS = {0: "sketch_additions_range", 1: "sketch_door_popcount"}
SLOT_CHECKS = {
    0: "pos_range",
    1: "page_accounting",
    2: "page_table_range",
    3: "gen_range",
    4: "dup_page_in_row",
}
PAGE_CHECKS = {
    0: "double_booked",
    1: "owner_mismatch",
    2: "owner_inactive",
    3: "owner_range",
}
SERVE_GLOBAL_CHECKS = {0: "nan_in_kv", 1: "counter_bounds"}


def _bit(cond: jnp.ndarray, i: int) -> jnp.ndarray:
    return jnp.where(cond, jnp.uint32(1 << i), jnp.uint32(0))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CacheReport:
    """Violation bitmap over one ``KWayState``."""

    lane_bits: jnp.ndarray    # uint32 [S, k] — CACHE_CHECKS bits per lane
    global_bits: jnp.ndarray  # uint32 []     — CACHE_GLOBAL_CHECKS bits
    bits: jnp.ndarray         # uint32 []     — OR of everything

    def clean(self) -> bool:
        return int(jax.device_get(self.bits)) == 0


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ServeReport:
    """Violation bitmap over one ``ServeState`` (cache report included)."""

    cache: CacheReport
    slot_bits: jnp.ndarray    # uint32 [max_slots] — SLOT_CHECKS bits
    page_bits: jnp.ndarray    # uint32 [private_pages] — PAGE_CHECKS bits
    global_bits: jnp.ndarray  # uint32 [] — SERVE_GLOBAL_CHECKS+SKETCH bits
    bits: jnp.ndarray         # uint32 [] — OR of everything

    def clean(self) -> bool:
        return int(jax.device_get(self.bits)) == 0


# ---------------------------------------------------------------------------
# cache invariants
# ---------------------------------------------------------------------------

def cache_lane_bits(cfg: KWayConfig, state: KWayState,
                    vals_mode: str = "any",
                    expiry_mode: str = "strict") -> jnp.ndarray:
    """Per-lane violation bits, uint32 [S, k].  Pure traced function —
    usable inside a replay scan (``recovery.validated_replay``) as well as
    under the jitted ``check_cache`` wrapper.

    Expiry checks run only when the state carries an expiry lane;
    ``expiry_mode="lazy"`` skips ``expired_resident`` for lazily-scrubbed
    states (the hierarchy tiers)."""
    if vals_mode not in ("any", "key", "slot"):
        raise ValueError(
            f"vals_mode must be 'any', 'key' or 'slot', got {vals_mode!r}")
    if expiry_mode not in ("strict", "lazy"):
        raise ValueError(
            f"expiry_mode must be 'strict' or 'lazy', got {expiry_mode!r}")
    keys, fpr = state.keys, state.fprint
    s, k = cfg.num_sets, cfg.ways
    occupied = keys != EMPTY_KEY
    empty = ~occupied
    bits = jnp.zeros((s, k), jnp.uint32)

    if cfg.layout == "soa":
        bits |= _bit(occupied & (fpr != hashing.fingerprint(keys)), 0)
        empty_dirty = empty & ((fpr != 0) | (state.vals != 0)
                               | (state.meta_a != 0) | (state.meta_b != 0))
    else:  # aos: the fprint lane is unused by the probe — exclude it
        empty_dirty = empty & ((state.vals != 0) | (state.meta_a != 0)
                               | (state.meta_b != 0))
    bits |= _bit(empty_dirty, 1)

    home = hashing.set_index(keys, s, cfg.seed)
    rows = jnp.arange(s, dtype=jnp.int32)[:, None]
    bits |= _bit(occupied & (home != rows), 2)

    # duplicate key within a set: O(k^2) pairwise compare per row (k is
    # small by design — that is the paper)
    same = (keys[:, :, None] == keys[:, None, :]) \
        & occupied[:, :, None] & occupied[:, None, :]
    bits |= _bit(jnp.sum(same, axis=-1) > 1, 3)

    clk = state.clock
    a, b = state.meta_a, state.meta_b
    if cfg.policy in (Policy.LRU, Policy.FIFO):
        bad_meta = (a < 0) | (a >= clk) | (b != 0)
    elif cfg.policy == Policy.LFU:
        bad_meta = (a < 1) | (a > clk) | (b != 0)
    elif cfg.policy == Policy.RANDOM:
        bad_meta = (a != 0) | (b != 0)
    elif cfg.policy == Policy.HYPERBOLIC:
        bad_meta = (a < 1) | (a > clk) | (b < 0) | (b >= clk)
    else:  # pragma: no cover - Policy is a closed enum
        raise ValueError(f"unknown policy {cfg.policy}")
    bits |= _bit(occupied & bad_meta, 4)

    if vals_mode == "key":
        bits |= _bit(occupied & (state.vals.astype(jnp.uint32) != keys), 5)
    elif vals_mode == "slot":
        slot_id = rows * jnp.int32(k) + jnp.arange(k, dtype=jnp.int32)[None]
        bits |= _bit(occupied & (state.vals != slot_id), 5)

    if state.expiry is not None:
        exp = state.expiry
        # empty lanes park the NO_EXPIRY sentinel — same class of wear as
        # a dirty fprint/meta lane, so fold into empty_lane_dirty
        bits |= _bit(empty & (exp != NO_EXPIRY), 1)
        if cfg.policy in (Policy.LRU, Policy.FIFO):
            # meta_a is the last-touch (LRU) / insert (FIFO) timestamp: a
            # stamp at or past the deadline proves a hit was served on an
            # already-expired entry (scrub-before-probe forbids that)
            bits |= _bit(occupied & (exp != NO_EXPIRY) & (a >= exp), 6)
        if expiry_mode == "strict":
            bits |= _bit(occupied & (exp <= clk), 7)
    return bits


def _cache_report(cfg: KWayConfig, state: KWayState, vals_mode: str,
                  expiry_mode: str = "strict") -> CacheReport:
    lane_bits = cache_lane_bits(cfg, state, vals_mode, expiry_mode)
    gbits = _bit(state.clock < 0, 0)
    bits = jnp.bitwise_or(jnp.bitwise_or.reduce(lane_bits, axis=(0, 1)),
                          gbits)
    return CacheReport(lane_bits=lane_bits, global_bits=gbits, bits=bits)


@partial(jax.jit, static_argnums=0,
         static_argnames=("vals_mode", "expiry_mode"))
def check_cache(cfg: KWayConfig, state: KWayState, *,
                vals_mode: str = "any",
                expiry_mode: str = "strict") -> CacheReport:
    """Validate one cache state.  ``vals_mode`` selects the payload
    convention to enforce: ``"key"`` for the replay paths (val == key),
    ``"slot"`` for the serving engine (val == landing slot id), ``"any"``
    to skip the payload check.  ``expiry_mode="lazy"`` relaxes the
    ``expired_resident`` check for lazily-scrubbed states."""
    return _cache_report(cfg, state, vals_mode, expiry_mode)


def sketch_bits(cfg, st) -> jnp.ndarray:
    """TinyLFU sketch violation bits (SKETCH_CHECKS), uint32 scalar.
    ``cfg`` is a ``TinyLFUConfig``, ``st`` a ``TinyLFUState``."""
    bad_add = (st.additions < 0) | (st.additions >= cfg.sample)
    pop = jnp.sum(jax.lax.population_count(st.door).astype(jnp.int32))
    return _bit(bad_add, 0) | _bit(pop > st.additions, 1)


# ---------------------------------------------------------------------------
# hierarchy invariants
# ---------------------------------------------------------------------------

def unpack_tier(packed: jnp.ndarray, ways: int, clock) -> KWayState:
    """One packed hierarchy row array (int32 [S, ROW_W], the
    ``core/hierarchy`` section layout) -> a ``KWayState`` view in the
    uint32 key/fprint domain with the expiry lane attached — exactly what
    ``cache_lane_bits`` consumes.  The mailbox section and way padding are
    dropped."""
    from repro.core.hierarchy import _unpack_expiry, _unpack_lanes

    k, f, v, a, b = _unpack_lanes(packed, ways)
    return KWayState(keys=k.astype(jnp.uint32), fprint=f.astype(jnp.uint32),
                     vals=v, meta_a=a, meta_b=b,
                     clock=jnp.asarray(clock, jnp.int32),
                     expiry=_unpack_expiry(packed, ways))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HierReport:
    """Violation bitmap over one ``HierState`` (both tiers + exclusivity).

    ``double_bits`` carries the ``double_resident`` bit per L1 lane; the
    tier reports use ``expiry_mode="lazy"`` (the hierarchy scrubs rows on
    touch, so untouched rows legitimately retain expired entries)."""

    l1: CacheReport
    l2: CacheReport
    double_bits: jnp.ndarray  # uint32 [S1, l1_ways] — bit 8 per L1 lane
    bits: jnp.ndarray         # uint32 []            — OR of everything

    def clean(self) -> bool:
        return int(jax.device_get(self.bits)) == 0


def hier_lane_bits(cfg: KWayConfig, hier, state, vals_mode: str = "any"):
    """Per-lane violation bits for both hierarchy tiers — pure traced
    function shared by ``check_hier`` and ``recovery.scrub_hier``.

    Returns ``(l1_bits uint32 [S1, l1_ways], l2_bits uint32 [S, k],
    double_bits uint32 [S1, l1_ways])``; the ``double_resident`` bit is
    reported on the L1 lane holding the duplicated key (tiers are checked
    with ``expiry_mode="lazy"`` — lazy row scrub keeps expired entries in
    untouched rows legitimately)."""
    from repro.core.hierarchy import L1_SEED_SALT

    l1_cfg = dataclasses.replace(
        cfg, num_sets=hier.l1_sets, ways=hier.l1_ways,
        seed=cfg.seed ^ L1_SEED_SALT)
    l1_bits = cache_lane_bits(l1_cfg, state.l1, vals_mode, "lazy")
    l2_bits = cache_lane_bits(cfg, state.l2, vals_mode, "lazy")

    keys1 = state.l1.keys
    occ = keys1 != EMPTY_KEY
    home = hashing.set_index(keys1, cfg.num_sets, cfg.seed)
    rows2 = state.l2.keys[home]             # [S1, l1_ways, ways]
    dup = occ & jnp.any(rows2 == keys1[..., None], axis=-1)
    return l1_bits, l2_bits, _bit(dup, 8)


@partial(jax.jit, static_argnums=(0, 1), static_argnames=("vals_mode",))
def check_hier(cfg: KWayConfig, hier, state, *,
               vals_mode: str = "any") -> HierReport:
    """Validate one ``HierState``: the full per-lane catalogue on both
    tiers (the L1 routes with ``seed ^ L1_SEED_SALT``), plus L1/L2
    exclusivity — an L1-resident key occupying its L2 home set too is a
    ``double_resident`` violation (promotion removes from L2, demotion
    removes from L1)."""
    l1_bits, l2_bits, dbits = hier_lane_bits(cfg, hier, state, vals_mode)
    gb1 = _bit(state.l1.clock < 0, 0)
    gb2 = _bit(state.l2.clock < 0, 0)
    l1 = CacheReport(
        lane_bits=l1_bits, global_bits=gb1,
        bits=jnp.bitwise_or.reduce(l1_bits, axis=(0, 1)) | gb1)
    l2 = CacheReport(
        lane_bits=l2_bits, global_bits=gb2,
        bits=jnp.bitwise_or.reduce(l2_bits, axis=(0, 1)) | gb2)
    bits = l1.bits | l2.bits | jnp.bitwise_or.reduce(dbits, axis=(0, 1))
    return HierReport(l1=l1, l2=l2, double_bits=dbits, bits=bits)


def explain_hier(report: HierReport, limit: int = 32) -> list[str]:
    """Human-readable violations for a HierReport: both tier reports
    prefixed with their tier name, plus the double-resident lanes."""
    out = [f"l1 {s}" for s in explain_cache(report.l1, limit=limit)]
    out += [f"l2 {s}" for s in explain_cache(report.l2, limit=limit)]
    dbits = np.asarray(jax.device_get(report.double_bits))
    for s, w in np.argwhere(dbits != 0)[:limit]:
        out.append(f"l1 set {int(s)} way {int(w)}: double_resident")
    return out


# ---------------------------------------------------------------------------
# serving-state invariants
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnums=(0,))
def check_serve(ecfg, st) -> ServeReport:
    """Validate one ``ServeState`` against its (static) ``EngineConfig``.

    Covers the prefix cache (vals_mode="slot"), the TinyLFU sketch when
    enabled, page-table/owner referential integrity, per-slot counters and
    the KV pools.
    """
    from repro.core import admission

    kcfg = KWayConfig(num_sets=ecfg.num_sets, ways=ecfg.ways,
                      policy=ecfg.policy)
    n_slots = ecfg.max_batch
    n_priv = ecfg.private_pages
    shared = kcfg.capacity
    total = shared + n_priv
    page = ecfg.page
    pps = ecfg.max_seq // page

    cache = _cache_report(kcfg, st.kstate, "slot")

    # ---- per slot --------------------------------------------------------
    active = st.active
    sbits = jnp.zeros((n_slots,), jnp.uint32)
    sbits |= _bit(active & ((st.pos < 1) | (st.pos > ecfg.max_seq)), 0)
    sbits |= _bit(active & ((st.n_pages < 0) | (st.n_pages > pps)
                            | (st.pos > st.n_pages * page)), 1)
    valid_e = active[:, None] & (jnp.arange(pps, dtype=jnp.int32)[None, :]
                                 < st.n_pages[:, None])
    in_range = (st.page_tbl >= 0) & (st.page_tbl < total)
    sbits |= _bit(jnp.any(valid_e & ~in_range, axis=1), 2)
    sbits |= _bit(active & ((st.n_gen < 1)
                            | (st.n_gen > st.max_new + 1)), 3)
    same_pg = (st.page_tbl[:, :, None] == st.page_tbl[:, None, :]) \
        & valid_e[:, :, None] & valid_e[:, None, :]
    sbits |= _bit(jnp.any(jnp.sum(same_pg, axis=-1) > 1, axis=1), 4)

    # ---- per private page ------------------------------------------------
    # refcount over the valid prefixes of active slots' page tables; shared
    # pages are legitimately multi-booked (that is the prefix cache), the
    # private region must be exclusive.
    is_priv = valid_e & (st.page_tbl >= shared) & in_range
    pidx = jnp.where(is_priv, st.page_tbl - shared, n_priv)
    counts = jnp.zeros((n_priv,), jnp.int32).at[pidx].add(1, mode="drop")
    slot_ids = jnp.broadcast_to(
        jnp.arange(n_slots, dtype=jnp.int32)[:, None], pidx.shape)
    ref_slot = jnp.full((n_priv,), -1, jnp.int32).at[pidx].max(
        slot_ids, mode="drop")
    owner = st.owner
    pbits = jnp.zeros((n_priv,), jnp.uint32)
    pbits |= _bit(counts > 1, 0)
    owned = owner >= 0
    pbits |= _bit(((counts == 1) & (owner != ref_slot))
                  | (owned & (counts == 0)), 1)
    owner_c = jnp.clip(owner, 0, n_slots - 1)
    pbits |= _bit(owned & ~active[owner_c], 2)
    pbits |= _bit((owner < -1) | (owner >= n_slots), 3)

    # ---- global ----------------------------------------------------------
    gbits = _bit(jnp.any(jnp.isnan(st.pool_k.astype(jnp.float32)))
                 | jnp.any(jnp.isnan(st.pool_v.astype(jnp.float32))), 0)
    ctr_bad = (st.prefix_hits < 0) | (st.prefix_lookups < 0) \
        | (st.prefix_hits > st.prefix_lookups) | (st.evictions < 0) \
        | (st.prefills < 0) | (st.decode_steps < 0)
    gbits |= _bit(ctr_bad, 1)
    if ecfg.tinylfu:
        sk_cfg = admission.for_capacity(kcfg.capacity)
        gbits |= sketch_bits(sk_cfg, st.sketch) << jnp.uint32(8)

    bits = cache.bits \
        | jnp.bitwise_or.reduce(sbits) \
        | jnp.bitwise_or.reduce(pbits) | gbits
    return ServeReport(cache=cache, slot_bits=sbits, page_bits=pbits,
                       global_bits=gbits, bits=bits)


# ---------------------------------------------------------------------------
# host-side explain
# ---------------------------------------------------------------------------

def _named(bits: int, catalogue: dict, shift: int = 0) -> list[str]:
    return [name for i, name in catalogue.items()
            if bits & (1 << (i + shift))]


def explain_cache(report: CacheReport, limit: int = 32) -> list[str]:
    """Turn a cache report into human-readable strings naming set/way and
    the violated invariants.  Host-side only (pulls the bitmaps once)."""
    lane_bits, gbits = jax.device_get((report.lane_bits, report.global_bits))
    lane_bits = np.asarray(lane_bits)
    out = [f"cache: {n}" for n in _named(int(gbits), CACHE_GLOBAL_CHECKS)]
    for s, w in np.argwhere(lane_bits != 0)[:limit]:
        names = _named(int(lane_bits[s, w]), CACHE_CHECKS)
        out.append(f"set {int(s)} way {int(w)}: {'|'.join(names)}")
    n_bad = int((lane_bits != 0).sum())
    if n_bad > limit:
        out.append(f"... and {n_bad - limit} more corrupted lanes")
    return out


def explain_serve(report: ServeReport, limit: int = 32) -> list[str]:
    """Human-readable violations for a ServeReport — slot/page/global plus
    the embedded cache report."""
    out = explain_cache(report.cache, limit=limit)
    slot_bits, page_bits, gbits = jax.device_get(
        (report.slot_bits, report.page_bits, report.global_bits))
    for (i,) in np.argwhere(np.asarray(slot_bits) != 0)[:limit]:
        names = _named(int(slot_bits[i]), SLOT_CHECKS)
        out.append(f"slot {int(i)}: {'|'.join(names)}")
    for (p,) in np.argwhere(np.asarray(page_bits) != 0)[:limit]:
        names = _named(int(page_bits[p]), PAGE_CHECKS)
        out.append(f"private page {int(p)}: {'|'.join(names)}")
    g = int(gbits)
    out.extend(f"serve: {n}" for n in _named(g, SERVE_GLOBAL_CHECKS))
    out.extend(f"serve: {n}" for n in _named(g, SKETCH_CHECKS, shift=8))
    return out
