"""Fault tolerance for the k-way serving stack (DESIGN.md §13).

The paper's pitch — limited associativity makes concurrent caches simple —
is also what makes them *defensible*: the whole cache is a handful of dense
``[sets, ways]`` lanes with explicit metadata, so structural corruption is
cheap to detect (one vectorized pass) and cheap to repair (reset the
damaged sets and keep serving).  This package wires that observation
through the stack:

  * :mod:`repro.robust.invariants` — jittable structural validators over
    ``KWayState`` (including the TTL-expiry bits of DESIGN.md §15 and the
    two-tier + exclusivity checks for ``HierState``), the TinyLFU sketch
    and the serving engine's ``ServeState``, returning violation bitmaps
    plus a host-side ``explain()`` that names set/way/slot/page;
  * :mod:`repro.robust.faults` — a deterministic fault injector (seeded
    bit-flips, NaN injection, duplicate/stale slot entries, crash-mid-
    commit, request-stream faults), every fault reproducible from
    ``(seed, site, step)``;
  * :mod:`repro.robust.recovery` — scrub-and-invalidate repair (corrupted
    sets reset to EMPTY, tallied as forced evictions) and engine
    checkpoint/restore through ``ckpt/manager.py``'s atomic-rename
    protocol;
  * :mod:`repro.robust.ladder` — the graceful-degradation backend ladder
    (pallas resident → chunked scan → jnp) with every fallback recorded as
    an observable :mod:`repro.robust.events` event;
  * :mod:`repro.robust.watchdog` — bounded retry/backoff around host↔device
    sync points (the serving tick's ``device_get``, the showdown harness's
    worker joins).
"""
from repro.robust import events, faults  # noqa: F401
from repro.robust.faults import FaultReport  # noqa: F401
from repro.robust.invariants import (  # noqa: F401
    CacheReport,
    HierReport,
    ServeReport,
    check_cache,
    check_hier,
    check_serve,
    explain_cache,
    explain_hier,
    explain_serve,
)
from repro.robust.ladder import ReplayOutcome, resilient_replay  # noqa: F401
from repro.robust.recovery import (  # noqa: F401
    CheckpointedEngine,
    restore_engine,
    save_engine,
    scrub,
    scrub_hier,
    validated_replay,
)
from repro.robust.watchdog import WatchdogTimeout, watch  # noqa: F401
