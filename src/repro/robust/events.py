"""Degradation-event log — fallbacks and recoveries made observable.

Before PR 8 the system degraded *silently*: the pallas replay fell back to
the chunked scan when the cache state outgrew the VMEM budget, and nothing
recorded that the fast path was not taken.  This module is the single
process-wide log every degradation writes to — backend-ladder descents
(:mod:`repro.robust.ladder`), the VMEM-budget fallback in
``PallasBackend.replay``, watchdog re-waits (:mod:`repro.robust.watchdog`)
and scrub repairs — so engine/replay stats, the robustness benchmark and
the chaos tests can all see *that* and *why* a slow path ran.

The log is append-only within a process; readers hold a ``cursor()`` and
ask for events ``since(cursor)`` (the serving engine does this for its
``stats["degradation_events"]``), so one component draining the log can
never hide events from another.  ``clear()`` exists for test isolation.

Appends are thread-safe: the ladder, the watchdog and the engine may all
record from different threads, so each event is stamped — under the log
lock — with a process-monotonic ``seq`` that totally orders events even
when wall-clock timestamps collide.  ``seq`` survives ``clear()`` (the
counter never rewinds), so ordering comparisons across a test-isolation
boundary stay valid.

This module deliberately imports nothing from the rest of the repo: core
layers (``core/backend.py``) may record events without a dependency cycle.
"""
from __future__ import annotations

import dataclasses
import threading
import time

__all__ = ["DegradationEvent", "record", "log", "cursor", "since", "count",
           "clear"]


@dataclasses.dataclass(frozen=True)
class DegradationEvent:
    component: str          # e.g. "pallas.replay", "engine.tick_sync"
    reason: str             # "vmem_budget" | "kernel_failure" |
    #                         "validator_alarm" | "sync_timeout" |
    #                         "l1_demotion" (hierarchical L1 exceeds the
    #                         VMEM budget; L1L2 falls to the jnp twin) | ...
    fallback_from: str = ""  # rung/path abandoned ("" for non-ladder events)
    fallback_to: str = ""    # rung/path taken instead
    detail: str = ""
    time_unix: float = 0.0
    seq: int = -1            # process-monotonic order stamp (-1 = unstamped)


_LOCK = threading.Lock()
_LOG: list[DegradationEvent] = []
_SEQ = 0                     # never rewinds — not even on clear()


def record(component: str, reason: str, fallback_from: str = "",
           fallback_to: str = "", detail: str = "") -> DegradationEvent:
    """Append one event; returns it (handy for in-line logging).  The
    ``seq`` stamp is assigned under the log lock, so concurrent recorders
    get distinct, monotonically increasing stamps in append order."""
    global _SEQ
    with _LOCK:
        ev = DegradationEvent(component=component, reason=reason,
                              fallback_from=fallback_from,
                              fallback_to=fallback_to, detail=detail,
                              time_unix=time.time(), seq=_SEQ)
        _SEQ += 1
        _LOG.append(ev)
    return ev


def log() -> tuple[DegradationEvent, ...]:
    """The full event log (immutable snapshot)."""
    with _LOCK:
        return tuple(_LOG)


def cursor() -> int:
    """Position marker: pass to ``since``/``count`` to scope a reader to
    events recorded after this call."""
    with _LOCK:
        return len(_LOG)


def since(start: int) -> tuple[DegradationEvent, ...]:
    with _LOCK:
        return tuple(_LOG[start:])


def count(component: str | None = None, reason: str | None = None,
          start: int = 0) -> int:
    """Number of events (optionally filtered) recorded at/after ``start``."""
    return sum(
        1 for ev in since(start)
        if (component is None or ev.component == component)
        and (reason is None or ev.reason == reason)
    )


def clear() -> None:
    """Drop all events — test isolation only; production readers use
    cursors so they never need to mutate the log."""
    with _LOCK:
        _LOG.clear()
