"""Render the §Dry-run and §Roofline tables from dryrun_results.json.

    PYTHONPATH=src python -m repro.roofline.report [dryrun_results.json]
"""
from __future__ import annotations

import json
import sys


def _fmt_bytes(b):
    return f"{b/2**30:.2f}"


def render(results: dict) -> str:
    out = []
    out.append("### Dry-run summary\n")
    ok = [r for r in results.values() if r.get("status") == "ok"]
    sk = [r for r in results.values() if r.get("status") == "skipped"]
    fl = [r for r in results.values() if r.get("status") == "fail"]
    out.append(f"compiled cells: {len(ok)}   documented skips: {len(sk)}   "
               f"failures: {len(fl)}\n")
    out.append("| arch | shape | mesh | chips | args GiB/dev | temp GiB/dev | compile s |")
    out.append("|---|---|---|---:|---:|---:|---:|")
    for key in sorted(results):
        r = results[key]
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - | "
                       f"skip: {r['reason'][:40]}… |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - | "
                       f"FAIL {r.get('error','')[:40]} |")
            continue
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} "
            f"| {_fmt_bytes(m['argument_bytes'])} "
            f"| {_fmt_bytes(m['temp_bytes'])} | {r.get('compile_s','')} |"
        )

    out.append("\n### Roofline (single-pod 16x16, 256 chips)\n")
    out.append("| arch | shape | t_compute s | t_memory s | t_collective s "
               "| bottleneck | MODEL_FLOPS | useful ratio | roofline frac |")
    out.append("|---|---|---:|---:|---:|---|---:|---:|---:|")
    for key in sorted(results):
        r = results[key]
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rf['t_compute']:.4f} | {rf['t_memory']:.4f} "
            f"| {rf['t_collective']:.4f} | {rf['bottleneck']} "
            f"| {rf['model_flops']:.3g} | {rf['useful_flops_ratio']:.2f} "
            f"| {rf['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        print(render(json.load(f)))


if __name__ == "__main__":
    main()
