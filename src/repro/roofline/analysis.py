"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs        / (chips × peak_FLOPs)
    memory     = HLO_bytes        / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Sources and the scan-trip-count problem: ``compiled.cost_analysis()`` counts
a ``lax.scan`` body ONCE regardless of trip count (verified empirically).
The dry-run therefore lowers each step three times:
  * full-L **scanned** — the production artifact: memory_analysis + the
    proof that it compiles on the production mesh;
  * **unrolled** with p and 2p layers (p = layer-pattern period, 2 for
    gemma2's local/global alternation, 1 otherwise) — no while loops, so
    cost_analysis and the HLO collective scrape are exact; per-period costs
    extrapolate linearly:  total(L) = c(p) + (L/p - 1) · (c(2p) - c(p)).

Collective bytes are scraped from the *post-SPMD* HLO text (per-device
shapes): we sum the result-shape bytes of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute and multiply by the device
count to get global bytes, matching the formula's chips-normalized form.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

# --- hardware constants (TPU v5e) ---
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (per chip, one direction)

_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_per_device(hlo_text: str) -> dict:
    """Sum result bytes of collective ops in a (post-SPMD) HLO module.

    Returns {op_kind: bytes} per device.  Must be called on HLO without
    while loops (the dry-run's unrolled lowerings) for exact totals.
    """
    out: dict = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # global, extrapolated to full L
    hlo_bytes: float             # global HBM traffic
    coll_bytes: float            # global collective bytes
    coll_breakdown: dict
    model_flops: float           # analytic 6·N·D (active params for MoE)
    per_device_peak_memory: float  # from memory_analysis (scanned compile)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPs/s achieved at the roofline step time vs peak — the
        MFU the compiled program could reach if perfectly overlapped."""
        if self.step_time == 0:
            return 0.0
        return self.model_flops / (self.step_time * self.chips * PEAK_FLOPS)

    def to_json(self) -> dict:
        return {
            **{f.name: getattr(self, f.name) for f in dataclasses.fields(self)},
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "step_time": self.step_time,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def extrapolate(c_p: float, c_2p: float, num_periods: int) -> float:
    """total(L) = c(p) + (L/p - 1) · (c(2p) - c(p));  num_periods = L/p."""
    per_period = c_2p - c_p
    return c_p + (num_periods - 1) * per_period


def extrapolate_dict(d_p: dict, d_2p: dict, num_periods: int) -> dict:
    keys = set(d_p) | set(d_2p)
    return {
        k: extrapolate(d_p.get(k, 0), d_2p.get(k, 0), num_periods) for k in keys
    }


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for one step of this cell.

    train: 6·N·D (fwd+bwd, D = tokens/step).   prefill: 2·N·D.
    decode: 2·N·B (one token per sequence) — attention-over-cache flops are
    excluded by convention (they are reported via HLO flops instead).
    """
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.seq_len * shape.global_batch
    return 2.0 * n_active * shape.global_batch
