"""TinyLFU admission filter — counting Bloom filter + doorkeeper + aging.

The paper pairs LFU eviction (and Hyperbolic) with the TinyLFU admission
policy [17]: a new key is admitted only if its estimated frequency exceeds the
victim's.  We implement the standard construction:

  * a count-min sketch with 4 hash rows of 4-bit saturating counters
    (packed 8 per int32 word for density — same trick as the reference
    implementation's long[] packing),
  * a "doorkeeper" Bloom filter absorbing one-hit wonders,
  * periodic aging: when the sample counter reaches W, every counter is
    halved and the doorkeeper is cleared.

Everything is a fixed-shape pytree, batched over requests, jit-safe.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import hashing

_ROWS = 4


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TinyLFUState:
    packed: jnp.ndarray   # uint32 [ROWS, W/8] — 8 × 4-bit counters per word
    door: jnp.ndarray     # uint32 [DW]        — doorkeeper bloom bits
    additions: jnp.ndarray  # int32 []         — since last aging


@dataclasses.dataclass(frozen=True)
class TinyLFUConfig:
    width: int            # counters per row (power of two)
    door_bits: int        # doorkeeper bits (power of two)
    sample: int           # aging period W (counts of additions)

    def __post_init__(self):
        assert self.width % 8 == 0 and self.width & (self.width - 1) == 0
        assert self.door_bits & (self.door_bits - 1) == 0


def for_capacity(capacity: int) -> TinyLFUConfig:
    """Standard sizing: ~1 counter per cached item × small multiplier."""
    width = max(64, 1 << (capacity - 1).bit_length())
    return TinyLFUConfig(width=width, door_bits=width * 2, sample=capacity * 8)


def make_sketch(cfg: TinyLFUConfig) -> TinyLFUState:
    return TinyLFUState(
        packed=jnp.zeros((_ROWS, cfg.width // 8), jnp.uint32),
        door=jnp.zeros((cfg.door_bits // 32,), jnp.uint32),
        additions=jnp.zeros((), jnp.int32),
    )


def _positions(cfg: TinyLFUConfig, keys: jnp.ndarray):
    """Per row: (word index, nibble shift) for each key. Shapes [ROWS, B]."""
    idx = jnp.stack(
        [
            hashing.hash_u32(keys, seed=0xA000 + r) & jnp.uint32(cfg.width - 1)
            for r in range(_ROWS)
        ]
    )
    word = (idx >> 3).astype(jnp.int32)
    shift = ((idx & jnp.uint32(7)) * jnp.uint32(4)).astype(jnp.uint32)
    return word, shift


def estimate(cfg: TinyLFUConfig, st: TinyLFUState, keys: jnp.ndarray) -> jnp.ndarray:
    """Count-min estimate (+1 if the doorkeeper has the key). int32 [B]."""
    keys = hashing.sanitize_keys(keys)
    word, shift = _positions(cfg, keys)
    rows = jnp.arange(_ROWS)[:, None]
    nib = (st.packed[rows, word] >> shift) & jnp.uint32(0xF)
    est = jnp.min(nib, axis=0).astype(jnp.int32)
    dh = hashing.hash_u32(keys, seed=0xD00E) & jnp.uint32(cfg.door_bits - 1)
    dbit = (st.door[(dh >> 5).astype(jnp.int32)] >> (dh & jnp.uint32(31))) & jnp.uint32(1)
    return est + dbit.astype(jnp.int32)


@partial(jax.jit, static_argnums=0)
def record(cfg: TinyLFUConfig, st: TinyLFUState, keys: jnp.ndarray,
           enabled: Optional[jnp.ndarray] = None) -> TinyLFUState:
    """Record one access per key (batched).

    First access goes to the doorkeeper; repeat offenders increment the
    sketch.  Saturating 4-bit adds; duplicate batch keys coalesce into a
    single increment per step (an accepted approximation — the serial
    oracle in tests uses B=1 where semantics are exact).  ``enabled``
    (bool[B], optional) masks whole lanes: a disabled lane touches neither
    the doorkeeper, the counters, nor the aging tally — used for the tail
    padding of batched replays and the padding lanes of the sharded router.
    """
    keys = hashing.sanitize_keys(keys)
    if enabled is None:
        enabled = jnp.ones(keys.shape, jnp.bool_)
    dh = hashing.hash_u32(keys, seed=0xD00E) & jnp.uint32(cfg.door_bits - 1)
    dword = (dh >> 5).astype(jnp.int32)
    dmask = jnp.where(enabled, jnp.uint32(1) << (dh & jnp.uint32(31)),
                      jnp.uint32(0))
    in_door = (st.door[dword] & dmask) != 0

    # Disabled lanes scatter out of bounds (dropped): writing their word
    # back unchanged is NOT a no-op under duplicate indices — a stale
    # rewrite can clobber an enabled lane's fresh bit in the same word.
    dword_w = jnp.where(enabled, dword, jnp.int32(cfg.door_bits // 32))
    door = st.door.at[dword_w].set(st.door[dword] | dmask, mode="drop")

    word, shift = _positions(cfg, keys)          # [ROWS, B]
    rows = jnp.arange(_ROWS)[:, None]
    cur = (st.packed[rows, word] >> shift) & jnp.uint32(0xF)
    not_sat = cur < jnp.uint32(15)
    inc = jnp.where(in_door[None, :] & not_sat, jnp.uint32(1) << shift, jnp.uint32(0))
    # scatter-OR-free: use max-merge per nibble via set of (cur+1)<<shift;
    # duplicates coalesce because the write value is identical per position.
    new_word_val = st.packed[rows, word] + inc
    packed = st.packed.at[rows, word].max(
        jnp.where(inc != 0, new_word_val, jnp.uint32(0))
    )

    additions = st.additions + jnp.sum(enabled.astype(jnp.int32))
    st2 = TinyLFUState(packed=packed, door=door, additions=additions)
    return jax.lax.cond(
        additions >= cfg.sample, lambda s: _age(s), lambda s: s, st2
    )


def _age(st: TinyLFUState) -> TinyLFUState:
    """Halve every 4-bit counter, clear the doorkeeper (TinyLFU reset)."""
    halved = (st.packed >> 1) & jnp.uint32(0x77777777)
    return TinyLFUState(
        packed=halved,
        door=jnp.zeros_like(st.door),
        additions=jnp.zeros_like(st.additions),
    )


@partial(jax.jit, static_argnums=0)
def admit(
    cfg: TinyLFUConfig,
    st: TinyLFUState,
    cand_keys: jnp.ndarray,
    victim_keys: jnp.ndarray,
    victim_valid: jnp.ndarray,
) -> jnp.ndarray:
    """TinyLFU decision: admit iff est(candidate) > est(victim) (or the slot
    is empty).  bool [B]."""
    ce = estimate(cfg, st, cand_keys)
    ve = estimate(cfg, st, victim_keys)
    return (~victim_valid) | (ce > ve)
