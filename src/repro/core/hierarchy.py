"""Two-level replay hierarchy: a small VMEM-pinned L1 over the HBM L2.

PR 5's trace-resident megakernel keeps ALL five state lanes in VMEM and
wins 7×+ over the chunked scan — but only below ``RESIDENT_VMEM_BUDGET``
(12 MiB).  One set past the budget the backend silently falls off a cliff
back to the per-chunk scan.  *Limited Associativity Caching in the Data
Plane* (PAPERS.md) shows the classic fix transplants cleanly to limited
associativity: a small fast-memory set-associative front tier backed by a
large slow-memory tier, with victim *demotion* instead of eviction.

This module is the single source of truth for the hierarchy's semantics:

  * ``HierarchyConfig`` — the L1 knob (``l1_sets`` × ``l1_ways``) plus the
    ``promote`` / ``demote`` movement switches;
  * ``HierState`` — an (L1, L2) pair of ordinary ``KWayState`` pytrees;
  * the pure per-row phase transitions (``_l1_hit_row`` /
    ``_l2_hit_row`` / ``_l1_fill_row`` / ``_l2_demote_row``) shared
    verbatim by the jnp twin below AND the Pallas kernel
    (kernels/replay.py) — both callers only differ in how a set row is
    fetched/stored (dynamic_slice vs ref/DMA), so the arithmetic —
    scores, tie-breaks, metadata transitions — is bit-identical by
    construction;
  * ``replay_l1_over_l2`` — the jitted chunked-scan twin, the hierarchy's
    differential oracle (tests/test_hierarchy.py pins kernel == twin
    bit-for-bit on states, hit counts and eviction counts).

Row layout: each tier travels as ONE int32 ``[sets, ROW_W]`` array of seven
128-column sections — ``keys | fprint | vals | meta_a | meta_b | scalars |
expiry``.  The sixth section is an in-row scalar mailbox: every phase WRITES the
scalars later phases need (hit flags, the promoted entry, the displaced
victim, the eviction flag) into the row it stores, and consumers read them
back from the row AFTER the store.  That discipline — a fetched row's
values flow only into that row's writeback; cross-phase scalars travel
through the post-store row; and each loop iteration performs AT MOST ONE
fetch->store round-trip per tier (hence the even/odd phase interleave in
the replay loops: A+B on even steps, C+D on odd) — is what lets XLA keep
every row update in-place inside the replay loop.  Breaking any leg of it
(a pre-store value escaping to another buffer, or a second round-trip on
the same array in one iteration) makes copy-insertion clone the whole
tier per lane, turning the O(row) update into O(sets).  The packed layout
also means one L2 set row is ONE DMA on the kernel path.

Semantics (exclusive hierarchy, DESIGN.md §14):

  Each lane of a chunk is processed sequentially (lane i sees lane i-1's
  inserts — the hierarchy's transfer ops are RMW on two tiers, so the
  flat path's buffered-insert reordering does not apply).  Per lane:

    1. probe L1 (fingerprint pre-filter + full-key confirm).  Hit →
       ``on_hit`` on the L1 metadata at t_get.  Done.
    2. probe L2.  Hit → ``on_hit`` on the L2 metadata at t_get; with
       ``promote`` the slot is MOVED into L1 (L2 slot cleared — the tiers
       stay exclusive, no key is ever resident twice), else updated in
       place.
    3. full miss → insert (val == key payload) into L1 with ``on_insert``
       metadata at t_put.
    4. any L1 insert displaces that set's policy victim; with ``demote``
       the displaced entry is inserted into ITS OWN L2 set (metadata
       carried — recency/frequency survives the demotion), else dropped.
       An eviction is counted when an entry leaves the hierarchy: a
       demotion landing on an occupied L2 victim, or a displaced entry
       dropped with ``demote=False``.

  The L1 uses a salted set hash (``seed ^ L1_SEED_SALT``) so the two
  tiers' set mappings are independent — a pathological L2 set does not
  collapse onto one L1 set.

``l1_sets == 0`` disables the hierarchy entirely: every caller dispatches
to the existing flat paths, so the disabled mode is bit-exact with them
by construction (pinned by the differential suite).

Expiry (DESIGN.md §15): the seventh row section carries the per-lane
deadline on the shared logical clock.  Replay with ``ttls`` scrubs each
FETCHED row lazily — lanes whose deadline falls at or before the chunk's
exit clock (``base + 2B``) are reclaimed before any probe or victim
scoring, so an expired entry is never served from either tier and its
lane scores as empty (the preferred victim).  Lazy scrub at the same
horizon as the flat path's eager batch-entry ``kway.scrub_expired`` is
bit-equivalent for every touched row: entries inserted, promoted or
demoted within the chunk always carry deadlines past the horizon, so a
re-fetch never reclaims them.  Promotion and demotion carry the deadline
with the entry (mailbox slots ``SC_PEXP`` / ``SC_DE``).  With TTLs
disabled the section is all ``NO_EXPIRY``, the scrub is compiled out,
and every output is bit-identical to the pre-expiry code.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core.kway import (NO_EXPIRY, KWayConfig, KWayState, ensure_expiry,
                             make_cache)
from repro.core.policies import Policy
from repro.kernels.kway_probe import (LANES, NEG_INF, POS_INF,
                                      _fingerprint_i32, _hash_u32,
                                      _scores_for_policy)

__all__ = [
    "L1_SEED_SALT", "ROW_W", "HierarchyConfig", "HierState", "l1_config",
    "make_hier", "as_hier_state", "hier_footprint_bytes",
    "replay_l1_over_l2",
]

#: XOR salt for the L1 set hash — decorrelates the two tiers' set mappings.
L1_SEED_SALT = 0x7A11

_EMPTY = -1  # EMPTY_KEY (0xFFFFFFFF) in the kernels' int32 bit-cast domain

#: packed-row width: five state sections + the scalar-mailbox section +
#: the expiry section (DESIGN.md §15), each LANES columns wide
ROW_SECS = 7
ROW_W = ROW_SECS * LANES

# scalar-mailbox slots.  Each phase overwrites the WHOLE scalar section of
# the row it stores, so slots only need to be unique within one phase:
#   L1 hit phase   -> SC_HIT1
#   L2 hit phase   -> SC_L2HIT, SC_PVAL, SC_PA, SC_PB, SC_PEXP
#   L1 fill phase  -> SC_DVALID, SC_DK..SC_DB, SC_DE (the displaced victim)
#   L2 demote      -> SC_EV
SC_HIT1 = 0
SC_L2HIT = 0
SC_PVAL = 1
SC_PA = 2
SC_PB = 3
SC_PEXP = 4
SC_DVALID = 0
SC_DK = 1
SC_DF = 2
SC_DV = 3
SC_DA = 4
SC_DB = 5
SC_DE = 6
SC_EV = 0


@dataclasses.dataclass(frozen=True)
class HierarchyConfig:
    """Static L1-over-L2 configuration (hashable; safe as a jit static).

    ``l1_sets == 0`` means "no hierarchy" — callers fall through to the
    flat replay paths unchanged.  ``promote`` moves L2 hits into L1
    (exclusive move, the L2 slot is cleared); ``demote`` re-inserts L1
    victims into their own L2 set instead of dropping them.
    """

    l1_sets: int
    l1_ways: int = 16
    promote: bool = True
    demote: bool = True

    def __post_init__(self):
        assert self.l1_sets >= 0
        assert self.l1_sets == 0 or self.l1_sets & (self.l1_sets - 1) == 0, \
            "l1_sets must be 0 or a power of two"
        assert 1 <= self.l1_ways <= LANES

    @property
    def enabled(self) -> bool:
        return self.l1_sets > 0

    @property
    def l1_capacity(self) -> int:
        return self.l1_sets * self.l1_ways


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HierState:
    """The hierarchy's contents: two ordinary k-way states.

    The logical clock is shared (both tiers' ``clock`` fields hold the
    same value after every replay); ``l2.clock`` is authoritative on
    entry.
    """

    l1: KWayState
    l2: KWayState

    def occupancy(self) -> jnp.ndarray:
        return self.l1.occupancy() + self.l2.occupancy()


def l1_config(cfg: KWayConfig, hier: HierarchyConfig) -> KWayConfig:
    """The L1 tier as a plain KWayConfig (same policy, salted set seed)."""
    return KWayConfig(num_sets=hier.l1_sets, ways=hier.l1_ways,
                      policy=cfg.policy, layout=cfg.layout,
                      seed=cfg.seed ^ L1_SEED_SALT)


def make_hier(cfg: KWayConfig, hier: HierarchyConfig, *,
              ttl: bool = False) -> HierState:
    """Fresh empty hierarchy over an empty L2 of ``cfg``'s geometry.
    ``ttl=True`` attaches the expiry lane to both tiers (all NO_EXPIRY)."""
    return HierState(l1=make_cache(l1_config(cfg, hier), ttl=ttl),
                     l2=make_cache(cfg, ttl=ttl))


def as_hier_state(cfg: KWayConfig, hier: HierarchyConfig,
                  state, *, ttl: bool = False) -> HierState:
    """Coerce a replay input state: a ``HierState`` passes through, a bare
    L2 ``KWayState`` gets a fresh empty L1 attached.  ``ttl=True`` ensures
    both tiers carry the expiry lane (TTL replay needs it)."""
    if isinstance(state, HierState):
        if ttl:
            return HierState(l1=ensure_expiry(state.l1),
                             l2=ensure_expiry(state.l2))
        return state
    ttl = ttl or state.expiry is not None
    return HierState(
        l1=make_cache(l1_config(cfg, hier), ttl=ttl),
        l2=ensure_expiry(state) if ttl else state)


def hier_footprint_bytes(hier: HierarchyConfig) -> int:
    """VMEM bytes the hierarchical megakernel pins: the packed L1 rows
    (five state sections plus the scalar mailbox and the expiry section,
    ways padded to the 128-lane register width), double-buffered (input copy + resident
    output) — the analogue of the flat kernel's ``resident_fits``
    accounting with ``l1_sets`` in place of ``num_sets``.  The two DMA
    staging rows (2 × ROW_W·4 B) are noise against any real budget.
    """
    return 2 * hier.l1_sets * ROW_W * 4


# ---------------------------------------------------------------------------
# packed-row helpers (pure [1, *]-row arithmetic)
#
# Everything below operates on int32 rows and python-literal constants
# only, so the SAME functions trace inside a pallas_call body and inside
# the jnp twin.  Any drift between the two paths is a drift in the
# fetch/store glue, which the differential suite catches.
# ---------------------------------------------------------------------------

def _iota_lane():
    return jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)


def _row_sel(row, lane, idx):
    """Scalar read of column ``idx`` from an in-register [1, LANES] row."""
    return jnp.sum(jnp.where(lane == idx, row, 0))


def _row_put(row, lane, idx, val):
    """Column ``idx`` of ``row`` replaced by scalar ``val``."""
    return jnp.where(lane == idx, val, row)


def _sec(row, j):
    """Section ``j`` (static) of a packed [1, ROW_W] row -> [1, LANES]."""
    return jax.lax.slice(row, (0, j * LANES), (1, (j + 1) * LANES))

def _secs(row):
    """The five state sections of a packed row."""
    return tuple(_sec(row, j) for j in range(5))


def _sec_exp(row):
    """The expiry section (section 6) of a packed row -> [1, LANES]."""
    return _sec(row, 6)


def _scrub_secs(k, f, v, a, b, e, ways, lane, horizon):
    """Reclaim expired lanes of a fetched row BEFORE any probe or victim
    scoring — the hierarchy's lazy analogue of ``kway.scrub_expired`` at
    the same horizon (the chunk-exit clock ``base + 2B``), so an expired
    entry is never served and its lane scores as empty, i.e. the
    preferred victim.  Reclaim is not an eviction (no demotion, no
    eviction count), exactly like the flat path's batch-entry scrub."""
    dead = (k != _EMPTY) & (lane < ways) & (e <= horizon)
    k = jnp.where(dead, jnp.int32(_EMPTY), k)
    f = jnp.where(dead, jnp.int32(0), f)
    v = jnp.where(dead, jnp.int32(0), v)
    a = jnp.where(dead, jnp.int32(0), a)
    b = jnp.where(dead, jnp.int32(0), b)
    e = jnp.where(dead, jnp.int32(NO_EXPIRY), e)
    return k, f, v, a, b, e


def _sc_section(slots):
    """Build a fresh scalar-mailbox section from (slot, int32 value)
    pairs; unnamed slots are zero (deterministic — the kernel and the
    twin must store bit-identical rows)."""
    lane = _iota_lane()
    out = jnp.zeros((1, LANES), jnp.int32)
    for slot, val in slots:
        out = jnp.where(lane == slot, val, out)
    return out


def _sc_get(row, slot):
    """Read mailbox slot ``slot`` from a packed [1, ROW_W] row."""
    return _row_sel(_sec(row, 5), _iota_lane(), slot)


def _pack_row(k, f, v, a, b, sc, e):
    return jnp.concatenate([k, f, v, a, b, sc, e], axis=1)


def _probe_row(row_keys, row_fpr, qk, fp, ways, lane):
    """Fingerprint-prefiltered set probe (KW-WFSC Algorithm 5): a 16-bit
    fingerprint match is confirmed on the full key, so the result is
    bit-identical to a plain full-key compare.  Returns (hit bool scalar,
    way int32 scalar; ``LANES`` when no hit)."""
    occupied = (row_keys != _EMPTY) & (lane < ways)
    eq = (row_fpr == fp) & (row_keys == qk) & occupied
    hit = jnp.any(eq)
    way = jnp.min(jnp.where(eq, lane, LANES))
    return hit, way


def _victim_way(policy, row_keys, row_a, row_b, now, ways, lane):
    """Policy victim of one set row at time ``now`` (empty ways first,
    padding lanes never, ties toward the lowest lane — the flat kernel's
    exact masking and tie-break)."""
    occupied = (row_keys != _EMPTY) & (lane < ways)
    sc = _scores_for_policy(policy, row_keys, row_a, row_b, now)
    sc = jnp.where(occupied, sc, NEG_INF)
    sc = jnp.where(lane < ways, sc, POS_INF)
    return jnp.min(jnp.where(sc == jnp.min(sc), lane, LANES))


def _hit_meta(policy, ma, mb, now):
    """policies.on_hit on one scalar (specialized statically)."""
    if policy == Policy.LRU:
        return now, mb
    if policy in (Policy.LFU, Policy.HYPERBOLIC):
        return ma + 1, mb
    return ma, mb                       # FIFO / RANDOM: identity


def _insert_meta(policy, now):
    """policies.on_insert on one scalar (specialized statically)."""
    if policy in (Policy.LRU, Policy.FIFO):
        return now, jnp.int32(0)
    if policy == Policy.LFU:
        return jnp.int32(1), jnp.int32(0)
    if policy == Policy.RANDOM:
        return jnp.int32(0), jnp.int32(0)
    return jnp.int32(1), now            # HYPERBOLIC: (n=1, t0=now)


def _set_index_i32(key_i32, num_sets: int, seed: int):
    """hashing.set_index on one int32-domain scalar (bit-identical)."""
    h = _hash_u32(key_i32.astype(jnp.uint32), seed)
    return (h & jnp.uint32(num_sets - 1)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# the four per-lane phases.  One lane = A (L1 hit) -> B (L2 hit/promote)
# -> C (L1 fill) -> D (L2 demote), each phase one row fetch + one row
# store; scalars cross phases through the stored row's mailbox only.
# ---------------------------------------------------------------------------

def _l1_hit_row(policy: int, row, qk, fp, t_get, en, l1_ways: int,
                ttl: bool = False, horizon=None):
    """Phase A: probe L1, apply ``on_hit`` at t_get.  Mailbox: SC_HIT1.
    With ``ttl`` the row is scrubbed at ``horizon`` before the probe, so
    an expired L1 entry can never register a hit."""
    lane = _iota_lane()
    k, f, v, a, b = _secs(row)
    e = _sec_exp(row)
    if ttl:
        k, f, v, a, b, e = _scrub_secs(k, f, v, a, b, e, l1_ways, lane,
                                       horizon)
    hit1, w1 = _probe_row(k, f, qk, fp, l1_ways, lane)
    ha, hb = _hit_meta(policy, _row_sel(a, lane, w1),
                       _row_sel(b, lane, w1), t_get)
    do1 = hit1 & en
    a = jnp.where(do1, _row_put(a, lane, w1, ha), a)
    b = jnp.where(do1, _row_put(b, lane, w1, hb), b)
    sc = _sc_section([(SC_HIT1, hit1.astype(jnp.int32))])
    return _pack_row(k, f, v, a, b, sc, e)


def _l2_hit_row(policy: int, promote: bool, row, qk, fp, hit1, t_get, en,
                l2_ways: int, ttl: bool = False, horizon=None):
    """Phase B: probe L2; on an L2 hit apply ``on_hit`` — carried by the
    promoted copy (slot cleared, the tiers stay exclusive) or in place
    when promotion is off.  Mailbox: SC_L2HIT, SC_PVAL, SC_PA, SC_PB,
    SC_PEXP (the promoted entry's deadline, carried into phase C)."""
    lane = _iota_lane()
    k, f, v, a, b = _secs(row)
    e = _sec_exp(row)
    if ttl:
        k, f, v, a, b, e = _scrub_secs(k, f, v, a, b, e, l2_ways, lane,
                                       horizon)
    hit2, w2 = _probe_row(k, f, qk, fp, l2_ways, lane)
    l2_hit = (~hit1) & hit2
    pa, pb = _hit_meta(policy, _row_sel(a, lane, w2),
                       _row_sel(b, lane, w2), t_get)
    pval = _row_sel(v, lane, w2)
    pexp = _row_sel(e, lane, w2)
    do2 = l2_hit & en
    if promote:
        # exclusive move: the L2 slot is cleared, the entry lives on in L1
        k = jnp.where(do2, _row_put(k, lane, w2, jnp.int32(_EMPTY)), k)
        f = jnp.where(do2, _row_put(f, lane, w2, jnp.int32(0)), f)
        v = jnp.where(do2, _row_put(v, lane, w2, jnp.int32(0)), v)
        a = jnp.where(do2, _row_put(a, lane, w2, jnp.int32(0)), a)
        b = jnp.where(do2, _row_put(b, lane, w2, jnp.int32(0)), b)
        e = jnp.where(do2,
                      _row_put(e, lane, w2, jnp.int32(NO_EXPIRY)), e)
    else:
        a = jnp.where(do2, _row_put(a, lane, w2, pa), a)
        b = jnp.where(do2, _row_put(b, lane, w2, pb), b)
    sc = _sc_section([(SC_L2HIT, l2_hit.astype(jnp.int32)),
                      (SC_PVAL, pval), (SC_PA, pa), (SC_PB, pb),
                      (SC_PEXP, pexp)])
    return _pack_row(k, f, v, a, b, sc, e)


def _l1_fill_row(policy: int, promote: bool, row, qk, fp, hit1, l2_hit,
                 pval, pa, pb, t_put, en, l1_ways: int,
                 ttl: bool = False, horizon=None, pexp=None, dl=None):
    """Phase C: insert into L1 — the promoted L2 entry (metadata carried)
    or, on a full miss, a fresh ``on_insert`` entry at t_put.  Victim
    scoring sees the post-hit row (phase A already ran on this set);
    with ``ttl`` the row is re-scrubbed first (idempotent — phase A
    already stored the scrubbed row), so an expired lane is the
    preferred victim.  The insert's deadline is the promoted entry's
    carried ``pexp`` or the fresh ``dl`` (``base + 2B + ttl``).
    Mailbox: SC_DVALID + the displaced victim SC_DK..SC_DB, SC_DE."""
    lane = _iota_lane()
    k, f, v, a, b = _secs(row)
    e = _sec_exp(row)
    if ttl:
        k, f, v, a, b, e = _scrub_secs(k, f, v, a, b, e, l1_ways, lane,
                                       horizon)
    if pexp is None:
        pexp = jnp.int32(NO_EXPIRY)
    if dl is None:
        dl = jnp.int32(NO_EXPIRY)
    miss = (~hit1) & (~l2_hit)
    ia, ib = _insert_meta(policy, t_put)
    if promote:
        ins = en & (miss | l2_hit)
        ins_v = jnp.where(l2_hit, pval, qk)   # payload convention val == key
        ins_a = jnp.where(l2_hit, pa, ia)
        ins_b = jnp.where(l2_hit, pb, ib)
        ins_e = jnp.where(l2_hit, pexp, dl)
    else:
        ins = en & miss
        ins_v, ins_a, ins_b, ins_e = qk, ia, ib, dl
    vw = _victim_way(policy, k, a, b, t_put, l1_ways, lane)
    dk = _row_sel(k, lane, vw)
    df = _row_sel(f, lane, vw)
    dv = _row_sel(v, lane, vw)
    da = _row_sel(a, lane, vw)
    db = _row_sel(b, lane, vw)
    de = _row_sel(e, lane, vw)
    dvalid = ins & (dk != _EMPTY)
    k = jnp.where(ins, _row_put(k, lane, vw, qk), k)
    f = jnp.where(ins, _row_put(f, lane, vw, fp), f)
    v = jnp.where(ins, _row_put(v, lane, vw, ins_v), v)
    a = jnp.where(ins, _row_put(a, lane, vw, ins_a), a)
    b = jnp.where(ins, _row_put(b, lane, vw, ins_b), b)
    e = jnp.where(ins, _row_put(e, lane, vw, ins_e), e)
    sc = _sc_section([(SC_DVALID, dvalid.astype(jnp.int32)),
                      (SC_DK, dk), (SC_DF, df), (SC_DV, dv),
                      (SC_DA, da), (SC_DB, db), (SC_DE, de)])
    return _pack_row(k, f, v, a, b, sc, e)


def _l2_demote_row(policy: int, row, dk, df, dv, da, db, dvalid, t_put,
                   l2_ways: int, ttl: bool = False, horizon=None, de=None):
    """Phase D: insert the displaced L1 entry into ITS OWN L2 set's row
    (victim selection at t_put, metadata AND deadline ``de`` carried
    verbatim; the row is scrubbed first with ``ttl``, so an expired L2
    lane absorbs the demotion without an eviction).  Mailbox: SC_EV — 1
    when the demotion lands on an occupied L2 victim, i.e. an entry
    leaves the hierarchy."""
    lane = _iota_lane()
    k, f, v, a, b = _secs(row)
    e = _sec_exp(row)
    if ttl:
        k, f, v, a, b, e = _scrub_secs(k, f, v, a, b, e, l2_ways, lane,
                                       horizon)
    if de is None:
        de = jnp.int32(NO_EXPIRY)
    vw = _victim_way(policy, k, a, b, t_put, l2_ways, lane)
    ev = (dvalid & (_row_sel(k, lane, vw) != _EMPTY)).astype(jnp.int32)
    k = jnp.where(dvalid, _row_put(k, lane, vw, dk), k)
    f = jnp.where(dvalid, _row_put(f, lane, vw, df), f)
    v = jnp.where(dvalid, _row_put(v, lane, vw, dv), v)
    a = jnp.where(dvalid, _row_put(a, lane, vw, da), a)
    b = jnp.where(dvalid, _row_put(b, lane, vw, db), b)
    e = jnp.where(dvalid, _row_put(e, lane, vw, de), e)
    sc = _sc_section([(SC_EV, ev)])
    return _pack_row(k, f, v, a, b, sc, e)


# ---------------------------------------------------------------------------
# packed-state conversion
# ---------------------------------------------------------------------------

def _pad_ways_i32(arr, fill):
    s, k = arr.shape
    if k == LANES:
        return arr.astype(jnp.int32)
    return jnp.concatenate(
        [arr.astype(jnp.int32),
         jnp.full((s, LANES - k), fill, jnp.int32)], axis=1)


def _pack_lanes(keys, fpr, vals, ma, mb, exp=None):
    """Five [S, ways] lanes (+ optional expiry) -> one packed int32
    [S, ROW_W] array (ways padded per section; mailbox section zeroed;
    expiry section NO_EXPIRY-filled when absent)."""
    sc = jnp.zeros((keys.shape[0], LANES), jnp.int32)
    ex = (jnp.full((keys.shape[0], LANES), NO_EXPIRY, jnp.int32)
          if exp is None else _pad_ways_i32(exp, NO_EXPIRY))
    return jnp.concatenate(
        [_pad_ways_i32(keys, -1), _pad_ways_i32(fpr, 0),
         _pad_ways_i32(vals, 0), _pad_ways_i32(ma, 0),
         _pad_ways_i32(mb, 0), sc, ex], axis=1)


def _unpack_lanes(packed, ways: int):
    """Packed [S, ROW_W] -> five int32 [S, ways] lanes (mailbox junk and
    way padding dropped)."""
    s = packed.shape[0]
    return tuple(
        jax.lax.slice(packed, (0, j * LANES), (s, j * LANES + ways))
        for j in range(5))


def _unpack_expiry(packed, ways: int):
    """Packed [S, ROW_W] -> the int32 [S, ways] expiry lane."""
    s = packed.shape[0]
    return jax.lax.slice(packed, (0, 6 * LANES), (s, 6 * LANES + ways))


# ---------------------------------------------------------------------------
# jitted chunked-scan twin — the hierarchy's differential oracle
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("policy", "l1_ways", "l2_ways", "seed",
                     "promote", "demote", "ttl"))
def _replay_hier_scan(
    l1p, l2p, clock,                     # packed int32 [S, ROW_W] tiers
    qk, s1, s2, en, tt,                  # int32 [T, B] streams
    *,
    policy: int,
    l1_ways: int,
    l2_ways: int,
    seed: int,
    promote: bool,
    demote: bool,
    ttl: bool,
):
    steps, batch = qk.shape
    l2_sets = l2p.shape[0]

    def chunk_step(carry, xs):
        l1p, l2p, base = carry
        qk_r, s1_r, s2_r, en_r, tt_r = xs

        # Lane i runs as loop steps 2i (phases A+B) and 2i+1 (phases C+D)
        # so every step performs exactly ONE fetch->store round-trip per
        # tier — a second round-trip on the same buffer within one step
        # re-introduces the defensive full-array copy (see module
        # docstring).  The even step's scalars (hit1, the promoted entry)
        # ride the loop carry into the odd step; the phase order per tier
        # is unchanged, so the interleave is bit-exact with the
        # straight-line A->B->C->D formulation.
        # chunk-exit clock == the flat path's batch-entry scrub horizon,
        # and the base of every deadline minted this chunk
        hz = base + jnp.int32(2 * batch) if ttl else None

        def lane_body(step, st):
            (l1p, l2p, hits, evs, hit1_c, l2_c, pval_c, pa_c, pb_c,
             pexp_c) = st
            i = step >> 1
            is_even = (step & jnp.int32(1)) == 0
            qk_i = qk_r[i]
            fp_i = _fingerprint_i32(qk_i.astype(jnp.uint32))
            en_i = en_r[i] != 0
            t_get = base + i
            t_put = base + jnp.int32(batch) + i
            s1_i, s2_i = s1_r[i], s2_r[i]
            if ttl:
                tt_i = tt_r[i]
                dl_i = jnp.where(tt_i > 0, hz + tt_i, jnp.int32(NO_EXPIRY))
            else:
                dl_i = None

            # L1 round-trip: phase A (even) / phase C (odd), both on s1
            r1 = jax.lax.dynamic_slice(l1p, (s1_i, 0), (1, ROW_W))
            row_a = _l1_hit_row(policy, r1, qk_i, fp_i, t_get, en_i,
                                l1_ways, ttl=ttl, horizon=hz)
            row_c = _l1_fill_row(policy, promote, r1, qk_i, fp_i,
                                 hit1_c != 0, l2_c != 0, pval_c, pa_c,
                                 pb_c, t_put, en_i, l1_ways,
                                 ttl=ttl, horizon=hz, pexp=pexp_c,
                                 dl=dl_i)
            l1p = jax.lax.dynamic_update_slice(
                l1p, jnp.where(is_even, row_a, row_c), (s1_i, 0))
            r1p = jax.lax.dynamic_slice(l1p, (s1_i, 0), (1, ROW_W))
            hit1 = _sc_get(r1p, SC_HIT1) != 0       # even-step mailbox
            dvalid = _sc_get(r1p, SC_DVALID) != 0   # odd-step mailbox
            dk = _sc_get(r1p, SC_DK)

            # L2 round-trip: phase B (even, set s2) / phase D (odd, the
            # displaced victim's own set).  The even store lands before
            # the odd fetch, so the s2v == s2 aliasing case reads the
            # post-promote row.
            if demote:
                s2v = _set_index_i32(dk, l2_sets, seed)
                sl2 = jnp.where(is_even, s2_i, s2v)
            else:
                sl2 = s2_i
            r2 = jax.lax.dynamic_slice(l2p, (sl2, 0), (1, ROW_W))
            row_b = _l2_hit_row(policy, promote, r2, qk_i, fp_i, hit1,
                                t_get, en_i, l2_ways, ttl=ttl, horizon=hz)
            if demote:
                df = _sc_get(r1p, SC_DF)
                dv = _sc_get(r1p, SC_DV)
                da = _sc_get(r1p, SC_DA)
                db = _sc_get(r1p, SC_DB)
                de = _sc_get(r1p, SC_DE)
                row_d = _l2_demote_row(policy, r2, dk, df, dv, da, db,
                                       dvalid, t_put, l2_ways,
                                       ttl=ttl, horizon=hz, de=de)
            else:
                row_d = r2                          # odd step: no-op store
            l2p = jax.lax.dynamic_update_slice(
                l2p, jnp.where(is_even, row_b, row_d), (sl2, 0))
            r2p = jax.lax.dynamic_slice(l2p, (sl2, 0), (1, ROW_W))
            l2_hit = _sc_get(r2p, SC_L2HIT) != 0
            pval = _sc_get(r2p, SC_PVAL)
            pa = _sc_get(r2p, SC_PA)
            pb = _sc_get(r2p, SC_PB)
            pexp = _sc_get(r2p, SC_PEXP)
            if demote:
                ev = _sc_get(r2p, SC_EV)
            else:
                ev = dvalid.astype(jnp.int32)

            hit = (en_i & (hit1 | l2_hit)).astype(jnp.int32)
            hits = hits + jnp.where(is_even, hit, 0)
            evs = evs + jnp.where(is_even, jnp.int32(0), ev)
            hit1_c = jnp.where(is_even, hit1.astype(jnp.int32), hit1_c)
            l2_c = jnp.where(is_even, l2_hit.astype(jnp.int32), l2_c)
            pval_c = jnp.where(is_even, pval, pval_c)
            pa_c = jnp.where(is_even, pa, pa_c)
            pb_c = jnp.where(is_even, pb, pb_c)
            pexp_c = jnp.where(is_even, pexp, pexp_c)
            return (l1p, l2p, hits, evs, hit1_c, l2_c, pval_c, pa_c, pb_c,
                    pexp_c)

        z = jnp.int32(0)
        l1p, l2p, hits, evs, *_ = jax.lax.fori_loop(
            0, 2 * batch, lane_body, (l1p, l2p, z, z, z, z, z, z, z, z))
        return (l1p, l2p, base + jnp.int32(2 * batch)), (hits, evs)

    (l1p, l2p, _), (hits, evs) = jax.lax.scan(
        chunk_step, (l1p, l2p, clock.astype(jnp.int32)),
        (qk, s1, s2, en, tt))
    return hits, evs, l1p, l2p


def replay_l1_over_l2(cfg: KWayConfig, hier: HierarchyConfig,
                      state: HierState, chunks, enabled, ttls=None):
    """Replay routed chunks through the L1-over-L2 hierarchy, pure XLA.

    ``chunks`` uint32 [steps, B] / ``enabled`` bool [steps, B] — the
    ``router.pad_chunks`` layout, payload ``val == key``.  This is the
    hierarchy's bit-exact oracle: the Pallas kernel
    (kernels/replay.replay_hierarchical) must reproduce its per-chunk hit
    and eviction counts and final tier states exactly.

    ``ttls`` (int32 [steps, B], optional) gives each request a
    time-to-live on the logical clock (DESIGN.md §15): misses insert
    with deadline ``base + 2B + ttl`` (``ttl <= 0`` = never expires) and
    expired lanes are lazily scrubbed from every row a chunk touches
    before it is probed — an expired key is never a hit on either tier.

    Returns (hits int32 [steps], evs int32 [steps], HierState', None).
    """
    assert hier.enabled, "replay_l1_over_l2 needs l1_sets > 0"
    ttl = ttls is not None
    if ttl:
        state = HierState(l1=ensure_expiry(state.l1),
                          l2=ensure_expiry(state.l2))
    steps, batch = chunks.shape
    qk = hashing.sanitize_keys(jnp.asarray(chunks, jnp.uint32).reshape(-1))
    s1 = hashing.set_index(qk, hier.l1_sets,
                           cfg.seed ^ L1_SEED_SALT).reshape(steps, batch)
    s2 = hashing.set_index(qk, cfg.num_sets, cfg.seed).reshape(steps, batch)
    qk = qk.astype(jnp.int32).reshape(steps, batch)
    en = jnp.asarray(enabled).astype(jnp.int32)
    tt = (jnp.asarray(ttls, jnp.int32) if ttl
          else jnp.zeros((steps, batch), jnp.int32))

    l1, l2 = state.l1, state.l2
    carry_exp = l1.expiry is not None or l2.expiry is not None
    l1p = _pack_lanes(l1.keys, l1.fprint, l1.vals, l1.meta_a, l1.meta_b,
                      l1.expiry)
    l2p = _pack_lanes(l2.keys, l2.fprint, l2.vals, l2.meta_a, l2.meta_b,
                      l2.expiry)

    hits, evs, l1p_f, l2p_f = _replay_hier_scan(
        l1p, l2p, state.l2.clock, qk, s1, s2, en, tt,
        policy=int(cfg.policy), l1_ways=hier.l1_ways, l2_ways=cfg.ways,
        seed=cfg.seed, promote=hier.promote, demote=hier.demote, ttl=ttl)

    clock_f = state.l2.clock + jnp.int32(2 * batch * steps)

    def unpack(packed, ways):
        k, f, v, a, b = _unpack_lanes(packed, ways)
        return KWayState(keys=k.astype(jnp.uint32),
                         fprint=f.astype(jnp.uint32),
                         vals=v, meta_a=a, meta_b=b, clock=clock_f,
                         expiry=(_unpack_expiry(packed, ways)
                                 if carry_exp else None))

    out = HierState(l1=unpack(l1p_f, hier.l1_ways),
                    l2=unpack(l2p_f, cfg.ways))
    return hits, evs, out, None
