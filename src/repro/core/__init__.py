"""Core library: the paper's K-way set-associative cache and its ecosystem.

Public API:
    KWayConfig, KWayState, make_cache, get, put, access, peek_victims
    fully_associative  — the paper's baseline as the S=1 corner case
    Policy             — LRU / LFU / FIFO / RANDOM / HYPERBOLIC
    TinyLFU admission  — admission.{TinyLFUConfig, make_sketch, record, admit}
    CacheBackend layer — backend.{make_backend, available_backends}
                         ("jnp" | "pallas" | "ref", one contract — DESIGN.md §3)
    Set sharding       — sharded.{ShardedConfig, ShardedCache} (DESIGN.md §5)
    Request routing    — router.{route, bucket, unscatter}: the device-
                         resident owner router behind sharding (DESIGN.md §9)
    simulate.replay    — jitted hit-ratio trace replay
    traces.generate    — synthetic workload families
"""
from repro.core.backend import (  # noqa: F401
    CacheBackend,
    available_backends,
    make_backend,
)
from repro.core.kway import (  # noqa: F401
    KWayConfig,
    KWayState,
    access,
    fully_associative,
    get,
    make_cache,
    peek_victims,
    put,
)
from repro.core.policies import Policy  # noqa: F401
