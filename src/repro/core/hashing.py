"""Vectorized avalanche hashing for set selection and fingerprints.

The paper uses xxHash to distribute keys to sets.  On TPU we want a hash that
is (a) a handful of uint32 VPU ops, (b) seedable so the set hash, fingerprint
hash and sketch hashes are independent, and (c) a good avalanche so the
balls-into-bins analysis of Theorem 4.1 applies.  We use the murmur3/xxhash
32-bit finalizer pattern (xor-shift + odd-constant multiply), which is the
same construction xxHash's avalanche step uses.

All functions operate on ``uint32`` arrays elementwise and are jit/vmap safe.
"""
from __future__ import annotations

import jax.numpy as jnp

# Odd multiplicative constants (murmur3 fmix32 / xxhash primes).
_C1 = jnp.uint32(0x85EBCA6B)
_C2 = jnp.uint32(0xC2B2AE35)
_PRIME1 = jnp.uint32(0x9E3779B1)  # xxhash PRIME32_1
_PRIME2 = jnp.uint32(0x85EBCA77)  # xxhash PRIME32_2

# Sentinel for an empty way.  User keys are remapped so they never collide
# with it (see ``sanitize_keys``).
EMPTY_KEY = jnp.uint32(0xFFFFFFFF)


def _fmix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit finalizer — full avalanche."""
    x = x ^ (x >> 16)
    x = x * _C1
    x = x ^ (x >> 13)
    x = x * _C2
    x = x ^ (x >> 16)
    return x


def hash_u32(keys: jnp.ndarray, seed: int) -> jnp.ndarray:
    """Seeded avalanche hash of uint32 keys -> uint32."""
    k = keys.astype(jnp.uint32)
    h = (k + jnp.uint32(seed) * _PRIME1) * _PRIME2
    return _fmix32(h)


def set_index(keys: jnp.ndarray, num_sets: int, seed: int = 0x51CA) -> jnp.ndarray:
    """Map keys to set indices.  ``num_sets`` must be a power of two (paper
    masks with ``numberOfSets-1``)."""
    assert num_sets & (num_sets - 1) == 0, "num_sets must be a power of two"
    return (hash_u32(keys, seed) & jnp.uint32(num_sets - 1)).astype(jnp.int32)


def fingerprint(keys: jnp.ndarray, seed: int = 0xF19E) -> jnp.ndarray:
    """Short fingerprint used by the SoA (KW-WFSC) layout to pre-filter the
    set scan without touching the full key record."""
    return hash_u32(keys, seed) & jnp.uint32(0xFFFF)


def sanitize_keys(keys: jnp.ndarray) -> jnp.ndarray:
    """Remap user keys so the EMPTY_KEY sentinel can never be a valid key.

    Keys equal to the sentinel are folded onto 0xFFFFFFFE.  (In a production
    library keys are opaque 64-bit hashes; the 1/2^32 fold is the standard
    sentinel trick.)
    """
    k = keys.astype(jnp.uint32)
    return jnp.where(k == EMPTY_KEY, jnp.uint32(0xFFFFFFFE), k)
