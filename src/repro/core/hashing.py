"""Vectorized avalanche hashing for set selection and fingerprints.

The paper uses xxHash to distribute keys to sets.  On TPU we want a hash that
is (a) a handful of uint32 VPU ops, (b) seedable so the set hash, fingerprint
hash and sketch hashes are independent, and (c) a good avalanche so the
balls-into-bins analysis of Theorem 4.1 applies.  We use the murmur3/xxhash
32-bit finalizer pattern (xor-shift + odd-constant multiply), which is the
same construction xxHash's avalanche step uses.

All functions operate on ``uint32`` arrays elementwise and are jit/vmap safe.

This module is also the single source of truth for the serving engine's
*prefix-chain block hash* (content addressing of full KV pages):
``prefix_block_hashes`` is the host/numpy form, ``prefix_block_hashes_jnp``
the traced form usable inside a jitted serving tick.  Both produce identical
uint32 values (pinned by tests/test_serve_engine.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Odd multiplicative constants (murmur3 fmix32 / xxhash primes).
_C1 = jnp.uint32(0x85EBCA6B)
_C2 = jnp.uint32(0xC2B2AE35)
_PRIME1 = jnp.uint32(0x9E3779B1)  # xxhash PRIME32_1
_PRIME2 = jnp.uint32(0x85EBCA77)  # xxhash PRIME32_2

# Sentinel for an empty way.  User keys are remapped so they never collide
# with it (see ``sanitize_keys``).
EMPTY_KEY = jnp.uint32(0xFFFFFFFF)


def _fmix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit finalizer — full avalanche."""
    x = x ^ (x >> 16)
    x = x * _C1
    x = x ^ (x >> 13)
    x = x * _C2
    x = x ^ (x >> 16)
    return x


def hash_u32(keys: jnp.ndarray, seed: int) -> jnp.ndarray:
    """Seeded avalanche hash of uint32 keys -> uint32."""
    k = keys.astype(jnp.uint32)
    h = (k + jnp.uint32(seed) * _PRIME1) * _PRIME2
    return _fmix32(h)


def set_index(keys: jnp.ndarray, num_sets: int, seed: int = 0x51CA) -> jnp.ndarray:
    """Map keys to set indices.  ``num_sets`` must be a power of two (paper
    masks with ``numberOfSets-1``)."""
    assert num_sets & (num_sets - 1) == 0, "num_sets must be a power of two"
    return (hash_u32(keys, seed) & jnp.uint32(num_sets - 1)).astype(jnp.int32)


def fingerprint(keys: jnp.ndarray, seed: int = 0xF19E) -> jnp.ndarray:
    """Short fingerprint used by the SoA (KW-WFSC) layout to pre-filter the
    set scan without touching the full key record."""
    return hash_u32(keys, seed) & jnp.uint32(0xFFFF)


# ---------------------------------------------------------------------------
# prefix-chain block hashing (serve/engine.py content addressing)
# ---------------------------------------------------------------------------

#: FNV-1a fold constants for the per-block digest.
_FNV_OFFSET = 2166136261
_FNV_PRIME = 16777619
#: Position salt multiplier (golden-ratio constant == xxhash PRIME32_1).
_GOLDEN = 0x9E3779B1


def _fmix32_np(x: np.ndarray) -> np.ndarray:
    """murmur3 finalizer — numpy port of ``_fmix32`` (bit-identical)."""
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(0x85EBCA6B)
    x = x ^ (x >> np.uint32(13))
    x = x * np.uint32(0xC2B2AE35)
    x = x ^ (x >> np.uint32(16))
    return x


def prefix_block_hashes(tokens: np.ndarray, page: int) -> np.ndarray:
    """Rolling prefix-chain hash per full block (content addressing).

    block_hash[i] covers tokens[0 : (i+1)*page] — a block only matches when
    its entire prefix matches, so a page hit guarantees identical KV.

    Vectorized: an FNV-1a fold over each block's tokens runs across all
    blocks at once (``page`` numpy steps instead of one interpreted step per
    prompt token), each block digest is avalanche-mixed with its position,
    and the prefix chain is a cumulative XOR of the position-salted digests.
    The content-addressing contract — same-prefix ⇒ same-hash,
    change-block-i ⇒ chain differs from i on — is what matters; hashes are
    ephemeral in-memory keys, never persisted.  O(page + n) numpy ops.
    """
    n = len(tokens) // page
    if n == 0:
        return np.empty(0, np.uint32)
    blocks = np.asarray(tokens[: n * page], dtype=np.uint32).reshape(n, page)
    h = np.full(n, np.uint32(_FNV_OFFSET), np.uint32)
    with np.errstate(over="ignore"):
        for j in range(page):                # page steps, vectorized over n
            h = (h ^ blocks[:, j]) * np.uint32(_FNV_PRIME)
        salt = np.arange(1, n + 1, dtype=np.uint32) * np.uint32(_GOLDEN)
        out = np.bitwise_xor.accumulate(_fmix32_np(h ^ salt)).astype(np.uint32)
    out[out == np.uint32(0xFFFFFFFF)] = np.uint32(1)  # avoid EMPTY_KEY
    return out


def prefix_block_hashes_jnp(tokens: jnp.ndarray, page: int) -> jnp.ndarray:
    """Traced twin of ``prefix_block_hashes`` for fixed-width token lanes.

    ``tokens`` int32 [n*page] (a padded prompt lane); returns uint32 [n]
    chain hashes over ALL n blocks.  The first ``len(prompt) // page``
    entries are bit-identical to the numpy form (the chain is a prefix
    scan, so hashes over padding garbage never contaminate real blocks);
    callers mask the rest with their ``valid`` lane mask.
    """
    n = tokens.shape[-1] // page
    blocks = tokens[..., : n * page].astype(jnp.uint32).reshape(n, page)
    h = jnp.full((n,), jnp.uint32(_FNV_OFFSET))
    for j in range(page):                    # page unrolled vector steps
        h = (h ^ blocks[:, j]) * jnp.uint32(_FNV_PRIME)
    salt = jnp.arange(1, n + 1, dtype=jnp.uint32) * jnp.uint32(_GOLDEN)
    out = jax.lax.associative_scan(jnp.bitwise_xor, _fmix32(h ^ salt))
    return jnp.where(out == EMPTY_KEY, jnp.uint32(1), out)


def sanitize_keys(keys: jnp.ndarray) -> jnp.ndarray:
    """Remap user keys so the EMPTY_KEY sentinel can never be a valid key.

    Keys equal to the sentinel are folded onto 0xFFFFFFFE.  (In a production
    library keys are opaque 64-bit hashes; the 1/2^32 fold is the standard
    sentinel trick.)
    """
    k = keys.astype(jnp.uint32)
    return jnp.where(k == EMPTY_KEY, jnp.uint32(0xFFFFFFFE), k)
