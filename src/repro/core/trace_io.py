"""Real-trace ingestion — public cache traces as drop-in trace families.

The paper evaluates on public traces (Wikipedia, OLTP, F1/F2, multi*, ...)
that ship in two dominant on-disk shapes.  This module parses both into the
same ``np.uint32`` key arrays the synthetic families in ``core/traces.py``
emit, so a downloaded trace file drops into every existing sweep, gate and
golden-trace workflow unchanged:

  * ``"arc"``  — ARC/LIRS-style plain text (``.trace``/``.lirs``): one
    decimal block id per line.  Extra whitespace-separated columns after the
    key (the 4-column ARC header form ``start count ignored id``) are
    tolerated; the first field is the key.  Numeric ids are used directly
    (mod 2^32) — block-id locality is part of the workload.
  * ``"csv"``  — Twitter/Memcached-style CSV with op/key/size columns.
    A header row naming ``op``/``key`` (any column order, extra columns
    ignored) is auto-detected; headerless files are read positionally as
    ``op,key[,size[,ttl]]``.  Keys are opaque strings and are
    **fingerprint-hashed** into the uint32 key space (see
    ``fingerprint_keys``).

TTL columns (DESIGN.md §15): pass ``with_ttl=True`` (or
``register_trace(..., ttl=True)``) to surface a per-request TTL stream
alongside the keys.  In CSV the TTL is the header-named ``ttl`` column, or
positional column 3 for headerless files; rows without the column (and the
op-less ARC format entirely) default to TTL ``0`` — which the replay
layers map to "never expires", so a TTL-oblivious file replayed through a
TTL-aware path is bit-identical to the TTL-free replay.

Key-space fingerprint contract: a string key maps to
``fmix32(FNV1a_32(utf8(key)))`` — deterministic across runs/platforms, full
avalanche (murmur3 finalizer, the same mixer ``core/hashing.py`` uses), and
folded away from the cache's EMPTY_KEY sentinel.  Collisions are the usual
birthday bound (~n^2/2^33); at trace sizes up to a few million keys this
perturbs hit ratios far below the gate tolerances.

Reads are streaming/chunked (``iter_trace_chunks``): a multi-GB trace never
needs to fit in memory as text — only the uint32 key array does.

``register_trace`` drops an ingested file into the ``traces.generate()``
registry: ``generate(name, n)`` serves the first ``n`` requests (tiling the
file if ``n`` exceeds it), which is exactly the contract every sweep and
replay entry point already assumes.
"""
from __future__ import annotations

import csv as _csv
import os

import numpy as np

from repro.core import traces

__all__ = ["load_trace", "iter_trace_chunks", "fingerprint_keys",
           "trace_fingerprint", "register_trace", "unregister_trace",
           "detect_format", "register_fixture_traces", "fixture_dir",
           "FIXTURE_TRACES"]

#: murmur3 fmix32 constants — the same avalanche mixer as core/hashing.py.
_C1 = 0x85EBCA6B
_C2 = 0xC2B2AE35
_FNV_OFFSET = 2166136261
_FNV_PRIME = 16777619
_MASK = 0xFFFFFFFF
_EMPTY_KEY = 0xFFFFFFFF

#: default read-op set for the ``ops=`` filter ("reads only" ingestion);
#: ``ops=None`` keeps every row — our caches model key residency, and a
#: SET on a missing key allocates just like a GET-miss does.
READ_OPS = frozenset({"get", "gets", "read"})


def _fmix32_int(x: int) -> int:
    x ^= x >> 16
    x = (x * _C1) & _MASK
    x ^= x >> 13
    x = (x * _C2) & _MASK
    x ^= x >> 16
    return x


def _sanitize(k: int) -> int:
    """Fold the EMPTY_KEY sentinel exactly like hashing.sanitize_keys."""
    k &= _MASK
    return 0xFFFFFFFE if k == _EMPTY_KEY else k


def fingerprint_keys(keys) -> np.ndarray:
    """Map opaque string keys into the uint32 key space (the contract the
    module docstring documents).  -> uint32 [len(keys)]."""
    out = np.empty(len(keys), np.uint32)
    for i, key in enumerate(keys):
        h = _FNV_OFFSET
        for b in key.encode("utf-8"):
            h = ((h ^ b) * _FNV_PRIME) & _MASK
        out[i] = _sanitize(_fmix32_int(h))
    return out


def trace_fingerprint(keys: np.ndarray) -> str:
    """Order-sensitive digest of a key array — provenance for artifacts.

    FNV-1a folded over the raw little-endian bytes, avalanche-finished;
    eight hex chars.  Two ingestions of the same file always agree; any
    reordering, truncation or parse change shows up immediately.
    """
    h = _FNV_OFFSET
    for b in np.ascontiguousarray(keys, np.uint32).tobytes():
        h = ((h ^ b) * _FNV_PRIME) & _MASK
    return f"{_fmix32_int(h):08x}"


def detect_format(path: str) -> str:
    """File-extension format sniff: ``.csv`` -> "csv", else "arc"."""
    return "csv" if os.path.splitext(path)[1].lower() == ".csv" else "arc"


# ---------------------------------------------------------------------------
# parsers (streaming)
# ---------------------------------------------------------------------------

def _iter_arc(path: str, chunk: int):
    buf = []
    n_seen = 0
    with open(path) as f:
        for lineno, line in enumerate(f, start=1):
            fields = line.split()
            if not fields:
                continue                     # blank lines are separators
            try:
                key = int(fields[0], 10)
            except ValueError:
                raise ValueError(
                    f"{path}:{lineno}: malformed ARC/LIRS trace line "
                    f"{line.strip()!r} — the first field must be a decimal "
                    "key") from None
            buf.append(_sanitize(key))
            n_seen += 1
            if len(buf) >= chunk:
                yield np.asarray(buf, np.uint32)
                buf = []
    if buf:
        yield np.asarray(buf, np.uint32)
    if n_seen == 0:
        raise ValueError(f"{path}: empty trace (no requests parsed)")


def _header_columns(row) -> dict | None:
    """Map column name -> index when ``row`` is a header row, else None."""
    names = [c.strip().lower() for c in row]
    if "op" in names and "key" in names:
        return {name: i for i, name in enumerate(names)}
    return None


#: positional TTL column for headerless CSV rows (``op,key[,size[,ttl]]``)
_TTL_POS = 3


def _iter_csv(path: str, chunk: int, ops, with_ttl: bool = False):
    ops = None if ops is None else frozenset(o.lower() for o in ops)
    buf: list[str] = []
    tbuf: list[int] = []
    n_seen = 0

    def flush():
        arr = fingerprint_keys(buf)
        buf.clear()
        if not with_ttl:
            return arr
        tarr = np.asarray(tbuf, np.int32)
        tbuf.clear()
        return arr, tarr

    with open(path, newline="") as f:
        reader = _csv.reader(f)
        cols = {"op": 0, "key": 1}
        ttl_col = _TTL_POS
        first = True
        for lineno, row in enumerate(reader, start=1):
            if not row or all(not c.strip() for c in row):
                continue
            if first:
                first = False
                named = _header_columns(row)
                if named is not None:
                    cols = named
                    # header-named ttl column wins; a header without one
                    # means the file has no TTLs (don't misread a stray
                    # positional column as deadlines)
                    ttl_col = named.get("ttl")
                    continue                 # header row consumed
            if len(row) <= max(cols["op"], cols["key"]):
                raise ValueError(
                    f"{path}:{lineno}: malformed CSV trace row {row!r} — "
                    f"need op/key columns at indices "
                    f"{cols['op']}/{cols['key']}")
            op = row[cols["op"]].strip().lower()
            key = row[cols["key"]].strip()
            if not op or not key:
                raise ValueError(
                    f"{path}:{lineno}: malformed CSV trace row {row!r} — "
                    "empty op or key field")
            n_seen += 1
            if ops is not None and op not in ops:
                continue
            buf.append(key)
            if with_ttl:
                ttl = 0                      # absent column -> never expires
                if ttl_col is not None and len(row) > ttl_col:
                    field = row[ttl_col].strip()
                    if field:
                        try:
                            ttl = int(field, 10)
                        except ValueError:
                            raise ValueError(
                                f"{path}:{lineno}: malformed CSV trace row "
                                f"{row!r} — ttl column must be a decimal "
                                f"integer, got {field!r}") from None
                tbuf.append(ttl)
            if len(buf) >= chunk:
                yield flush()
    if buf:
        yield flush()
    if n_seen == 0:
        raise ValueError(f"{path}: empty trace (no requests parsed)")


def iter_trace_chunks(path: str, fmt: str | None = None,
                      chunk: int = 1 << 16, ops=None,
                      with_ttl: bool = False):
    """Stream a trace file as uint32 key-array chunks (<= ``chunk`` keys).

    ``fmt``: "arc" | "csv" | None (sniff from the extension).  ``ops``
    filters CSV rows to the given operation names (e.g. ``READ_OPS``);
    ignored for the op-less ARC format.  ``with_ttl`` yields
    ``(keys, ttls)`` pairs instead (int32 TTLs; see the module docstring
    for the column contract — ARC traces yield all-zero TTLs).
    """
    fmt = fmt or detect_format(path)
    if fmt == "arc":
        it = _iter_arc(path, chunk)
        if not with_ttl:
            return it
        return ((arr, np.zeros(len(arr), np.int32)) for arr in it)
    if fmt == "csv":
        return _iter_csv(path, chunk, ops, with_ttl=with_ttl)
    raise ValueError(f"unknown trace format {fmt!r}; expected 'arc' or 'csv'")


def load_trace(path: str, fmt: str | None = None, limit: int | None = None,
               ops=None, with_ttl: bool = False):
    """Parse a whole trace file -> uint32 key array (see module docstring).

    ``limit`` stops the streaming read after that many requests — a cheap
    way to sample the head of a multi-GB trace.  ``with_ttl`` returns
    ``(keys, ttls)`` (int32 TTLs, 0 = never expires) instead of bare keys.
    """
    parts, tparts, total = [], [], 0
    for item in iter_trace_chunks(path, fmt=fmt, ops=ops, with_ttl=with_ttl):
        arr, tarr = item if with_ttl else (item, None)
        parts.append(arr)
        if with_ttl:
            tparts.append(tarr)
        total += len(arr)
        if limit is not None and total >= limit:
            break
    if not parts:
        raise ValueError(
            f"{path}: no requests survived the op filter {sorted(ops)!r}")
    out = parts[0] if len(parts) == 1 else np.concatenate(parts)
    out = out[:limit] if limit is not None else out
    if not with_ttl:
        return out
    tout = tparts[0] if len(tparts) == 1 else np.concatenate(tparts)
    return out, tout[:len(out)]


# ---------------------------------------------------------------------------
# traces.generate() registry integration
# ---------------------------------------------------------------------------

def register_trace(name: str, path: str, fmt: str | None = None,
                   ops=None, limit: int | None = None,
                   ttl: bool = False) -> str:
    """Register a trace file as a ``traces.generate()`` family.

    The file is parsed lazily on first use and memoized.  The family
    callable ignores the rng (real traces are fixed request streams — the
    seed only matters for synthetic families) and serves the first ``n``
    requests, tiling the file when ``n`` exceeds its length, so ingested
    traces satisfy the same ``generate(family, n)`` contract as every
    synthetic family.  Returns ``name``.

    ``ttl=True`` additionally parses the file's TTL column (module
    docstring) and registers the trace in ``traces.TTL_FAMILIES``:
    ``traces.generate_ttl(name, n)`` then serves the ``(keys, ttls)``
    pair, tiled in lockstep, so a TTL-bearing fixture replays through
    ``simulate.replay_batched(..., ttls=...)`` unchanged.
    """
    cache: dict = {}

    def _load():
        if "keys" not in cache:
            if ttl:
                cache["keys"], cache["ttls"] = load_trace(
                    path, fmt=fmt, limit=limit, ops=ops, with_ttl=True)
            else:
                cache["keys"] = load_trace(path, fmt=fmt, limit=limit,
                                           ops=ops)

    def _tile(arr, n):
        if n <= len(arr):
            return arr[:n].copy()
        reps = -(-n // len(arr))
        return np.tile(arr, reps)[:n]

    def ingested(rng, n):
        _load()
        return _tile(cache["keys"], n)

    ingested.__name__ = f"ingested_{name}"
    ingested.path = path
    traces.register_family(name, ingested)
    if ttl:
        def ingested_ttl(rng, n):
            _load()
            return _tile(cache["keys"], n), _tile(cache["ttls"], n)

        ingested_ttl.__name__ = f"ingested_{name}_ttl"
        ingested_ttl.path = path
        traces.TTL_FAMILIES[name] = ingested_ttl
    return name


def unregister_trace(name: str) -> None:
    """Remove a ``register_trace`` entry from the family registry."""
    traces.unregister_family(name)


#: committed fixture traces (tests/fixtures/*) registered by
#: ``register_fixture_traces`` — name -> filename.  ``lirs_two_pools`` is
#: the deterministic LIRS-style loop workload the hierarchy and showdown
#: sweeps use as their "real trace" family (see
#: tests/fixtures/make_lirs_two_pools.py for provenance);
#: ``sample_twitter_ttl`` is the pinned TTL-column CSV exercising the
#: DESIGN.md §15 ingestion path (registered with ``ttl=True``).
FIXTURE_TRACES = {"lirs_two_pools": "lirs_two_pools.trace",
                  "sample_twitter_ttl": "sample_twitter_ttl.csv"}

#: fixtures whose files carry a TTL column (registered with ``ttl=True``)
_TTL_FIXTURES = frozenset({"sample_twitter_ttl"})


def fixture_dir() -> str:
    """Path of the repo's committed ``tests/fixtures`` directory."""
    here = os.path.dirname(os.path.abspath(__file__))
    # src/repro/core -> repo root is three levels up
    return os.path.join(os.path.dirname(os.path.dirname(
        os.path.dirname(here))), "tests", "fixtures")


def register_fixture_traces() -> list[str]:
    """Register every committed fixture trace as a ``generate()`` family.

    Idempotent (``register_trace`` overwrites in place); returns the list
    of family names registered.  Benchmarks call this so sweeps can name
    ``lirs_two_pools`` alongside the synthetic families.
    """
    root = fixture_dir()
    names = []
    for name, fname in FIXTURE_TRACES.items():
        path = os.path.join(root, fname)
        if os.path.exists(path):
            names.append(register_trace(name, path,
                                        ttl=name in _TTL_FIXTURES))
    return names
