"""Synthetic trace families standing in for the paper's workload suite.

The paper evaluates on Wikipedia, Sprite, multi1-3, OLTP, DS1, S1/S3, P8-14,
F1/F2 and W2/W3 traces — none redistributable offline.  Each family below is
parameterized to match a *class* of those workloads (DESIGN.md §6):

  zipf            — web/CDN-like skewed popularity (wiki*, S*, W*)
  zipf_shift      — popularity drifts in phases (multi1-3 mixtures)
  scan_loop       — cyclic scans larger than the cache (glimpse/postgres;
                    the classic LRU-killer)
  recency         — stack-distance-driven, strongly recency-biased (sprite,
                    filesystem traces)
  oltp_mix        — skewed working set + uniform background writes (OLTP,
                    F1/F2 financial)
  ttl_churn       — TTL-bearing memcached-style mix (DESIGN.md §15): a
                    Zipf-popular core with long TTLs over a churning
                    uniform minority with short TTLs.  ``generate`` serves
                    the keys; ``generate_ttl`` returns ``(keys, ttls)``.

Generators are seeded numpy (host side — traces are inputs, not model state).

Ingested real traces (``core/trace_io.py``) register additional families at
runtime via ``register_family`` — every registry entry, synthetic or
ingested, is callable as ``fn(rng, n, **kw) -> np.ndarray`` and drops into
``generate()`` (and therefore every sweep, gate and golden-trace workflow)
unchanged.
"""
from __future__ import annotations

import inspect

import numpy as np

__all__ = ["generate", "generate_ttl", "FAMILIES", "TTL_FAMILIES",
           "register_family", "unregister_family"]


def _zipf_catalog(rng: np.random.Generator, n: int, catalog: int, alpha: float):
    ranks = np.arange(1, catalog + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    # Random identity permutation so key id != popularity rank.
    ident = rng.permutation(catalog).astype(np.uint32)
    draws = rng.choice(catalog, size=n, p=p)
    return ident[draws]


def zipf(rng, n, catalog=1 << 16, alpha=0.9):
    return _zipf_catalog(rng, n, catalog, alpha)


def zipf_shift(rng, n, catalog=1 << 16, alpha=0.9, phases=4):
    """Popularity permutation re-drawn each phase (multi* style)."""
    per = n // phases
    parts = []
    for p in range(phases):
        m = per if p < phases - 1 else n - per * (phases - 1)
        parts.append(_zipf_catalog(rng, m, catalog, alpha) + np.uint32(p * catalog))
    return np.concatenate(parts)


def scan_loop(rng, n, working=1 << 14, noise=0.1, catalog=1 << 20):
    """Sequential loop over `working` keys with `noise` random accesses."""
    base = np.arange(n, dtype=np.uint32) % np.uint32(working)
    mask = rng.random(n) < noise
    base[mask] = rng.integers(0, catalog, size=mask.sum(), dtype=np.uint32)
    return base


def recency(rng, n, catalog=1 << 18, theta=0.8):
    """Stack-distance model: each access re-references a recently used key
    with probability theta (distance ~ geometric), else a fresh key."""
    window = 4096
    recent = np.full(window, 0, dtype=np.uint32)
    out = np.empty(n, dtype=np.uint32)
    head = 0
    fresh = iter(rng.integers(0, catalog, size=n, dtype=np.uint32))
    reuse = rng.random(n) < theta
    dist = rng.geometric(0.02, size=n) % window
    for i in range(n):
        if reuse[i] and i > 0:
            # Only the most recent min(i, window) ring slots have been
            # written; an unclamped distance wraps into unwritten zero slots
            # and inflates key 0's popularity for the whole warm-up window.
            k = recent[(head - 1 - dist[i] % min(i, window)) % window]
        else:
            k = next(fresh)
        out[i] = k
        recent[head % window] = k
        head += 1
    return out


def oltp_mix(rng, n, catalog=1 << 17, alpha=1.1, hot_frac=0.7):
    hot = _zipf_catalog(rng, n, max(1024, catalog // 64), alpha)
    cold = rng.integers(0, catalog, size=n, dtype=np.uint32)
    take_hot = rng.random(n) < hot_frac
    return np.where(take_hot, hot, cold + np.uint32(1 << 24)).astype(np.uint32)


def ttl_churn(rng, n, catalog=1 << 12, alpha=0.9, hot_ttl=4096,
              churn_ttl=48, churn_frac=0.3):
    """Memcached-style TTL workload (DESIGN.md §15): a Zipf-popular core
    whose entries live long (``hot_ttl`` clock ticks) interleaved with a
    churning uniform minority (fraction ``churn_frac``, disjoint key range)
    whose entries expire almost immediately (``churn_ttl``).  A cache that
    never reclaims expired lanes drowns in dead churn entries; one that
    prefers expired victims keeps the hot core resident.

    Returns ``(keys, ttls)`` — uint32 keys and int32 per-request TTLs.
    Callable through ``generate`` (keys only) or ``generate_ttl`` (both).
    """
    hot = _zipf_catalog(rng, n, catalog, alpha)
    cold = rng.integers(0, catalog, size=n, dtype=np.uint32)
    churn = rng.random(n) < churn_frac
    keys = np.where(churn, cold + np.uint32(catalog), hot).astype(np.uint32)
    ttls = np.where(churn, churn_ttl, hot_ttl).astype(np.int32)
    return keys, ttls


FAMILIES = {
    "zipf": zipf,
    "zipf_shift": zipf_shift,
    "scan_loop": scan_loop,
    "recency": recency,
    "oltp_mix": oltp_mix,
    "ttl_churn": lambda rng, n, **kw: ttl_churn(rng, n, **kw)[0],
}

#: TTL-bearing families: ``fn(rng, n, **kw) -> (keys uint32, ttls int32)``.
#: ``generate()`` serves the key stream of such a family (the keys-only
#: wrapper above); ``generate_ttl()`` returns both streams from ONE rng
#: draw, so ``generate_ttl(f, n, seed)[0] == generate(f, n, seed)``.
#: ``core/trace_io.py`` registers ingested TTL-column traces here too.
TTL_FAMILIES = {
    "ttl_churn": ttl_churn,
}

#: the synthetic families above are permanent; runtime registrations
#: (ingested traces) may shadow nothing in this set
_BUILTINS = frozenset(FAMILIES)


def register_family(name: str, fn) -> None:
    """Register a runtime trace family (``fn(rng, n, **kw) -> ndarray``).

    Used by ``core/trace_io.py`` to drop ingested real traces into the
    ``generate()`` registry.  Re-registering a runtime family replaces it;
    the built-in synthetic families cannot be shadowed.
    """
    if name in _BUILTINS:
        raise ValueError(
            f"cannot register {name!r}: it would shadow the built-in "
            f"synthetic family of the same name")
    FAMILIES[name] = fn


def unregister_family(name: str) -> None:
    """Remove a runtime-registered family (built-ins cannot be removed).
    Drops a matching runtime ``TTL_FAMILIES`` entry alongside."""
    if name in _BUILTINS:
        raise ValueError(f"cannot unregister built-in family {name!r}")
    FAMILIES.pop(name, None)
    TTL_FAMILIES.pop(name, None)


def generate(family: str, n: int, seed: int = 0, **kw) -> np.ndarray:
    fn = FAMILIES.get(family)
    if fn is None:
        raise ValueError(
            f"unknown trace family {family!r}; known families: "
            f"{', '.join(sorted(FAMILIES))}")
    params = inspect.signature(fn).parameters
    if not any(p.kind is inspect.Parameter.VAR_KEYWORD
               for p in params.values()):
        bad = sorted(set(kw) - set(params))
        if bad:
            accepted = sorted(set(params) - {"rng", "n"})
            raise ValueError(
                f"unknown trace kwargs {bad} for family {family!r}; "
                f"accepted: {accepted}")
    rng = np.random.default_rng(seed)
    return fn(rng, n, **kw).astype(np.uint32)


def generate_ttl(family: str, n: int, seed: int = 0, **kw):
    """``(keys, ttls)`` for a TTL-bearing family (``TTL_FAMILIES``).

    The family draws both streams from one seeded rng, so the key stream
    is bit-identical to ``generate(family, n, seed, **kw)`` — a TTL-aware
    replay and a TTL-blind replay of the same family see the same keys.
    """
    fn = TTL_FAMILIES.get(family)
    if fn is None:
        raise ValueError(
            f"unknown TTL trace family {family!r}; known TTL families: "
            f"{', '.join(sorted(TTL_FAMILIES))}")
    rng = np.random.default_rng(seed)
    keys, ttls = fn(rng, n, **kw)
    return keys.astype(np.uint32), np.asarray(ttls, np.int32)
