"""Unified CacheBackend layer — one API over the jnp / Pallas / oracle paths.

The paper's limited-associativity design makes each set an independent unit
of work, which is why the same cache runs on three execution substrates in
this repo: vectorized XLA ops (core/kway.py), a Pallas TPU kernel
(kernels/kway_probe.py), and a sequential Python oracle (core/refimpl.py).
This module gives them one contract (DESIGN.md §3):

    backend = make_backend("jnp" | "pallas" | "ref", cfg)
    state = backend.init()
    state, hit, vals = backend.get(state, keys)
    state, ek, ev, slot_sets, slot_ways = backend.put(state, keys, vals)
    state, hit, vals, ek, ev = backend.access(state, keys, vals)
    vkeys, vvalid = backend.peek_victims(state, keys)
    hits, evs, state, sketch = backend.replay(state, chunks, enabled)

All backends are functional (state in, state out) over the same ``KWayState``
pytree, so states are interchangeable between backends mid-stream — the
differential test suite replays one trace through all three and asserts
bit-identical hits, evictions and final state.

``put`` returns the landing ``(set, way)`` slot per request (-1 when the key
did not land), which is what lets serve/engine.py store "payload == slot id"
in a single call instead of probing again after the write.

Semantics:
  * ``jnp`` and ``pallas`` share the deterministic batched conflict
    resolution of core/kway.apply_put and agree bit-for-bit at any batch
    size (the kernel emits the same probe decisions the jnp path computes).
  * ``ref`` processes lanes of a batch sequentially within each phase; it is
    bit-identical to the others at batch size 1 and a valid serialization at
    larger batches (the documented CAS-race outcomes may differ).
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admission, hashing, kway
from repro.core.hashing import EMPTY_KEY
from repro.core.kway import KWayConfig, KWayState
from repro.core.refimpl import RefKWay

#: VMEM budget for the trace-resident replay megakernel (DESIGN.md §10):
#: the resident footprint — input + working copies of the 5 state lanes at
#: the 128-lane padded width, plus streams and sketch — must fit the ~16 MiB
#: of a TPU core with headroom for the compiler.  Past this the flat
#: resident path is unavailable; the hierarchical kernel (DESIGN.md §14)
#: or the chunked-scan replay take over.
RESIDENT_VMEM_BUDGET = 12 << 20


@contextlib.contextmanager
def vmem_budget(nbytes: int):
    """Temporarily override ``RESIDENT_VMEM_BUDGET`` (try/finally restore).

    The chaos figures and tests force VMEM breaches by shrinking the
    budget; doing that with an inline set/restore leaks the override when
    the timed call raises mid-measurement.  This is the one sanctioned way
    to patch the budget.
    """
    global RESIDENT_VMEM_BUDGET
    prev = RESIDENT_VMEM_BUDGET
    RESIDENT_VMEM_BUDGET = nbytes
    try:
        yield
    finally:
        RESIDENT_VMEM_BUDGET = prev

_REGISTRY: dict[str, type] = {}


def register_backend(name: str):
    """Class decorator: register a CacheBackend implementation under ``name``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


def make_backend(name: str, cfg: KWayConfig) -> "CacheBackend":
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown cache backend {name!r}; available: {available_backends()}"
        )
    return _REGISTRY[name](cfg)


class CacheBackend:
    """The backend contract.  Subclasses implement get/put/peek_victims;
    ``access`` (get; on miss, put) is derived and shared."""

    name = "?"
    traceable = True   # safe under jit/vmap/shard_map (False: host Python)

    def __init__(self, cfg: KWayConfig):
        self.cfg = cfg
        # (tinylfu, has_ttl) -> jitted chunked-scan replay
        self._replay_fns: dict = {}

    def init(self, *, ttl: bool = False) -> KWayState:
        return kway.make_cache(self.cfg, ttl=ttl)

    # -- required ----------------------------------------------------------
    def get(self, state, qkeys, enabled=None):
        """-> (state', hit bool[B], vals int32[B])"""
        raise NotImplementedError

    def put(self, state, qkeys, qvals, admit=None, enabled=None, *,
            slot_value: bool = False):
        """-> (state', evicted_keys[B], evicted_valid[B], slot_sets[B],
        slot_ways[B]); slot_* == -1 where the key did not land."""
        raise NotImplementedError

    def peek_victims(self, state, qkeys):
        """-> (victim_keys uint32[B], victim_valid bool[B]), no mutation."""
        raise NotImplementedError

    # -- derived -----------------------------------------------------------
    def access_two_phase(self, state, qkeys, qvals, admit_on_miss=None,
                         enabled=None, *, slot_value: bool = False):
        """The unfused get-then-put-on-miss composition — two probes, two
        apply passes.  Kept on every backend as the differential oracle for
        the fused ``access`` (tests assert bit-identity).

        ``slot_value`` is the cache-as-allocator mode: the put phase stores
        slot ids as payload and ``vals`` returns, per lane, the page/slot id
        the key resides in (hit or fresh insert) or -1 where it did not
        land — the serving engine's one-call prefix-chain transaction."""
        state, hit, vals = self.get(state, qkeys, enabled=enabled)
        en = (~hit) if enabled is None else (enabled & ~hit)
        state, ek, ev, ss, sw = self.put(
            state, qkeys, qvals, admit=admit_on_miss, enabled=en,
            slot_value=slot_value,
        )
        if slot_value:
            slot_id = ss * jnp.int32(self.cfg.ways) + sw
            vals = jnp.where(hit, vals, jnp.where(ss >= 0, slot_id, -1))
        else:
            vals = jnp.where(hit, vals, qvals)
        return state, hit, vals, ek, ev

    def access(self, state, qkeys, qvals, admit_on_miss=None, enabled=None,
               ttls=None, *, slot_value: bool = False):
        """-> (state', hit[B], vals[B], evicted_keys[B], evicted_valid[B])

        Backends with a fused single-probe path override this; the default
        is the two-phase composition (the ref oracle replays sequentially
        either way).  ``ttls`` (int32 [B], optional) gives each request a
        time-to-live on the logical clock (DESIGN.md §15); the two-phase
        composition has no expiry semantics, so the default rejects it.
        """
        if ttls is not None:
            raise ValueError(
                f"backend {self.name!r} access has no fused TTL path; "
                "per-request TTLs require the jnp, pallas or ref backend")
        return self.access_two_phase(state, qkeys, qvals,
                                     admit_on_miss=admit_on_miss,
                                     enabled=enabled, slot_value=slot_value)

    def _replay_hier(self, state, chunks, enabled, tinylfu, hierarchy,
                     ttls=None):
        """Hierarchical replay through the pure-XLA twin
        (core/hierarchy.replay_l1_over_l2).  ``state`` may be a
        ``HierState`` (resumed hierarchy) or a plain ``KWayState`` (the L2;
        a fresh empty L1 is attached).  Returns (hits, evs, HierState',
        None)."""
        from repro.core import hierarchy as hier_mod
        if tinylfu is not None:
            raise ValueError(
                "hierarchical replay does not support TinyLFU admission "
                "(the sketch has no per-tier semantics yet)")
        hst = hier_mod.as_hier_state(self.cfg, hierarchy, state)
        return hier_mod.replay_l1_over_l2(self.cfg, hierarchy, hst,
                                          chunks, enabled, ttls=ttls)

    def replay(self, state, chunks, enabled, tinylfu=None, sketch=None,
               hierarchy=None, ttls=None):
        """Replay a whole chunked trace: ``chunks`` uint32 [steps, B] and
        ``enabled`` bool [steps, B] in the ``router.pad_chunks`` layout,
        payload convention ``val == key`` (as int32).

        -> (hits int32 [steps], evs int32 [steps], state', sketch'|None):
        per-chunk hit and eviction counts, the final cache state, and the
        updated TinyLFU sketch when ``tinylfu`` is given.

        ``hierarchy`` (a :class:`repro.core.hierarchy.HierarchyConfig`
        with ``l1_sets > 0``) selects the L1-over-L2 replay mode: ``state``
        may then be a ``HierState`` or a bare L2 ``KWayState``, and the
        returned state is a ``HierState``.  ``l1_sets == 0`` (or None)
        falls through to the flat paths unchanged.

        ``ttls`` (int32 [steps, B], chunked like the trace) enables expiry
        semantics: each request's insert carries a deadline, expired
        entries are scrubbed at every batch entry and never count as hits
        (DESIGN.md §15).  Mutually exclusive with ``tinylfu`` (admission
        has no expiry-aware victim semantics yet).

        Default implementation: one jitted ``lax.scan`` over the chunks
        through the fused ``access`` with the TinyLFU record → peek → admit
        phase order of the batched replay — the chunked-scan oracle the
        trace-resident megakernel (PallasBackend) is pinned against.
        """
        if not self.traceable:
            raise ValueError(
                f"backend {self.name!r} is host Python and has no scanned "
                "replay; drive it through simulate.replay_batched")
        if ttls is not None and tinylfu is not None:
            raise ValueError(
                "per-request TTLs and TinyLFU admission are mutually "
                "exclusive (the sketch has no expiry-aware semantics)")
        if hierarchy is not None and hierarchy.enabled:
            return self._replay_hier(state, chunks, enabled, tinylfu,
                                     hierarchy, ttls=ttls)
        if ttls is not None:
            return self._replay_ttl(state, chunks, enabled, ttls)
        if tinylfu is not None and sketch is None:
            sketch = admission.make_sketch(tinylfu)
        if tinylfu is None and sketch is None:
            sketch = jnp.zeros((), jnp.int32)   # scan carry placeholder
        if tinylfu not in self._replay_fns:
            def fn(state, chunks, enabled, sketch, _tl=tinylfu):
                def step(carry, xs):
                    cache, sk = carry
                    keys, en = xs
                    admit = None
                    if _tl is not None:
                        sk = admission.record(_tl, sk, keys, enabled=en)
                        vk, vv = self.peek_victims(cache, keys)
                        admit = admission.admit(_tl, sk, keys, vk, vv)
                    cache, hit, _, _, ev = self.access(
                        cache, keys, keys.astype(jnp.int32), admit, en)
                    return (cache, sk), (jnp.sum(hit.astype(jnp.int32)),
                                         jnp.sum(ev.astype(jnp.int32)))

                (state, sk), (hits, evs) = jax.lax.scan(
                    step, (state, sketch), (chunks, enabled))
                return hits, evs, state, sk
            self._replay_fns[tinylfu] = jax.jit(fn)
        hits, evs, state, sk = self._replay_fns[tinylfu](
            jax.tree_util.tree_map(jnp.asarray, state),
            jnp.asarray(chunks, jnp.uint32), jnp.asarray(enabled, jnp.bool_),
            sketch)
        return hits, evs, state, (sk if tinylfu is not None else None)

    def _replay_ttl(self, state, chunks, enabled, ttls):
        """TTL-enabled chunked-scan replay: a separate scan whose xs carry
        the per-request TTL stream.  Kept apart from the TTL-less scan so
        the ``ttls=None`` replay traces the exact pre-TTL program."""
        state = kway.ensure_expiry(state)
        key = ("ttl",)
        if key not in self._replay_fns:
            def fn(state, chunks, enabled, tchunks):
                def step(cache, xs):
                    keys, en, tt = xs
                    cache, hit, _, _, ev = self.access(
                        cache, keys, keys.astype(jnp.int32), None, en,
                        ttls=tt)
                    return cache, (jnp.sum(hit.astype(jnp.int32)),
                                   jnp.sum(ev.astype(jnp.int32)))

                state, (hits, evs) = jax.lax.scan(
                    step, state, (chunks, enabled, tchunks))
                return hits, evs, state
            self._replay_fns[key] = jax.jit(fn)
        hits, evs, state = self._replay_fns[key](
            jax.tree_util.tree_map(jnp.asarray, state),
            jnp.asarray(chunks, jnp.uint32), jnp.asarray(enabled, jnp.bool_),
            jnp.asarray(ttls, jnp.int32))
        return hits, evs, state, None


@register_backend("jnp")
class JnpBackend(CacheBackend):
    """Today's vectorized XLA path (core/kway.py), unchanged semantics."""

    def get(self, state, qkeys, enabled=None):
        return kway.get(self.cfg, state, qkeys, enabled=enabled)

    def put(self, state, qkeys, qvals, admit=None, enabled=None, *,
            slot_value: bool = False):
        return kway.put(self.cfg, state, qkeys, qvals, admit=admit,
                        enabled=enabled, slot_value=slot_value)

    def access(self, state, qkeys, qvals, admit_on_miss=None, enabled=None,
               ttls=None, *, slot_value: bool = False):
        # fused single-probe path (kway.apply_access); bit-identical to
        # access_two_phase
        return kway.access(self.cfg, state, qkeys, qvals,
                           admit_on_miss=admit_on_miss, enabled=enabled,
                           ttls=ttls, slot_value=slot_value)

    def access_donated(self, state, qkeys, qvals, admit_on_miss=None,
                       enabled=None, *, slot_value: bool = False):
        """Fused access with the ``state`` buffers donated to XLA —
        in-place update of the 5 S×k lanes.  The caller must rebind and
        never reuse the input state."""
        return kway.access_donated(self.cfg, state, qkeys, qvals,
                                   admit_on_miss, enabled,
                                   slot_value=slot_value)

    def peek_victims(self, state, qkeys):
        return kway.peek_victims(self.cfg, state, qkeys)


@register_backend("pallas")
class PallasBackend(CacheBackend):
    """Pallas kernel probe (interpret mode off-TPU) + the shared scatter
    apply.  Bit-identical to ``jnp`` at any batch size: the kernel emits the
    same (hit, way, victim-order) decisions core/kway computes, and both
    paths funnel through kway.apply_get / kway.apply_put."""

    def __init__(self, cfg: KWayConfig):
        from repro.kernels import kway_probe as _kp
        if cfg.sample:
            raise ValueError("pallas backend does not support sampled "
                             "policies (cfg.sample > 0); use the jnp backend")
        if cfg.ways > _kp.LANES:
            raise ValueError(
                f"pallas backend requires ways <= {_kp.LANES} (one VREG row "
                f"per set); got {cfg.ways}")
        super().__init__(cfg)

    def get(self, state, qkeys, enabled=None):
        from repro.kernels import ops
        # need_victims=False kernel variant: the read path skips the
        # victim-selection rounds entirely
        _, sets, hit, way = ops.probe_hits(
            self.cfg, state, jnp.asarray(qkeys, jnp.uint32))
        if enabled is not None:
            hit = hit & enabled
        return kway.apply_get(self.cfg, state, sets, hit, way)

    def access(self, state, qkeys, qvals, admit_on_miss=None, enabled=None,
               ttls=None, *, slot_value: bool = False):
        # ONE kernel launch (fused probe + victim order on hit-updated
        # metadata) + the shared fused apply — bit-identical to the
        # two-launch access_two_phase path.  The expiry scrub runs before
        # the probe launch (exactly where the jnp path scrubs), so the
        # kernel itself needs no expiry awareness.
        from repro.kernels import ops
        if state.expiry is not None:
            b = jnp.asarray(qkeys).shape[0]
            state = kway.scrub_expired(state,
                                       state.clock + jnp.int32(2 * b))
        qk, sets, hit_raw, way, order = ops.fused_probe(
            self.cfg, state, jnp.asarray(qkeys, jnp.uint32), enabled)
        return kway.apply_access(
            self.cfg, state, qk, qvals, sets, hit_raw, way,
            admit_on_miss, enabled, order=order, ttls=ttls,
            slot_value=slot_value)

    def put(self, state, qkeys, qvals, admit=None, enabled=None, *,
            slot_value: bool = False):
        from repro.kernels import ops
        qk, sets, present, way_present, order = ops.probe_orders(
            self.cfg, state, jnp.asarray(qkeys, jnp.uint32)
        )
        return kway.apply_put(
            self.cfg, state, qk, qvals, sets, present, way_present, order,
            admit, enabled, slot_value=slot_value,
        )

    def peek_victims(self, state, qkeys):
        from repro.kernels import ops
        _, _, hit, _, _, vkey = ops.probe(self.cfg, state,
                                          jnp.asarray(qkeys, jnp.uint32))
        valid = (vkey != EMPTY_KEY) & (~hit)
        return vkey, valid

    # -- trace-resident replay (DESIGN.md §10) -----------------------------
    def resident_fits(self) -> bool:
        """True when the replay megakernel's VMEM-resident footprint fits
        the budget: input + working copies of the 5 state lanes at the
        128-lane padded width (streams and sketch are noise next to them)."""
        from repro.kernels import kway_probe as _kp
        lane_bytes = self.cfg.num_sets * _kp.LANES * 4
        return 2 * 5 * lane_bytes <= RESIDENT_VMEM_BUDGET

    def hier_fits(self, hierarchy) -> bool:
        """True when the HIERARCHICAL megakernel's VMEM-resident footprint
        (the five L1 lanes, padded and double-buffered — same accounting as
        ``resident_fits`` with ``l1_sets`` in place of ``num_sets``) fits
        the budget.  The L2 stays in slow memory and does not count."""
        from repro.core.hierarchy import hier_footprint_bytes
        return hier_footprint_bytes(hierarchy) <= RESIDENT_VMEM_BUDGET

    def replay_scan(self, state, chunks, enabled, tinylfu=None, sketch=None,
                    ttls=None):
        """The chunked-scan replay (the CacheBackend default), kept callable
        on this backend as the megakernel's differential oracle and as the
        fallback when the cache state exceeds the VMEM budget."""
        return CacheBackend.replay(self, state, chunks, enabled,
                                   tinylfu=tinylfu, sketch=sketch, ttls=ttls)

    def replay(self, state, chunks, enabled, tinylfu=None, sketch=None,
               hierarchy=None, ttls=None):
        """Trace-resident replay with a three-way dispatch (DESIGN.md §14):

          1. ``hierarchy`` configured (``l1_sets > 0``) → the hierarchical
             megakernel: L1 pinned in VMEM, L2 behind per-set row DMAs —
             near-resident throughput at capacities far past the flat
             budget.  If even the L1 exceeds the budget, the L1 tier is
             abandoned (``l1_demotion`` event) and the jnp twin runs.
          2. no hierarchy, flat state fits (``resident_fits``) → the flat
             megakernel: ALL lanes pinned in VMEM, bit-identical to
             ``replay_scan``.
          3. otherwise → the chunked-scan replay (``vmem_budget`` event;
             the hierarchical mode is named in the event detail as the
             faster opt-in).
        """
        from repro.kernels import ops
        if ttls is not None and tinylfu is not None:
            raise ValueError(
                "per-request TTLs and TinyLFU admission are mutually "
                "exclusive (the sketch has no expiry-aware semantics)")
        if hierarchy is not None and hierarchy.enabled:
            if tinylfu is not None:
                raise ValueError(
                    "hierarchical replay does not support TinyLFU admission "
                    "(the sketch has no per-tier semantics yet)")
            from repro.core import hierarchy as hier_mod
            hst = hier_mod.as_hier_state(self.cfg, hierarchy, state,
                                         ttl=ttls is not None)
            if self.hier_fits(hierarchy):
                return ops.replay_hierarchical(self.cfg, hierarchy, hst,
                                               chunks, enabled, ttls=ttls)
            from repro.robust import events
            events.record(
                component="pallas.replay", reason="l1_demotion",
                fallback_from="pallas-resident-l1l2",
                fallback_to="jnp-l1l2-scan",
                detail=(f"L1 footprint "
                        f"{hier_mod.hier_footprint_bytes(hierarchy)} B "
                        f"exceeds budget {RESIDENT_VMEM_BUDGET} B "
                        f"(l1_sets={hierarchy.l1_sets}); hierarchy "
                        f"demoted to the jnp l1_over_l2 twin"))
            return hier_mod.replay_l1_over_l2(self.cfg, hierarchy, hst,
                                              chunks, enabled, ttls=ttls)
        if not self.resident_fits():
            from repro.robust import events
            lane_bytes = self.cfg.num_sets * 128 * 4
            events.record(
                component="pallas.replay", reason="vmem_budget",
                fallback_from="pallas-resident", fallback_to="chunked-scan",
                detail=(f"resident footprint {2 * 5 * lane_bytes} B exceeds "
                        f"budget {RESIDENT_VMEM_BUDGET} B "
                        f"(num_sets={self.cfg.num_sets}); falling back to "
                        f"chunked-scan — the hierarchical resident mode "
                        f"(HierarchyConfig(l1_sets>0)) keeps a VMEM L1 over "
                        f"the HBM L2 at this capacity"))
            return self.replay_scan(state, chunks, enabled,
                                    tinylfu=tinylfu, sketch=sketch,
                                    ttls=ttls)
        return ops.replay_resident(self.cfg, state, chunks, enabled,
                                   tinylfu=tinylfu, sketch=sketch, ttls=ttls)


@register_backend("ref")
class RefBackend(CacheBackend):
    """Sequential Python oracle behind the same functional API.

    Each call imports the KWayState into a RefKWay, replays the batch one
    lane at a time (phase order matches the batched implementations: a
    disabled lane still consumes a logical timestamp), and exports back.
    Intended for differential testing, not throughput — and being host
    Python, it cannot run under jit/vmap/shard_map (traceable=False).
    """

    traceable = False

    def _import(self, state: KWayState) -> RefKWay:
        cfg = self.cfg
        ref = RefKWay(cfg.num_sets, cfg.ways, cfg.policy, cfg.seed)
        keys = np.asarray(state.keys)
        vals = np.asarray(state.vals)
        ma = np.asarray(state.meta_a)
        mb = np.asarray(state.meta_b)
        exp = None if state.expiry is None else np.asarray(state.expiry)
        empty = int(EMPTY_KEY)
        for s in range(cfg.num_sets):
            for w in range(cfg.ways):
                if int(keys[s, w]) != empty:
                    node = {
                        "key": int(keys[s, w]), "val": int(vals[s, w]),
                        "a": int(ma[s, w]), "b": int(mb[s, w]),
                    }
                    if exp is not None:
                        node["exp"] = int(exp[s, w])
                    ref.sets[s][w] = node
        ref.clock = int(state.clock)
        # _export mirrors the lane back out only when the incoming state
        # carried one — TTL-disabled states round-trip without it.
        ref.expiry_enabled = exp is not None
        return ref

    def _export(self, ref: RefKWay) -> KWayState:
        cfg = self.cfg
        keys = np.full((cfg.num_sets, cfg.ways), int(EMPTY_KEY), np.uint32)
        vals = np.zeros((cfg.num_sets, cfg.ways), np.int32)
        ma = np.zeros((cfg.num_sets, cfg.ways), np.int32)
        mb = np.zeros((cfg.num_sets, cfg.ways), np.int32)
        has_exp = getattr(ref, "expiry_enabled", False)
        exp = (np.full((cfg.num_sets, cfg.ways), kway.NO_EXPIRY, np.int32)
               if has_exp else None)
        for s in range(cfg.num_sets):
            for w, node in enumerate(ref.sets[s]):
                if node is not None:
                    keys[s, w] = node["key"]
                    vals[s, w] = node["val"]
                    ma[s, w] = node["a"]
                    mb[s, w] = node["b"]
                    if exp is not None:
                        exp[s, w] = node.get("exp", kway.NO_EXPIRY)
        keys_j = jnp.asarray(keys)
        fpr = jnp.where(keys_j == EMPTY_KEY, jnp.uint32(0),
                        hashing.fingerprint(keys_j))
        return KWayState(
            keys=keys_j, fprint=fpr, vals=jnp.asarray(vals),
            meta_a=jnp.asarray(ma), meta_b=jnp.asarray(mb),
            clock=jnp.asarray(ref.clock, jnp.int32),
            expiry=None if exp is None else jnp.asarray(exp),
        )

    @staticmethod
    def _lanes(qkeys, enabled):
        ks = [int(k) for k in np.asarray(qkeys, np.uint32)]
        # sanitize_keys: the EMPTY_KEY sentinel folds onto 0xFFFFFFFE
        ks = [0xFFFFFFFE if k == 0xFFFFFFFF else k for k in ks]
        en = (np.ones(len(ks), bool) if enabled is None
              else np.asarray(enabled, bool))
        return ks, en

    def get(self, state, qkeys, enabled=None):
        ref = self._import(state)
        ks, en = self._lanes(qkeys, enabled)
        hit = np.zeros(len(ks), bool)
        vals = np.full(len(ks), -1, np.int32)
        for i, k in enumerate(ks):
            if not en[i]:
                ref.clock += 1  # disabled lane still consumes a timestamp
                continue
            v = ref.get(k)
            if v is not None:
                hit[i], vals[i] = True, v
        return self._export(ref), jnp.asarray(hit), jnp.asarray(vals)

    def put(self, state, qkeys, qvals, admit=None, enabled=None, *,
            slot_value: bool = False):
        ref = self._import(state)
        ks, en = self._lanes(qkeys, enabled)
        vs = np.asarray(qvals, np.int32)
        ad = (np.ones(len(ks), bool) if admit is None
              else np.asarray(admit, bool))
        b = len(ks)
        ek = np.zeros(b, np.uint32)
        ev = np.zeros(b, bool)
        slot_sets = np.full(b, -1, np.int32)
        slot_ways = np.full(b, -1, np.int32)
        for i, k in enumerate(ks):
            if not en[i]:
                ref.clock += 1
                continue
            evicted, s, w = ref.put(k, int(vs[i]), admit=bool(ad[i]))
            if w is not None:
                slot_sets[i], slot_ways[i] = s, w
                if slot_value:
                    ref.sets[s][w]["val"] = s * self.cfg.ways + w
                if getattr(ref, "expiry_enabled", False):
                    # parity with kway.apply_put: a bare put has no TTL
                    # argument, so the landing lane is marked never-expiring
                    ref.sets[s][w]["exp"] = int(kway.NO_EXPIRY)
            if evicted is not None:
                ek[i], ev[i] = evicted, True
        return (self._export(ref), jnp.asarray(ek), jnp.asarray(ev),
                jnp.asarray(slot_sets), jnp.asarray(slot_ways))

    def peek_victims(self, state, qkeys):
        ref = self._import(state)
        ks, _ = self._lanes(qkeys, None)
        clock0 = ref.clock
        vk = np.zeros(len(ks), np.uint32)
        vv = np.zeros(len(ks), bool)
        for i, k in enumerate(ks):
            ref.clock = clock0 + i   # lane i probes at logical time clock+i
            victim = ref.peek_victim(k)
            if victim is not None:
                vk[i], vv[i] = victim, True
        ref.clock = clock0
        return jnp.asarray(vk), jnp.asarray(vv)

    def access(self, state, qkeys, qvals, admit_on_miss=None, enabled=None,
               ttls=None, *, slot_value: bool = False):
        """Oracle access with the same expiry discipline as the batched
        paths (DESIGN.md §15): scrub lanes whose deadline falls at or before
        the batch-exit clock BEFORE probing (so an expired key can never be
        served), then two-phase get/put, then stamp landed lanes with
        ``clock0 + 2B + ttl`` (``ttl <= 0`` = never expires)."""
        if state.expiry is not None:
            b = int(np.asarray(qkeys).shape[0])
            state = kway.scrub_expired(state, state.clock + jnp.int32(2 * b))
        if ttls is None:
            return self.access_two_phase(
                state, qkeys, qvals, admit_on_miss=admit_on_miss,
                enabled=enabled, slot_value=slot_value)
        if state.expiry is None:
            raise ValueError(
                "ref access: ttls given but the state has no expiry lane — "
                "build it with make_cache(cfg, ttl=True) or ensure_expiry()")
        clock0 = int(state.clock)
        b = int(np.asarray(qkeys).shape[0])
        state, hit, vals = self.get(state, qkeys, enabled=enabled)
        en = (~hit) if enabled is None else (jnp.asarray(enabled) & ~hit)
        state, ek, ev, ss, sw = self.put(
            state, qkeys, qvals, admit=admit_on_miss, enabled=en,
            slot_value=slot_value)
        # deadline-stamp the lanes the put phase landed (ss/sw == -1 where
        # the key did not land); matches kway.insert_deadlines bit-for-bit
        tt = np.asarray(ttls, np.int32)
        exp = np.asarray(state.expiry).copy()
        ssn = np.asarray(ss)
        swn = np.asarray(sw)
        for i in range(b):
            if ssn[i] >= 0:
                exp[ssn[i], swn[i]] = (
                    clock0 + 2 * b + int(tt[i]) if tt[i] > 0
                    else int(kway.NO_EXPIRY))
        state = dataclasses.replace(state, expiry=jnp.asarray(exp))
        if slot_value:
            slot_id = ss * jnp.int32(self.cfg.ways) + sw
            vals = jnp.where(hit, vals, jnp.where(ss >= 0, slot_id, -1))
        else:
            vals = jnp.where(hit, vals, qvals)
        return state, hit, vals, ek, ev
