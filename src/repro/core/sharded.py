"""Set-sharded execution: the paper's "Alice and Bob never synchronize"
parallelism, with the request router resident on device (DESIGN.md §5, §9).

Sets are data-independent, so a global cache of S sets splits into D
sub-caches of S/D sets with zero cross-shard traffic.  The only cross-shard
work is routing query keys to the shard owning their set — and since PR 4
that routing is traceable jnp (core/router.py): owner = high bits of the
global set index, one stable argsort into a fixed ``[D, capacity]`` bucket
layout, inverse-permutation unscatter.  Routing therefore lives *inside*
jit — an entire chunked trace replays in ONE ``lax.scan`` (route →
vmap/shard_map fused access → unscatter per step) with the shard states
donated across steps, instead of the old per-chunk numpy bucketing with a
device↔host round trip per batch.

Execution modes:
  * ``mesh`` given — ``shard_map`` over the set axis; compiles to zero
    collectives in the cache step (verified by tests/test_kway_sharding.py);
    the router runs replicated (its inputs are the whole batch).
  * no mesh (default) — a ``vmap`` over the shard axis on one device: the
    same math, used as the single-device fallback and for CPU benchmarking.

Admission composes with sharding by privatization ("Flexible Support for
Fast Parallel Commutative Updates"): the TinyLFU sketch is stacked per shard
(leaves [D, …]) and record/peek/admit run inside the shard body on the
shard's own stream — each shard admits on its local frequency view, which
tracks the global sketch closely (tests bound the hit-ratio gap) without a
single shared-counter synchronization point.

Because every request of one set lands in the same shard bucket with its
arrival order preserved, the batched conflict resolution inside each shard
matches the unsharded cache request-for-request: hits, evictions, and final
keys/vals are identical for the timestamp-order-invariant policies
(LRU / LFU / FIFO).  RANDOM and HYPERBOLIC score on absolute clock values,
which shard-local clocks shift, so they are statistically — not bitwise —
equivalent.

Overflow-defer: with ``route_capacity`` below the batch size, lanes ranked
past a bucket's capacity are *deferred* — not processed, never silently
dropped: ``access(..., return_deferred=True)`` reports the mask, ``replay``
counts them (as misses) and returns the total.  The default capacity equals
the batch size, which can never overflow.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admission, router
from repro.core.admission import TinyLFUConfig, TinyLFUState
from repro.core.backend import make_backend
from repro.core.kway import KWayConfig, KWayState

# Trace-time side effect (same pattern as repro/eval/runner.py): each jitted
# body bumps its key once per XLA compilation, so tests can assert the fixed
# [D, capacity] router layout really is shape-stable — ≤ 1 compile per
# (op, shape) — instead of recompiling per batch like the old counts.max()
# bucketing did.
_TRACE_COUNTS: collections.Counter = collections.Counter()


def trace_counts() -> dict:
    """Compilation tally of the sharded kernels, keyed by (op, shape...)."""
    return dict(_TRACE_COUNTS)


def reset_trace_counts() -> None:
    _TRACE_COUNTS.clear()


@dataclasses.dataclass(frozen=True)
class ShardedConfig:
    """Global cache shape + how to split its set axis."""

    cache: KWayConfig            # GLOBAL shape: cache.num_sets across all shards
    num_shards: int = 1
    backend: str = "jnp"
    # Donate the stacked state leaves to the jitted shard step so each batch
    # updates the [D, S/D, k] lanes in place instead of copying them.  The
    # caller must treat the state passed to ``access`` as consumed (rebind
    # the returned one) — which is how every replay loop already uses it.
    donate: bool = False
    # Router bucket capacity (requests per shard per step).  None — the
    # default — means "the batch size", which can never overflow.  Smaller
    # values shrink the padded [D, capacity] layout; overflow lanes are
    # deferred (reported, not dropped) — see the module docstring.
    route_capacity: Optional[int] = None

    def __post_init__(self):
        assert self.num_shards >= 1
        assert self.num_shards & (self.num_shards - 1) == 0, \
            "num_shards must be a power of two (it splits the set-index bits)"
        assert self.cache.num_sets % self.num_shards == 0 and \
            self.cache.num_sets >= self.num_shards
        assert self.route_capacity is None or self.route_capacity >= 1

    @property
    def local(self) -> KWayConfig:
        """Per-shard cache config: same ways/policy, S/D sets."""
        return dataclasses.replace(
            self.cache, num_sets=self.cache.num_sets // self.num_shards
        )

    def capacity_for(self, batch: int) -> int:
        return batch if self.route_capacity is None else self.route_capacity


class ShardedCache:
    """A K-way cache whose set axis is sharded D ways.

    The state is the per-shard ``KWayState`` stacked on a leading shard axis
    (leaves [D, S/D, k]; clock [D]).  All public operations route on device:
    ``access``/``get``/``put``/``peek_victims`` are one jitted call each
    (router + per-shard op + unscatter), and ``replay`` runs a whole chunked
    trace in a single ``lax.scan``.

    ``get``/``put`` follow the CacheBackend contract closely enough for
    serve/engine.py to use a ShardedCache as its prefix-cache backend:
    ``put(slot_value=True)`` stores and reports *global* slot ids
    (``global_set * ways + way`` with ``global_set = d * S/D + local_set``).
    """

    def __init__(self, cfg: ShardedConfig, mesh=None):
        self.cfg = cfg
        self.backend = make_backend(cfg.backend, cfg.local)
        if not self.backend.traceable:
            raise ValueError(
                f"backend {cfg.backend!r} is host Python and cannot run "
                "under vmap/shard_map; shard the 'jnp' or 'pallas' backend")
        self.mesh = mesh
        if mesh is not None:
            if "sets" not in mesh.axis_names or \
                    mesh.shape["sets"] != cfg.num_shards:
                raise ValueError(
                    "mesh must carry a 'sets' axis of exactly num_shards "
                    f"devices (one shard per device); got axes "
                    f"{dict(mesh.shape)} for num_shards={cfg.num_shards}")
        self._fns: dict = {}   # (kind, *statics) -> jitted callable

    # ------------------------------------------------------------- plumbing
    def init(self, *, ttl: bool = False) -> KWayState:
        d = self.cfg.num_shards
        st = self.backend.init(ttl=ttl)
        stack = lambda l: jnp.tile(l[None], (d,) + (1,) * l.ndim)  # noqa: E731
        leaves = [stack(l)
                  for l in (st.keys, st.fprint, st.vals, st.meta_a, st.meta_b)]
        return KWayState(
            *leaves, clock=jnp.zeros((d,), jnp.int32),
            expiry=stack(st.expiry) if st.expiry is not None else None)

    def init_sketches(self, tinylfu: TinyLFUConfig) -> TinyLFUState:
        """Per-shard TinyLFU sketches, stacked on the shard axis [D, …]."""
        d = self.cfg.num_shards
        return jax.vmap(lambda _: admission.make_sketch(tinylfu))(
            jnp.arange(d))

    def owner_of(self, keys) -> np.ndarray:
        """Owning shard per key: the high bits of the global set index."""
        return np.asarray(router.owner_of(
            jnp.asarray(keys, jnp.uint32), self.cfg.cache.num_sets,
            self.cfg.num_shards, self.cfg.cache.seed))

    def _route(self, keys, enabled, capacity):
        owner = router.owner_of(keys, self.cfg.cache.num_sets,
                                self.cfg.num_shards, self.cfg.cache.seed)
        return router.route(owner, self.cfg.num_shards, capacity, enabled)

    def _local_access(self, tinylfu, two_phase, shard_idx, keys, vals, en,
                      sketch, state: KWayState, ttls=None):
        """One shard's step on its own bucket ([capacity] lanes).

        Runs the TinyLFU record→peek→admit phases on the shard's private
        sketch (same phase order as the unsharded batched replay), then the
        fused access — or the two-phase oracle when ``two_phase``.
        ``ttls`` (int32 [capacity], optional) are the bucketed per-request
        TTLs; deadlines are chunk-constant (``clock + 2·capacity + ttl``),
        so bucketing's lane permutation cannot perturb them.
        """
        del shard_idx
        be = self.backend
        admit = None
        if tinylfu is not None:
            sketch = admission.record(tinylfu, sketch, keys, enabled=en)
            vkeys, vvalid = be.peek_victims(state, keys)
            admit = admission.admit(tinylfu, sketch, keys, vkeys, vvalid)
        if two_phase:
            state, hit, out, ek, ev = be.access_two_phase(
                state, keys, vals, admit, en)
        else:
            kw = {} if ttls is None else {"ttls": ttls}
            state, hit, out, ek, ev = be.access(
                state, keys, vals, admit, en, **kw)
        return state, sketch, hit, out, ek, ev

    def _bucketed(self, plan, keys, vals, capacity):
        d = self.cfg.num_shards
        kb = router.bucket(plan, keys, d, capacity, jnp.uint32(0))
        vb = router.bucket(plan, vals, d, capacity, jnp.int32(0))
        eb = router.bucket_mask(plan, d, capacity)
        return kb, vb, eb

    def _shard_call(self, body, args_bucketed, state, sketch):
        """Run ``body`` once per shard over bucketed args: ``vmap`` on one
        device, ``shard_map`` over the mesh's set axis otherwise."""
        d = self.cfg.num_shards
        shard_ids = jnp.arange(d, dtype=jnp.int32)
        if self.mesh is None:
            return jax.vmap(body)(shard_ids, *args_bucketed, sketch, state)
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def dev(body_args):
            out = body(*jax.tree_util.tree_map(lambda x: x[0], body_args))
            return jax.tree_util.tree_map(lambda x: x[None], out)

        sharded = shard_map(
            lambda *a: dev(a), mesh=self.mesh,
            in_specs=jax.tree_util.tree_map(
                lambda _: P("sets"), (shard_ids,) + tuple(args_bucketed)
                + (sketch, state)),
            out_specs=P("sets"))
        return sharded(shard_ids, *args_bucketed, sketch, state)

    def _step(self, tinylfu, two_phase, keys, vals, enabled, state, sketch,
              capacity):
        """Route one batch, run every shard, unscatter.  Fully traceable.

        Returns (state', sketch', hit[B], out[B], ek[B], ev[B], deferred[B])
        in original request order.
        """
        plan = self._route(keys, enabled, capacity)
        kb, vb, eb = self._bucketed(plan, keys, vals, capacity)

        def body(shard_idx, k, v, e, sk, st):
            st2, sk2, hit, out, ek, ev = self._local_access(
                tinylfu, two_phase, shard_idx, k, v, e, sk, st)
            return st2, sk2, hit, out, ek, ev

        state, sketch, hit_b, out_b, ek_b, ev_b = self._shard_call(
            body, (kb, vb, eb), state, sketch)
        hit = router.unscatter(plan, hit_b, False)
        out = router.unscatter(plan, out_b, jnp.int32(-1))
        ek = router.unscatter(plan, ek_b, jnp.uint32(0))
        ev = router.unscatter(plan, ev_b, False)
        return state, sketch, hit, out, ek, ev, plan.deferred

    # ------------------------------------------------------------------ API
    def access(self, state: KWayState, keys, vals, *, tinylfu=None,
               sketches=None, two_phase=False, return_deferred=False):
        """Batched get-or-insert across all shards — one jitted call
        (device-resident routing; no host bucketing).

        Returns (state', hit[B], vals[B], evicted_keys[B], evicted_valid[B])
        in the original request order; with ``return_deferred=True`` the
        overflow-defer mask is appended.  With ``tinylfu`` the per-shard
        ``sketches`` (``init_sketches``) ride along and the updated stack is
        appended to the return.
        """
        keys = jnp.asarray(np.asarray(keys, np.uint32))
        vals = jnp.asarray(np.asarray(vals, np.int32))
        b = keys.shape[0]
        capacity = self.cfg.capacity_for(b)
        fkey = ("step", tinylfu, two_phase, capacity)
        if fkey not in self._fns:
            def fn(keys, vals, state, sketch, _tl=tinylfu, _tp=two_phase,
                   _cap=capacity):
                _TRACE_COUNTS[("step", self.cfg.backend,
                               self.cfg.num_shards, self.cfg.local.num_sets,
                               self.cfg.cache.ways, _cap, keys.shape[0],
                               _tl is not None, _tp)] += 1
                en = jnp.ones(keys.shape, jnp.bool_)
                st, sk, hit, out, ek, ev, defer = self._step(
                    _tl, _tp, keys, vals, en, state, sketch, _cap)
                return st, sk, hit, out, ek, ev, defer
            donate = (2, 3) if self.cfg.donate else ()
            self._fns[fkey] = jax.jit(fn, donate_argnums=donate)
        sketch_in = (sketches if sketches is not None
                     else jnp.zeros((self.cfg.num_shards,), jnp.int32))
        st, sk, hit, out, ek, ev, defer = self._fns[fkey](
            keys, vals, state, sketch_in)
        ret = (st, hit, out, ek, ev)
        if return_deferred:
            ret = ret + (defer,)
        if tinylfu is not None:
            ret = ret + (sk,)
        return ret

    def _bucket_all(self, chunks, en, capacity: int, tt=None):
        """Route EVERY chunk of a replay up front — one jitted call.

        Returns (kb uint32 [D, steps, capacity], eb bool [D, steps,
        capacity], tb int32 [D, steps, capacity] | None, deferred int32
        scalar): per-shard request streams in the exact per-chunk bucket
        layout the scanned replay routes step by step, transposed
        shard-major so each shard's whole trace is one contiguous
        [steps, capacity] stream (what ``CacheBackend.replay`` consumes).
        ``tb`` carries the per-request TTLs when ``tt`` is given.
        """
        fkey = ("bucket_all", capacity, chunks.shape, tt is not None)
        if fkey not in self._fns:
            def fn(chunks, en, tt, _cap=capacity):
                _TRACE_COUNTS[("bucket_all", self.cfg.backend,
                               self.cfg.num_shards, _cap,
                               chunks.shape[1])] += 1

                def per_chunk(keys, e, t):
                    plan = self._route(keys, e, _cap)
                    kb = router.bucket(plan, keys, self.cfg.num_shards,
                                       _cap, jnp.uint32(0))
                    eb = router.bucket_mask(plan, self.cfg.num_shards, _cap)
                    tb = (None if t is None else
                          router.bucket(plan, t, self.cfg.num_shards, _cap,
                                        jnp.int32(0)))
                    return kb, eb, tb, jnp.sum(plan.deferred, dtype=jnp.int32)

                kb, eb, tb, defer = jax.vmap(per_chunk)(chunks, en, tt)
                tr = lambda a: a.transpose(1, 0, 2)  # noqa: E731
                return (tr(kb), tr(eb), None if tb is None else tr(tb),
                        jnp.sum(defer))
            self._fns[fkey] = jax.jit(fn)
        return self._fns[fkey](chunks, en, tt)

    def _replay_resident(self, chunks, en, capacity, tinylfu, state,
                         hierarchy=None, ttls=None):
        """Resident replay: route all chunks once, then ONE megakernel (or
        scanned replay, for the jnp backend) per shard — D launches for the
        whole trace instead of D×steps, with each shard's five state lanes
        and TinyLFU sketch pinned in VMEM for the duration (DESIGN.md §10).

        Bit-identical to the scanned path: the per-chunk bucket streams are
        routed by the same ``router.route``, and ``CacheBackend.replay``
        applies the same fused access + admission phases per chunk.

        ``hierarchy`` threads the L1-over-L2 mode (DESIGN.md §14) through
        each shard's replay: every shard gets its OWN private L1 (attached
        fresh by ``CacheBackend.replay`` when the shard state is a bare
        ``KWayState``) while the L2 remains the sharded global state — the
        returned stacked state is a ``HierState`` of per-shard tiers.
        """
        d = self.cfg.num_shards
        kb, eb, tb, defers = self._bucket_all(chunks, en, capacity, ttls)
        sketches = (self.init_sketches(tinylfu) if tinylfu is not None
                    else None)
        hits = 0
        shard_states = []
        for i in range(d):
            st_i = jax.tree_util.tree_map(lambda l: l[i], state)
            sk_i = (jax.tree_util.tree_map(lambda l: l[i], sketches)
                    if tinylfu is not None else None)
            h, _, st_i, _ = self.backend.replay(
                st_i, kb[i], eb[i], tinylfu=tinylfu, sketch=sk_i,
                hierarchy=hierarchy,
                ttls=None if tb is None else tb[i])
            hits += int(jnp.sum(h))
            shard_states.append(st_i)
        stacked = jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls), *shard_states)
        return hits, int(defers), stacked

    def replay(self, trace, batch: int, *, tinylfu=None, two_phase=False,
               state: Optional[KWayState] = None, resident: bool = False,
               hierarchy=None, ttls=None):
        """Replay a whole trace in ONE jitted ``lax.scan`` — route, shard
        access and hit accounting all on device; the only host transfers are
        the trace in and three scalars out.

        The tail chunk is padded with disabled lanes, so every request of
        the trace is replayed.  Returns (hits, deferred, state'): ``hits``
        counts over the full trace, ``deferred`` counts overflow-deferred
        lanes (0 under the default capacity — deferred lanes are the only
        requests not replayed, and they are reported, not dropped).

        The initial ``state`` (default ``init()``) is donated to the scan:
        shard states update in place across all steps.

        ``resident=True`` routes every chunk up front and hands each shard
        its whole stream in one ``CacheBackend.replay`` call — on the
        pallas backend D trace-resident megakernel launches for the entire
        replay (see ``_replay_resident``).  Excludes ``two_phase`` (the
        resident path is the fused access) and mesh execution (the host
        drives one launch per shard).

        ``ttls`` (int array [len(trace)], optional) gives each request a
        time-to-live on the logical clock (DESIGN.md §15).  Deadlines are
        chunk-constant (``clock + 2·capacity + ttl``) and shard-local
        clocks track the global clock at chunk boundaries, so the sharded
        expiry replay stays bit-identical to the unsharded one.  Excludes
        ``two_phase`` and ``tinylfu``.
        """
        trace = np.asarray(trace, np.uint32)
        chunks, en = router.pad_chunks(trace, batch)
        chunks = jnp.asarray(chunks)
        en = jnp.asarray(en)
        capacity = self.cfg.capacity_for(batch)
        if ttls is not None:
            if two_phase:
                raise ValueError(
                    "per-request TTLs run on the fused access path; "
                    "two_phase has no expiry semantics")
            if tinylfu is not None:
                raise ValueError(
                    "per-request TTLs and TinyLFU admission are mutually "
                    "exclusive (the sketch has no expiry-aware semantics)")
            if len(np.asarray(ttls)) != len(trace):
                raise ValueError(
                    f"ttls length {len(np.asarray(ttls))} != trace length "
                    f"{len(trace)}")
            tt = np.zeros(chunks.shape, np.int32)
            tt.reshape(-1)[: len(trace)] = np.asarray(ttls, np.int32)
            tt = jnp.asarray(tt)
        else:
            tt = None

        if hierarchy is not None and hierarchy.enabled and not resident:
            raise ValueError(
                "sharded hierarchical replay runs per-shard megakernels; "
                "pass resident=True")
        if resident:
            if two_phase:
                raise ValueError(
                    "resident replay is the fused access path; two_phase "
                    "is the chunked-scan oracle — use resident=False")
            if self.mesh is not None:
                raise ValueError(
                    "resident replay drives one megakernel per shard from "
                    "the host; run mesh execution through the scanned path")
            if hierarchy is not None and hierarchy.enabled and \
                    tinylfu is not None:
                raise ValueError(
                    "hierarchical replay does not support TinyLFU admission")
            return self._replay_resident(
                chunks, en, capacity, tinylfu,
                state if state is not None
                else self.init(ttl=tt is not None),
                hierarchy=hierarchy, ttls=tt)

        fkey = ("replay", tinylfu, two_phase, capacity, batch,
                tt is not None)
        if fkey not in self._fns:
            def fn(chunks, en, tt, state, sketch, _tl=tinylfu,
                   _tp=two_phase, _cap=capacity, _ttl=tt is not None):
                _TRACE_COUNTS[("replay", self.cfg.backend,
                               self.cfg.num_shards, self.cfg.local.num_sets,
                               self.cfg.cache.ways, _cap, chunks.shape[1],
                               _tl is not None, _tp)] += 1

                def scan_step(carry, xs):
                    st, sk, hits, defers = carry
                    if _ttl:
                        keys, e, t = xs
                    else:
                        keys, e = xs
                    plan = self._route(keys, e, _cap)
                    kb, vb, eb = self._bucketed(
                        plan, keys, keys.astype(jnp.int32), _cap)
                    if _ttl:
                        tb = router.bucket(plan, t, self.cfg.num_shards,
                                           _cap, jnp.int32(0))

                        def body(shard_idx, k, v, e2, t2, sk1, st1):
                            st2, sk2, hit, out, ek, ev = self._local_access(
                                _tl, _tp, shard_idx, k, v, e2, sk1, st1,
                                ttls=t2)
                            return st2, sk2, jnp.sum(hit & e2,
                                                     dtype=jnp.int32)

                        args = (kb, vb, eb, tb)
                    else:
                        def body(shard_idx, k, v, e2, sk1, st1):
                            st2, sk2, hit, out, ek, ev = self._local_access(
                                _tl, _tp, shard_idx, k, v, e2, sk1, st1)
                            # hit counting happens pre-unscatter: summing
                            # the bucketed lanes equals summing the request
                            # lanes.
                            return st2, sk2, jnp.sum(hit & e2,
                                                     dtype=jnp.int32)

                        args = (kb, vb, eb)

                    st, sk, h = self._shard_call(body, args, st, sk)
                    return (st, sk, hits + jnp.sum(h),
                            defers + jnp.sum(plan.deferred,
                                             dtype=jnp.int32)), ()

                zero = jnp.zeros((), jnp.int32)
                xs = (chunks, en, tt) if _ttl else (chunks, en)
                (st, sk, hits, defers), _ = jax.lax.scan(
                    scan_step, (state, sketch, zero, zero), xs)
                return hits, defers, st, sk
            self._fns[fkey] = jax.jit(fn, donate_argnums=(3, 4))
        if state is None:
            state = self.init(ttl=tt is not None)
        sketch = (self.init_sketches(tinylfu) if tinylfu is not None
                  else jnp.zeros((self.cfg.num_shards,), jnp.int32))
        hits, defers, st, _ = self._fns[fkey](chunks, en, tt, state, sketch)
        return int(hits), int(defers), st

    # ----------------------------------------------- CacheBackend-ish ops
    # (the serve engine's prefix cache drives these; slot ids are global)
    def get(self, state: KWayState, qkeys, enabled=None):
        qkeys = jnp.asarray(np.asarray(qkeys, np.uint32))
        b = qkeys.shape[0]
        capacity = self.cfg.capacity_for(b)
        fkey = ("get", capacity)
        if fkey not in self._fns:
            def fn(qkeys, en, state, _cap=capacity):
                _TRACE_COUNTS[("get", self.cfg.backend, self.cfg.num_shards,
                               self.cfg.local.num_sets, self.cfg.cache.ways,
                               _cap, qkeys.shape[0])] += 1
                plan = self._route(qkeys, en, _cap)
                d = self.cfg.num_shards
                kb = router.bucket(plan, qkeys, d, _cap, jnp.uint32(0))
                eb = router.bucket_mask(plan, d, _cap)

                def body(shard_idx, k, e, sk, st):
                    del shard_idx, sk
                    st, hit, vals = self.backend.get(st, k, enabled=e)
                    return st, hit, vals

                st, hit_b, val_b = self._shard_call(
                    body, (kb, eb), state,
                    jnp.zeros((d,), jnp.int32))
                hit = router.unscatter(plan, hit_b, False)
                vals = router.unscatter(plan, val_b, jnp.int32(-1))
                return st, hit, vals
            self._fns[fkey] = jax.jit(fn)
        en = (jnp.ones((b,), jnp.bool_) if enabled is None
              else jnp.asarray(enabled))
        return self._fns[fkey](qkeys, en, state)

    def put(self, state: KWayState, qkeys, qvals, admit=None, enabled=None,
            *, slot_value: bool = False):
        qkeys = jnp.asarray(np.asarray(qkeys, np.uint32))
        qvals = jnp.asarray(np.asarray(qvals, np.int32))
        b = qkeys.shape[0]
        capacity = self.cfg.capacity_for(b)
        s_local = self.cfg.local.num_sets
        ways = self.cfg.cache.ways
        fkey = ("put", capacity, slot_value)
        if fkey not in self._fns:
            def fn(qkeys, qvals, admit, en, state, _cap=capacity,
                   _sv=slot_value):
                _TRACE_COUNTS[("put", self.cfg.backend, self.cfg.num_shards,
                               self.cfg.local.num_sets, self.cfg.cache.ways,
                               _cap, qkeys.shape[0], _sv)] += 1
                plan = self._route(qkeys, en, _cap)
                d = self.cfg.num_shards
                kb = router.bucket(plan, qkeys, d, _cap, jnp.uint32(0))
                vb = router.bucket(plan, qvals, d, _cap, jnp.int32(0))
                ab = router.bucket(plan, admit, d, _cap, False)
                eb = router.bucket_mask(plan, d, _cap)

                def body(shard_idx, k, v, a, e, sk, st):
                    del sk
                    st, ek, ev, ss, sw = self.backend.put(
                        st, k, v, admit=a, enabled=e, slot_value=_sv)
                    if _sv:
                        # The local put stored local slot ids as payload;
                        # lift them to global ids in place.  Scatter-SET the
                        # recomputed global id (not scatter-ADD an offset):
                        # two active lanes may legally share a (set, way) —
                        # a present key plus an insert victimizing its way —
                        # and duplicate-index adds would apply the shard
                        # offset twice; duplicate sets of the same value are
                        # idempotent.
                        landed = ss >= 0
                        ssw = jnp.where(landed, ss, jnp.int32(s_local))
                        gval = (ss + shard_idx * jnp.int32(s_local)) \
                            * jnp.int32(ways) + sw
                        vals2 = st.vals.at[ssw, jnp.maximum(sw, 0)].set(
                            jnp.where(landed, gval, 0), mode="drop")
                        st = dataclasses.replace(st, vals=vals2)
                    gs = jnp.where(ss >= 0, ss + shard_idx * s_local, -1)
                    return st, ek, ev, gs, sw

                st, ek_b, ev_b, ss_b, sw_b = self._shard_call(
                    body, (kb, vb, ab, eb), state,
                    jnp.zeros((d,), jnp.int32))
                ek = router.unscatter(plan, ek_b, jnp.uint32(0))
                ev = router.unscatter(plan, ev_b, False)
                ss = router.unscatter(plan, ss_b, jnp.int32(-1))
                sw = router.unscatter(plan, sw_b, jnp.int32(-1))
                return st, ek, ev, ss, sw
            self._fns[fkey] = jax.jit(fn)
        en = (jnp.ones((b,), jnp.bool_) if enabled is None
              else jnp.asarray(enabled))
        ad = (jnp.ones((b,), jnp.bool_) if admit is None
              else jnp.asarray(admit))
        return self._fns[fkey](qkeys, qvals, ad, en, state)

    def peek_victims(self, state: KWayState, qkeys):
        qkeys = jnp.asarray(np.asarray(qkeys, np.uint32))
        b = qkeys.shape[0]
        capacity = self.cfg.capacity_for(b)
        fkey = ("peek", capacity)
        if fkey not in self._fns:
            def fn(qkeys, state, _cap=capacity):
                _TRACE_COUNTS[("peek", self.cfg.backend, self.cfg.num_shards,
                               self.cfg.local.num_sets, self.cfg.cache.ways,
                               _cap, qkeys.shape[0])] += 1
                en = jnp.ones(qkeys.shape, jnp.bool_)
                plan = self._route(qkeys, en, _cap)
                d = self.cfg.num_shards
                kb = router.bucket(plan, qkeys, d, _cap, jnp.uint32(0))

                def body(shard_idx, k, sk, st):
                    del shard_idx, sk
                    return self.backend.peek_victims(st, k)

                vk_b, vv_b = self._shard_call(
                    body, (kb,), state, jnp.zeros((d,), jnp.int32))
                vk = router.unscatter(plan, vk_b, jnp.uint32(0))
                vv = router.unscatter(plan, vv_b, False)
                return vk, vv
            self._fns[fkey] = jax.jit(fn)
        return self._fns[fkey](qkeys, state)

    def global_view(self, state: KWayState) -> KWayState:
        """Reassemble the stacked shard states into the equivalent global
        single-device state (sets of shard d map to global sets
        [d*S/D, (d+1)*S/D)).  Clock is summed — a diagnostic view; policy
        metadata keeps its shard-local timestamps."""
        s, k = self.cfg.cache.num_sets, self.cfg.cache.ways
        merge = lambda l: l.reshape((s, k))  # noqa: E731
        return KWayState(
            keys=merge(state.keys), fprint=merge(state.fprint),
            vals=merge(state.vals), meta_a=merge(state.meta_a),
            meta_b=merge(state.meta_b), clock=jnp.sum(state.clock),
            expiry=(merge(state.expiry) if state.expiry is not None
                    else None),
        )
