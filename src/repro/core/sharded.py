"""Set-sharded execution: the paper's "Alice and Bob never synchronize"
parallelism across devices (DESIGN.md §5).

Sets are data-independent, so a global cache of S sets splits into D
sub-caches of S/D sets with zero cross-shard traffic: the only cross-shard
work is bucketing query keys by owning shard, which happens on the host
before launch.  The shard of a key is the HIGH log2(D) bits of its global
set index, so each shard's local ``set_index`` (the LOW bits of the same
hash) needs no rewriting — shard d's local set s is global set
``d * (S/D) + s``, and the disjoint union of the shard states *is* the
global cache, slot for slot.

Execution modes:
  * ``mesh`` given — ``shard_map`` over the set axis; compiles to zero
    collectives (verified by tests/test_kway_sharding.py).
  * no mesh (default) — a ``vmap`` over the shard axis on one device: the
    same math, bucketing and per-shard states, used as the single-device
    fallback and for CPU benchmarking.

Because every request of one set lands in the same shard bucket with its
arrival order preserved, the batched conflict resolution inside each shard
matches the unsharded cache request-for-request: hits, evictions, and final
keys/vals are identical for the timestamp-order-invariant policies
(LRU / LFU / FIFO).  RANDOM and HYPERBOLIC score on absolute clock values,
which shard-local clocks shift, so they are statistically — not bitwise —
equivalent.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.backend import make_backend
from repro.core.kway import KWayConfig, KWayState


@dataclasses.dataclass(frozen=True)
class ShardedConfig:
    """Global cache shape + how to split its set axis."""

    cache: KWayConfig            # GLOBAL shape: cache.num_sets across all shards
    num_shards: int = 1
    backend: str = "jnp"
    # Donate the stacked state leaves to the jitted shard step so each batch
    # updates the [D, S/D, k] lanes in place instead of copying them.  The
    # caller must treat the state passed to ``access`` as consumed (rebind
    # the returned one) — which is how every replay loop already uses it.
    donate: bool = False

    def __post_init__(self):
        assert self.num_shards >= 1
        assert self.num_shards & (self.num_shards - 1) == 0, \
            "num_shards must be a power of two (it splits the set-index bits)"
        assert self.cache.num_sets % self.num_shards == 0 and \
            self.cache.num_sets >= self.num_shards

    @property
    def local(self) -> KWayConfig:
        """Per-shard cache config: same ways/policy, S/D sets."""
        return dataclasses.replace(
            self.cache, num_sets=self.cache.num_sets // self.num_shards
        )


class ShardedCache:
    """A K-way cache whose set axis is sharded D ways.

    The state is the per-shard ``KWayState`` stacked on a leading shard axis
    (leaves [D, S/D, k]; clock [D]).  ``access`` buckets the batch by owning
    shard on the host, runs all shards in parallel, and scatters results
    back to the original request order.
    """

    def __init__(self, cfg: ShardedConfig, mesh=None):
        self.cfg = cfg
        self.backend = make_backend(cfg.backend, cfg.local)
        if not self.backend.traceable:
            raise ValueError(
                f"backend {cfg.backend!r} is host Python and cannot run "
                "under vmap/shard_map; shard the 'jnp' or 'pallas' backend")
        self.mesh = mesh
        if mesh is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            if "sets" not in mesh.axis_names or \
                    mesh.shape["sets"] != cfg.num_shards:
                raise ValueError(
                    "mesh must carry a 'sets' axis of exactly num_shards "
                    f"devices (one shard per device); got axes "
                    f"{dict(mesh.shape)} for num_shards={cfg.num_shards}")

            def sm_local(*args):
                out = self._local(*(x[0] for x in args))
                return tuple(o[None] for o in out)

            spec = (P("sets"),) * 9
            # args 3..8 are the state leaves (keys/fprint/vals/meta_a/meta_b/
            # clock) — the donated, in-place-updated half of the signature
            donate = tuple(range(3, 9)) if cfg.donate else ()
            self._fn = jax.jit(shard_map(
                sm_local, mesh=mesh, in_specs=spec, out_specs=(P("sets"),) * 10
            ), donate_argnums=donate)
        else:
            donate = tuple(range(3, 9)) if cfg.donate else ()
            self._fn = jax.jit(jax.vmap(self._local), donate_argnums=donate)

    # ------------------------------------------------------------- plumbing
    def _local(self, keys, vals, en, k, f, v, a, mb, c):
        st = KWayState(keys=k, fprint=f, vals=v, meta_a=a, meta_b=mb, clock=c)
        st, hit, out, ek, ev = self.backend.access(st, keys, vals, enabled=en)
        return (hit, out, ek, ev,
                st.keys, st.fprint, st.vals, st.meta_a, st.meta_b, st.clock)

    def init(self) -> KWayState:
        d = self.cfg.num_shards
        st = self.backend.init()
        leaves = [jnp.tile(l[None], (d,) + (1,) * l.ndim)
                  for l in (st.keys, st.fprint, st.vals, st.meta_a, st.meta_b)]
        return KWayState(*leaves, clock=jnp.zeros((d,), jnp.int32))

    def owner_of(self, keys) -> np.ndarray:
        """Owning shard per key: the high bits of the global set index."""
        gset = hashing.set_index(
            jnp.asarray(keys, jnp.uint32), self.cfg.cache.num_sets,
            self.cfg.cache.seed,
        )
        return np.asarray(gset) // self.cfg.local.num_sets

    def _bucket(self, keys: np.ndarray):
        d = self.cfg.num_shards
        owner = self.owner_of(keys)
        counts = np.bincount(owner, minlength=d)
        # pad buckets to a power of two ≥ 8 (kernel query tile) so the jitted
        # shard function sees few distinct shapes
        bl = 8
        while bl < int(counts.max() if counts.size else 1):
            bl *= 2
        order = np.argsort(owner, kind="stable")   # arrival order per shard
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        pos = np.empty(len(keys), np.int64)
        pos[order] = np.arange(len(keys)) - starts[owner[order]]
        return owner, pos, bl

    # ------------------------------------------------------------------ API
    def access(self, state: KWayState, keys, vals):
        """Batched get-or-insert across all shards.

        Returns (state', hit[B], vals[B], evicted_keys[B], evicted_valid[B])
        in the original request order.
        """
        keys = np.asarray(keys, np.uint32)
        vals = np.asarray(vals, np.int32)
        d = self.cfg.num_shards
        owner, pos, bl = self._bucket(keys)
        keys_b = np.zeros((d, bl), np.uint32)
        vals_b = np.zeros((d, bl), np.int32)
        en_b = np.zeros((d, bl), bool)
        keys_b[owner, pos] = keys
        vals_b[owner, pos] = vals
        en_b[owner, pos] = True

        hit_b, val_b, ek_b, ev_b, k2, f2, v2, a2, b2, c2 = self._fn(
            jnp.asarray(keys_b), jnp.asarray(vals_b), jnp.asarray(en_b),
            state.keys, state.fprint, state.vals,
            state.meta_a, state.meta_b, state.clock,
        )
        state = KWayState(keys=k2, fprint=f2, vals=v2,
                          meta_a=a2, meta_b=b2, clock=c2)
        sel = (np.asarray(owner), np.asarray(pos))
        return (
            state,
            np.asarray(hit_b)[sel],
            np.asarray(val_b)[sel],
            np.asarray(ek_b)[sel],
            np.asarray(ev_b)[sel],
        )

    def global_view(self, state: KWayState) -> KWayState:
        """Reassemble the stacked shard states into the equivalent global
        single-device state (sets of shard d map to global sets
        [d*S/D, (d+1)*S/D)).  Clock is summed — a diagnostic view; policy
        metadata keeps its shard-local timestamps."""
        s, k = self.cfg.cache.num_sets, self.cfg.cache.ways
        merge = lambda l: l.reshape((s, k))  # noqa: E731
        return KWayState(
            keys=merge(state.keys), fprint=merge(state.fprint),
            vals=merge(state.vals), meta_a=merge(state.meta_a),
            meta_b=merge(state.meta_b), clock=jnp.sum(state.clock),
        )
