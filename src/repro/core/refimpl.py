"""Serial Python oracle of the k-way cache — ground truth for tests.

A direct, unoptimized transcription of the paper's Algorithms 1-6 semantics
(single-threaded).  The JAX implementation at batch size 1 must agree with
this oracle exactly; at batch size B it must agree with *some* serialization
per the documented conflict-resolution rules (property-tested separately).
"""
from __future__ import annotations

import numpy as np

from repro.core import hashing
from repro.core.policies import Policy


def _h32(key: int, seed: int) -> int:
    return int(hashing.hash_u32(np.uint32(key), seed))


class RefKWay:
    def __init__(self, num_sets: int, ways: int, policy: Policy, seed: int = 0x51CA):
        self.num_sets, self.ways, self.policy, self.seed = num_sets, ways, policy, seed
        # each set: fixed array of `ways` slots; None == empty way.  Matching
        # the JAX layout slot-for-slot makes tie-breaking identical (lowest
        # way index wins ties, empty ways fill first).
        self.sets = [[None] * ways for _ in range(num_sets)]
        self.clock = 0

    def _set_of(self, key: int) -> int:
        return _h32(key, self.seed) & (self.num_sets - 1)

    def _score(self, node, now):
        """Victim score in the float32 domain the JAX/Pallas paths compare
        in — float64 here would resolve float32 score *ties* differently
        (e.g. two RANDOM hashes 2 apart above 2^24 both round to one
        float32), breaking bit-identical victim choice."""
        p = self.policy
        if p in (Policy.LRU, Policy.LFU, Policy.FIFO):
            return float(np.float32(node["a"]))
        if p == Policy.RANDOM:
            return float(np.float32(_h32(node["key"] ^ (now & 0xFFFFFFFF), 0xBADA)))
        if p == Policy.HYPERBOLIC:
            age = np.float32(now - node["b"]) + np.float32(1.0)  # as in jnp
            return float(np.float32(node["a"]) / age)
        raise ValueError(p)

    def _touch(self, node, now):
        if self.policy == Policy.LRU:
            node["a"] = now
        elif self.policy in (Policy.LFU, Policy.HYPERBOLIC):
            node["a"] += 1

    def get(self, key: int):
        now = self.clock
        self.clock += 1
        s = self.sets[self._set_of(key)]
        for node in s:
            if node is not None and node["key"] == key:
                self._touch(node, now)
                return node["val"]
        return None

    def put(self, key: int, val: int, admit: bool = True):
        """Returns (evicted_key | None, set_idx | None, way | None).

        ``set_idx``/``way`` name the landing slot (present-key overwrite or
        fresh insert); all three are None when the key was not admitted.
        """
        now = self.clock
        self.clock += 1
        si = self._set_of(key)
        s = self.sets[si]
        for i, node in enumerate(s):
            if node is not None and node["key"] == key:
                node["val"] = val
                self._touch(node, now)
                return None, si, i
        if not admit:
            return None, None, None
        # victim way: empty ways first (lowest index), else min score with
        # lowest way index breaking ties — exactly the JAX stable argsort.
        evicted = None
        way = None
        for i, node in enumerate(s):
            if node is None:
                way = i
                break
        if way is None:
            scored = [(self._score(n, now), i) for i, n in enumerate(s)]
            _, way = min(scored)
            evicted = s[way]["key"]
        a, b = self._insert_meta(now)
        s[way] = {"key": key, "val": val, "a": a, "b": b}
        return evicted, si, way

    def peek_victim(self, key: int):
        """Prospective victim of ``key`` without mutating the cache.

        Mirrors ``kway.peek_victims`` at B=1: returns (victim_key | None);
        None when the key is present or its set has a free way.
        """
        now = self.clock
        s = self.sets[self._set_of(key)]
        for node in s:
            if node is not None and node["key"] == key:
                return None
        if any(node is None for node in s):
            return None
        scored = [(self._score(n, now), i) for i, n in enumerate(s)]
        _, way = min(scored)
        return s[way]["key"]

    def _insert_meta(self, now):
        p = self.policy
        if p == Policy.LRU or p == Policy.FIFO:
            return now, 0
        if p == Policy.LFU:
            return 1, 0
        if p == Policy.RANDOM:
            return 0, 0
        if p == Policy.HYPERBOLIC:
            return 1, now
        raise ValueError(p)

    def access(self, key: int, val: int):
        """get-then-put-on-miss; returns hit bool.

        Mirrors ``kway.access`` clock semantics exactly: the write phase
        advances the logical clock even when the lane is disabled by a hit.
        """
        got = self.get(key)
        if got is None:
            self.put(key, val)
            return False
        self.clock += 1  # disabled put lane still advances the clock
        return True

    def contents(self):
        return {n["key"] for s in self.sets for n in s if n is not None}

    def occupancy(self):
        return sum(1 for s in self.sets for n in s if n is not None)
