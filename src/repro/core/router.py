"""Device-resident request router — set-owner bucketing as traceable jnp ops.

The paper's parallelism story routes every request to the thread owning its
set before any cache work happens ("hash routing", Fig. 1); "Limited
Associativity Caching in the Data Plane" pushes the same partition-then-route
structure into the forwarding fast path.  This module is that router for the
set-sharded layer (core/sharded.py): pure shape-stable jnp, so routing lives
*inside* jit/vmap/shard_map/lax.scan instead of numpy on the host.

Layout contract (DESIGN.md §9):

  * The owner of a key is the HIGH ``log2(D)`` bits of its *global* set index
    (``owner = gset // (S/D)``); the LOW bits are the shard-local set index,
    so per-shard probing reuses the same hash unchanged.
  * A batch of B requests is bucketed into a **fixed** ``[D, capacity]``
    layout via one stable argsort on the owner id — arrival order is
    preserved inside each bucket, which is what makes the sharded cache
    bit-equal to the unsharded one for timestamp-order-invariant policies.
  * ``capacity`` is static (a ``ShardedConfig`` knob).  The default,
    ``capacity == B``, can never overflow (the degenerate case routes the
    whole batch to one shard).  Smaller capacities trade padding work for an
    **overflow-defer** policy: lanes ranked beyond ``capacity`` in their
    bucket are *not* routed this step — they are reported in
    ``RoutePlan.deferred`` (never silently dropped) and the caller decides
    (``ShardedCache.access`` returns them as unprocessed misses; replay
    counts them as misses and reports the defer total).
  * ``unscatter`` inverts the permutation: per-request results come back in
    the original batch order without a host round trip.

Everything here is shape-static in (B, D, capacity): one XLA compilation per
shape, asserted by the trace counters in core/sharded.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RoutePlan:
    """Where every request of one batch goes: shard ``owner``, arrival rank
    ``pos`` inside that shard's bucket, and the overflow-``deferred`` mask.
    A pytree of [B] arrays — scan/vmap-safe."""

    owner: jnp.ndarray     # int32 [B]  owning shard (high bits of gset)
    pos: jnp.ndarray       # int32 [B]  arrival rank within the owner bucket
    deferred: jnp.ndarray  # bool  [B]  ranked past capacity: not routed
    enabled: jnp.ndarray   # bool  [B]  the caller's lane mask (pre-defer)

    @property
    def routed(self) -> jnp.ndarray:
        """Lanes that actually land in a bucket this step."""
        return self.enabled & ~self.deferred


def pad_chunks(trace: np.ndarray, batch: int):
    """Chunk a trace for batched replay, padding the trailing
    ``len % batch`` requests into a disabled-lane tail chunk (no request is
    silently dropped).  The single definition shared by the unsharded
    (simulate) and sharded replay paths.  -> (chunks [steps, B] uint32,
    enabled [steps, B] bool), as host arrays.
    """
    trace = np.asarray(trace, np.uint32)
    n = trace.shape[0]
    steps = -(-n // batch)
    padded = np.zeros((steps * batch,), np.uint32)
    padded[:n] = trace
    enabled = np.zeros((steps * batch,), bool)
    enabled[:n] = True
    return padded.reshape(steps, batch), enabled.reshape(steps, batch)


def owner_of(keys: jnp.ndarray, num_sets: int, num_shards: int,
             seed: int) -> jnp.ndarray:
    """Owning shard per key: high bits of the global set index. int32 [B]."""
    gset = hashing.set_index(
        jnp.asarray(keys, jnp.uint32), num_sets, seed)
    return gset // jnp.int32(num_sets // num_shards)


def route(owner: jnp.ndarray, num_shards: int, capacity: int,
          enabled: Optional[jnp.ndarray] = None) -> RoutePlan:
    """Stable-argsort bucketing of one batch.  Traceable, shape-static.

    ``pos[i]`` is the number of earlier enabled requests owned by the same
    shard — the vectorized equivalent of appending to D per-shard queues in
    arrival order.  Disabled lanes rank last in every bucket (they never
    displace a real request) and are never routed.
    """
    b = owner.shape[0]
    if enabled is None:
        enabled = jnp.ones((b,), jnp.bool_)
    if num_shards == 1:
        # Degenerate routing is the identity: one bucket, arrival order.
        pos = jnp.cumsum(enabled.astype(jnp.int32)) - 1
        pos = jnp.where(enabled, pos, b)
        return RoutePlan(owner=jnp.zeros((b,), jnp.int32), pos=pos,
                         deferred=enabled & (pos >= capacity),
                         enabled=enabled)
    # Disabled lanes sort under a sentinel owner id past every real shard.
    key = jnp.where(enabled, owner, jnp.int32(num_shards))
    perm = jnp.argsort(key, stable=True)       # arrival order kept per shard
    sorted_key = key[perm]
    idx = jnp.arange(b, dtype=jnp.int32)
    new_group = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), sorted_key[1:] != sorted_key[:-1]])
    group_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(new_group, idx, 0))
    pos = jnp.zeros((b,), jnp.int32).at[perm].set(idx - group_start)
    pos = jnp.where(enabled, pos, b)
    return RoutePlan(owner=owner, pos=pos,
                     deferred=enabled & (pos >= capacity), enabled=enabled)


def _dest(plan: RoutePlan, capacity: int, num_shards: int) -> jnp.ndarray:
    """Flat [D*capacity] scatter index per lane; un-routed lanes point one
    past the end and are dropped by the scatter."""
    return jnp.where(plan.routed, plan.owner * capacity + plan.pos,
                     jnp.int32(num_shards * capacity))


def bucket(plan: RoutePlan, values: jnp.ndarray, num_shards: int,
           capacity: int, fill) -> jnp.ndarray:
    """Scatter a per-request [B] array into the [D, capacity] bucket layout.
    Padding lanes hold ``fill``."""
    flat = jnp.full((num_shards * capacity,), fill, values.dtype)
    flat = flat.at[_dest(plan, capacity, num_shards)].set(values, mode="drop")
    return flat.reshape(num_shards, capacity)


def bucket_mask(plan: RoutePlan, num_shards: int,
                capacity: int) -> jnp.ndarray:
    """The [D, capacity] enabled mask: True exactly where a request landed."""
    flat = jnp.zeros((num_shards * capacity,), jnp.bool_)
    flat = flat.at[_dest(plan, capacity, num_shards)].set(
        plan.routed, mode="drop")
    return flat.reshape(num_shards, capacity)


def unscatter(plan: RoutePlan, bucketed: jnp.ndarray, fill) -> jnp.ndarray:
    """Inverse permutation: gather per-request results [B] back into the
    original batch order from the [D, capacity, ...] bucket layout.
    Deferred/disabled lanes read ``fill``."""
    d, capacity = bucketed.shape[:2]
    flat = bucketed.reshape((d * capacity,) + bucketed.shape[2:])
    take = jnp.where(plan.routed, plan.owner * capacity + plan.pos, 0)
    out = flat[take]
    mask = plan.routed.reshape((-1,) + (1,) * (out.ndim - 1))
    return jnp.where(mask, out, jnp.asarray(fill, bucketed.dtype))
