"""Jitted trace replay — the hit-ratio study engine (paper §5.2).

Replays a request trace through any (policy × associativity × admission)
configuration and reports the hit ratio.  The replay is a ``lax.scan`` over
the trace with batch size 1 (exact sequential semantics, matching the paper's
single-threaded hit-ratio measurements), jit-compiled once per cache shape —
million-request traces replay in seconds on CPU and would be trivially fast
on TPU.

A batched variant (``replay_batched``) replays B requests per step with the
deterministic conflict-resolution semantics of ``kway.access`` — this is the
throughput path and also demonstrates that batching barely perturbs the hit
ratio (the vectorized analogue of the paper's observation that racy metadata
updates do not hurt policy quality).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admission, kway
from repro.core.kway import KWayConfig


@dataclasses.dataclass(frozen=True)
class SimConfig:
    cache: KWayConfig
    tinylfu: Optional[admission.TinyLFUConfig] = None  # None = admit always


@partial(jax.jit, static_argnums=0)
def _replay_scan(sim: SimConfig, trace: jnp.ndarray):
    cache = kway.make_cache(sim.cache)
    sketch = admission.make_sketch(sim.tinylfu) if sim.tinylfu else None

    def step(carry, key):
        cache, sketch, hits = carry
        kb = key[None]
        if sim.tinylfu is None:
            cache, hit, _, _, _ = kway.access(sim.cache, cache, kb, kb.astype(jnp.int32))
        else:
            sketch = admission.record(sim.tinylfu, sketch, kb)
            vkeys, vvalid = kway.peek_victims(sim.cache, cache, kb)
            ok = admission.admit(sim.tinylfu, sketch, kb, vkeys, vvalid)
            cache, hit, _, _, _ = kway.access(
                sim.cache, cache, kb, kb.astype(jnp.int32), admit_on_miss=ok
            )
        return (cache, sketch, hits + hit[0]), ()

    (cache, _, hits), _ = jax.lax.scan(
        step, (cache, sketch, jnp.zeros((), jnp.int32)), trace
    )
    return hits, cache


def replay(sim: SimConfig, trace: np.ndarray) -> float:
    """Exact sequential replay -> hit ratio."""
    trace = jnp.asarray(trace, jnp.uint32)
    hits, _ = _replay_scan(sim, trace)
    return float(hits) / trace.shape[0]


@partial(jax.jit, static_argnums=(0, 2))
def _replay_batched_scan(sim: SimConfig, trace: jnp.ndarray, batch: int):
    cache = kway.make_cache(sim.cache)
    steps = trace.shape[0] // batch
    chunks = trace[: steps * batch].reshape(steps, batch)

    def step(carry, keys):
        cache, hits = carry
        cache, hit, _, _, _ = kway.access(
            sim.cache, cache, keys, keys.astype(jnp.int32)
        )
        return (cache, hits + jnp.sum(hit.astype(jnp.int32))), ()

    (cache, hits), _ = jax.lax.scan(step, (cache, jnp.zeros((), jnp.int32)), chunks)
    return hits, cache


def replay_batched(sim: SimConfig, trace: np.ndarray, batch: int = 64) -> float:
    trace = jnp.asarray(trace, jnp.uint32)
    n = (trace.shape[0] // batch) * batch
    hits, _ = _replay_batched_scan(sim, trace, batch)
    return float(hits) / n
