"""Jitted trace replay — the hit-ratio study engine (paper §5.2).

Replays a request trace through any (policy × associativity × admission ×
backend) configuration and reports the hit ratio.  The replay is a
``lax.scan`` over the trace with batch size 1 (exact sequential semantics,
matching the paper's single-threaded hit-ratio measurements), jit-compiled
once per cache shape — million-request traces replay in seconds on CPU and
would be trivially fast on TPU.

A batched variant (``replay_batched``) replays B requests per step with the
deterministic conflict-resolution semantics of ``kway.access`` — this is the
throughput path and also demonstrates that batching barely perturbs the hit
ratio (the vectorized analogue of the paper's observation that racy metadata
updates do not hurt policy quality).

Both entry points accept ``SimConfig.backend`` ("jnp" | "pallas" | "ref");
``replay_batched`` additionally takes ``shards`` to run the set-sharded
execution layer (core/sharded.py).  The ``ref`` backend replays in plain
Python (it is the differential-testing oracle, not a throughput path).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admission
from repro.core.backend import make_backend
from repro.core.kway import KWayConfig


@dataclasses.dataclass(frozen=True)
class SimConfig:
    cache: KWayConfig
    tinylfu: Optional[admission.TinyLFUConfig] = None  # None = admit always
    backend: str = "jnp"
    # True: replay through the unfused get-then-put composition
    # (backend.access_two_phase) instead of the fused single-probe access —
    # the differential-oracle knob for the fused path.
    two_phase: bool = False


def _access_fn(sim: SimConfig, be):
    return be.access_two_phase if sim.two_phase else be.access


@partial(jax.jit, static_argnums=0)
def _replay_scan(sim: SimConfig, trace: jnp.ndarray):
    be = make_backend(sim.backend, sim.cache)
    access = _access_fn(sim, be)
    cache = be.init()
    sketch = admission.make_sketch(sim.tinylfu) if sim.tinylfu else None

    def step(carry, key):
        cache, sketch, hits = carry
        kb = key[None]
        if sim.tinylfu is None:
            cache, hit, _, _, _ = access(cache, kb, kb.astype(jnp.int32))
        else:
            sketch = admission.record(sim.tinylfu, sketch, kb)
            vkeys, vvalid = be.peek_victims(cache, kb)
            ok = admission.admit(sim.tinylfu, sketch, kb, vkeys, vvalid)
            cache, hit, _, _, _ = access(
                cache, kb, kb.astype(jnp.int32), admit_on_miss=ok
            )
        return (cache, sketch, hits + hit[0]), ()

    (cache, _, hits), _ = jax.lax.scan(
        step, (cache, sketch, jnp.zeros((), jnp.int32)), trace
    )
    return hits, cache


def _replay_python(sim: SimConfig, trace: np.ndarray):
    """Sequential replay for backends that cannot live inside lax.scan."""
    if sim.tinylfu is not None:
        raise ValueError("TinyLFU replay is not wired for the ref backend")
    be = make_backend(sim.backend, sim.cache)
    access = _access_fn(sim, be)
    cache = be.init()
    hits = 0
    for t in trace:
        kb = jnp.asarray([t], jnp.uint32)
        cache, hit, _, _, _ = access(cache, kb, kb.astype(jnp.int32))
        hits += int(hit[0])
    return hits, cache


def replay(sim: SimConfig, trace: np.ndarray) -> float:
    """Exact sequential replay -> hit ratio."""
    trace = np.asarray(trace, np.uint32)
    if sim.backend == "ref":
        hits, _ = _replay_python(sim, trace)
        return float(hits) / trace.shape[0]
    hits, _ = _replay_scan(sim, jnp.asarray(trace))
    return float(hits) / trace.shape[0]


@partial(jax.jit, static_argnums=(0, 2))
def _replay_batched_scan(sim: SimConfig, trace: jnp.ndarray, batch: int):
    be = make_backend(sim.backend, sim.cache)
    access = _access_fn(sim, be)
    cache = be.init()
    sketch = admission.make_sketch(sim.tinylfu) if sim.tinylfu else None
    steps = trace.shape[0] // batch
    chunks = trace[: steps * batch].reshape(steps, batch)

    def step(carry, keys):
        cache, sketch, hits = carry
        if sim.tinylfu is None:
            cache, hit, _, _, _ = access(cache, keys, keys.astype(jnp.int32))
        else:
            # Same phase order as the sequential path, per chunk: record the
            # accesses, peek each request's prospective victim, gate admission.
            # Duplicate keys within a chunk coalesce in the sketch (documented
            # record() approximation), so batched+TinyLFU tracks — not equals —
            # sequential+TinyLFU; tests bound the hit-ratio gap.
            sketch = admission.record(sim.tinylfu, sketch, keys)
            vkeys, vvalid = be.peek_victims(cache, keys)
            ok = admission.admit(sim.tinylfu, sketch, keys, vkeys, vvalid)
            cache, hit, _, _, _ = access(
                cache, keys, keys.astype(jnp.int32), admit_on_miss=ok
            )
        return (cache, sketch, hits + jnp.sum(hit.astype(jnp.int32))), ()

    (cache, _, hits), _ = jax.lax.scan(
        step, (cache, sketch, jnp.zeros((), jnp.int32)), chunks
    )
    return hits, cache


def replay_batched(
    sim: SimConfig, trace: np.ndarray, batch: int = 64, shards: int = 1
) -> float:
    """Batched replay -> hit ratio.  ``shards`` > 1 runs the set-sharded
    layer (shard_map when a device mesh is available, vmap emulation
    otherwise) with host-side key bucketing per chunk."""
    trace = np.asarray(trace, np.uint32)
    n = (trace.shape[0] // batch) * batch
    if sim.tinylfu is not None and shards > 1:
        raise ValueError(
            "TinyLFU admission is not wired into the set-sharded layer "
            "(the sketch is global, shards are independent); use shards=1")
    if sim.tinylfu is not None and sim.backend == "ref":
        raise ValueError("TinyLFU replay is not wired for the ref backend")
    if shards > 1:
        if sim.two_phase:
            raise ValueError(
                "two_phase replay is not wired into the set-sharded layer "
                "(ShardedCache runs the fused access); use shards=1")
        if sim.backend == "ref":
            raise ValueError(
                "the ref backend is sequential host Python and cannot be "
                "sharded; use backend='jnp' or 'pallas' with shards > 1")
        from repro.core.sharded import ShardedCache, ShardedConfig

        sc = ShardedCache(ShardedConfig(
            cache=sim.cache, num_shards=shards, backend=sim.backend))
        state = sc.init()
        hits = 0
        for i in range(0, n, batch):
            chunk = trace[i : i + batch]
            state, hit, _, _, _ = sc.access(state, chunk, chunk.astype(np.int32))
            hits += int(hit.sum())
        return hits / n
    if sim.backend == "ref":
        be = make_backend(sim.backend, sim.cache)
        access = _access_fn(sim, be)
        cache = be.init()
        hits = 0
        for i in range(0, n, batch):
            chunk = jnp.asarray(trace[i : i + batch])
            cache, hit, _, _, _ = access(cache, chunk, chunk.astype(jnp.int32))
            hits += int(np.asarray(hit).sum())
        return hits / n
    hits, _ = _replay_batched_scan(sim, jnp.asarray(trace), batch)
    return float(hits) / n
