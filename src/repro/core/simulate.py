"""Jitted trace replay — the hit-ratio study engine (paper §5.2).

Replays a request trace through any (policy × associativity × admission ×
backend) configuration and reports the hit ratio.  The replay is a
``lax.scan`` over the trace with batch size 1 (exact sequential semantics,
matching the paper's single-threaded hit-ratio measurements), jit-compiled
once per cache shape — million-request traces replay in seconds on CPU and
would be trivially fast on TPU.

A batched variant (``replay_batched``) replays B requests per step with the
deterministic conflict-resolution semantics of ``kway.access`` — this is the
throughput path and also demonstrates that batching barely perturbs the hit
ratio (the vectorized analogue of the paper's observation that racy metadata
updates do not hurt policy quality).

Both entry points accept ``SimConfig.backend`` ("jnp" | "pallas" | "ref");
``replay_batched`` additionally takes ``shards`` to run the set-sharded
execution layer (core/sharded.py) — since PR 4 a single jitted ``lax.scan``
with device-resident routing that composes with TinyLFU (per-shard
sketches) and ``two_phase``.  The ``ref`` backend replays in plain Python
(it is the differential-testing oracle, not a throughput path).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admission, router
from repro.core.backend import make_backend
from repro.core.kway import KWayConfig


@dataclasses.dataclass(frozen=True)
class SimConfig:
    cache: KWayConfig
    tinylfu: Optional[admission.TinyLFUConfig] = None  # None = admit always
    backend: str = "jnp"
    # True: replay through the unfused get-then-put composition
    # (backend.access_two_phase) instead of the fused single-probe access —
    # the differential-oracle knob for the fused path.
    two_phase: bool = False


def _access_fn(sim: SimConfig, be):
    return be.access_two_phase if sim.two_phase else be.access


@lru_cache(maxsize=None)
def _cached_backend(name: str, cache: KWayConfig):
    """Backend instances memoized per config so their per-instance jit
    caches (CacheBackend._replay_fns) survive across replay calls —
    backends are functional, so sharing instances is safe."""
    return make_backend(name, cache)


@partial(jax.jit, static_argnums=0)
def _replay_scan(sim: SimConfig, trace: jnp.ndarray):
    be = make_backend(sim.backend, sim.cache)
    access = _access_fn(sim, be)
    cache = be.init()
    sketch = admission.make_sketch(sim.tinylfu) if sim.tinylfu else None

    def step(carry, key):
        cache, sketch, hits = carry
        kb = key[None]
        if sim.tinylfu is None:
            cache, hit, _, _, _ = access(cache, kb, kb.astype(jnp.int32))
        else:
            sketch = admission.record(sim.tinylfu, sketch, kb)
            vkeys, vvalid = be.peek_victims(cache, kb)
            ok = admission.admit(sim.tinylfu, sketch, kb, vkeys, vvalid)
            cache, hit, _, _, _ = access(
                cache, kb, kb.astype(jnp.int32), admit_on_miss=ok
            )
        return (cache, sketch, hits + hit[0]), ()

    (cache, _, hits), _ = jax.lax.scan(
        step, (cache, sketch, jnp.zeros((), jnp.int32)), trace
    )
    return hits, cache


def _replay_python(sim: SimConfig, trace: np.ndarray):
    """Sequential replay for backends that cannot live inside lax.scan."""
    if sim.tinylfu is not None:
        raise ValueError("TinyLFU replay is not wired for the ref backend")
    be = make_backend(sim.backend, sim.cache)
    access = _access_fn(sim, be)
    cache = be.init()
    hits = 0
    for t in trace:
        kb = jnp.asarray([t], jnp.uint32)
        cache, hit, _, _, _ = access(cache, kb, kb.astype(jnp.int32))
        hits += int(hit[0])
    return hits, cache


def replay(sim: SimConfig, trace: np.ndarray) -> float:
    """Exact sequential replay -> hit ratio."""
    trace = np.asarray(trace, np.uint32)
    if sim.backend == "ref":
        hits, _ = _replay_python(sim, trace)
        return float(hits) / trace.shape[0]
    hits, _ = _replay_scan(sim, jnp.asarray(trace))
    return float(hits) / trace.shape[0]


@partial(jax.jit, static_argnums=0)
def _replay_batched_scan(sim: SimConfig, chunks: jnp.ndarray,
                         enabled: jnp.ndarray):
    """Scan over pre-chunked trace [steps, B] with an enabled mask — the
    tail chunk is padded with disabled lanes, so hit ratios cover the whole
    trace (padding lanes touch neither the cache nor the sketch)."""
    be = make_backend(sim.backend, sim.cache)
    access = _access_fn(sim, be)
    cache = be.init()
    sketch = admission.make_sketch(sim.tinylfu) if sim.tinylfu else None

    def step(carry, xs):
        cache, sketch, hits = carry
        keys, en = xs
        if sim.tinylfu is None:
            cache, hit, _, _, _ = access(
                cache, keys, keys.astype(jnp.int32), None, en)
        else:
            # Same phase order as the sequential path, per chunk: record the
            # accesses, peek each request's prospective victim, gate admission.
            # Duplicate keys within a chunk coalesce in the sketch (documented
            # record() approximation), so batched+TinyLFU tracks — not equals —
            # sequential+TinyLFU; tests bound the hit-ratio gap.
            sketch = admission.record(sim.tinylfu, sketch, keys, enabled=en)
            vkeys, vvalid = be.peek_victims(cache, keys)
            ok = admission.admit(sim.tinylfu, sketch, keys, vkeys, vvalid)
            cache, hit, _, _, _ = access(
                cache, keys, keys.astype(jnp.int32), ok, en
            )
        return (cache, sketch, hits + jnp.sum(hit.astype(jnp.int32))), ()

    (cache, _, hits), _ = jax.lax.scan(
        step, (cache, sketch, jnp.zeros((), jnp.int32)), (chunks, enabled)
    )
    return hits, cache


def _pad_ttl_chunks(ttls: np.ndarray, batch: int) -> np.ndarray:
    """Chunk a per-request TTL array [n] -> int32 [steps, B] with the same
    steps/batch geometry as ``router.pad_chunks`` (padding lanes carry
    ttl 0 == never expires; they are disabled anyway)."""
    ttls = np.asarray(ttls, np.int32)
    n = ttls.shape[0]
    steps = -(-n // batch)
    padded = np.zeros((steps * batch,), np.int32)
    padded[:n] = ttls
    return padded.reshape(steps, batch)


def replay_batched(
    sim: SimConfig, trace: np.ndarray, batch: int = 64, shards: int = 1,
    resident: bool = False, hierarchy=None, ttls=None,
) -> float:
    """Batched replay -> hit ratio over the WHOLE trace (the tail chunk is
    padded with disabled lanes on every path).

    ``shards`` > 1 replays through the set-sharded layer as a single jitted
    ``lax.scan`` — device-resident routing (core/router.py), per-shard
    TinyLFU sketches, and ``two_phase`` all compose with sharding; only the
    sequential-Python ``ref`` oracle cannot be sharded.

    ``resident=True`` replays through ``CacheBackend.replay`` — on the
    pallas backend the trace-resident megakernel (kernels/replay.py): the
    whole trace in ONE launch with the cache state pinned in VMEM,
    bit-identical to the chunked scan.  Sharded resident replay runs one
    megakernel per shard (D launches total).  The resident path IS the
    fused access composition, so it excludes ``two_phase``.

    ``hierarchy`` (a ``HierarchyConfig`` with ``l1_sets > 0``) selects the
    L1-over-L2 replay mode (DESIGN.md §14): on the pallas backend the
    hierarchical megakernel (VMEM L1, HBM L2), on the jnp backend the
    bit-exact chunked-scan twin.  ``l1_sets == 0`` is the flat path
    unchanged.  The hierarchy has sequential per-lane semantics and no
    TinyLFU/two_phase composition yet.

    ``ttls`` (int32 [n], optional, aligned with ``trace``) gives each
    request a time-to-live on the logical replay clock (DESIGN.md §15):
    a request that misses inserts with deadline ``clock + 2B + ttl``
    (``ttl <= 0`` = never expires), and an entry whose deadline has passed
    is never served as a hit on any path.  TTLs exclude ``two_phase`` and
    TinyLFU (the unfused composition and the sketch have no expiry
    semantics)."""
    trace = np.asarray(trace, np.uint32)
    n = trace.shape[0]
    if sim.tinylfu is not None and sim.backend == "ref":
        raise ValueError("TinyLFU replay is not wired for the ref backend")
    if ttls is not None:
        ttls = np.asarray(ttls, np.int32)
        if ttls.shape[0] != n:
            raise ValueError(
                f"ttls length {ttls.shape[0]} != trace length {n}")
        if sim.two_phase:
            raise ValueError(
                "per-request TTLs require the fused access path; "
                "two_phase has no expiry semantics")
        if sim.tinylfu is not None:
            raise ValueError(
                "per-request TTLs and TinyLFU admission are mutually "
                "exclusive (the sketch has no expiry-aware semantics)")
    if hierarchy is not None and not hierarchy.enabled:
        hierarchy = None          # l1_sets == 0: the flat path, verbatim
    if hierarchy is not None:
        if sim.backend == "ref":
            raise ValueError(
                "hierarchical replay needs a traceable backend "
                "('jnp' or 'pallas'); the ref oracle is flat-only")
        if sim.two_phase:
            raise ValueError(
                "hierarchical replay is the fused sequential-lane path; "
                "two_phase does not compose with it")
        if sim.tinylfu is not None:
            raise ValueError(
                "hierarchical replay does not support TinyLFU admission")
    if resident:
        if sim.backend == "ref":
            raise ValueError(
                "the ref backend is sequential host Python; the resident "
                "replay needs a traceable backend ('jnp' or 'pallas')")
        if sim.two_phase:
            raise ValueError(
                "resident replay is the fused access path; two_phase is the "
                "chunked-scan oracle — replay with resident=False")
    if shards > 1:
        if sim.backend == "ref":
            raise ValueError(
                "the ref backend is sequential host Python and cannot be "
                "sharded; use backend='jnp' or 'pallas' with shards > 1")
        from repro.core.sharded import ShardedCache, ShardedConfig

        sc = ShardedCache(ShardedConfig(
            cache=sim.cache, num_shards=shards, backend=sim.backend))
        if hierarchy is not None:
            hits, _, _ = sc.replay(trace, batch, resident=True,
                                   hierarchy=hierarchy, ttls=ttls)
            return hits / n
        hits, _, _ = sc.replay(trace, batch, tinylfu=sim.tinylfu,
                               two_phase=sim.two_phase, resident=resident,
                               ttls=ttls)
        return hits / n
    tchunks = None if ttls is None else _pad_ttl_chunks(ttls, batch)
    if hierarchy is not None:
        # hierarchical mode always runs the routed-chunk replay: the kernel
        # on pallas (with its own budget/fallback ladder inside
        # PallasBackend.replay), the jitted jnp twin otherwise.
        be = _cached_backend(sim.backend, sim.cache)
        chunks, enabled = router.pad_chunks(trace, batch)
        hits, _, _, _ = be.replay(be.init(ttl=tchunks is not None),
                                  chunks, enabled,
                                  hierarchy=hierarchy, ttls=tchunks)
        return float(jnp.sum(hits)) / n
    if resident:
        be = _cached_backend(sim.backend, sim.cache)
        chunks, enabled = router.pad_chunks(trace, batch)
        hits, _, _, _ = be.replay(be.init(ttl=tchunks is not None),
                                  chunks, enabled,
                                  tinylfu=sim.tinylfu, ttls=tchunks)
        return float(jnp.sum(hits)) / n
    if sim.backend == "ref":
        be = make_backend(sim.backend, sim.cache)
        access = _access_fn(sim, be)
        cache = be.init(ttl=ttls is not None)
        chunks, enabled = router.pad_chunks(trace, batch)
        hits = 0
        for step, (chunk, en) in enumerate(zip(chunks, enabled)):
            tt = None if tchunks is None else jnp.asarray(tchunks[step])
            cache, hit, _, _, _ = access(
                cache, jnp.asarray(chunk), jnp.asarray(chunk, jnp.int32),
                None, jnp.asarray(en),
                **({} if tt is None else {"ttls": tt}))
            hits += int(np.asarray(hit).sum())
        return hits / n
    chunks, enabled = router.pad_chunks(trace, batch)
    if tchunks is not None:
        # the TTL chunked scan lives behind CacheBackend.replay (it carries
        # the expiry lane through the scan); _cached_backend keeps its jit
        # cache warm across calls just like _replay_batched_scan's.
        be = _cached_backend(sim.backend, sim.cache)
        hits, _, _, _ = be.replay(be.init(ttl=True), chunks, enabled,
                                  ttls=tchunks)
        return float(jnp.sum(hits)) / n
    hits, _ = _replay_batched_scan(
        sim, jnp.asarray(chunks), jnp.asarray(enabled))
    return float(hits) / n
