"""Eviction policies over per-way metadata — the paper's O(k) realization.

The paper's central simplification: with limited associativity, every classic
policy reduces to "keep one or two short counters per way; on eviction scan
the k counters of one set and pick the extremum".  We encode that contract as
three pure functions per policy:

  * ``victim_scores(meta_a, meta_b, now, rng)`` -> float scores, *lower* means
    "evict sooner".  Empty ways are handled by the caller (forced to -inf).
  * ``on_hit(meta_a, meta_b, now)``     -> updated metadata for a cache hit.
  * ``on_insert(now)``                  -> fresh metadata for an admitted key.

Metadata is two int32 lanes (``meta_a``, ``meta_b``) — enough for every policy
in the paper (Hyperbolic needs both: access count and insertion time).  All
functions are elementwise over arbitrary leading shapes, so the same code
serves the k-way cache (shape [B, k]), the fully-associative oracle (shape
[1, C]) and the Pallas kernel reference.
"""
from __future__ import annotations

import enum

import jax.numpy as jnp

from repro.core import hashing


class Policy(enum.IntEnum):
    LRU = 0
    LFU = 1
    FIFO = 2
    RANDOM = 3
    HYPERBOLIC = 4

    @staticmethod
    def parse(name: str) -> "Policy":
        return Policy[name.upper()]


def victim_scores(
    policy: int,
    meta_a: jnp.ndarray,
    meta_b: jnp.ndarray,
    now: jnp.ndarray,
    stored_keys: jnp.ndarray,
) -> jnp.ndarray:
    """Score every way; the eviction victim is the argmin.

    ``now`` is the logical clock (int32, broadcastable).  ``stored_keys``
    feeds the RANDOM policy's stateless per-epoch permutation (hash of key and
    clock epoch — matches the paper's "Random" without carrying PRNG state in
    the cache pytree).
    """
    a = meta_a.astype(jnp.float32)
    if policy == Policy.LRU:
        return a  # last-access time: oldest == smallest == victim
    if policy == Policy.LFU:
        return a  # access count: least frequent == victim
    if policy == Policy.FIFO:
        return a  # insertion time: oldest insert == victim
    if policy == Policy.RANDOM:
        # Stateless random: hash(key, clock_epoch).  Changes every access so
        # repeated evictions in one set do not always pick the same way.
        epoch = (now.astype(jnp.uint32) if hasattr(now, "astype") else jnp.uint32(now))
        h = hashing.hash_u32(stored_keys ^ epoch, seed=0xBADA)
        return h.astype(jnp.float32)
    if policy == Policy.HYPERBOLIC:
        # priority = n_accesses / age ; evict the smallest priority.
        n = meta_a.astype(jnp.float32)
        age = (now - meta_b).astype(jnp.float32) + 1.0
        return n / age
    raise ValueError(f"unknown policy {policy}")


def on_hit(
    policy: int, meta_a: jnp.ndarray, meta_b: jnp.ndarray, now: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Metadata transition on a cache hit."""
    if policy == Policy.LRU:
        return jnp.broadcast_to(now, meta_a.shape).astype(meta_a.dtype), meta_b
    if policy in (Policy.LFU, Policy.HYPERBOLIC):
        return meta_a + 1, meta_b
    if policy in (Policy.FIFO, Policy.RANDOM):
        return meta_a, meta_b
    raise ValueError(f"unknown policy {policy}")


def on_insert(
    policy: int, now: jnp.ndarray, shape: tuple[int, ...] = ()
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fresh metadata for a newly admitted key."""
    now_arr = jnp.broadcast_to(jnp.asarray(now, jnp.int32), shape)
    one = jnp.ones(shape, jnp.int32)
    zero = jnp.zeros(shape, jnp.int32)
    if policy == Policy.LRU:
        return now_arr, zero
    if policy == Policy.LFU:
        return one, zero
    if policy == Policy.FIFO:
        return now_arr, zero
    if policy == Policy.RANDOM:
        return zero, zero
    if policy == Policy.HYPERBOLIC:
        return one, now_arr  # (n=1, t0=now)
    raise ValueError(f"unknown policy {policy}")


# ---------------------------------------------------------------------------
# Dynamic dispatch — policy as a *traced* value.
#
# The three functions above branch on `policy` in Python, so every policy is
# its own XLA program.  The sweep runner (repro/eval/runner.py) stacks
# same-shape configurations with different policies into one compiled replay;
# for that the policy must be data, not a static argument.  Each _dyn variant
# evaluates every policy's (cheap, elementwise) transition and selects by the
# traced `policy_idx` — one compilation covers all policies.
# ---------------------------------------------------------------------------

def _select_pair(policy_idx, pairs):
    sel = [policy_idx == int(p) for p in Policy]
    return (jnp.select(sel, [a for a, _ in pairs]),
            jnp.select(sel, [b for _, b in pairs]))


def victim_scores_dyn(
    policy_idx: jnp.ndarray,
    meta_a: jnp.ndarray,
    meta_b: jnp.ndarray,
    now: jnp.ndarray,
    stored_keys: jnp.ndarray,
) -> jnp.ndarray:
    """`victim_scores` with `policy_idx` as a traced int32 scalar/array."""
    branches = [victim_scores(p, meta_a, meta_b, now, stored_keys)
                for p in Policy]
    return jnp.select([policy_idx == int(p) for p in Policy], branches)


def on_hit_dyn(policy_idx, meta_a, meta_b, now):
    """`on_hit` with `policy_idx` as a traced value."""
    return _select_pair(policy_idx, [on_hit(p, meta_a, meta_b, now)
                                     for p in Policy])


def on_insert_dyn(policy_idx, now, shape: tuple[int, ...] = ()):
    """`on_insert` with `policy_idx` as a traced value."""
    return _select_pair(policy_idx, [on_insert(p, now, shape) for p in Policy])
