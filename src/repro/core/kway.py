"""K-way set-associative cache — the paper's core, as a functional JAX module.

The cache is a pytree of dense, fixed-shape arrays (the paper's "static
memory, no pointers" claim maps one-to-one onto jit/pjit requirements):

    keys    uint32[S, k]   stored keys (EMPTY_KEY sentinel = empty way)
    fprint  uint32[S, k]   16-bit fingerprints (SoA / KW-WFSC layout only)
    vals    int32 [S, k]   payload (e.g. KV-page index, object handle)
    meta_a  int32 [S, k]   policy lane A (LRU ts / LFU count / hyperbolic n)
    meta_b  int32 [S, k]   policy lane B (hyperbolic t0)
    clock   int32 []       global logical clock (paper: per-set AtomicLong)

Concurrency adaptation (see DESIGN.md §2): the paper's T threads become a
batch of B requests per step.  Requests to different sets are data-independent
(the paper's embarrassing parallelism) and are processed by pure vector ops.
Requests that collide on one set are resolved deterministically:

  * duplicate keys within a batch: the first occurrence performs the insert,
    later ones are dropped (the CAS-race outcome in KW-WFA);
  * distinct missing keys in one set: the i-th such request takes the i-th
    worst victim of that set (rank-ordered victim selection — the retry loop
    of KW-WFA collapsed into one vectorized pass).  At most k admissions per
    set per batch; overflow requests are not admitted (bounded, deterministic).

Layouts: ``soa`` (KW-WFSC — separate key/fingerprint/counter arrays, scans
touch contiguous memory, the TPU-friendly default) and ``aos`` (KW-WFA — one
interleaved record array [S, k, 4], gathered as records; kept as the layout
baseline the paper also measures).

The fully-associative oracle is *this same cache* with ``num_sets=1,
ways=capacity`` — the paper's observation that full associativity is the
degenerate corner of the design space.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core.hashing import EMPTY_KEY
from repro.core.policies import Policy, on_hit, on_insert, victim_scores

NEG_INF = jnp.float32(-3.0e38)
POS_INF = jnp.float32(3.0e38)

#: "never expires" deadline sentinel (int32 max).  Every lane of a fresh
#: expiry array holds it, so a cache with the lane but no TTL-bearing
#: requests behaves bit-identically to one without the lane.
NO_EXPIRY = 0x7FFFFFFF


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KWayState:
    """Cache contents.  A pytree — shardable, scannable, checkpointable.

    ``expiry`` is the optional TTL lane (DESIGN.md §15): an absolute
    int32 deadline on the replay clock per cached entry, ``NO_EXPIRY``
    when the entry never expires.  ``None`` (the default) means the
    cache has no expiry semantics at all — the pytree then has exactly
    the pre-TTL leaves, so every TTL-disabled code path is bit-identical
    to the lane-less implementation by construction.
    """

    keys: jnp.ndarray    # uint32 [S, k]
    fprint: jnp.ndarray  # uint32 [S, k]
    vals: jnp.ndarray    # int32  [S, k]
    meta_a: jnp.ndarray  # int32  [S, k]
    meta_b: jnp.ndarray  # int32  [S, k]
    clock: jnp.ndarray   # int32  []
    expiry: Optional[jnp.ndarray] = None  # int32 [S, k] | None

    @property
    def num_sets(self) -> int:
        return self.keys.shape[0]

    @property
    def ways(self) -> int:
        return self.keys.shape[1]

    @property
    def capacity(self) -> int:
        return self.keys.size

    def occupancy(self) -> jnp.ndarray:
        return jnp.sum(self.keys != EMPTY_KEY)


@dataclasses.dataclass(frozen=True)
class KWayConfig:
    """Static cache configuration (hashable; safe as a jit static arg)."""

    num_sets: int
    ways: int
    policy: Policy = Policy.LRU
    layout: str = "soa"          # "soa" (KW-WFSC) | "aos" (KW-WFA)
    sample: int = 0              # >0: sampled policy — score only `sample`
    #                              random ways (Redis-style; meaningful for
    #                              the fully-associative configuration)
    seed: int = 0x51CA

    def __post_init__(self):
        assert self.num_sets >= 1 and self.num_sets & (self.num_sets - 1) == 0
        assert self.ways >= 1
        assert self.layout in ("soa", "aos")

    @property
    def capacity(self) -> int:
        return self.num_sets * self.ways


def fully_associative(capacity: int, policy: Policy, sample: int = 0) -> KWayConfig:
    """The paper's baseline: one set spanning the whole cache."""
    return KWayConfig(num_sets=1, ways=capacity, policy=policy, sample=sample)


def make_cache(cfg: KWayConfig, *, ttl: bool = False) -> KWayState:
    s, k = cfg.num_sets, cfg.ways
    return KWayState(
        keys=jnp.full((s, k), EMPTY_KEY, jnp.uint32),
        fprint=jnp.zeros((s, k), jnp.uint32),
        vals=jnp.zeros((s, k), jnp.int32),
        meta_a=jnp.zeros((s, k), jnp.int32),
        meta_b=jnp.zeros((s, k), jnp.int32),
        clock=jnp.zeros((), jnp.int32),
        expiry=(jnp.full((s, k), NO_EXPIRY, jnp.int32) if ttl else None),
    )


def ensure_expiry(state: KWayState) -> KWayState:
    """Attach an all-``NO_EXPIRY`` expiry lane if the state lacks one."""
    if state.expiry is not None:
        return state
    return dataclasses.replace(
        state, expiry=jnp.full(state.keys.shape, NO_EXPIRY, jnp.int32))


def scrub_expired(state: KWayState, horizon: jnp.ndarray) -> KWayState:
    """Reclaim every entry whose deadline is at or before ``horizon``.

    The expiry contract (DESIGN.md §15): each batch scrubs with
    ``horizon = clock_at_entry + 2B`` — the clock value at batch *exit* —
    so an entry is visible to a batch only if it is still live when the
    batch retires.  Scrubbed lanes become ordinary empty lanes (never
    hit, filled first by victim selection); reclaiming one is not an
    eviction.  The resulting steady-state invariant, independent of
    batch size, is ``occupied ⇒ expiry > clock`` — what the
    ``expired_resident`` validator bit checks.  No-op when the state has
    no expiry lane.
    """
    if state.expiry is None:
        return state
    dead = (state.keys != EMPTY_KEY) & (state.expiry <= horizon)
    return dataclasses.replace(
        state,
        keys=jnp.where(dead, jnp.uint32(EMPTY_KEY), state.keys),
        fprint=jnp.where(dead, jnp.uint32(0), state.fprint),
        vals=jnp.where(dead, jnp.int32(0), state.vals),
        meta_a=jnp.where(dead, jnp.int32(0), state.meta_a),
        meta_b=jnp.where(dead, jnp.int32(0), state.meta_b),
        expiry=jnp.where(dead, jnp.int32(NO_EXPIRY), state.expiry),
    )


def insert_deadlines(clock, b: int, ttls: Optional[jnp.ndarray]):
    """Deadlines for this batch's inserts: ``clock + 2B + ttl`` (TTL
    counted from the batch-exit clock), ``NO_EXPIRY`` for ``ttl <= 0``.

    The deadline is a *chunk-level* constant plus the per-request TTL —
    deliberately independent of the lane's position inside the batch, so
    the sharded replay (which permutes lanes into owner buckets but
    advances every shard's clock by the same 2B per step) lands
    bit-identical deadlines to the unsharded path.
    """
    if ttls is None:
        return None
    dl = clock + jnp.int32(2 * b) + ttls.astype(jnp.int32)
    return jnp.where(ttls > 0, dl, jnp.int32(NO_EXPIRY))


# ---------------------------------------------------------------------------
# probing
# ---------------------------------------------------------------------------

def _probe(cfg: KWayConfig, state: KWayState, qkeys: jnp.ndarray):
    """Gather each query's set and locate the key.

    Returns (sets[B], set_keys[B,k], hit[B], way[B]).  The SoA layout
    pre-filters with fingerprints (KW-WFSC Algorithm 5); AoS compares full
    keys directly (KW-WFA Algorithm 2).  Both produce identical results —
    fingerprints are a scan accelerator, never a correctness shortcut: a
    fingerprint match is confirmed against the full key.
    """
    qkeys = hashing.sanitize_keys(qkeys)
    sets = hashing.set_index(qkeys, cfg.num_sets, cfg.seed)
    set_keys = state.keys[sets]                      # [B, k] gather
    if cfg.layout == "soa":
        qfp = hashing.fingerprint(qkeys)[:, None]
        cand = state.fprint[sets] == qfp             # cheap contiguous scan
        eq = cand & (set_keys == qkeys[:, None])     # confirm on full key
    else:
        eq = set_keys == qkeys[:, None]
    eq = eq & (set_keys != EMPTY_KEY)
    hit = jnp.any(eq, axis=-1)
    way = jnp.argmax(eq, axis=-1).astype(jnp.int32)
    return qkeys, sets, set_keys, hit, way


def _batch_times(state: KWayState, b: int):
    """Per-request logical timestamps: batch order == arrival order."""
    times = state.clock + jnp.arange(b, dtype=jnp.int32)
    return times, state.clock + jnp.int32(b)


def _intra_batch_rank(sets: jnp.ndarray, active: jnp.ndarray) -> jnp.ndarray:
    """rank[i] = #(j<i : active[j] and sets[j]==sets[i]) for active i.

    The vectorized stand-in for the paper's CAS retry loop: the r-th insert
    colliding on a set takes the r-th worst victim.  O(B log B) via sort.
    """
    b = sets.shape[0]
    order_key = jnp.where(active, sets, jnp.int32(0x7FFFFFFF))
    # Stable sort by set id; arrival order preserved inside each set group.
    perm = jnp.argsort(order_key, stable=True)
    sorted_sets = order_key[perm]
    new_group = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), sorted_sets[1:] != sorted_sets[:-1]]
    )
    idx = jnp.arange(b, dtype=jnp.int32)
    group_start = jax.lax.associative_scan(jnp.maximum, jnp.where(new_group, idx, 0))
    rank_sorted = idx - group_start
    rank = jnp.zeros((b,), jnp.int32).at[perm].set(rank_sorted)
    return jnp.where(active, rank, 0)


def _first_occurrence(qkeys: jnp.ndarray, active: jnp.ndarray) -> jnp.ndarray:
    """True for the first active occurrence of each key in the batch."""
    b = qkeys.shape[0]
    # Inactive lanes sort under EMPTY_KEY, which sanitize_keys guarantees is
    # never a real key — a valid-key sentinel (e.g. 0) would absorb the first
    # occurrence of that key whenever an inactive lane precedes it.
    order_key = jnp.where(active, qkeys, EMPTY_KEY).astype(jnp.uint32)
    # sort by (key, arrival); first of each equal-key run wins
    perm = jnp.argsort(order_key, stable=True)
    sorted_keys = order_key[perm]
    first_sorted = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), sorted_keys[1:] != sorted_keys[:-1]]
    )
    first = jnp.zeros((b,), jnp.bool_).at[perm].set(first_sorted)
    return first & active


def sampled_way_ids(sample: int, ways: int, times: jnp.ndarray) -> jnp.ndarray:
    """Pseudo-random way ids (with replacement) for sampled victim selection
    (Redis-style, O(sample)).  ``times`` int32 [...] -> int32 [..., sample].
    The single source of truth for the draw scheme — the sweep runner
    (repro/eval/runner.py) replays it bit-for-bit."""
    draw = jnp.arange(sample, dtype=jnp.uint32)
    h = hashing.hash_u32(
        draw + times[..., None].astype(jnp.uint32) * jnp.uint32(2654435761),
        seed=0x5A5A,
    )
    return (h % jnp.uint32(ways)).astype(jnp.int32)


def _victim_order_arrays(cfg: KWayConfig, keys_arr, meta_a_arr, meta_b_arr,
                         sets, set_keys, times):
    """Per request: ways of its set ordered worst-victim-first. [B, k]
    (or [B, sample] for sampled policies — see below).  Takes the state
    lanes as plain arrays so the fused access path can score on the
    hit-updated metadata without materialising an intermediate state."""
    if cfg.sample > 0 and cfg.sample < cfg.ways:
        # Sampled policy: draw `sample` ways (with replacement), score only
        # those.
        m = cfg.sample
        way_ids = sampled_way_ids(m, cfg.ways, times)               # [B, m]
        ma = meta_a_arr[sets[:, None], way_ids]
        mb = meta_b_arr[sets[:, None], way_ids]
        keys_s = keys_arr[sets[:, None], way_ids]
        scores = victim_scores(cfg.policy, ma, mb, times[:, None], keys_s)
        scores = jnp.where(keys_s == EMPTY_KEY, NEG_INF, scores)
        order_local = jnp.argsort(scores, axis=-1)
        return jnp.take_along_axis(way_ids, order_local, axis=-1)   # [B, m]
    ma = meta_a_arr[sets]
    mb = meta_b_arr[sets]
    scores = victim_scores(cfg.policy, ma, mb, times[:, None], set_keys)
    empty = set_keys == EMPTY_KEY
    scores = jnp.where(empty, NEG_INF, scores)  # fill empty ways first
    return jnp.argsort(scores, axis=-1).astype(jnp.int32)  # [B, k]


def _victim_order(cfg: KWayConfig, state: KWayState, sets, set_keys, times):
    return _victim_order_arrays(cfg, state.keys, state.meta_a, state.meta_b,
                                sets, set_keys, times)


def _resolve_inserts(cfg: KWayConfig, qkeys, sets, eligible, order):
    """Deterministic insert conflict resolution, shared by ``apply_put`` and
    ``apply_access`` (one definition so the fused and two-phase paths cannot
    drift): dedupe duplicate keys within the batch, rank same-set collisions
    by arrival order, cap at k admits per set, and pick each insert's victim
    way from ``order`` ([B, m], worst-victim-first).

    Returns (is_insert bool[B], way_victim int32[B]); way_victim is the
    rank-selected way for every lane (callers mask with is_insert).
    """
    is_insert = eligible & _first_occurrence(qkeys, eligible)
    rank = _intra_batch_rank(sets, is_insert)
    is_insert &= rank < cfg.ways                          # ≤ k admits per set
    rank_c = jnp.clip(rank, 0, order.shape[1] - 1)  # dropped lanes: safe idx
    way_victim = jnp.take_along_axis(order, rank_c[:, None], axis=-1)[:, 0]
    return is_insert, way_victim


# ---------------------------------------------------------------------------
# decision application (shared by every probe implementation)
#
# Probing (locate the key / rank the victims) and applying (scatter the new
# contents) are split so alternative probe substrates — the pure-jnp path
# below, the Pallas kernel in kernels/kway_probe.py — feed one common apply
# and stay bit-identical (DESIGN.md §3).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnums=0)
def apply_get(cfg: KWayConfig, state: KWayState, sets, hit, way):
    """Apply read-side policy-metadata updates for already-probed queries.

    Returns (state', hit[B], vals[B]).
    """
    b = sets.shape[0]
    times, clock = _batch_times(state, b)

    ma_hit = state.meta_a[sets, way]
    mb_hit = state.meta_b[sets, way]
    new_a, new_b = on_hit(cfg.policy, ma_hit, mb_hit, times)
    # Duplicate (set, way) pairs in one batch: LFU/Hyperbolic counts must
    # accumulate (two hits = +2), LRU must take the max timestamp.  Scatter-add
    # the deltas instead of scatter-set.
    da = jnp.where(hit, new_a - ma_hit, 0)
    if cfg.policy in (Policy.LFU, Policy.HYPERBOLIC):
        meta_a = state.meta_a.at[sets, way].add(da)
    else:
        meta_a = state.meta_a.at[sets, way].max(jnp.where(hit, new_a, -(2**31 - 1)))
    db = jnp.where(hit, new_b - mb_hit, 0)
    meta_b = state.meta_b.at[sets, way].add(db)

    vals = jnp.where(hit, state.vals[sets, way], -1)
    return (
        dataclasses.replace(state, meta_a=meta_a, meta_b=meta_b, clock=clock),
        hit,
        vals,
    )


@partial(jax.jit, static_argnums=0, static_argnames=("slot_value",))
def apply_put(
    cfg: KWayConfig,
    state: KWayState,
    qkeys: jnp.ndarray,
    qvals: jnp.ndarray,
    sets: jnp.ndarray,
    present: jnp.ndarray,
    way_present: jnp.ndarray,
    order: jnp.ndarray,
    admit: Optional[jnp.ndarray] = None,
    enabled: Optional[jnp.ndarray] = None,
    *,
    slot_value: bool = False,
):
    """Apply write decisions: deterministic conflict resolution + one scatter.

    ``order`` is [B, m]: per request, the ways of its set worst-victim-first
    (m == ways, or the sample size for sampled policies).  ``slot_value``
    stores ``set * ways + way`` — the landing slot id — as the payload
    instead of ``qvals`` (the paged-KV engine's page-id convention).

    Returns (state', evicted_keys[B], evicted_valid[B], slot_sets[B],
    slot_ways[B]); slot_* are -1 for lanes that did not land (not admitted,
    intra-batch duplicate, per-set overflow, or disabled).
    """
    b = qkeys.shape[0]
    times, clock = _batch_times(state, b)
    if admit is None:
        admit = jnp.ones((b,), jnp.bool_)
    if enabled is None:
        enabled = jnp.ones((b,), jnp.bool_)
    present = present & enabled

    is_insert, way_victim = _resolve_inserts(
        cfg, qkeys, sets, (~present) & admit & enabled, order)

    way = jnp.where(present, way_present, way_victim)
    active = present | is_insert

    evicted_keys = state.keys[sets, way_victim]
    evicted_valid = is_insert & (evicted_keys != EMPTY_KEY)

    ia, ib = on_insert(cfg.policy, times, (b,))

    # For present keys: overwrite value, metadata takes the on_hit transition
    # (a put of an existing key counts as an access — paper Algorithm 3 line 6).
    ha, hb = on_hit(cfg.policy, state.meta_a[sets, way], state.meta_b[sets, way], times)
    new_a = jnp.where(present, ha, ia)
    new_b = jnp.where(present, hb, ib)

    if slot_value:
        qvals = (sets * jnp.int32(cfg.ways) + way).astype(jnp.int32)

    # Inactive lanes scatter to an out-of-bounds set index — JAX drops
    # out-of-bounds scatter updates, making them true no-ops.  (Routing them
    # to slot (0,0) with its "current" value is NOT a no-op: a duplicate
    # scatter index lets the stale inactive write clobber an active lane's
    # genuine insert into (0,0).)
    sets_w = jnp.where(active, sets, jnp.int32(cfg.num_sets))
    way_w = jnp.where(active, way, 0)

    keys = state.keys.at[sets_w, way_w].set(qkeys)
    fpr = state.fprint.at[sets_w, way_w].set(hashing.fingerprint(qkeys))
    vals = state.vals.at[sets_w, way_w].set(qvals)
    meta_a = state.meta_a.at[sets_w, way_w].set(new_a)
    meta_b = state.meta_b.at[sets_w, way_w].set(new_b)
    # put has no TTL argument (TTL riding is the fused access path's job);
    # an expiry lane, when present, is carried with landing lanes marked
    # never-expiring so the structural invariants stay intact.
    expiry = (None if state.expiry is None
              else state.expiry.at[sets_w, way_w].set(jnp.int32(NO_EXPIRY)))

    new_state = KWayState(keys, fpr, vals, meta_a, meta_b, clock, expiry)
    slot_sets = jnp.where(active, sets, -1)
    slot_ways = jnp.where(active, way, -1)
    return new_state, evicted_keys, evicted_valid, slot_sets, slot_ways


# ---------------------------------------------------------------------------
# public operations
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnums=0)
def get(
    cfg: KWayConfig,
    state: KWayState,
    qkeys: jnp.ndarray,
    enabled: Optional[jnp.ndarray] = None,
):
    """Batched read (paper Algorithm 2/5/8).

    Returns (state', hit[B] bool, vals[B] int32).  Hits update policy
    metadata; misses leave the cache untouched.  ``enabled`` (bool[B],
    optional) masks whole lanes (they still consume a logical timestamp —
    used by the sharded layer's padding lanes).
    """
    qkeys, sets, set_keys, hit, way = _probe(cfg, state, qkeys)
    if enabled is not None:
        hit = hit & enabled
    return apply_get(cfg, state, sets, hit, way)


@partial(jax.jit, static_argnums=0, static_argnames=("slot_value",))
def put(
    cfg: KWayConfig,
    state: KWayState,
    qkeys: jnp.ndarray,
    qvals: jnp.ndarray,
    admit: Optional[jnp.ndarray] = None,
    enabled: Optional[jnp.ndarray] = None,
    *,
    slot_value: bool = False,
):
    """Batched write (paper Algorithm 3/6/9).

    Present keys are overwritten in place; absent keys evict a policy victim
    from their own set.  ``admit`` (bool[B], optional) gates admission of
    absent keys — the hook the TinyLFU filter plugs into.  ``enabled``
    (bool[B], optional) disables whole lanes (used by ``access`` so a lane
    that already hit in the read phase is not written twice).

    Returns (state', evicted_keys uint32[B], evicted_valid bool[B],
    slot_sets int32[B], slot_ways int32[B]).  The evicted keys let callers
    (e.g. the paged-KV allocator) recycle the victims' payloads; the slot
    arrays report where each key landed (-1 when it did not land).
    """
    qkeys, sets, set_keys, present, way_present = _probe(cfg, state, qkeys)
    times, _ = _batch_times(state, qkeys.shape[0])
    order = _victim_order(cfg, state, sets, set_keys, times)
    return apply_put(
        cfg, state, qkeys, qvals, sets, present, way_present, order,
        admit, enabled, slot_value=slot_value,
    )


@partial(jax.jit, static_argnums=0, static_argnames=("slot_value",))
def apply_access(
    cfg: KWayConfig,
    state: KWayState,
    qkeys: jnp.ndarray,
    qvals: jnp.ndarray,
    sets: jnp.ndarray,
    hit_raw: jnp.ndarray,
    way: jnp.ndarray,
    admit: Optional[jnp.ndarray] = None,
    enabled: Optional[jnp.ndarray] = None,
    order: Optional[jnp.ndarray] = None,
    set_keys: Optional[jnp.ndarray] = None,
    ttls: Optional[jnp.ndarray] = None,
    *,
    slot_value: bool = False,
):
    """Fused one-pass apply for ``access`` — one probe feeds both phases.

    Consumes one probe's decisions (``hit_raw``/``way``, *unmasked* by
    ``enabled``) and applies the get-then-put-on-miss composition in a single
    pass, bit-identical to ``apply_get`` followed by ``apply_put`` (DESIGN.md
    §8).  Two-phase clock accounting is preserved: hits stamp ``t+i``,
    inserts stamp ``t+B+i``, and the clock advances by 2B.  Victim scores are
    computed on the *post-hit-update* metadata (``meta_a1``), exactly what
    the second probe of the two-phase path would observe — the keys lanes are
    untouched by the hit phase, so the probe itself never needs repeating.

    ``order`` (int32 [B, m], worst-victim-first) can be supplied by a caller
    that already derived it from the same post-hit metadata (the fused Pallas
    kernel); otherwise it is computed here from ``set_keys`` (the [B, k]
    gather of the first probe).  Exactly one of the two must be given.

    Scatter economy vs the two-phase applies (7 scatters per step): the hit
    phase scatters only ``meta_a`` (``on_hit`` keeps ``meta_b`` for every
    policy, and is the identity for FIFO/RANDOM), and the insert phase is
    one packed scatter pass — a single (set, way) index pair shared by all
    five state lanes.

    ``slot_value`` is the cache-as-allocator mode (the paged-KV engine's
    page-id convention): inserts store ``set * ways + way`` — the landing
    slot id — as the payload, and ``vals`` returns the hit lane's stored
    slot id, the insert lane's fresh slot id, or -1 where the key did not
    land (not admitted / duplicate / per-set overflow / disabled).  One
    fused call answers "which page holds this block, allocating if absent"
    for a whole batch — bit-identical to the get + slot-returning-put
    composition (``CacheBackend.access_two_phase`` with ``slot_value``).

    ``ttls`` (int32 [B], optional) gives each request a time-to-live on
    the logical clock: its insert lands with deadline ``clock + 2B + ttl``
    (``NO_EXPIRY`` for ``ttl <= 0``); hits never refresh a deadline.  The
    caller is responsible for having scrubbed expired entries at batch
    entry (``scrub_expired`` with the batch-exit horizon) — the probe
    feeding this apply then cannot see an expired key.  Requires the
    state to carry an expiry lane.

    Returns (state', hit[B], vals[B], evicted_keys[B], evicted_valid[B]).
    """
    if ttls is not None and state.expiry is None:
        raise ValueError(
            "apply_access: ttls given but the state has no expiry lane — "
            "build it with make_cache(cfg, ttl=True) or ensure_expiry()")
    b = qkeys.shape[0]
    times_get = state.clock + jnp.arange(b, dtype=jnp.int32)
    times_put = times_get + jnp.int32(b)
    clock = state.clock + jnp.int32(2 * b)

    hit = hit_raw if enabled is None else (hit_raw & enabled)

    # ---- hit phase (apply_get semantics at times t+i) --------------------
    ma_hit = state.meta_a[sets, way]
    new_a, _ = on_hit(cfg.policy, ma_hit, state.meta_b[sets, way], times_get)
    if cfg.policy in (Policy.LFU, Policy.HYPERBOLIC):
        meta_a1 = state.meta_a.at[sets, way].add(
            jnp.where(hit, new_a - ma_hit, 0))
    elif cfg.policy in (Policy.FIFO, Policy.RANDOM):
        meta_a1 = state.meta_a          # on_hit is the identity here
    else:
        meta_a1 = state.meta_a.at[sets, way].max(
            jnp.where(hit, new_a, -(2**31 - 1)))
    # on_hit keeps meta_b for every policy, so the apply_get meta_b
    # scatter-add is always adding zero — elided.
    vals_out = jnp.where(hit, state.vals[sets, way], qvals)

    # ---- miss phase (apply_put semantics at times t+B+i) -----------------
    # In the composition, every lane the put phase sees is either disabled
    # (it hit in the get phase) or absent, so the present/overwrite branch of
    # apply_put never fires: the put phase is pure insert resolution.
    if admit is None:
        admit = jnp.ones((b,), jnp.bool_)
    if enabled is None:
        enabled = jnp.ones((b,), jnp.bool_)
    if order is None:
        order = _victim_order_arrays(
            cfg, state.keys, meta_a1, state.meta_b, sets, set_keys, times_put)

    is_insert, way_victim = _resolve_inserts(
        cfg, qkeys, sets, (~hit_raw) & admit & enabled, order)

    evicted_keys = state.keys[sets, way_victim]
    evicted_valid = is_insert & (evicted_keys != EMPTY_KEY)

    if slot_value:
        slot_id = (sets * jnp.int32(cfg.ways) + way_victim).astype(jnp.int32)
        qvals = slot_id                      # stored payload for inserts
        vals_out = jnp.where(
            hit, state.vals[sets, way],
            jnp.where(is_insert, slot_id, jnp.int32(-1)))

    ia, ib = on_insert(cfg.policy, times_put, (b,))

    # One packed scatter pass: the (set, way) index pair is computed once and
    # shared by all five lanes.  Inactive lanes route out of bounds (dropped
    # by JAX) — see apply_put for why slot (0,0) is not a safe parking spot.
    sets_w = jnp.where(is_insert, sets, jnp.int32(cfg.num_sets))
    way_w = jnp.where(is_insert, way_victim, 0)

    keys = state.keys.at[sets_w, way_w].set(qkeys)
    fpr = state.fprint.at[sets_w, way_w].set(hashing.fingerprint(qkeys))
    vals = state.vals.at[sets_w, way_w].set(qvals)
    meta_a = meta_a1.at[sets_w, way_w].set(ia)
    meta_b = state.meta_b.at[sets_w, way_w].set(ib)
    expiry = state.expiry
    if expiry is not None:
        ie = insert_deadlines(state.clock, b, ttls)
        if ie is None:           # lane present, no TTLs: never-expiring
            ie = jnp.full((b,), NO_EXPIRY, jnp.int32)
        expiry = expiry.at[sets_w, way_w].set(ie)

    new_state = KWayState(keys, fpr, vals, meta_a, meta_b, clock, expiry)
    return new_state, hit, vals_out, evicted_keys, evicted_valid


def _access_fused(
    cfg: KWayConfig,
    state: KWayState,
    qkeys: jnp.ndarray,
    qvals: jnp.ndarray,
    admit_on_miss: Optional[jnp.ndarray] = None,
    enabled: Optional[jnp.ndarray] = None,
    ttls: Optional[jnp.ndarray] = None,
    *,
    slot_value: bool = False,
):
    # Expiry scrub precedes the probe (the "never serve stale" hard
    # guarantee): an expired key is reclaimed before any hit decision is
    # made, so the probe itself needs no expiry awareness.
    if state.expiry is not None:
        b = qkeys.shape[0]
        state = scrub_expired(state, state.clock + jnp.int32(2 * b))
    qkeys, sets, set_keys, hit_raw, way = _probe(cfg, state, qkeys)
    return apply_access(cfg, state, qkeys, qvals, sets, hit_raw, way,
                        admit_on_miss, enabled, set_keys=set_keys,
                        ttls=ttls, slot_value=slot_value)


#: The canonical cache loop: get; on miss, put (paper §5.1.2 methodology) —
#: fused single-probe form.  Returns (state', hit[B], vals[B],
#: evicted_keys[B], evicted_valid[B]); bit-identical to ``access_two_phase``.
access = partial(jax.jit, static_argnums=0,
                 static_argnames=("slot_value",))(_access_fused)

#: Buffer-donating variant of ``access``: the input ``state`` buffers are
#: donated to XLA so ``KWayState`` is updated in place (5 S×k arrays are not
#: copied every batch).  The caller must not reuse ``state`` afterwards.
#: Backends without donation support (CPU on older jaxlibs) fall back to a
#: copy with a one-time warning.
access_donated = partial(
    jax.jit, static_argnums=0, donate_argnums=1,
    static_argnames=("slot_value",))(_access_fused)


@partial(jax.jit, static_argnums=0, static_argnames=("slot_value",))
def access_two_phase(
    cfg: KWayConfig,
    state: KWayState,
    qkeys: jnp.ndarray,
    qvals: jnp.ndarray,
    admit_on_miss: Optional[jnp.ndarray] = None,
    enabled: Optional[jnp.ndarray] = None,
    *,
    slot_value: bool = False,
):
    """The unfused get-then-put composition — two probes, two apply passes.

    Kept as the differential oracle for ``access``: tests assert the fused
    path is bit-identical to this one (hits, evictions, final state) — with
    ``slot_value``, also the returned page/slot ids.
    """
    state, hit, vals = get(cfg, state, qkeys, enabled=enabled)
    admit = admit_on_miss if admit_on_miss is not None else None
    en = (~hit) if enabled is None else (enabled & ~hit)
    state, ek, ev, ss, sw = put(cfg, state, qkeys, qvals, admit=admit,
                                enabled=en, slot_value=slot_value)
    if slot_value:
        landed = ss >= 0
        slot_id = ss * jnp.int32(cfg.ways) + sw
        vals = jnp.where(hit, vals, jnp.where(landed, slot_id, -1))
    else:
        vals = jnp.where(hit, vals, qvals)
    return state, hit, vals, ek, ev


@partial(jax.jit, static_argnums=0)
def peek_victims(cfg: KWayConfig, state: KWayState, qkeys: jnp.ndarray):
    """Prospective victim key for each query, without mutating the cache.

    Used by admission filters (TinyLFU): the candidate competes against the
    key it *would* evict.  Returns (victim_keys uint32[B], victim_valid
    bool[B]); victim_valid is False when the set has a free way (admission is
    then unconditional) or the key is already present (no eviction).
    """
    qkeys2, sets, set_keys, present, _ = _probe(cfg, state, qkeys)
    times, _ = _batch_times(state, qkeys.shape[0])
    order = _victim_order(cfg, state, sets, set_keys, times)
    way0 = order[:, 0]
    vkeys = state.keys[sets, way0]
    valid = (vkeys != EMPTY_KEY) & (~present)
    return vkeys, valid


# ---------------------------------------------------------------------------
# AoS record packing (KW-WFA layout baseline)
# ---------------------------------------------------------------------------

def pack_aos(state: KWayState) -> jnp.ndarray:
    """Interleave the SoA lanes into one [S, k, 4] record array (int32).

    KW-WFA stores a node per way; gathering a record touches 4 interleaved
    words.  The throughput benchmark contrasts this with the SoA layout to
    reproduce the paper's KW-WFA vs KW-WFSC comparison on vector hardware.
    """
    return jnp.stack(
        [
            state.keys.astype(jnp.int32),
            state.vals,
            state.meta_a,
            state.meta_b,
        ],
        axis=-1,
    )


def unpack_aos(rec: jnp.ndarray, clock: jnp.ndarray) -> KWayState:
    keys = rec[..., 0].astype(jnp.uint32)
    return KWayState(
        keys=keys,
        fprint=hashing.fingerprint(keys),
        vals=rec[..., 1],
        meta_a=rec[..., 2],
        meta_b=rec[..., 3],
        clock=clock,
    )
