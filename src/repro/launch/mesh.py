"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state — the dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(data: int = 1, model: int = 1):
    """Small mesh for tests on however many devices exist."""
    return jax.make_mesh((data, model), ("data", "model"))
