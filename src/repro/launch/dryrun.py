import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (GSPMD partitions cleanly on the
    16×16 single-pod mesh AND the 2×16×16 multi-pod mesh);
  * it fits (memory_analysis of the full scanned+remat step);
  * and extracts the roofline terms (cost_analysis + HLO collective scrape
    from unrolled p/2p-layer lowerings; see repro/roofline/analysis.py).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --skip-multi-pod
Results accumulate in dryrun_results.json (resumable; --force recomputes).
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.optim import adamw
from repro.roofline import analysis as roof
from repro.train.step import TrainConfig, make_train_step

RESULTS_PATH = "dryrun_results.json"


def _pattern_period(cfg: ModelConfig) -> int:
    return 2 if cfg.alt_local_global else 1


def _reduced(cfg: ModelConfig, n_layers: int) -> ModelConfig:
    kw = {"num_layers": n_layers}
    if cfg.enc_layers > 0:
        kw["enc_layers"] = n_layers
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# step builders (full-L scanned, or reduced unrolled)
# ---------------------------------------------------------------------------

def build_train_fn(cfg: ModelConfig, unroll: bool, act_spec=None,
                   microbatches: int = 1):
    """Train step.  The production (scanned) variant microbatches with
    gradient accumulation — peak activation memory scales 1/mb.  The
    roofline (unrolled) variant runs the full batch in one pass: FLOPs are
    linear in tokens so the totals are identical, and cost_analysis would
    count an accumulation scan body only once."""
    tcfg = TrainConfig()

    def loss_fn(params, batch):
        from repro.train.step import cross_entropy
        logits = lm.forward(
            cfg, params, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            enc_embeds=batch.get("enc_embeds"),
            unroll=unroll, act_spec=act_spec,
        )
        labels = batch["labels"][:, : logits.shape[1]]
        return cross_entropy(cfg, logits, labels)

    def step(params, opt_state, batch):
        mb = 1 if unroll else microbatches
        if mb > 1:
            def acc(carry, i):
                loss_acc, grad_acc = carry
                mbatch = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // mb), x.shape[0] // mb, 0
                    ),
                    batch,
                )
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                return (loss_acc + l,
                        jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                     grad_acc, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), zeros), jnp.arange(mb)
            )
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, om = adamw.update(
            tcfg.optimizer, grads, opt_state, params
        )
        return params, opt_state, {"loss": loss, **om}

    return step


def build_prefill_fn(cfg: ModelConfig, unroll: bool, act_spec=None):
    def step(params, batch):
        return lm.forward(
            cfg, params, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            enc_embeds=batch.get("enc_embeds"),
            unroll=unroll, act_spec=act_spec,
        )

    return step


def build_decode_fn(cfg: ModelConfig, unroll: bool, act_spec=None):
    def step(params, cache, batch):
        return lm.decode_step(
            cfg, params, batch["token"], batch["pos"], cache, unroll=unroll,
            act_spec=act_spec,
        )

    return step


# ---------------------------------------------------------------------------
# lowering one cell on one mesh
# ---------------------------------------------------------------------------

def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *, unroll: bool):
    """Lower (not compile) one cell.  Returns (lowered, donate_info)."""
    pspecs = configs.param_specs(cfg)
    pshard = shd.param_shardings(cfg, pspecs, mesh)
    ispecs = configs.input_specs(cfg, shape)
    ishard = shd.input_shardings(cfg, shape, ispecs, mesh)
    aspec = NamedSharding(mesh, shd.batch_pspec(cfg, shape.global_batch, mesh))
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        ostate = jax.eval_shape(adamw.init, pspecs)
        oshard = adamw.state_shardings(pshard, mesh, pspecs)
        mb = 8 if shape.global_batch % 8 == 0 else 1
        fn = build_train_fn(cfg, unroll, aspec, microbatches=mb)
        jitted = jax.jit(
            fn,
            in_shardings=(pshard, oshard, ishard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(pspecs, ostate, ispecs)
    elif shape.kind == "prefill":
        fn = build_prefill_fn(cfg, unroll, aspec)
        jitted = jax.jit(fn, in_shardings=(pshard, ishard))
        lowered = jitted.lower(pspecs, ispecs)
    else:  # decode
        cspecs = configs.cache_specs(cfg, shape)
        cshard = shd.cache_shardings(cfg, shape, cspecs, mesh)
        fn = build_decode_fn(cfg, unroll, aspec)
        jitted = jax.jit(
            fn,
            in_shardings=(pshard, cshard, ishard),
            out_shardings=(rep, cshard),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(pspecs, cspecs, ispecs)
    return lowered


def run_cell(arch_id: str, shape: ShapeConfig, *, multi_pod: bool,
             roofline: bool = True, mesh=None) -> dict:
    """Compile one cell; return the record for dryrun_results.json."""
    spec = configs.get(arch_id)
    cfg = spec.config
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rec = {
        "arch": arch_id, "shape": shape.name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": chips,
    }
    t0 = time.time()

    # 1) production artifact -> memory + provability.
    #    train/prefill: full L, scanned + remat (small HLO).
    #    decode: full L, UNROLLED — a layer scan would capture the multi-TB
    #    KV cache in the while-loop state (measured: +2x cache temp copies);
    #    unrolled, the cache stays a jit-level donated buffer and the
    #    append aliases in place.  Decode HLO per layer is tiny, so the
    #    unrolled module stays manageable and cost_analysis is exact.
    is_decode = shape.kind == "decode"
    lowered = lower_cell(cfg, shape, mesh, unroll=is_decode)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
    }
    rec["compile_s"] = round(time.time() - t0, 1)

    if not roofline:
        return rec

    if is_decode:
        # the production artifact is already fully unrolled: costs are exact
        ca = compiled.cost_analysis()
        flops_dev = float(ca.get("flops", 0.0))
        bytes_dev = float(ca.get("bytes accessed", 0.0))
        coll_dev = roof.collective_bytes_per_device(compiled.as_text())
    else:
        # 2) roofline: unrolled p / 2p layer lowerings (exact, no while loop)
        p = _pattern_period(cfg)
        costs = {}
        for n in (p, 2 * p):
            rcfg = _reduced(cfg, n)
            lo = lower_cell(rcfg, shape, mesh, unroll=True)
            co = lo.compile()
            ca = co.cost_analysis()
            costs[n] = {
                "flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0)),
                "coll": roof.collective_bytes_per_device(co.as_text()),
            }
        periods = cfg.num_layers // p
        flops_dev = roof.extrapolate(
            costs[p]["flops"], costs[2 * p]["flops"], periods
        )
        bytes_dev = roof.extrapolate(
            costs[p]["bytes"], costs[2 * p]["bytes"], periods
        )
        coll_dev = roof.extrapolate_dict(
            costs[p]["coll"], costs[2 * p]["coll"], periods
        )

    cell = roof.CellRoofline(
        arch=arch_id, shape=shape.name, mesh=rec["mesh"], chips=chips,
        hlo_flops=flops_dev * chips,
        hlo_bytes=bytes_dev * chips,
        coll_bytes=float(sum(coll_dev.values())) * chips,
        coll_breakdown={k: v * chips for k, v in coll_dev.items()},
        model_flops=roof.model_flops(cfg, shape),
        per_device_peak_memory=rec["memory"]["argument_bytes"]
        + rec["memory"]["temp_bytes"] + rec["memory"]["output_bytes"],
    )
    rec["roofline"] = cell.to_json()
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def all_cells():
    for arch_id in configs.ARCH_IDS:
        spec = configs.get(arch_id)
        for shape in spec.shapes():
            yield arch_id, shape
        for shape in spec.skipped_shapes():
            yield arch_id, shape  # recorded as documented skips


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--skip-multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=RESULTS_PATH)
    args = ap.parse_args()

    results = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)

    def save():
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)

    n_fail = 0
    for arch_id, shape in all_cells():
        if args.arch and arch_id != args.arch:
            continue
        if args.shape and shape.name != args.shape:
            continue
        spec = configs.get(arch_id)
        skipped = shape.name == "long_500k" and not spec.supports_long_context

        meshes = [("single", False)] + ([] if args.skip_multi_pod else [("multi", True)])
        for mesh_name, mp in meshes:
            key = f"{arch_id}|{shape.name}|{mesh_name}"
            if key in results and results[key].get("status") in ("ok", "skipped"):
                continue
            if skipped:
                results[key] = {
                    "arch": arch_id, "shape": shape.name, "mesh": mesh_name,
                    "status": "skipped",
                    "reason": "pure full-attention arch; long_500k requires "
                              "sub-quadratic attention (DESIGN.md §4)",
                }
                save()
                continue
            print(f"=== {key} ===", flush=True)
            try:
                rec = run_cell(
                    arch_id, shape, multi_pod=mp,
                    roofline=(mesh_name == "single"),
                )
                rec["status"] = "ok"
                results[key] = rec
                extra = ""
                if "roofline" in rec:
                    r = rec["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" frac={r['roofline_fraction']:.3f}")
                print(f"    ok in {rec.get('total_s', rec['compile_s'])}s"
                      f" mem/dev={rec['memory']['temp_bytes']/2**30:.2f}GiB"
                      + extra, flush=True)
            except Exception as e:  # noqa: BLE001 — record and continue
                n_fail += 1
                results[key] = {
                    "arch": arch_id, "shape": shape.name, "mesh": mesh_name,
                    "status": "fail", "error": f"{type(e).__name__}: {e}",
                }
                print("    FAIL:", type(e).__name__, str(e)[:500], flush=True)
                traceback.print_exc()
            save()

    ok = sum(1 for r in results.values() if r.get("status") == "ok")
    sk = sum(1 for r in results.values() if r.get("status") == "skipped")
    fl = sum(1 for r in results.values() if r.get("status") == "fail")
    print(f"\nDONE ok={ok} skipped={sk} fail={fl}")
    return 0 if fl == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
