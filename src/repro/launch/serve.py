"""Serving driver: batched requests through the K-way paged engine.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --smoke \
        --requests 16 --policy lru [--tinylfu] [--jitted] [--decode-block 4]

Prints throughput, prefix-cache hit ratio and page-pool stats — the serving
analogue of the paper's §5.3 trace runs.  ``--jitted`` runs the
device-resident one-traced-program serving tick (DESIGN.md §11) instead of
the host loop; ``--decode-block`` sets the multi-step decode burst both
modes schedule.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.core.policies import Policy
from repro.models import lm
from repro.serve.engine import Engine, EngineConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--policy", default="lru",
                    choices=[p.name.lower() for p in Policy])
    ap.add_argument("--backend", default="jnp",
                    choices=["jnp", "pallas", "ref"],
                    help="prefix-cache backend (DESIGN.md §3): jnp vector "
                         "ops, the Pallas probe kernel, or the Python oracle")
    ap.add_argument("--tinylfu", action="store_true")
    ap.add_argument("--jitted", action="store_true",
                    help="device-resident serving tick: whole step is ONE "
                         "traced program, one host sync per tick "
                         "(DESIGN.md §11; requires a traceable backend)")
    ap.add_argument("--decode-block", type=int, default=1,
                    help="decode steps per engine tick (both modes run the "
                         "same burst schedule)")
    ap.add_argument("--shared-prefix", type=int, default=48,
                    help="tokens shared by all prompts (prefix-cache fodder)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    spec = configs.get(args.arch)
    cfg = spec.smoke
    if not (cfg.has_attention and cfg.enc_layers == 0 and not cfg.has_ssm):
        print(f"{args.arch}: paged engine targets decoder-only attention "
              "archs (DESIGN.md §4); serving via plain batched decode only.")
        return 0
    params = lm.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, EngineConfig(
        page=8, num_sets=32, ways=8, policy=Policy[args.policy.upper()],
        tinylfu=args.tinylfu, max_batch=8, max_seq=256, private_pages=256,
        backend=args.backend, jitted=args.jitted,
        decode_block=args.decode_block,
    ))
    rng = np.random.default_rng(args.seed)
    shared = rng.integers(2, cfg.vocab_size - 1, args.shared_prefix)
    t0 = time.time()
    for _ in range(args.requests):
        tail = rng.integers(2, cfg.vocab_size - 1, rng.integers(4, 16))
        eng.submit(np.concatenate([shared, tail]), max_new=args.max_new)
    fin = eng.run()
    dt = time.time() - t0
    total_new = sum(len(r.generated) for r in fin.values())
    print(f"served {len(fin)} requests, {total_new} tokens "
          f"in {dt:.1f}s ({total_new/dt:.1f} tok/s)")
    print(f"prefix-cache hit ratio: {eng.hit_ratio():.3f}  stats: {eng.stats}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
