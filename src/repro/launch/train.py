"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke \
        --steps 50 --ckpt-dir /tmp/run1 [--data data, --model model]

Fault-tolerance posture (scaled down to one host, same control flow as a
1000-node launcher):
  * auto-resume: on start, the newest committed checkpoint (atomic manifest
    rename, see ckpt/manager.py) is restored — params, optimizer moments AND
    the data-pipeline cursor, so the token stream continues exactly;
  * periodic + terminal checkpoints; SIGTERM (preemption) triggers an
    immediate checkpoint before exit;
  * step retry loop: a transient step failure (in production: a failed
    all-reduce after a chip drop) restores the last checkpoint and replays;
  * elastic restart: restore() reshards to whatever mesh the relaunch got
    (tested in tests/test_ckpt.py with a shrunken data axis).
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.ckpt import manager as ckpt
from repro.data.pipeline import DataConfig, DataState, SyntheticPipeline
from repro.dist import sharding as shd
from repro.launch.mesh import make_dev_mesh
from repro.models import lm
from repro.optim import adamw
from repro.train.step import TrainConfig, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", type=int, default=1, help="data mesh axis")
    ap.add_argument("--model", type=int, default=1, help="model mesh axis")
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "const"])
    args = ap.parse_args(argv)

    spec = configs.get(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    mesh = make_dev_mesh(args.data, args.model)
    ocfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                             warmup_steps=max(args.steps // 20, 5),
                             schedule=args.schedule)
    tcfg = TrainConfig(optimizer=ocfg)

    params = lm.init_params(cfg, jax.random.key(0))
    pshard = shd.param_shardings(cfg, params, mesh)
    params = jax.device_put(params, pshard)
    opt_state = adamw.init(params)
    opt_state = jax.device_put(opt_state, adamw.state_shardings(pshard, mesh))

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    pipe = SyntheticPipeline(dcfg)
    dstate = DataState()

    start_step = 0
    if args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            (params, opt_state), extra = ckpt.restore(
                args.ckpt_dir, last, (params, opt_state),
                shardings=(pshard, adamw.state_shardings(pshard, mesh)),
            )
            start_step = extra["step"]
            dstate = DataState(step=extra["data_step"])
            print(f"resumed from step {start_step}")

    step_fn = jax.jit(
        make_train_step(cfg, tcfg),
        in_shardings=(pshard, adamw.state_shardings(pshard, mesh), None),
        out_shardings=(pshard, adamw.state_shardings(pshard, mesh), None),
        donate_argnums=(0, 1),
    )

    def save(step):
        if args.ckpt_dir:
            ckpt.save(args.ckpt_dir, step, (params, opt_state),
                      extra={"step": step, "data_step": dstate.step})

    interrupted = {"flag": False}

    def on_sigterm(signum, frame):
        interrupted["flag"] = True

    signal.signal(signal.SIGTERM, on_sigterm)

    t0 = time.time()
    losses = []
    step = start_step
    while step < args.steps:
        toks, labels = pipe.batch(dstate)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        if cfg.frontend == "patch":
            batch["tokens"] = batch["tokens"][:, : args.seq - cfg.frontend_len]
            batch["prefix_embeds"] = jnp.zeros(
                (args.batch, cfg.frontend_len, cfg.d_model), jnp.bfloat16
            )
        if cfg.enc_layers:
            half = args.seq // 2
            batch["tokens"] = batch["tokens"][:, :half]
            batch["labels"] = batch["labels"][:, :half]
            batch["enc_embeds"] = jnp.zeros(
                (args.batch, args.seq - half, cfg.d_model), jnp.bfloat16
            )
        for attempt in range(3):  # step retry loop
            try:
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                break
            except Exception as e:  # noqa: BLE001
                print(f"step {step} attempt {attempt} failed: {e}")
                if attempt == 2:
                    save(step)
                    raise
        dstate = pipe.advance(dstate)
        step += 1
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % 10 == 0 or step == args.steps:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/max(step-start_step,1):.2f}s/step)")
        if args.ckpt_dir and step % args.ckpt_every == 0:
            save(step)
        if interrupted["flag"]:
            print("SIGTERM: checkpointing and exiting")
            save(step)
            return 0
    save(args.steps)
    print(f"final loss {np.mean(losses[-10:]):.4f} "
          f"(first 10: {np.mean(losses[:10]):.4f})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
