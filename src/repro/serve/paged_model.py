"""Model entry points for the paged serving engine.

Each op comes in two forms: an undecorated ``_*_impl`` (inlinable inside a
larger traced program — the jitted serving tick of serve/engine.py calls
these directly so the whole tick stays ONE XLA computation) and a jitted
wrapper with buffer donation for the host-loop engine:

  * ``prefill_with_kv``  — forward over prompt tokens returning last-token
    logits AND the per-layer K/V [L, B, S, KVH, D] (to be scattered into
    the page pool at the slots the K-way cache assigned);
  * ``prefill_padded``   — the fixed-width form: tokens are padded to a
    static width and the logits are gathered at ``length - 1`` (causal
    attention makes real-token outputs independent of the padding);
  * ``decode_paged``     — one decode token per sequence, attending through
    the page table with the Pallas paged_attention kernel (ops.attend_paged)
    and writing the new token's K/V into the current private page slot;
  * ``write_pages``      — scatter whole-page prefill KV into the pool.

The page pool layout is [L, KVH, P, page, D] (head-major per layer, matching
kernels/paged_attention.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models import lm


def _prefill_impl(cfg: ModelConfig, params, tokens, length=None):
    """Forward over (possibly padded) prompt tokens.

    tokens int32 [B, S]; ``length`` int32 [B] (None: the full width S).
    Returns (logits [B, Vp] at position length-1, k, v [L, B, S, KVH, D]).
    """
    x = params["embed"][tokens] * jnp.asarray(cfg.scale_emb, jnp.bfloat16)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None]
    windows = lm.layer_windows(cfg)

    def body(carry, xs):
        p, w = xs
        h = L.rms_norm(carry, p["ln1"], cfg.norm_eps)
        k = (h @ p["attn"]["wk"]).reshape(b, s, cfg.num_kv_heads, cfg.hd)
        v = (h @ p["attn"]["wv"]).reshape(b, s, cfg.num_kv_heads, cfg.hd)
        k = L.rope(k, positions, cfg.rope_theta)
        x2 = lm._block_seq(cfg, p, carry, positions, w, None, None)
        return x2, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], windows))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if length is None:
        xl = x[:, -1]
    else:
        last = jnp.clip(jnp.asarray(length, jnp.int32) - 1, 0, s - 1)
        xl = x[jnp.arange(b), last]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (xl @ head.astype(x.dtype)).astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits, ks, vs


@partial(jax.jit, static_argnums=0)
def prefill_with_kv(cfg: ModelConfig, params, tokens):
    """Run the prompt; return (logits_last [B, Vp], k, v [L,B,S,KVH,D])."""
    return _prefill_impl(cfg, params, tokens)


@partial(jax.jit, static_argnums=0)
def prefill_padded(cfg: ModelConfig, params, tokens, length):
    """Fixed-width prefill: logits are read at ``length - 1`` per lane, so
    one compiled program serves every prompt length up to the pad width."""
    return _prefill_impl(cfg, params, tokens, length)


def _write_pages_impl(cfg: ModelConfig, kv, slots, pool_k, pool_v, valid):
    """Scatter prefill KV into pool pages.

    kv: (k, v) [L, B, S, KVH, D];  slots: [B, nblocks] page ids (-1 = skip);
    pool: [L, KVH, P, page, D].  Writes whole pages (S must be a multiple of
    the page size).  Skipped lanes route their scatter out of bounds —
    ``mode="drop"`` makes them true no-ops (parking them on page 0 would let
    a stale masked write race a genuine write to page 0).
    """
    k, v = kv
    lnum, b, s, kvh, d = k.shape
    page = pool_k.shape[3]
    total = pool_k.shape[2]
    nb = s // page
    kp = k.reshape(lnum, b, nb, page, kvh, d)
    vp = v.reshape(lnum, b, nb, page, kvh, d)
    kp = jnp.moveaxis(kp.reshape(lnum, b * nb, page, kvh, d), 3, 1)
    vp = jnp.moveaxis(vp.reshape(lnum, b * nb, page, kvh, d), 3, 1)
    flat_slots = slots.reshape(-1)
    ok = (flat_slots >= 0) & valid.reshape(-1)
    safe = jnp.where(ok, flat_slots, total)
    pool_k = pool_k.at[:, :, safe].set(kp, mode="drop")
    pool_v = pool_v.at[:, :, safe].set(vp, mode="drop")
    return pool_k, pool_v


@partial(jax.jit, static_argnums=0, donate_argnums=(3, 4))
def write_pages(cfg: ModelConfig, kv, slots, pool_k, pool_v, valid):
    return _write_pages_impl(cfg, kv, slots, pool_k, pool_v, valid)


def _decode_paged_impl(
    cfg: ModelConfig,
    params,
    token,        # [B] int32
    pos,          # [B] int32 current position (== tokens so far)
    pool_k,       # [L, KVH, P, page, D]
    pool_v,
    page_table,   # [B, PPS] int32
    active,       # [B] bool
):
    """One paged decode step.  Returns (logits [B, Vp], pool_k, pool_v)."""
    x = params["embed"][token][:, None, :] * jnp.asarray(
        cfg.scale_emb, jnp.bfloat16
    )
    b = token.shape[0]
    page = pool_k.shape[3]
    windows = lm.layer_windows(cfg)
    seq_with_new = jnp.where(active, pos + 1, 0)

    # Inactive slots (active=False) must not write: their pos=0 would land
    # in page_table[.,0] slot 0 and corrupt a live request's first token.
    # Route them out of bounds — .set(mode="drop") discards OOB writes.
    total_pages = pool_k.shape[2]
    cur_page = jnp.where(
        active, page_table[jnp.arange(b), pos // page], total_pages
    )                                                    # [B]
    cur_off = pos % page

    def body(carry, xs):
        x = carry
        p, w, pk, pv = xs["p"], xs["w"], xs["pk"], xs["pv"]
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        k_new, v_new = L.project_kv_step(
            p["attn"], h, pos, num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
            rope_theta=cfg.rope_theta,
        )
        # write the new token into its private page slot
        pk = pk.at[:, cur_page, cur_off].set(
            jnp.moveaxis(k_new[:, 0], 1, 0), mode="drop"
        )
        pv = pv.at[:, cur_page, cur_off].set(
            jnp.moveaxis(v_new[:, 0], 1, 0), mode="drop"
        )
        q = (h @ p["attn"]["wq"]).reshape(b, 1, cfg.num_heads, cfg.hd)
        q = L.rope(q, pos[:, None], cfg.rope_theta)[:, 0]
        o = kops.attend_paged(
            q, pk, pv, xs["pt"], seq_with_new,
            softcap=cfg.attn_softcap,
        )
        o = o.reshape(b, 1, cfg.num_heads * cfg.hd)
        x = x + o @ p["attn"]["wo"]
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            x = x + L.moe(p["moe"], h2, num_experts=cfg.num_experts,
                          top_k=cfg.top_k, ff_shards=cfg.moe_ff_shards)
        else:
            x = x + L.mlp(p["mlp"], h2)
        return x, {"pk": pk, "pv": pv}

    xs = {
        "p": params["blocks"],
        "w": windows,
        "pk": pool_k,
        "pv": pool_v,
        "pt": jnp.broadcast_to(page_table, (cfg.num_layers,) + page_table.shape),
    }
    x, pools = jax.lax.scan(body, x, xs)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x[:, 0] @ head.astype(x.dtype)).astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits, pools["pk"], pools["pv"]


@partial(jax.jit, static_argnums=0, donate_argnums=(4, 5))
def decode_paged(cfg: ModelConfig, params, token, pos, pool_k, pool_v,
                 page_table, active):
    return _decode_paged_impl(cfg, params, token, pos, pool_k, pool_v,
                              page_table, active)
