"""Continuous-batching serving engine with a K-way set-associative prefix
cache — the paper's technique as the page-residency manager of a paged KV
cache.

Design (DESIGN.md §2, §11): the page pool is split into
  * a **shared region** of exactly ``num_sets × ways`` pages, owned 1:1 by
    the K-way cache slots: cache value == page id.  A full prompt block
    (page_size tokens) keyed by its *prefix-chain hash* lives at most once;
    eviction policy (LRU/LFU/Hyperbolic + optional TinyLFU admission)
    decides residency, and evicting a key automatically frees its page —
    the paper's "dense, static memory, no pointers" argument applied to KV
    paging;
  * a **private region** for decode-time pages (partial blocks are not
    content-addressable until full), tracked by a per-page owner lane.

Two execution modes share one set of semantics (DESIGN.md §11):

  * ``jitted=False`` — the host loop: python bookkeeping per request, one
    jitted call per model op.  The differential oracle.
  * ``jitted=True``  — the device-resident engine: one serving tick (admit
    waiting requests into retired lanes → vectorized prefix-cache probe →
    page allocation through the slot-returning cache access → batched paged
    decode → sampling → retirement) is ONE traced program over a fixed
    ``[max_slots]`` request-slot array (``ServeState``), stepped by a jitted
    ``serve_step(params, state, batch) -> (state', emitted)`` with the state
    donated.  The host shell only manages queues and token I/O; the single
    ``device_get(emitted)`` is the one host round-trip per tick.

Both modes drive the SAME fixed-width prefix-chain transaction — TinyLFU
record → peek_victims → admit, then the slot-returning cache access over
``max_prompt // page`` padded block lanes — so their emitted tokens, hit
ratios and eviction counts are identical (pinned by tests and by
``benchmarks/serving.py --serving-compare``).  The prefix cache runs on any
CacheBackend (DESIGN.md §3) via ``EngineConfig.backend``; the jitted tick
requires a traceable backend ("jnp" or "pallas") and an unsharded cache.

``trace_counts()`` exposes per-shape compile counters for the jitted tick —
the compile-economy contract (≤1 trace per engine shape) is a test.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import Counter
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import admission, hashing
from repro.core.backend import make_backend
from repro.core.hashing import (  # noqa: F401  (re-export: engine API)
    prefix_block_hashes,
    prefix_block_hashes_jnp,
)
from repro.core.kway import KWayConfig
from repro.core.policies import Policy
from repro.serve import paged_model as pm

#: Compile counter for the jitted serving tick, keyed by engine shape —
#: bumped inside the traced body, so a retrace (shape leak, cache miss)
#: shows up as a count > 1.  Same pattern as eval/runner.py.
_TRACE_COUNTS: Counter = Counter()


def trace_counts() -> dict:
    """Snapshot of jitted-tick trace counts per engine shape."""
    return dict(_TRACE_COUNTS)


def reset_trace_counts() -> None:
    _TRACE_COUNTS.clear()


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    slot: int = -1                    # batch slot when running
    pos: int = 0                      # tokens materialized so far
    pages: list = dataclasses.field(default_factory=list)   # page ids in order
    private: list = dataclasses.field(default_factory=list)  # owned free-pool pages
    done: bool = False
    prefix_hits: int = 0
    prefix_lookups: int = 0


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    page: int = 16
    num_sets: int = 64                # shared region = num_sets × ways pages
    ways: int = 8
    policy: Policy = Policy.LRU
    tinylfu: bool = False
    max_batch: int = 8                # request slots (the jitted tick's lane count)
    max_seq: int = 512
    private_pages: int = 256
    backend: str = "jnp"              # cache backend: "jnp" | "pallas" | "ref"
    # > 1: run the prefix cache set-sharded (core/sharded.py) — the shared
    # region's set axis splits across shards with device-resident routing;
    # slot ids stay global, so page bookkeeping is unchanged.  The ref
    # backend cannot be sharded (host Python).
    shards: int = 1
    # True: run the whole serving tick as ONE traced program (ServeState +
    # serve_step) — one dispatch and one host sync per tick.  Requires a
    # traceable backend ("jnp"/"pallas") and shards == 1; the host loop
    # (jitted=False) is the differential oracle.
    jitted: bool = False
    # Static prompt-width ceiling for the fixed-width prefix transaction and
    # the padded prefill (0: max_seq).  Must be a multiple of ``page``;
    # prompts longer than this are rejected at submit().
    max_prompt: int = 0
    # 0: greedy decode (argmax).  > 0: softmax sampling at this temperature,
    # seeded from (sample_seed, decode_step) identically in both modes.  The
    # prefill's first token is always argmax.
    temperature: float = 0.0
    sample_seed: int = 0
    # Decode steps per engine tick (multi-step scheduling).  The jitted
    # engine runs the whole burst inside ONE traced tick (one dispatch, one
    # host sync per ``decode_block`` tokens); the host loop runs the same
    # admit-then-N-decodes schedule so it stays an exact oracle — page
    # allocation order, and thus out-of-page retirement, depends on the
    # schedule, so both modes must share it.
    decode_block: int = 1
    # > 0: watchdog over the tick's one host↔device sync — each expired
    # wait of ``sync_timeout_s`` (growing by ``sync_backoff``) records a
    # degradation event; after ``sync_retries`` extra waits the tick raises
    # WatchdogTimeout instead of hanging.  0 disables (plain device_get).
    sync_timeout_s: float = 0.0
    sync_retries: int = 2
    sync_backoff: float = 2.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ServeState:
    """Device-resident serving state — the jitted tick's donated carry.

    Slot lanes are indexed by the fixed ``[max_batch]`` request-slot array;
    ``owner`` maps each private page to its owning slot (-1 = free); the
    prefix cache (``kstate``), TinyLFU sketch and the stat counters ride in
    the same pytree so one donated step updates everything in place.
    """

    kstate: object        # KWayState
    sketch: object        # TinyLFUState | int32[] placeholder
    pool_k: jnp.ndarray   # bf16 [L, KVH, P, page, D]
    pool_v: jnp.ndarray
    owner: jnp.ndarray    # int32 [private_pages] owning slot | -1
    active: jnp.ndarray   # bool  [S]
    rid: jnp.ndarray      # int32 [S]
    pos: jnp.ndarray      # int32 [S] tokens materialized
    n_gen: jnp.ndarray    # int32 [S] tokens emitted (prefill token included)
    max_new: jnp.ndarray  # int32 [S]
    last_tok: jnp.ndarray  # int32 [S]
    n_pages: jnp.ndarray  # int32 [S]
    page_tbl: jnp.ndarray  # int32 [S, PPS]
    prefix_hits: jnp.ndarray     # int32 [] device stat counters
    prefix_lookups: jnp.ndarray  # int32 []
    evictions: jnp.ndarray       # int32 []
    prefills: jnp.ndarray        # int32 []
    decode_steps: jnp.ndarray    # int32 []


def _sample_next(ecfg: EngineConfig, logits: jnp.ndarray,
                 decode_step) -> jnp.ndarray:
    """Next-token choice shared by both modes: greedy argmax, or seeded
    categorical sampling keyed on the decode-step counter (identical key
    sequence ⇒ identical tokens in host-loop and jitted engines)."""
    if ecfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    key = jax.random.fold_in(jax.random.PRNGKey(ecfg.sample_seed),
                             jnp.asarray(decode_step, jnp.int32))
    return jax.random.categorical(
        key, logits / jnp.float32(ecfg.temperature), axis=-1
    ).astype(jnp.int32)


# ---------------------------------------------------------------------------
# the jitted serving tick
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _serve_step_fn(cfg: ModelConfig, ecfg: EngineConfig):
    """Build (once per engine shape) the jitted one-tick program.

    The lru_cache makes the compile economy structural: two engines with the
    same (model, engine) configs share one traced program, and
    ``trace_counts()`` proves it.
    """
    kcfg = KWayConfig(num_sets=ecfg.num_sets, ways=ecfg.ways,
                      policy=ecfg.policy)
    backend = make_backend(ecfg.backend, kcfg)
    sketch_cfg = admission.for_capacity(kcfg.capacity) if ecfg.tinylfu else None
    page = ecfg.page
    n_slots = ecfg.max_batch
    max_prompt = ecfg.max_prompt or ecfg.max_seq
    pbw = max_prompt // page          # prefix-transaction block lanes
    pps = ecfg.max_seq // page        # page-table row width
    shared = kcfg.capacity
    n_priv = ecfg.private_pages
    total_pages = shared + n_priv
    # one counter key per (model, engine) config — exactly the lru_cache key,
    # so "shares a traced program" and "shares a counter" coincide
    tkey = ("serve_step", cfg.name, ecfg)
    tile = min(8, n_slots)            # prefill tile width (phase 3)

    def step(params, st: ServeState, batch):
        _TRACE_COUNTS[tkey] += 1
        # ---- phase 1: admission transactions -----------------------------
        # Waiting lane j -> j-th free slot, in order; a refused lane blocks
        # the rest (the host loop's break-on-refusal back-off; refusal is
        # checked AFTER the cache mutation, also like the host).  The scan
        # carries ONLY the cache lanes + the page-owner vector: the multi-MB
        # KV pools never enter the per-lane cond branches, and the model
        # compute is hoisted into phase 3's tiled batched prefill.
        order = jnp.argsort(st.active, stable=True).astype(jnp.int32)
        n_free = jnp.sum(~st.active).astype(jnp.int32)

        def admit_lane(carry, xs):
            kstate, sketch, owner, blocked = carry
            j, toks, length, avail = xs
            slot = order[j]
            do = avail & (j < n_free) & ~blocked

            def run(args):
                kstate, sketch, owner = args
                # fixed-width prefix-chain transaction (same phase order as
                # the host loop and CacheBackend.replay)
                hashes = hashing.prefix_block_hashes_jnp(toks, page)
                n_full = (length // page).astype(jnp.int32)
                validb = jnp.arange(pbw, dtype=jnp.int32) < n_full
                admit_mask = None
                if sketch_cfg is not None:
                    sketch = admission.record(sketch_cfg, sketch, hashes,
                                              enabled=validb)
                    vk, vv = backend.peek_victims(kstate, hashes)
                    admit_mask = admission.admit(sketch_cfg, sketch, hashes,
                                                 vk, vv)
                # ONE fused slot-returning access answers "which page holds
                # this block, allocating if absent" for the whole chain
                kstate, hit, pages_blk, _, ev = backend.access(
                    kstate, hashes, jnp.zeros(pbw, jnp.int32),
                    admit_on_miss=admit_mask, enabled=validb,
                    slot_value=True)
                n_hit = jnp.sum(jnp.cumprod(hit.astype(jnp.int32)))
                tail = length - n_full * page
                unlanded = validb & (pages_blk < 0)
                need = (jnp.sum(unlanded.astype(jnp.int32))
                        + (tail > 0).astype(jnp.int32))
                free_cnt = jnp.sum((owner < 0).astype(jnp.int32))
                ok = free_cnt >= need + 2
                # private pages for unlanded blocks + tail, lowest free
                # indices first (page identity is engine-local; only counts
                # are part of the differential contract).  The owner
                # scatters are masked by ``ok``: a refused lane allocates
                # nothing.
                free_order = jnp.argsort(owner >= 0,
                                         stable=True).astype(jnp.int32)
                rank = jnp.cumsum(unlanded.astype(jnp.int32)) - 1
                blk_idx = free_order[jnp.clip(rank, 0, n_priv - 1)]
                pages2 = jnp.where(unlanded, shared + blk_idx, pages_blk)
                n_unl = jnp.sum(unlanded.astype(jnp.int32))
                tail_idx = free_order[jnp.clip(n_unl, 0, n_priv - 1)]
                owner = owner.at[
                    jnp.where(ok & unlanded, blk_idx, n_priv)
                ].set(slot, mode="drop")
                owner = owner.at[
                    jnp.where(ok & (tail > 0), tail_idx, n_priv)
                ].set(slot, mode="drop")
                return ((kstate, sketch, owner),
                        (jnp.bool_(True), ok, n_hit, n_full, tail,
                         jnp.sum(ev.astype(jnp.int32)), pages2,
                         shared + tail_idx))

            def skip(args):
                return (args, (jnp.bool_(False), jnp.bool_(False),
                               jnp.int32(0), jnp.int32(0), jnp.int32(0),
                               jnp.int32(0), jnp.zeros(pbw, jnp.int32),
                               jnp.int32(0)))

            (kstate, sketch, owner), ys = jax.lax.cond(
                do, run, skip, (kstate, sketch, owner))
            blocked = blocked | (do & ~ys[1])
            return (kstate, sketch, owner, blocked), ys

        lanes = (jnp.arange(n_slots, dtype=jnp.int32), batch["tokens"],
                 batch["length"], batch["avail"])
        (kstate, sketch, owner, _), ys = jax.lax.scan(
            admit_lane,
            (st.kstate, st.sketch, st.owner, jnp.bool_(False)), lanes)
        (attempted, admitted, pre_hits, pre_lookups, tail, ev_cnt,
         pages2, tail_page) = ys
        st = dataclasses.replace(
            st, kstate=kstate, sketch=sketch, owner=owner,
            prefix_lookups=st.prefix_lookups + jnp.sum(pre_lookups),
            prefix_hits=st.prefix_hits + jnp.sum(pre_hits),
            evictions=st.evictions + jnp.sum(ev_cnt),
            prefills=st.prefills + jnp.sum(admitted.astype(jnp.int32)))

        # ---- phase 2: lane activation (one vectorized scatter per field) --
        safe_slot = jnp.where(admitted, order, n_slots)
        validb_all = (jnp.arange(pbw, dtype=jnp.int32)[None, :]
                      < pre_lookups[:, None])
        rows = jnp.zeros((n_slots, pps), jnp.int32).at[:, :pbw].set(
            jnp.where(validb_all, pages2, 0))
        rows = rows.at[
            jnp.where(admitted & (tail > 0),
                      jnp.arange(n_slots, dtype=jnp.int32), n_slots),
            jnp.clip(pre_lookups, 0, pps - 1)
        ].set(tail_page, mode="drop")
        st = dataclasses.replace(
            st,
            active=st.active.at[safe_slot].set(True, mode="drop"),
            rid=st.rid.at[safe_slot].set(batch["rid"], mode="drop"),
            pos=st.pos.at[safe_slot].set(batch["length"], mode="drop"),
            n_gen=st.n_gen.at[safe_slot].set(1, mode="drop"),
            max_new=st.max_new.at[safe_slot].set(batch["max_new"],
                                                 mode="drop"),
            n_pages=st.n_pages.at[safe_slot].set(
                pre_lookups + (tail > 0).astype(jnp.int32), mode="drop"),
            page_tbl=st.page_tbl.at[safe_slot].set(rows, mode="drop"))

        # ---- phase 3: tiled batched prefill + page writes ----------------
        # Batched prefill rows are bitwise-identical to per-lane prefill
        # (row-diagonal attention mask, per-row logit gather), so hoisting
        # the model call out of the admission scan is invisible to the host
        # oracle.  Tiles whose lanes admitted nothing skip entirely, so the
        # steady-state decode-only tick pays no prefill FLOPs.
        pool_k, pool_v = st.pool_k, st.pool_v
        tok0 = jnp.zeros(n_slots, jnp.int32)
        arange_pg = jnp.arange(page, dtype=jnp.int32)
        for lo in range(0, n_slots, tile):
            sel = slice(lo, min(lo + tile, n_slots))
            adm_t = admitted[sel]

            def run_tile(pools, sel=sel, adm_t=adm_t):
                pool_k, pool_v = pools
                logits, ks, vs = pm._prefill_impl(
                    cfg, params, batch["tokens"][sel], batch["length"][sel])
                # write KV for blocks from each lane's first chain miss on
                wmask = (validb_all[sel]
                         & (jnp.arange(pbw, dtype=jnp.int32)[None, :]
                            >= pre_hits[sel, None])
                         & adm_t[:, None])
                pool_k, pool_v = pm._write_pages_impl(
                    cfg, (ks, vs), pages2[sel], pool_k, pool_v, wmask)
                # tail tokens -> one private page per lane (zero-padded)
                idx = jnp.minimum(pre_lookups[sel, None] * page
                                  + arange_pg[None, :], max_prompt - 1)
                kt = jnp.take_along_axis(ks, idx[None, :, :, None, None],
                                         axis=2)
                vt = jnp.take_along_axis(vs, idx[None, :, :, None, None],
                                         axis=2)
                tmask = (arange_pg[None, :]
                         < tail[sel, None])[None, :, :, None, None]
                kt = jnp.where(tmask, kt, 0)
                vt = jnp.where(tmask, vt, 0)
                tgt = jnp.where(adm_t & (tail[sel] > 0), tail_page[sel],
                                total_pages)
                pool_k = pool_k.at[:, :, tgt].set(
                    jnp.moveaxis(kt, 3, 1), mode="drop")
                pool_v = pool_v.at[:, :, tgt].set(
                    jnp.moveaxis(vt, 3, 1), mode="drop")
                return (pool_k, pool_v,
                        jnp.argmax(logits, axis=-1).astype(jnp.int32))

            def skip_tile(pools, n=sel.stop - sel.start):
                return (*pools, jnp.zeros(n, jnp.int32))

            pool_k, pool_v, tk = jax.lax.cond(
                jnp.any(adm_t), run_tile, skip_tile, (pool_k, pool_v))
            tok0 = tok0.at[sel].set(tk)
        st = dataclasses.replace(
            st, pool_k=pool_k, pool_v=pool_v,
            last_tok=st.last_tok.at[safe_slot].set(tok0, mode="drop"))

        # ---- phase 4: decode burst (decode_block steps, one dispatch) ----
        def decode_once(st):
            # sequential page allocation: an out-of-page retire frees its
            # private pages for later slots in the SAME step, exactly like
            # the host loop
            def alloc_lane(carry, i):
                owner, page_tbl, n_pages, active = carry
                a = active[i]
                needs = a & (st.pos[i] % page == 0) & \
                    (st.pos[i] // page >= n_pages[i])
                free_cnt = jnp.sum((owner < 0).astype(jnp.int32))
                can = needs & (free_cnt > 0)
                fidx = jnp.argmin(owner >= 0).astype(jnp.int32)  # first free
                owner = owner.at[jnp.where(can, fidx, n_priv)].set(
                    i, mode="drop")
                page_tbl = page_tbl.at[
                    jnp.where(can, i, n_slots), st.pos[i] // page
                ].set(shared + fidx, mode="drop")
                n_pages = n_pages.at[jnp.where(can, i, n_slots)].add(
                    1, mode="drop")
                er = needs & ~can              # out of pages: retire early
                owner = jnp.where(er & (owner == i), -1, owner)
                active = active.at[i].set(a & ~er)
                return (owner, page_tbl, n_pages, active), er

            (owner, page_tbl, n_pages, active2), early_ret = jax.lax.scan(
                alloc_lane,
                (st.owner, st.page_tbl, st.n_pages, st.active),
                jnp.arange(n_slots, dtype=jnp.int32))

            # batched paged decode + sampling
            tok = jnp.where(active2, st.last_tok, 0)
            posv = jnp.where(active2, st.pos, 0)

            def dec(pools):
                pool_k, pool_v = pools
                logits, pk, pv = pm._decode_paged_impl(
                    cfg, params, tok, posv, pool_k, pool_v, page_tbl,
                    active2)
                nxt = _sample_next(ecfg, logits, st.decode_steps)
                return pk, pv, nxt, jnp.int32(1)

            def nodec(pools):
                pool_k, pool_v = pools
                return (pool_k, pool_v, jnp.zeros(n_slots, jnp.int32),
                        jnp.int32(0))

            pool_k, pool_v, nxt, did = jax.lax.cond(
                jnp.any(active2), dec, nodec, (st.pool_k, st.pool_v))

            pos2 = jnp.where(active2, st.pos + 1, st.pos)
            n_gen2 = jnp.where(active2, st.n_gen + 1, st.n_gen)
            last2 = jnp.where(active2, nxt, st.last_tok)

            # retirement
            fin = active2 & ((n_gen2 >= st.max_new + 1) |
                             (pos2 >= ecfg.max_seq - 1))
            owner = jnp.where(
                (owner >= 0) & fin[jnp.clip(owner, 0, n_slots - 1)], -1,
                owner)
            st = dataclasses.replace(
                st, pool_k=pool_k, pool_v=pool_v, owner=owner,
                page_tbl=page_tbl, n_pages=n_pages, active=active2 & ~fin,
                pos=pos2, n_gen=n_gen2, last_tok=last2,
                decode_steps=st.decode_steps + did)
            return st, (active2, jnp.where(active2, nxt, 0),
                        early_ret | fin)

        st, (dec_mask, dec_tok, retired) = jax.lax.scan(
            lambda st, _: decode_once(st), st, None,
            length=ecfg.decode_block)

        emitted = {
            "admitted": admitted,            # [S] per waiting lane
            "pre_tok": tok0,                 # [S] prefill token per lane
            "pre_hits": pre_hits,            # [S] prefix-chain hits
            "pre_lookups": pre_lookups,      # [S] prefix-chain lookups
            "rid": st.rid,                   # [S] slot-resident request ids
            "dec_mask": dec_mask,            # [N, S] decoded at burst step n
            "dec_tok": dec_tok,              # [N, S]
            "retired": retired,              # [N, S] left its slot at step n
            "n_active": jnp.sum(st.active.astype(jnp.int32)),
        }
        return st, emitted

    return jax.jit(step, donate_argnums=(1,))


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        # typed input validation, not asserts: these guard user-supplied
        # configs and must survive ``python -O``
        if not (cfg.has_attention and cfg.enc_layers == 0):
            raise ValueError(
                "paged engine serves decoder-only attention archs; "
                f"got has_attention={cfg.has_attention}, "
                f"enc_layers={cfg.enc_layers} — attention-free archs bypass "
                "it (DESIGN.md §4)")
        if ecfg.max_seq % ecfg.page != 0:
            raise ValueError(
                f"EngineConfig.max_seq ({ecfg.max_seq}) must be a multiple "
                f"of page ({ecfg.page})")
        if ecfg.decode_block < 1:
            raise ValueError(
                f"EngineConfig.decode_block must be >= 1, "
                f"got {ecfg.decode_block}")
        self.cfg, self.params, self.ecfg = cfg, params, ecfg
        self.max_prompt = ecfg.max_prompt or ecfg.max_seq
        if self.max_prompt % ecfg.page != 0 or \
                self.max_prompt > ecfg.max_seq:
            raise ValueError(
                f"EngineConfig.max_prompt ({self.max_prompt}) must be a "
                f"multiple of page ({ecfg.page}) and <= max_seq "
                f"({ecfg.max_seq})")
        from repro.robust import events as _rev
        self._events = _rev
        self._events_start = _rev.cursor()
        self.kcfg = KWayConfig(
            num_sets=ecfg.num_sets, ways=ecfg.ways, policy=ecfg.policy
        )
        if ecfg.shards > 1:
            # Opt-in sharded prefix cache: ShardedCache implements the same
            # get/put/peek_victims contract with global slot ids.
            from repro.core.sharded import ShardedCache, ShardedConfig
            self.backend = ShardedCache(ShardedConfig(
                cache=self.kcfg, num_shards=ecfg.shards,
                backend=ecfg.backend))
        else:
            self.backend = make_backend(ecfg.backend, self.kcfg)
        self.kstate = self.backend.init()
        self.sketch_cfg = (
            admission.for_capacity(self.kcfg.capacity) if ecfg.tinylfu else None
        )
        self.sketch = (
            admission.make_sketch(self.sketch_cfg) if ecfg.tinylfu else None
        )
        shared = self.kcfg.capacity
        total = shared + ecfg.private_pages
        self._shared = shared
        shape = (cfg.num_layers, cfg.num_kv_heads, total, ecfg.page, cfg.hd)
        self.pps = ecfg.max_seq // ecfg.page
        self.pbw = self.max_prompt // ecfg.page
        self.waiting: list[Request] = []
        self.finished: dict[int, Request] = {}
        self._next_rid = 0
        self._stats = {"prefix_hits": 0, "prefix_lookups": 0, "prefills": 0,
                       "decode_steps": 0}
        self._ev_dev = jnp.zeros((), jnp.int32)  # device eviction tally
        if ecfg.jitted:
            if ecfg.shards > 1:
                raise ValueError(
                    "jitted engine requires an unsharded prefix cache "
                    "(shards == 1); the sharded path is host-loop only")
            if not getattr(self.backend, "traceable", False):
                raise ValueError(
                    f"jitted engine requires a traceable cache backend; "
                    f"{ecfg.backend!r} is host Python — use the host loop "
                    "(jitted=False) for the ref oracle")
            self.running: dict[int, Request] = {}
            self._sstate = ServeState(
                kstate=self.kstate,
                sketch=(self.sketch if self.sketch is not None
                        else jnp.zeros((), jnp.int32)),
                pool_k=jnp.zeros(shape, jnp.bfloat16),
                pool_v=jnp.zeros(shape, jnp.bfloat16),
                owner=jnp.full((ecfg.private_pages,), -1, jnp.int32),
                active=jnp.zeros(ecfg.max_batch, bool),
                rid=jnp.zeros(ecfg.max_batch, jnp.int32),
                pos=jnp.zeros(ecfg.max_batch, jnp.int32),
                n_gen=jnp.zeros(ecfg.max_batch, jnp.int32),
                max_new=jnp.zeros(ecfg.max_batch, jnp.int32),
                last_tok=jnp.zeros(ecfg.max_batch, jnp.int32),
                n_pages=jnp.zeros(ecfg.max_batch, jnp.int32),
                page_tbl=jnp.zeros((ecfg.max_batch, self.pps), jnp.int32),
                prefix_hits=jnp.zeros((), jnp.int32),
                prefix_lookups=jnp.zeros((), jnp.int32),
                evictions=jnp.zeros((), jnp.int32),
                prefills=jnp.zeros((), jnp.int32),
                decode_steps=jnp.zeros((), jnp.int32),
            )
            self._step_fn = _serve_step_fn(cfg, ecfg)
            s = ecfg.max_batch
            self._zero_batch = {
                "tokens": jnp.zeros((s, self.max_prompt), jnp.int32),
                "length": jnp.zeros(s, jnp.int32),
                "max_new": jnp.zeros(s, jnp.int32),
                "rid": jnp.zeros(s, jnp.int32),
                "avail": jnp.zeros(s, bool),
            }
        else:
            self.pool_k = jnp.zeros(shape, jnp.bfloat16)
            self.pool_v = jnp.zeros(shape, jnp.bfloat16)
            self.free = list(range(shared, total))
            self.slots: list[Optional[Request]] = [None] * ecfg.max_batch

    # ------------------------------------------------------------------ API
    def submit(self, prompt, max_new: int = 16) -> int:
        prompt = np.asarray(prompt, np.int32)
        if not 1 <= len(prompt) <= self.max_prompt:
            raise ValueError(
                f"prompt length {len(prompt)} outside [1, {self.max_prompt}]"
                " — raise EngineConfig.max_prompt (a page multiple "
                "<= max_seq) or truncate the prompt")
        rid = self._next_rid
        self._next_rid += 1
        self.waiting.append(Request(rid, prompt, max_new))
        return rid

    def step(self, greedy: bool = True):
        """One engine iteration: admit + prefill waiting, decode running."""
        if self.ecfg.jitted:
            self._step_jitted()
        else:
            self._admit()
            for _ in range(self.ecfg.decode_block):
                self._decode()

    def run(self, greedy: bool = True, max_steps: int = 10_000):
        steps = 0
        while (self.waiting or self._any_running()) and steps < max_steps:
            self.step(greedy)
            steps += 1
        return self.finished

    @property
    def stats(self) -> dict:
        """Engine counters, synced from the device in one pull.

        The host loop accumulates evictions as a device scalar (no per-call
        host round trip — the old ``int(ev.sum())`` pull per insert burned a
        sync per prefill); the jitted engine keeps every counter in
        ``ServeState``.
        """
        if self.ecfg.jitted:
            s = self._sstate
            ph, pl, ev, pf, ds = jax.device_get(
                (s.prefix_hits, s.prefix_lookups, s.evictions, s.prefills,
                 s.decode_steps))
            return {"prefix_hits": int(ph), "prefix_lookups": int(pl),
                    "prefills": int(pf), "decode_steps": int(ds),
                    "evictions": int(ev),
                    "degradation_events":
                        self._events.count(start=self._events_start)}
        d = dict(self._stats)
        d["evictions"] = int(jax.device_get(self._ev_dev))
        d["degradation_events"] = self._events.count(
            start=self._events_start)
        return d

    def hit_ratio(self) -> float:
        st = self.stats
        if st["prefix_lookups"] == 0:
            return 0.0
        return st["prefix_hits"] / st["prefix_lookups"]

    def _any_running(self) -> bool:
        if self.ecfg.jitted:
            return bool(self.running)
        return any(self.slots)

    # ----------------------------------------------------- jitted tick shell
    def _step_jitted(self):
        """One device tick + ONE host round-trip to drain emitted tokens."""
        s = self.ecfg.max_batch
        nwait = min(len(self.waiting), s)
        if nwait:
            toks = np.zeros((s, self.max_prompt), np.int32)
            length = np.zeros(s, np.int32)
            mx = np.zeros(s, np.int32)
            rid = np.zeros(s, np.int32)
            avail = np.zeros(s, bool)
            for j in range(nwait):
                r = self.waiting[j]
                toks[j, : len(r.prompt)] = r.prompt
                length[j] = len(r.prompt)
                mx[j] = r.max_new
                rid[j] = r.rid
                avail[j] = True
            batch = {"tokens": jnp.asarray(toks),
                     "length": jnp.asarray(length),
                     "max_new": jnp.asarray(mx),
                     "rid": jnp.asarray(rid),
                     "avail": jnp.asarray(avail)}
        else:
            batch = self._zero_batch
        self._sstate, emitted = self._step_fn(self.params, self._sstate,
                                              batch)
        if self.ecfg.sync_timeout_s > 0:
            # watchdog over the one host sync of the tick: bounded
            # retry/backoff, observable as degradation events, and a
            # WatchdogTimeout instead of an unbounded hang
            from repro.robust.watchdog import watch
            em = watch(lambda: jax.device_get(emitted),
                       timeout_s=self.ecfg.sync_timeout_s,
                       retries=self.ecfg.sync_retries,
                       backoff=self.ecfg.sync_backoff,
                       component="engine.tick_sync")
        else:
            em = jax.device_get(emitted)  # the one host sync of the tick
        # admitted lanes are a PREFIX of the waiting queue (in-order
        # free-lane assignment + break-on-refusal)
        n_adm = int(em["admitted"].sum())
        newly = self.waiting[:n_adm]
        del self.waiting[:n_adm]
        for j, r in enumerate(newly):
            r.generated.append(int(em["pre_tok"][j]))
            r.prefix_hits = int(em["pre_hits"][j])
            r.prefix_lookups = int(em["pre_lookups"][j])
            r.pos = len(r.prompt)
            self.running[r.rid] = r
        for n in range(self.ecfg.decode_block):
            dm, dt, rt = (em["dec_mask"][n], em["dec_tok"][n],
                          em["retired"][n])
            for i in range(s):
                if dm[i]:
                    r = self.running[int(em["rid"][i])]
                    r.generated.append(int(dt[i]))
                    r.pos += 1
            for i in range(s):
                if rt[i]:
                    r = self.running.pop(int(em["rid"][i]))
                    r.done = True
                    self.finished[r.rid] = r

    # ------------------------------------------------- host-loop internals
    def _admit(self):
        for i in range(self.ecfg.max_batch):
            if self.slots[i] is None and self.waiting:
                req = self.waiting.pop(0)
                if self._prefill(req, i):
                    self.slots[i] = req
                else:
                    self.waiting.insert(0, req)  # no free pages: back off
                    break

    def _prefix_transaction(self, hashes: np.ndarray):
        """Fixed-width slot-returning prefix-chain transaction.

        Pads the block chain to the static ``max_prompt // page`` lane width
        and runs TinyLFU record → peek_victims → admit, then the two-phase
        get + slot-returning put — bit-identical, by the fused≡two-phase
        invariant, to the single fused ``access(slot_value=True)`` the
        jitted tick issues.  Returns (n_hit, pages int64[n_full]) where
        ``pages[i]`` is block i's page id (hit or fresh insert) or -1.
        """
        pbw = self.pbw
        n_full = len(hashes)
        keys = np.zeros(pbw, np.uint32)
        keys[:n_full] = hashes
        valid = np.arange(pbw) < n_full
        jkeys = jnp.asarray(keys)
        jvalid = jnp.asarray(valid)
        admit_mask = None
        if self.sketch is not None:
            self.sketch = admission.record(self.sketch_cfg, self.sketch,
                                           jkeys, enabled=jvalid)
            vk, vv = self.backend.peek_victims(self.kstate, jkeys)
            admit_mask = admission.admit(self.sketch_cfg, self.sketch,
                                         jkeys, vk, vv)
        self.kstate, hit, vals = self.backend.get(self.kstate, jkeys,
                                                  enabled=jvalid)
        self.kstate, _, ev, ss, sw = self.backend.put(
            self.kstate, jkeys, jnp.zeros(pbw, jnp.int32), admit=admit_mask,
            enabled=jvalid & ~hit, slot_value=True)
        self._ev_dev = self._ev_dev + jnp.sum(ev.astype(jnp.int32))
        hit_h, vals_h, ss_h, sw_h = [
            np.asarray(a) for a in jax.device_get((hit, vals, ss, sw))]
        pages = np.where(hit_h, vals_h,
                         np.where(ss_h >= 0,
                                  ss_h * self.kcfg.ways + sw_h, -1))[:n_full]
        chain = np.cumprod(hit_h[:n_full].astype(np.int64)) \
            if n_full else np.empty(0, np.int64)
        return int(chain.sum()), pages

    def _prefill(self, req: Request, slot: int) -> bool:
        page = self.ecfg.page
        prompt = req.prompt
        ntok = len(prompt)
        hashes = prefix_block_hashes(prompt, page)
        n_full = len(hashes)
        tail = ntok - n_full * page
        n_hit, pages_blk = self._prefix_transaction(hashes)
        req.prefix_lookups = n_full
        req.prefix_hits = n_hit
        self._stats["prefix_lookups"] += n_full
        self._stats["prefix_hits"] += n_hit

        need_private = (1 if tail else 0) + int((pages_blk < 0).sum())
        if len(self.free) < need_private + 2:
            return False

        padded = np.zeros(self.max_prompt, np.int32)
        padded[:ntok] = prompt
        logits, ks, vs = pm.prefill_padded(
            self.cfg, self.params, jnp.asarray(padded[None]),
            jnp.asarray([ntok], jnp.int32))
        self._stats["prefills"] += 1

        # page assignment for the full blocks (private fill-ins for blocks
        # the cache did not admit)
        pages = []
        for j in range(n_full):
            p = int(pages_blk[j])
            if p < 0:
                p = self.free.pop()
                req.private.append(p)
            pages.append(p)
        if n_full > n_hit:
            # write KV from the first chain miss on (later-chain resident
            # blocks are re-written with identical content — same as the
            # jitted tick's masked scatter)
            slot_arr = jnp.asarray(np.array(pages[n_hit:], np.int32)[None])
            kseg = ks[:, :, n_hit * page: n_full * page]
            vseg = vs[:, :, n_hit * page: n_full * page]
            self.pool_k, self.pool_v = pm.write_pages(
                self.cfg, (kseg, vseg), slot_arr, self.pool_k, self.pool_v,
                jnp.ones((1, n_full - n_hit), bool),
            )
        # tail tokens -> one private page
        if tail:
            p = self.free.pop()
            req.private.append(p)
            pages.append(p)
            kt = jnp.zeros(
                (self.cfg.num_layers, 1, page, self.cfg.num_kv_heads,
                 self.cfg.hd), jnp.bfloat16,
            ).at[:, :, :tail].set(
                ks[:, :, n_full * page: n_full * page + tail])
            vt = jnp.zeros_like(kt).at[:, :, :tail].set(
                vs[:, :, n_full * page: n_full * page + tail])
            self.pool_k, self.pool_v = pm.write_pages(
                self.cfg, (kt, vt),
                jnp.asarray([[p]], jnp.int32), self.pool_k, self.pool_v,
                jnp.ones((1, 1), bool),
            )
        req.pages = pages
        req.pos = ntok
        req.slot = slot
        req.generated.append(int(jnp.argmax(logits[0])))
        return True

    def _page_table(self):
        b = self.ecfg.max_batch
        pt = np.zeros((b, self.pps), np.int32)
        pos = np.zeros(b, np.int32)
        tok = np.zeros(b, np.int32)
        active = np.zeros(b, bool)
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            pt[i, : len(req.pages)] = req.pages
            pos[i] = req.pos
            tok[i] = req.generated[-1]
            active[i] = True
        return pt, pos, tok, active

    def _decode(self):
        # Ensure every running request has a page for the incoming token
        # BEFORE the batch table is built: a request that cannot get one
        # finishes — and retires — in this very step (its slot is free for
        # the next _admit), instead of riding one more decode marked active
        # with a stale page table.
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            if req.pos % self.ecfg.page == 0 and \
                    req.pos // self.ecfg.page >= len(req.pages):
                if not self.free:
                    req.done = True  # out of pages: finish early
                    self._retire(i)
                    continue
                p = self.free.pop()
                req.private.append(p)
                req.pages.append(p)
        pt, pos, tok, active = self._page_table()
        if not active.any():
            return
        logits, self.pool_k, self.pool_v = pm.decode_paged(
            self.cfg, self.params,
            jnp.asarray(tok), jnp.asarray(pos),
            self.pool_k, self.pool_v,
            jnp.asarray(pt), jnp.asarray(active),
        )
        nxt = np.asarray(
            _sample_next(self.ecfg, logits, self._stats["decode_steps"]))
        self._stats["decode_steps"] += 1
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            req.pos += 1
            req.generated.append(int(nxt[i]))
            if len(req.generated) >= req.max_new + 1 or \
                    req.pos >= self.ecfg.max_seq - 1:
                req.done = True
                self._retire(i)

    def _retire(self, slot: int):
        req = self.slots[slot]
        self.free.extend(req.private)
        req.private = []
        self.finished[req.rid] = req
        self.slots[slot] = None
