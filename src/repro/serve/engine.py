"""Continuous-batching serving engine with a K-way set-associative prefix
cache — the paper's technique as the page-residency manager of a paged KV
cache.

Design (DESIGN.md §2): the page pool is split into
  * a **shared region** of exactly ``num_sets × ways`` pages, owned 1:1 by
    the K-way cache slots: cache value == page id.  A full prompt block
    (page_size tokens) keyed by its *prefix-chain hash* lives at most once;
    eviction policy (LRU/LFU/Hyperbolic + optional TinyLFU admission)
    decides residency, and evicting a key automatically frees its page —
    the paper's "dense, static memory, no pointers" argument applied to KV
    paging;
  * a **private region** with a free list for decode-time pages (partial
    blocks are not content-addressable until full).

The engine is single-host (batched requests on one device — CPU here, one
TPU chip in production; the multi-chip serve path is the dry-run's
``decode_*`` cells).  Host-side bookkeeping is numpy; all tensor work is
jitted (serve/paged_model.py; attention via the Pallas paged kernel).  The
prefix cache runs on any CacheBackend (DESIGN.md §3) via
``EngineConfig.backend``: "jnp" vector ops, "pallas" (the probe kernel as
the residency hot loop), or "ref" (the sequential oracle, for differential
tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import admission
from repro.core.backend import make_backend
from repro.core.kway import KWayConfig
from repro.core.policies import Policy
from repro.serve import paged_model as pm

_FNV_OFFSET = np.uint32(2166136261)
_FNV_PRIME = np.uint32(16777619)
_GOLDEN = np.uint32(0x9E3779B1)


def _fmix32(x: np.ndarray) -> np.ndarray:
    """murmur3 finalizer (numpy port of core/hashing._fmix32)."""
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(0x85EBCA6B)
    x = x ^ (x >> np.uint32(13))
    x = x * np.uint32(0xC2B2AE35)
    x = x ^ (x >> np.uint32(16))
    return x


def prefix_block_hashes(tokens: np.ndarray, page: int) -> np.ndarray:
    """Rolling prefix-chain hash per full block (content addressing).

    block_hash[i] covers tokens[0 : (i+1)*page] — a block only matches when
    its entire prefix matches, so a page hit guarantees identical KV.

    Vectorized: an FNV-1a fold over each block's tokens runs across all
    blocks at once (``page`` numpy steps instead of one interpreted step per
    prompt token), each block digest is avalanche-mixed with its position,
    and the prefix chain is a cumulative XOR of the position-salted digests.
    The content-addressing contract is preserved — same-prefix ⇒ same-hash,
    change-block-i ⇒ chain differs from i on — but the concrete hash VALUES
    differ from the earlier token-serial rolling FNV (that recurrence is
    inherently sequential and cannot be vectorized bit-exactly).  Hashes are
    ephemeral in-memory keys, never persisted, so only the contract matters.
    O(page + n) numpy ops instead of O(prompt_len) interpreter work per
    prefill.
    """
    n = len(tokens) // page
    if n == 0:
        return np.empty(0, np.uint32)
    blocks = np.asarray(tokens[: n * page], dtype=np.uint32).reshape(n, page)
    h = np.full(n, _FNV_OFFSET, np.uint32)
    for j in range(page):                    # page steps, vectorized over n
        h = (h ^ blocks[:, j]) * _FNV_PRIME
    salt = (np.arange(1, n + 1, dtype=np.uint32)) * _GOLDEN
    out = np.bitwise_xor.accumulate(_fmix32(h ^ salt)).astype(np.uint32)
    out[out == np.uint32(0xFFFFFFFF)] = np.uint32(1)  # avoid EMPTY_KEY
    return out


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    slot: int = -1                    # batch slot when running
    pos: int = 0                      # tokens materialized so far
    pages: list = dataclasses.field(default_factory=list)   # page ids in order
    private: list = dataclasses.field(default_factory=list)  # owned free-pool pages
    done: bool = False
    prefix_hits: int = 0
    prefix_lookups: int = 0


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    page: int = 16
    num_sets: int = 64                # shared region = num_sets × ways pages
    ways: int = 8
    policy: Policy = Policy.LRU
    tinylfu: bool = False
    max_batch: int = 8
    max_seq: int = 512
    private_pages: int = 256
    backend: str = "jnp"              # cache backend: "jnp" | "pallas" | "ref"
    # > 1: run the prefix cache set-sharded (core/sharded.py) — the shared
    # region's set axis splits across shards with device-resident routing;
    # slot ids stay global, so page bookkeeping is unchanged.  The ref
    # backend cannot be sharded (host Python).
    shards: int = 1


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        assert cfg.has_attention and cfg.enc_layers == 0, (
            "paged engine serves decoder-only attention archs; attention-free"
            " archs bypass it (DESIGN.md §4)"
        )
        self.cfg, self.params, self.ecfg = cfg, params, ecfg
        self.kcfg = KWayConfig(
            num_sets=ecfg.num_sets, ways=ecfg.ways, policy=ecfg.policy
        )
        if ecfg.shards > 1:
            # Opt-in sharded prefix cache: ShardedCache implements the same
            # get/put/peek_victims contract with global slot ids.
            from repro.core.sharded import ShardedCache, ShardedConfig
            self.backend = ShardedCache(ShardedConfig(
                cache=self.kcfg, num_shards=ecfg.shards,
                backend=ecfg.backend))
        else:
            self.backend = make_backend(ecfg.backend, self.kcfg)
        self.kstate = self.backend.init()
        self.sketch_cfg = (
            admission.for_capacity(self.kcfg.capacity) if ecfg.tinylfu else None
        )
        self.sketch = (
            admission.make_sketch(self.sketch_cfg) if ecfg.tinylfu else None
        )
        shared = self.kcfg.capacity
        total = shared + ecfg.private_pages
        shape = (cfg.num_layers, cfg.num_kv_heads, total, ecfg.page, cfg.hd)
        self.pool_k = jnp.zeros(shape, jnp.bfloat16)
        self.pool_v = jnp.zeros(shape, jnp.bfloat16)
        self.free = list(range(shared, total))
        self.pps = ecfg.max_seq // ecfg.page
        self.slots: list[Optional[Request]] = [None] * ecfg.max_batch
        self.waiting: list[Request] = []
        self.finished: dict[int, Request] = {}
        self._next_rid = 0
        self.stats = {"prefix_hits": 0, "prefix_lookups": 0, "prefills": 0,
                      "decode_steps": 0, "evictions": 0}

    # ------------------------------------------------------------------ API
    def submit(self, prompt, max_new: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.waiting.append(Request(rid, np.asarray(prompt, np.int32), max_new))
        return rid

    def step(self, greedy: bool = True):
        """One engine iteration: admit + prefill waiting, decode running."""
        self._admit()
        self._decode(greedy)

    def run(self, greedy: bool = True, max_steps: int = 10_000):
        steps = 0
        while (self.waiting or any(self.slots)) and steps < max_steps:
            self.step(greedy)
            steps += 1
        return self.finished

    # ------------------------------------------------------------- internals
    def _admit(self):
        for i in range(self.ecfg.max_batch):
            if self.slots[i] is None and self.waiting:
                req = self.waiting.pop(0)
                if self._prefill(req, i):
                    self.slots[i] = req
                else:
                    self.waiting.insert(0, req)  # no free pages: back off
                    break

    def _probe_prefix(self, hashes: np.ndarray):
        """K-way lookup of the prompt's block chain; stop at first miss
        (later blocks can't be valid without their prefix)."""
        if len(hashes) == 0:
            return 0, []
        keys = jnp.asarray(hashes, jnp.uint32)
        self.kstate, hit, vals = self.backend.get(self.kstate, keys)
        hit = np.asarray(hit)
        # first-miss = argmin of the cumulative AND of the hit flags; its
        # closed form is the chain sum (every element before the first zero
        # is one), so the host loop collapses to two vector ops.
        chain = np.cumprod(hit.astype(np.int64))
        n_hit = int(chain.sum())
        pages = [int(v) for v in np.asarray(vals)[:n_hit]]
        return n_hit, pages

    def _insert_blocks(self, hashes: np.ndarray):
        """Admit missed blocks; returns their assigned page ids (== slot
        index in the shared region) or -1 when not admitted."""
        if len(hashes) == 0:
            return []
        keys = jnp.asarray(hashes, jnp.uint32)
        admit_mask = None
        if self.sketch is not None:
            self.sketch = admission.record(self.sketch_cfg, self.sketch, keys)
            vk, vv = self.backend.peek_victims(self.kstate, keys)
            admit_mask = admission.admit(self.sketch_cfg, self.sketch, keys, vk, vv)
        # value payload: the slot index the key lands in == page id.  The
        # slot-returning put writes it in the same call (slot_value=True) and
        # reports where every key landed.
        self.kstate, ek, ev, slot_sets, slot_ways = self.backend.put(
            self.kstate, keys, jnp.zeros(len(hashes), jnp.int32),
            admit=admit_mask, slot_value=True,
        )
        self.stats["evictions"] += int(np.asarray(ev).sum())
        slot_sets = np.asarray(slot_sets)
        slot_ways = np.asarray(slot_ways)
        slots = np.where(
            slot_sets >= 0, slot_sets * self.kcfg.ways + slot_ways, -1
        )
        return [int(s) for s in slots]

    def _prefill(self, req: Request, slot: int) -> bool:
        page = self.ecfg.page
        prompt = req.prompt
        hashes = prefix_block_hashes(prompt, page)
        n_hit, hit_pages = self._probe_prefix(hashes)
        req.prefix_lookups = len(hashes)
        req.prefix_hits = n_hit
        self.stats["prefix_lookups"] += len(hashes)
        self.stats["prefix_hits"] += n_hit

        # compute KV for everything past the shared hit (simplicity: one
        # prefill over the full prompt; reuse would skip the hit tokens —
        # recorded as a hillclimb TODO since hits still save *decode* pages)
        miss_hashes = hashes[n_hit:]
        new_slots = self._insert_blocks(miss_hashes)

        ntok = len(prompt)
        n_full = ntok // page
        tail = ntok - n_full * page
        need_private = (1 if tail else 0) + sum(1 for s in new_slots if s < 0)
        if len(self.free) < need_private + 2:
            return False

        logits, ks, vs = pm.prefill_with_kv(
            self.cfg, self.params, jnp.asarray(prompt[None])
        )
        self.stats["prefills"] += 1

        # page assignment for the full blocks
        pages = list(hit_pages)
        blk_slots = []
        for s in new_slots:
            if s < 0:              # not admitted by TinyLFU: private page
                s = self.free.pop()
                req.private.append(s)
            pages.append(s)
            blk_slots.append(s)
        if blk_slots:
            slot_arr = jnp.asarray(
                np.array(blk_slots, np.int32)[None], jnp.int32
            )
            # write only the missed blocks' KV (slice from n_hit)
            kseg = ks[:, :, n_hit * page : n_full * page]
            vseg = vs[:, :, n_hit * page : n_full * page]
            self.pool_k, self.pool_v = pm.write_pages(
                self.cfg, (kseg, vseg), slot_arr, self.pool_k, self.pool_v,
                jnp.ones((1, len(blk_slots)), bool),
            )
        # tail tokens -> one private page
        if tail:
            p = self.free.pop()
            req.private.append(p)
            pages.append(p)
            kt = jnp.zeros(
                (self.cfg.num_layers, 1, page, self.cfg.num_kv_heads, self.cfg.hd),
                jnp.bfloat16,
            ).at[:, :, :tail].set(ks[:, :, n_full * page :])
            vt = jnp.zeros_like(kt).at[:, :, :tail].set(vs[:, :, n_full * page :])
            self.pool_k, self.pool_v = pm.write_pages(
                self.cfg, (kt, vt),
                jnp.asarray([[p]], jnp.int32), self.pool_k, self.pool_v,
                jnp.ones((1, 1), bool),
            )
        req.pages = pages
        req.pos = ntok
        req.slot = slot
        tok = int(jnp.argmax(logits[0]))
        req.generated.append(tok)
        return True

    def _page_table(self):
        b = self.ecfg.max_batch
        pt = np.zeros((b, self.pps), np.int32)
        pos = np.zeros(b, np.int32)
        tok = np.zeros(b, np.int32)
        active = np.zeros(b, bool)
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            pt[i, : len(req.pages)] = req.pages
            pos[i] = req.pos
            tok[i] = req.generated[-1]
            active[i] = True
        return pt, pos, tok, active

    def _decode(self, greedy: bool):
        # Ensure every running request has a page for the incoming token
        # BEFORE the batch table is built: a request that cannot get one
        # finishes — and retires — in this very step (its slot is free for
        # the next _admit), instead of riding one more decode marked active
        # with a stale page table.
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            if req.pos % self.ecfg.page == 0 and req.pos // self.ecfg.page >= len(req.pages):
                if not self.free:
                    req.done = True  # out of pages: finish early
                    self._retire(i)
                    continue
                p = self.free.pop()
                req.private.append(p)
                req.pages.append(p)
        pt, pos, tok, active = self._page_table()
        if not active.any():
            return
        logits, self.pool_k, self.pool_v = pm.decode_paged(
            self.cfg, self.params,
            jnp.asarray(tok), jnp.asarray(pos),
            self.pool_k, self.pool_v,
            jnp.asarray(pt), jnp.asarray(active),
        )
        self.stats["decode_steps"] += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            req.pos += 1
            req.generated.append(int(nxt[i]))
            if len(req.generated) >= req.max_new + 1 or req.pos >= self.ecfg.max_seq - 1:
                req.done = True
                self._retire(i)

    def _retire(self, slot: int):
        req = self.slots[slot]
        self.free.extend(req.private)
        req.private = []
        self.finished[req.rid] = req
        self.slots[slot] = None

    def hit_ratio(self) -> float:
        if self.stats["prefix_lookups"] == 0:
            return 0.0
        return self.stats["prefix_hits"] / self.stats["prefix_lookups"]
