"""Paged-KV serving on a K-way set-associative prefix cache (DESIGN.md §11).

Public surface: the host-loop/jitted :class:`Engine`, its
:class:`EngineConfig`, and the jitted-tick compile counters
(:func:`trace_counts` / :func:`reset_trace_counts`) that pin the ≤1-trace-
per-shape compile economy.
"""
from repro.serve.engine import (  # noqa: F401
    Engine,
    EngineConfig,
    Request,
    ServeState,
    reset_trace_counts,
    trace_counts,
)
