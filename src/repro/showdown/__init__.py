"""Showdown harness — production-grade concurrent caches vs our paths.

The paper's headline claim is "throughput improved by up to 5x compared to
production-grade caching libraries"; this package is the external side of
that comparison.  It replays the SAME uint32 key traces that drive the
jnp/pallas replay paths through:

  * ``CachetoolsCache``  — ``cachetools.LRUCache``/``LFUCache`` behind one
    global lock under a thread pool: the canonical production Python
    caching idiom (cachetools is not thread-safe; its docs prescribe
    exactly this lock).
  * ``LockStripedKWay``  — a pure-Python reference of the paper's design:
    k-way sets, one lock per set (lock striping), so contention is per-set
    instead of global.  Isolates what limited associativity alone buys a
    host-side implementation.

``harness.replay_threaded`` drives either cache with N worker threads and
the warmup-discard/steady-state protocol of ``eval/timing.py``;
``harness.hit_ratio`` replays single-threaded for the deterministic
hit-ratio parity records the CI gate checks.  ``eval/figures.showdown`` and
``benchmarks/showdown.py`` are the figure/CLI entry points.
"""
from repro.showdown.baselines import (HAVE_CACHETOOLS, CachetoolsCache,
                                      LockStripedKWay, make_baseline)
from repro.showdown.harness import hit_ratio, replay_threaded

__all__ = ["CachetoolsCache", "LockStripedKWay", "make_baseline",
           "replay_threaded", "hit_ratio", "HAVE_CACHETOOLS"]
