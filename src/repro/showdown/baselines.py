"""External cache baselines: cachetools behind a global lock, and a
lock-striped pure-Python k-way cache (the paper's design, host-side).

Both expose one method, ``access(key) -> bool`` (True = hit): look the key
up and insert it on a miss — the same get-or-allocate transaction the
jnp/pallas ``access`` paths perform per request.  Thread safety is part of
the contract: the harness hammers one shared instance from N threads.

Why these two baselines (DESIGN.md §12):

  * ``CachetoolsCache`` is the production stand-in.  cachetools is the
    standard Python caching library; it is documented as not thread-safe,
    and the prescribed concurrent idiom is a single lock around every
    operation — so its scaling curve shows what a monolithic-lock cache
    does as threads are added (the paper's Fig. 1 left half).
  * ``LockStripedKWay`` holds everything about our design that survives in
    pure Python — same set-index hash, same k-way sets, same LRU/LFU
    victim rule — but with one lock per set instead of one per cache.  It
    isolates the *structural* benefit of limited associativity (contention
    splits across sets) from the vectorization the jnp/pallas paths add.
"""
from __future__ import annotations

import threading

try:
    import cachetools
    HAVE_CACHETOOLS = True
except ImportError:                           # pragma: no cover - CI installs it
    cachetools = None
    HAVE_CACHETOOLS = False

#: murmur3 fmix32 / xxhash constants — bit-identical to core/hashing.py's
#: hash_u32 so the striped baseline distributes keys to sets exactly like
#: the device paths do.
_PRIME1 = 0x9E3779B1
_PRIME2 = 0x85EBCA77
_C1 = 0x85EBCA6B
_C2 = 0xC2B2AE35
_MASK = 0xFFFFFFFF
_EMPTY_KEY = 0xFFFFFFFF
_HASH_SEED = 0x51CA                           # KWayConfig.seed default

_MISS = object()


def hash_u32_host(key: int, seed: int = _HASH_SEED) -> int:
    """Pure-int port of ``hashing.hash_u32`` (bit-identical, see tests)."""
    x = ((key & _MASK) + seed * _PRIME1) & _MASK
    x = (x * _PRIME2) & _MASK
    x ^= x >> 16
    x = (x * _C1) & _MASK
    x ^= x >> 13
    x = (x * _C2) & _MASK
    x ^= x >> 16
    return x


class CachetoolsCache:
    """``cachetools.LRUCache``/``LFUCache`` + the documented global lock."""

    name = "cachetools"

    def __init__(self, capacity: int, policy: str = "lru"):
        if not HAVE_CACHETOOLS:
            raise ImportError(
                "cachetools is not installed — pip install -r "
                "requirements-dev.txt (the showdown harness benchmarks "
                "against it)")
        cls = {"lru": cachetools.LRUCache, "lfu": cachetools.LFUCache}
        try:
            self._cache = cls[policy](maxsize=capacity)
        except KeyError:
            raise ValueError(
                f"unknown cachetools policy {policy!r}; expected "
                f"{sorted(cls)}") from None
        self._lock = threading.Lock()

    def access(self, key: int) -> bool:
        with self._lock:
            if self._cache.get(key, _MISS) is not _MISS:
                return True
            self._cache[key] = key
            return False

    def __len__(self) -> int:
        return len(self._cache)


class LockStripedKWay:
    """Pure-Python k-way set-associative cache, one lock per set.

    Per set: a dict of at most ``ways`` entries mapping key -> metadata
    (monotonic per-set access time for LRU, hit count for LFU); the victim
    is the min-metadata entry, empty ways first — the sequential (B=1)
    semantics of ``core/kway.access``.  Keys are set-indexed with the same
    seeded avalanche hash as the device paths and the EMPTY_KEY sentinel is
    folded identically, so at matched geometry this cache is the host-side
    twin of a ``KWayConfig(num_sets, ways)`` replay.
    """

    name = "striped"

    def __init__(self, num_sets: int, ways: int, policy: str = "lru",
                 seed: int = _HASH_SEED):
        if num_sets & (num_sets - 1):
            raise ValueError(f"num_sets must be a power of two, "
                             f"got {num_sets}")
        if policy not in ("lru", "lfu"):
            raise ValueError(f"unknown striped policy {policy!r}; expected "
                             "['lfu', 'lru']")
        self.num_sets, self.ways, self.policy = num_sets, ways, policy
        self._seed = seed
        self._sets: list[dict] = [{} for _ in range(num_sets)]
        self._locks = [threading.Lock() for _ in range(num_sets)]
        self._clocks = [0] * num_sets         # per-set logical time (LRU)

    def _set_index(self, key: int) -> int:
        return hash_u32_host(key, self._seed) & (self.num_sets - 1)

    def access(self, key: int) -> bool:
        key &= _MASK
        if key == _EMPTY_KEY:
            key = 0xFFFFFFFE                  # hashing.sanitize_keys fold
        s = self._set_index(key)
        lru = self.policy == "lru"
        with self._locks[s]:
            d = self._sets[s]
            self._clocks[s] += 1
            now = self._clocks[s]
            meta = d.get(key)
            if meta is not None:
                d[key] = now if lru else meta + 1
                return True
            if len(d) >= self.ways:
                victim = min(d, key=d.get)    # min metadata == LRU/LFU rule
                del d[victim]
            d[key] = now if lru else 1
            return False

    def __len__(self) -> int:
        return sum(len(d) for d in self._sets)


def make_baseline(lib: str, capacity: int, policy: str, ways: int = 8):
    """Factory keyed by the figure's library names.

    ``lib``: "cachetools" (full-associativity LRU/LFU + global lock) or
    "striped" (k-way, ``ways`` ways, one lock per set).  ``capacity`` is
    total entries for both.
    """
    if lib == "cachetools":
        return CachetoolsCache(capacity, policy=policy)
    if lib == "striped":
        if capacity % ways:
            raise ValueError(f"capacity {capacity} not divisible by "
                             f"ways={ways}")
        return LockStripedKWay(capacity // ways, ways, policy=policy)
    raise ValueError(f"unknown baseline library {lib!r}; expected "
                     "['cachetools', 'striped']")
