"""Threaded replay harness for the external cache baselines.

Methodology (DESIGN.md §12): the paper's throughput figures give every
thread its own request loop against one shared cache and report aggregate
requests/second.  ``replay_threaded`` reproduces that — the trace is split
into ``threads`` contiguous slices, each worker replays its slice against
the shared cache counting hits locally, and one replay completes when every
worker has drained its slice.  The thread pool is created once per
configuration and reused across timing repetitions, so thread spawn cost
stays out of the steady-state window (the same reason the device paths keep
compiles in the discarded warmup).

Hit ratios under concurrent interleaving are nondeterministic (that is the
point of the paper's racy-access model), so throughput rows are
``comparable: false``; the deterministic parity records the CI gate checks
come from ``hit_ratio`` — a single-threaded replay of the same trace on a
fresh cache.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor

import numpy as np

__all__ = ["replay_threaded", "hit_ratio", "ThreadedReplay"]


def _worker(cache, keys) -> int:
    access = cache.access                    # one attr lookup per slice
    hits = 0
    for k in keys:
        if access(k):
            hits += 1
    return hits


class ThreadedReplay:
    """One (cache, trace, threads) replay bound to a reusable pool.

    Calling the instance replays the WHOLE trace once and returns the total
    hit count (a Python int — already synced, so the timing helpers'
    ``block_until_ready`` is a no-op).  Use as a context manager or call
    ``close()`` to drop the pool.

    ``timeout_s > 0`` arms a watchdog over the worker joins: each expired
    wait (growing by ``backoff``) records a degradation event, and after
    ``retries`` extra waits the replay raises ``WatchdogTimeout`` instead
    of hanging the harness on a deadlocked contender cache.
    """

    def __init__(self, cache, trace: np.ndarray, threads: int, *,
                 timeout_s: float = 0.0, retries: int = 2,
                 backoff: float = 2.0):
        if threads < 1:
            raise ValueError(f"threads must be >= 1, got {threads}")
        self.cache = cache
        self.threads = threads
        self.timeout_s = timeout_s
        self.retries = retries
        self.backoff = backoff
        # Python-int key lists, pre-split: uint32->int conversion cost is
        # paid once here, not inside the timed region.
        keys = [int(k) for k in np.asarray(trace, np.uint32)]
        bound = -(-len(keys) // threads)
        self._slices = [keys[i * bound:(i + 1) * bound]
                        for i in range(threads)]
        self._slices = [s for s in self._slices if s]
        self._pool = (ThreadPoolExecutor(max_workers=threads)
                      if threads > 1 else None)

    def __call__(self) -> int:
        if self.timeout_s > 0:
            from repro.robust.watchdog import watch
            return watch(self._replay_once, timeout_s=self.timeout_s,
                         retries=self.retries, backoff=self.backoff,
                         component="showdown.replay")
        return self._replay_once()

    def _replay_once(self) -> int:
        if self._pool is None:               # no pool round trip at T=1
            return _worker(self.cache, self._slices[0])
        futures = [self._pool.submit(_worker, self.cache, s)
                   for s in self._slices]
        return sum(f.result() for f in futures)

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def replay_threaded(cache, trace: np.ndarray, threads: int,
                    iters: int = 3, warmup: int = 1) -> dict:
    """Steady-state throughput of one cache under ``threads`` workers.

    Runs ``warmup`` discarded replays (cache warm-up — the steady state of
    a cache benchmark is the warmed cache, matching the device paths'
    warm-state timing) then ``iters`` timed replays of the whole trace.
    Returns ``{"p50", "p90", "req_s_p50", "req_s_p90", "hits_last", "n",
    "iters", "reps_discarded"}``.
    """
    from repro.eval.timing import time_replay_percentiles

    n = len(trace)
    with ThreadedReplay(cache, trace, threads) as replay:
        stats = time_replay_percentiles(replay, iters=iters, warmup=warmup)
        hits_last = replay()                 # warmed-state hit count
    return {
        "p50": stats["p50"], "p90": stats["p90"],
        "req_s_p50": n / stats["p50"], "req_s_p90": n / stats["p90"],
        "hits_last": int(hits_last), "n": n,
        "iters": stats["iters"], "reps_discarded": stats["reps_discarded"],
    }


def hit_ratio(cache, trace: np.ndarray) -> float:
    """Deterministic single-threaded hit ratio of a FRESH cache over the
    trace — the comparable parity record the showdown gate checks."""
    hits = _worker(cache, [int(k) for k in np.asarray(trace, np.uint32)])
    return hits / len(trace)
