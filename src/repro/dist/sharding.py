"""Sharding heuristics for the launch drivers (train / dryrun).

The mesh carries a ``data`` axis (plus an optional leading ``pod`` axis —
see launch/mesh.py) for batch parallelism and a ``model`` axis for tensor
parallelism.  The rules here are deliberately simple and shape-driven:

  * params — replicate small leaves; for large leaves (≥ 1 MiB elements),
    shard the largest dimension divisible by the ``model`` axis.  Leaves
    with no such dimension stay replicated ("dp_only" archs) — their
    optimizer state is then ZeRO-sharded by adamw.state_shardings.
  * inputs — batch-shard the leading dimension over the data axes when it
    divides; everything else replicated.
  * caches — decode caches are [layers, batch, ...]; batch-shard dim 1.

Every function accepts either concrete arrays or ShapeDtypeStruct specs
(only ``.shape``/``.size`` are read) and returns pytrees of NamedSharding.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MIN_SHARD_ELEMS = 1 << 20


def _data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh, axes) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def batch_pspec(cfg, global_batch: int, mesh) -> P:
    """PartitionSpec for a leading batch dimension."""
    axes = _data_axes(mesh)
    n = _axis_size(mesh, axes)
    if n > 1 and global_batch % n == 0:
        return P(axes if len(axes) > 1 else axes[0])
    return P(None)


def _shard_leading(leaf, mesh, dim: int):
    axes = _data_axes(mesh)
    n = _axis_size(mesh, axes)
    dims = [None] * len(leaf.shape)
    if n > 1 and len(leaf.shape) > dim and leaf.shape[dim] % n == 0:
        dims[dim] = axes if len(axes) > 1 else axes[0]
    return NamedSharding(mesh, P(*dims))


def param_shardings(cfg, params, mesh):
    """Tensor-parallel parameter shardings over the ``model`` axis."""
    m = mesh.shape["model"] if "model" in mesh.axis_names else 1

    def one(leaf):
        shape = leaf.shape
        if m == 1 or leaf.size < _MIN_SHARD_ELEMS or not shape:
            return NamedSharding(mesh, P())
        # largest dimension divisible by the model axis wins
        cand = [(d, i) for i, d in enumerate(shape) if d % m == 0]
        if not cand:
            return NamedSharding(mesh, P())   # dp_only leaf: ZeRO handles it
        _, i = max(cand)
        dims = [None] * len(shape)
        dims[i] = "model"
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(one, params)


def input_shardings(cfg, shape, ispecs, mesh):
    """Batch-shard every input's leading dimension over the data axes."""
    return jax.tree.map(lambda l: _shard_leading(l, mesh, 0), ispecs)


def cache_shardings(cfg, shape, cspecs, mesh):
    """Decode caches are [layers, batch, ...]: batch-shard dimension 1."""
    return jax.tree.map(lambda l: _shard_leading(l, mesh, 1), cspecs)
