"""Distribution helpers: sharding heuristics for params/inputs/caches."""
