"""AdamW with fully-sharded (ZeRO-3) states, f32 master weights, schedules.

States follow the parameter shardings exactly (every state leaf inherits its
parameter's PartitionSpec), so optimizer memory scales down with the full
mesh — the posture required at 1000+ nodes.

Schedules: cosine (default) and WSD (warmup-stable-decay, minicpm
[arXiv:2404.06395]).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"        # cosine | wsd | const
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1         # WSD: fraction of steps in decay phase


def schedule_fn(cfg: AdamWConfig) -> Callable:
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(cfg.warmup_steps, 1), 1.0)
        if cfg.schedule == "const":
            return cfg.lr * warm
        if cfg.schedule == "cosine":
            t = jnp.clip(
                (s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
                0.0, 1.0,
            )
            return cfg.lr * warm * (0.5 * (1 + jnp.cos(jnp.pi * t)))
        if cfg.schedule == "wsd":
            decay_start = cfg.total_steps * (1 - cfg.decay_frac)
            in_decay = s > decay_start
            t = jnp.clip(
                (s - decay_start) / max(cfg.total_steps - decay_start, 1), 0.0, 1.0
            )
            # MiniCPM: stable LR, then exponential-ish anneal to ~0.1 lr
            return cfg.lr * warm * jnp.where(in_decay, 0.1 ** t, 1.0)
        raise ValueError(cfg.schedule)

    return fn


def init(params):
    """Optimizer state: f32 master copy + first/second moments + step.

    The master copy must be a real copy even for params already in f32
    (astype would alias the buffer and break donation: 'attempt to donate
    the same buffer twice')."""
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)  # noqa: E731
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def update(cfg: AdamWConfig, grads, opt_state, params):
    """One AdamW step.  Returns (new_params_bf16, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule_fn(cfg)(step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def leaf(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        master = master - lr * (upd + cfg.weight_decay * master)
        return m, v, master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    flat_w = jax.tree.leaves(opt_state["master"])
    out = [leaf(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_w = jax.tree.unflatten(treedef, [o[2] for o in out])

    cast = jax.tree.map(
        lambda w, p: w.astype(p.dtype), new_w, params
    )
    new_state = {"master": new_w, "m": new_m, "v": new_v, "step": step}
    return cast, new_state, {"grad_norm": gnorm, "lr": lr}


def state_shardings(param_shardings_tree, mesh, params_tree=None):
    """Optimizer-state shardings.

    Default: every moment/master leaf inherits its parameter's sharding.
    ZeRO extension: when ``params_tree`` (abstract shapes) is given, large
    leaves whose *parameter* is fully replicated get their states sharded
    over the whole mesh anyway (param replicated, state sharded — the
    gather happens once per step in the master->param cast).  This is what
    keeps dp_only archs (see dist.sharding) from replicating 3x-f32 copies
    of multi-GB embeddings on every device."""
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    if params_tree is None:
        state_tree = param_shardings_tree
    else:
        all_axes = tuple(mesh.axis_names)
        world = int(np.prod([mesh.shape[a] for a in all_axes]))

        def one(shd, leaf):
            spec = shd.spec
            replicated = all(s is None for s in spec)
            if not replicated or leaf.size < (1 << 20):
                return shd
            for i, d in enumerate(leaf.shape):  # largest divisible dim
                if d % world == 0:
                    dims = [None] * len(leaf.shape)
                    dims[i] = all_axes
                    return NamedSharding(mesh, P(*dims))
                if d % mesh.shape[all_axes[-1]] == 0:
                    dims = [None] * len(leaf.shape)
                    dims[i] = all_axes[-1]
                    return NamedSharding(mesh, P(*dims))
            return shd

        state_tree = jax.tree.map(one, param_shardings_tree, params_tree)

    return {
        "master": state_tree,
        "m": state_tree,
        "v": state_tree,
        "step": NamedSharding(mesh, P()),
    }
