"""Composable LM definition covering all 10 assigned architectures.

One parameterized model family: decoder-only transformer (dense / MoE /
sliding-window / local-global / softcap), pure SSM (mamba2), hybrid
parallel attn+SSM (hymba), encoder-decoder (seamless backbone) and
prefix-embedding VLM (internvl backbone).

Layer parameters are stacked on a leading L axis and consumed with
``lax.scan`` (small HLO, one compile per 40 dry-run cells) under per-layer
``jax.checkpoint`` (remat).  Heterogeneous per-layer behaviour (gemma2's
local/global alternation) is expressed as *data* — a [L] window array scanned
alongside the params — so one homogeneous scan serves every config.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.layers import SSMDims

VOCAB_ALIGN = 256


def _constrain(x, act_spec):
    """Anchor the activation batch sharding.  GSPMD propagation through
    while loops + broadcast masks is lossy (measured: batch-replicated
    32 GiB attention logits on deepseek-7b without this).

    ``act_spec`` is a NamedSharding whose spec's first entry is the batch
    axes (so no mesh context manager is needed at trace time)."""
    if act_spec is None:
        return x
    from jax.sharding import NamedSharding

    spec = act_spec.spec
    b0 = spec[0] if len(spec) else None
    full = NamedSharding(
        act_spec.mesh, PartitionSpec(b0, *([None] * (x.ndim - 1)))
    )
    return jax.lax.with_sharding_constraint(x, full)


def padded_vocab(cfg: ModelConfig) -> int:
    return (cfg.vocab_size + VOCAB_ALIGN - 1) // VOCAB_ALIGN * VOCAB_ALIGN


def ssm_dims(cfg: ModelConfig) -> SSMDims:
    return SSMDims.from_config(
        cfg.d_model, cfg.ssm_state, cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_conv
    )


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer attention window (0 = full).  gemma2: even layers local."""
    if cfg.alt_local_global:
        w = [cfg.sliding_window if i % 2 == 0 else 0 for i in range(cfg.num_layers)]
    else:
        w = [cfg.sliding_window] * cfg.num_layers
    return jnp.asarray(w, jnp.int32)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(cfg: ModelConfig, key, *, cross: bool = False):
    ks = jax.random.split(key, 8)
    p = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
         "ln2": jnp.zeros((cfg.d_model,), jnp.float32)}
    if cfg.has_attention:
        p["attn"] = L.init_attention(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
        )
    if cfg.has_ssm:
        p["ssm"] = L.init_ssm(ks[1], ssm_dims(cfg))
    if cross:
        p["cross"] = L.init_attention(
            ks[2], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
        )
        p["ln_cross"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if cfg.is_moe:
        p["moe"] = L.init_moe(ks[3], cfg.d_model, cfg.d_ff, cfg.num_experts,
                              cfg.moe_ff_shards)
    elif cfg.d_ff > 0:
        p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    kemb, kblocks, khead, kenc = jax.random.split(key, 4)
    vp = padded_vocab(cfg)
    is_encdec = cfg.enc_layers > 0
    blocks = jax.vmap(
        lambda k: _init_block(cfg, k, cross=is_encdec)
    )(jax.random.split(kblocks, cfg.num_layers))
    params = {
        "embed": L.dense_init(kemb, (vp, cfg.d_model), in_axis=1),
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(khead, (cfg.d_model, vp))
    if is_encdec:
        enc_cfg = cfg  # same width; bidirectional blocks without cross/moe
        params["enc_blocks"] = jax.vmap(
            lambda k: _init_block(enc_cfg, k, cross=False)
        )(jax.random.split(kenc, cfg.enc_layers))
        params["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return params


# ---------------------------------------------------------------------------
# blocks (full-sequence: train / prefill / encode)
# ---------------------------------------------------------------------------

def _block_seq(cfg: ModelConfig, p, x, positions, window, enc_out, enc_mask,
               unroll=False, act_spec=None):
    """One decoder block over a full sequence."""
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    mix = jnp.zeros_like(x)
    if cfg.has_attention:
        mix = mix + L.attention(
            p["attn"], h, positions, None,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.hd, rope_theta=cfg.rope_theta,
            softcap=cfg.attn_softcap, window=window, unroll=unroll,
        )
    if cfg.has_ssm:
        y, _ = L.ssd_scan(p["ssm"], h, ssm_dims(cfg))
        mix = mix + y
    if cfg.has_attention and cfg.has_ssm:
        mix = mix * 0.5  # hymba: mean-fused parallel heads
    x = x + mix
    if "cross" in p:
        hc = L.rms_norm(x, p["ln_cross"], cfg.norm_eps)
        kv = L.cross_kv(p["cross"], enc_out, num_kv_heads=cfg.num_kv_heads,
                        head_dim=cfg.hd)
        x = x + L.attention(
            p["cross"], hc, positions, enc_mask, kv=kv,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.hd, rope_theta=cfg.rope_theta, use_rope=False,
        )
    if cfg.is_moe:
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + L.moe(p["moe"], h2, num_experts=cfg.num_experts,
                      top_k=cfg.top_k, act_spec=act_spec,
                      ff_shards=cfg.moe_ff_shards)
    elif cfg.d_ff > 0:
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h2)
    return x


def _scan_blocks(cfg, blocks, x, positions, windows, enc_out=None, enc_mask=None,
                 remat: bool = True, unroll: bool = False, act_spec=None):
    def body(carry, xs):
        p, w = xs
        carry = _constrain(carry, act_spec)
        return _block_seq(cfg, p, carry, positions, w, enc_out, enc_mask,
                          unroll=unroll, act_spec=act_spec), None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    if unroll:
        # Python-unrolled variant: same math, no while loop.  Used by the
        # roofline pass (cost_analysis counts a scan body once regardless of
        # trip count — unrolled 1/2-layer compiles give exact per-layer costs).
        for i in range(cfg.num_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], (blocks, windows)))
        return x
    x, _ = jax.lax.scan(body, x, (blocks, windows))
    return x


def _encode(cfg: ModelConfig, params, enc_embeds, unroll: bool = False,
            act_spec=None):
    """Bidirectional encoder over stub frame embeddings [B, T, d]."""
    b, t, _ = enc_embeds.shape
    pos = jnp.arange(t, dtype=jnp.int32)[None]
    full = jnp.ones((1, t, t), jnp.bool_)

    def body(carry, p):
        carry = _constrain(carry, act_spec)
        h = L.rms_norm(carry, p["ln1"], cfg.norm_eps)
        a = L.attention(
            p["attn"], h, pos, full,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.hd, rope_theta=cfg.rope_theta,
        )
        x = carry + a
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + L.mlp(p["mlp"], h2), None

    body = jax.checkpoint(
        body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    )
    if unroll:
        x = enc_embeds
        for i in range(cfg.enc_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["enc_blocks"]))
    else:
        x, _ = jax.lax.scan(body, enc_embeds, params["enc_blocks"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jnp.ndarray,                   # [B, S_tok]
    prefix_embeds: Optional[jnp.ndarray] = None,  # [B, P, d] (vlm stub)
    enc_embeds: Optional[jnp.ndarray] = None,     # [B, T_enc, d] (audio stub)
    unroll: bool = False,
    act_spec: Optional[PartitionSpec] = None,
) -> jnp.ndarray:
    """Returns logits [B, S, padded_vocab] over the full (prefix+token) seq."""
    x = params["embed"][tokens] * jnp.asarray(cfg.scale_emb, jnp.bfloat16)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = _constrain(x, act_spec)
    b, s, _ = x.shape
    # positions/masks are batch-free ([1, S]): a [B, S, S] mask would
    # materialize a replicated 16 GiB int tensor at production shapes
    positions = jnp.arange(s, dtype=jnp.int32)[None]

    enc_out = enc_mask = None
    if cfg.enc_layers > 0:
        assert enc_embeds is not None
        enc_out = _encode(cfg, params, enc_embeds, unroll=unroll,
                          act_spec=act_spec)
        enc_mask = jnp.ones((1, s, enc_out.shape[1]), jnp.bool_)

    x = _scan_blocks(cfg, params["blocks"], x, positions, layer_windows(cfg),
                     enc_out, enc_mask, unroll=unroll, act_spec=act_spec)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    if cfg.final_softcap > 0:
        lf = logits.astype(jnp.float32)
        logits = (jnp.tanh(lf / cfg.final_softcap) * cfg.final_softcap).astype(
            logits.dtype
        )
    return logits


# ---------------------------------------------------------------------------
# decode (single new token against caches)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Decode-state pytree.  Shapes are the serve_step roofline inputs."""
    cache = {}
    if cfg.has_attention:
        shape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, cfg.hd)
        cache["k"] = jnp.zeros(shape, dtype)
        cache["v"] = jnp.zeros(shape, dtype)
    if cfg.has_ssm:
        d = ssm_dims(cfg)
        cache["ssm"] = jnp.zeros(
            (cfg.num_layers, batch, d.nheads, d.head_dim, d.state), jnp.float32
        )
        cache["conv"] = jnp.zeros(
            (cfg.num_layers, batch, d.conv - 1, d.d_inner + 2 * d.state), dtype
        )
    if cfg.enc_layers > 0:
        enc_t = max_seq // 2
        kv = (cfg.num_layers, batch, enc_t, cfg.num_kv_heads, cfg.hd)
        cache["cross_k"] = jnp.zeros(kv, dtype)
        cache["cross_v"] = jnp.zeros(kv, dtype)
        cache["cross_len"] = jnp.full((batch,), enc_t, jnp.int32)
    return cache


def _block_decode(cfg, p, x, pos, window, ck, cv, cssm, cconv, xk, xv, xlen):
    """One decoder block for one token.

    The KV cache (ck/cv) is read-only here (attend-then-append: the new
    token's k/v are returned for the caller to write OUTSIDE the layer
    scan — in-scan writes would force XLA to double-buffer the whole
    multi-TB cache).  Returns (x, k_new, v_new, ssm, conv).
    """
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    mix = jnp.zeros_like(x)
    k_new = v_new = None
    if cfg.has_attention:
        k_new, v_new = L.project_kv_step(
            p["attn"], h, pos, num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
            rope_theta=cfg.rope_theta,
        )
        mix = mix + L.decode_attention(
            p["attn"], h, pos, ck, cv,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.hd, rope_theta=cfg.rope_theta,
            softcap=cfg.attn_softcap, window=window,
            kv_new=(k_new, v_new),
        )
    if cfg.has_ssm:
        y, (cssm, cconv) = L.ssd_step(p["ssm"], h, (cssm, cconv), ssm_dims(cfg))
        mix = mix + y
    if cfg.has_attention and cfg.has_ssm:
        mix = mix * 0.5
    x = x + mix
    if "cross" in p:
        hc = L.rms_norm(x, p["ln_cross"], cfg.norm_eps)
        x = x + L.decode_attention(
            p["cross"], hc, pos, xk, xv,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.hd, rope_theta=cfg.rope_theta,
            is_cross=True, cross_len=xlen,
        )
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        x = x + L.moe(p["moe"], h2, num_experts=cfg.num_experts,
                      top_k=cfg.top_k, ff_shards=cfg.moe_ff_shards)
    elif cfg.d_ff > 0:
        x = x + L.mlp(p["mlp"], h2)
    return x, k_new, v_new, cssm, cconv


def decode_step(
    cfg: ModelConfig,
    params: dict,
    token: jnp.ndarray,      # [B] int32 — the newly sampled token
    pos: jnp.ndarray,        # [B] int32 — its position (== current length)
    cache: dict,
    unroll: bool = False,
    act_spec: Optional[PartitionSpec] = None,
):
    """One serve step: append token, attend to cache, return next logits."""
    x = params["embed"][token][:, None, :] * jnp.asarray(
        cfg.scale_emb, jnp.bfloat16
    )
    x = _constrain(x, act_spec)
    windows = layer_windows(cfg)
    dummy = jnp.zeros((cfg.num_layers,), jnp.int32)

    def body(carry, xs):
        x = _constrain(carry, act_spec)
        p = xs["p"]
        w = xs["w"]
        x, k_new, v_new, cssm, cconv = _block_decode(
            cfg, p, x, pos, w,
            xs.get("ck"), xs.get("cv"), xs.get("cssm"), xs.get("cconv"),
            xs.get("xk"), xs.get("xv"), xs.get("xlen"),
        )
        out = {}
        if k_new is not None:
            out["k_new"], out["v_new"] = k_new, v_new
        if cssm is not None:
            out["cssm"], out["cconv"] = cssm, cconv
        return x, out

    xs = {"p": params["blocks"], "w": windows}
    if cfg.has_attention:
        xs["ck"], xs["cv"] = cache["k"], cache["v"]
    if cfg.has_ssm:
        xs["cssm"], xs["cconv"] = cache["ssm"], cache["conv"]
    if cfg.enc_layers > 0:
        xs["xk"], xs["xv"] = cache["cross_k"], cache["cross_v"]
        xs["xlen"] = jnp.broadcast_to(cache["cross_len"], (cfg.num_layers,) + cache["cross_len"].shape)
    del dummy

    if unroll:
        outs = []
        for i in range(cfg.num_layers):
            x, o = body(x, jax.tree.map(lambda a: a[i], xs))
            outs.append(o)
        new = jax.tree.map(lambda *ls: jnp.stack(ls), *outs)
    else:
        x, new = jax.lax.scan(body, x, xs)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(x.dtype))[:, 0]
    if cfg.final_softcap > 0:
        lf = logits.astype(jnp.float32)
        logits = (jnp.tanh(lf / cfg.final_softcap) * cfg.final_softcap).astype(
            logits.dtype
        )
    new_cache = dict(cache)
    if cfg.has_attention:
        # Single append OUTSIDE the scan, as an elementwise select on the
        # donated buffer (a vmapped dynamic_update_slice over the batch
        # lowers to transposes that copy the multi-TB cache; a where() is
        # in-place-aliasable).  c: [L,B,T,KVH,D], n: [L,B,1,KVH,D].
        t = cache["k"].shape[2]
        at_pos = (jnp.arange(t, dtype=jnp.int32)[None] == pos[:, None])

        def append(c, n):
            return jnp.where(at_pos[None, :, :, None, None], n, c)

        new_cache["k"] = append(cache["k"], new["k_new"])
        new_cache["v"] = append(cache["v"], new["v_new"])
    if cfg.has_ssm:
        new_cache["ssm"], new_cache["conv"] = new["cssm"], new["cconv"]
    return logits, new_cache
