"""Model-layer primitives: norms, RoPE, GQA attention, MLP, MoE, Mamba2 SSD.

Pure functions over param pytrees (no framework dependency).  Conventions:
  * params are plain nested dicts of jnp arrays; per-layer params are
    *stacked* on a leading L axis and consumed by ``lax.scan`` in lm.py.
  * compute dtype follows the input x (bf16 in production configs); softmax,
    SSM recurrences and losses accumulate in f32.
  * attention variants needed by the assigned archs are all here: GQA,
    sliding window, local/global alternation (per-layer dynamic window),
    logit softcap, encoder (bidirectional) and cross attention, decode
    against a KV cache.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# initialization helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis=0, dtype=jnp.bfloat16):
    fan_in = shape[in_axis] if in_axis is not None else shape[0]
    scale = fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Apply rotary embedding.  x: [..., S, H, D], positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(key, d_model, num_heads, num_kv_heads, head_dim):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d_model, num_heads * head_dim)),
        "wk": dense_init(k2, (d_model, num_kv_heads * head_dim)),
        "wv": dense_init(k3, (d_model, num_kv_heads * head_dim)),
        "wo": dense_init(k4, (num_heads * head_dim, d_model)),
    }


def _attn_weights(q, k, mask, scale, softcap):
    """q: [B,S,KVH,G,D]  k: [B,T,KVH,D]  mask: [B or 1, S, T] -> [B,S,KVH,G,T].

    bf16 operands accumulate into f32 via preferred_element_type — casting
    the operands to f32 first would materialize an f32 copy of the whole KV
    cache (measured: 7.5 GiB x 62 buffers on deepseek decode_32k).
    """
    logits = jnp.einsum(
        "bskgd,btkd->bkgst", q, k, preferred_element_type=jnp.float32
    ) * scale
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    return jax.nn.softmax(logits, axis=-1)


ATTN_Q_CHUNK = 2048  # q-block size for long sequences (see attention())


def attention(
    p: dict,
    x: jnp.ndarray,              # [B, S, d]
    positions: jnp.ndarray,      # [1 or B, S]
    mask: Optional[jnp.ndarray] = None,  # [B or 1, S, T]; None => causal
    kv: Optional[tuple] = None,  # cross-attn: precomputed (k, v) [B,T,KVH,D]
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float = 10000.0,
    softcap: float = 0.0,
    use_rope: bool = True,
    window: jnp.ndarray | int = 0,
    q_chunk: int = ATTN_Q_CHUNK,
    unroll: bool = False,
):
    """Full-sequence attention (train / prefill / encoder / cross).

    For causal self-attention with S > 2·q_chunk the query axis is blocked
    (flash-style memory behaviour from plain XLA ops): peak logits are
    [B, H, q_chunk, S] instead of [B, H, S, S] — at 32k context that is
    17 GiB -> 1 GiB per device.  The block loop is a ``lax.scan`` (or
    Python-unrolled under the roofline pass, which must not contain while
    loops).  Cross/encoder attention is left unblocked (masks are dense).
    """
    b, s, _ = x.shape
    g = num_heads // num_kv_heads
    q = (x @ p["wq"]).reshape(b, s, num_heads, head_dim)
    if kv is None:
        k = (x @ p["wk"]).reshape(b, s, num_kv_heads, head_dim)
        v = (x @ p["wv"]).reshape(b, s, num_kv_heads, head_dim)
        if use_rope:
            q = rope(q, positions, rope_theta)
            k = rope(k, positions, rope_theta)
        causal_self = True
    else:
        k, v = kv
        causal_self = False
    q = q.reshape(b, s, num_kv_heads, g, head_dim)

    if causal_self and q_chunk and s > 2 * q_chunk and s % q_chunk == 0:
        nc = s // q_chunk
        q_c = q.reshape(b, nc, q_chunk, num_kv_heads, g, head_dim)
        q_c = jnp.moveaxis(q_c, 1, 0)                   # [nc, b, qc, ...]
        pos_k = positions                                # [1, S]
        pos_c = positions.reshape(positions.shape[0], nc, q_chunk)
        pos_c = jnp.moveaxis(pos_c, 1, 0)                # [nc, 1, qc]

        def one_chunk(q_blk, pos_blk):
            m = causal_mask(pos_blk, pos_k, window=window)
            w = _attn_weights(q_blk, k, m, head_dim ** -0.5, softcap)
            return jnp.einsum(
                "bkgst,btkd->bskgd", w.astype(v.dtype), v,
                preferred_element_type=jnp.float32,
            )

        if unroll:
            o = jnp.concatenate(
                [one_chunk(q_c[i], pos_c[i]) for i in range(nc)], axis=1
            )
        else:
            _, o_c = jax.lax.scan(
                lambda c, inp: (c, one_chunk(*inp)), None, (q_c, pos_c)
            )
            o = jnp.moveaxis(o_c, 0, 1).reshape(
                b, s, num_kv_heads, g, head_dim
            )
        o = o.reshape(b, s, num_heads * head_dim).astype(x.dtype)
        return o @ p["wo"]

    if mask is None:
        mask = causal_mask(positions, positions, window=window)
    w = _attn_weights(q, k, mask, head_dim ** -0.5, softcap)
    o = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, s, num_heads * head_dim).astype(x.dtype)
    return o @ p["wo"]


def cross_kv(p: dict, enc_out: jnp.ndarray, *, num_kv_heads: int, head_dim: int):
    b, t, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(b, t, num_kv_heads, head_dim)
    v = (enc_out @ p["wv"]).reshape(b, t, num_kv_heads, head_dim)
    return k, v


def causal_mask(
    positions_q: jnp.ndarray,    # [B, S] absolute positions of queries
    positions_k: jnp.ndarray,    # [B, T]
    window: jnp.ndarray | int = 0,  # 0 = full causal; >0 = sliding window
    valid_k: Optional[jnp.ndarray] = None,  # [B, T] key validity (decode)
) -> jnp.ndarray:
    """Causal (+ optional sliding window) mask.  ``window`` may be a traced
    scalar — that's how gemma2's local/global alternation rides one scan."""
    diff = positions_q[:, :, None] - positions_k[:, None, :]
    m = diff >= 0
    w = jnp.asarray(window)
    m = m & ((w <= 0) | (diff < w))
    if valid_k is not None:
        m = m & valid_k[:, None, :]
    return m


def decode_attention(
    p: dict,
    x: jnp.ndarray,              # [B, 1, d] current token
    pos: jnp.ndarray,            # [B] current position
    k_cache: jnp.ndarray,        # [B, T, KVH, D] — positions < pos are valid;
    v_cache: jnp.ndarray,        #  the CURRENT token is NOT in the cache
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float = 10000.0,
    softcap: float = 0.0,
    window: jnp.ndarray | int = 0,
    is_cross: bool = False,
    cross_len: Optional[jnp.ndarray] = None,
    kv_new: Optional[tuple] = None,   # (k,v) [B,1,KVH,D] of the current token
):
    """Single-step decode: attend-then-append.

    The cache is READ-ONLY here; the current token's (k, v) arrive as
    ``kv_new`` and enter the softmax as an extra lane (two-part flash
    combine).  This lets the layer scan consume the cache as pure xs —
    no in-scan cache write, so XLA never materializes a second copy of a
    multi-TB KV cache (the caller appends once, outside the scan).
    """
    b = x.shape[0]
    t = k_cache.shape[1]
    g = num_heads // num_kv_heads
    scale = head_dim ** -0.5
    q = (x @ p["wq"]).reshape(b, 1, num_heads, head_dim)
    if not is_cross:
        q = rope(q, pos[:, None], rope_theta)
    q = q.reshape(b, 1, num_kv_heads, g, head_dim)

    kpos = jnp.arange(t, dtype=jnp.int32)[None, :]
    if is_cross:
        mask = (kpos < cross_len[:, None])[:, None, :]
    else:
        diff = pos[:, None, None] - kpos[:, None, :]    # [B, 1, T]
        mask = diff >= 1                                 # strictly older
        w_ = jnp.asarray(window)
        mask = mask & ((w_ <= 0) | (diff < w_))

    logits = jnp.einsum(
        "bskgd,btkd->bkgst", q, k_cache, preferred_element_type=jnp.float32
    ) * scale
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)

    if kv_new is not None:
        k_new, v_new = kv_new                           # [B, 1, KVH, D]
        l_self = jnp.einsum(
            "bskgd,bskd->bkgs", q, k_new, preferred_element_type=jnp.float32,
        )[..., None] * scale                            # [B,KVH,G,1,1]
        if softcap > 0.0:
            l_self = jnp.tanh(l_self / softcap) * softcap
        m = jnp.maximum(jnp.max(logits, axis=-1, keepdims=True), l_self)
        w_c = jnp.exp(logits - m)
        w_s = jnp.exp(l_self - m)
        num = jnp.einsum("bkgst,btkd->bskgd", w_c.astype(v_cache.dtype),
                         v_cache, preferred_element_type=jnp.float32)
        num = num + w_s.transpose(0, 3, 1, 2, 4) * v_new.astype(jnp.float32)[
            :, :, :, None, :
        ]
        den = jnp.sum(w_c, axis=-1, keepdims=True) + w_s
        o = num / den.transpose(0, 3, 1, 2, 4)
    else:
        w = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bkgst,btkd->bskgd", w.astype(v_cache.dtype), v_cache,
                       preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, num_heads * head_dim).astype(x.dtype)
    return o @ p["wo"]


def project_kv_step(p, x, pos, *, num_kv_heads, head_dim, rope_theta=10000.0):
    """K/V for the current decode token (to be written into the cache)."""
    b = x.shape[0]
    k = (x @ p["wk"]).reshape(b, 1, num_kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(b, 1, num_kv_heads, head_dim)
    k = rope(k, pos[:, None], rope_theta)
    return k, v


# ---------------------------------------------------------------------------
# MLP (gated) and MoE
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, (d_model, d_ff)),
        "wg": dense_init(k2, (d_model, d_ff)),
        "wo": dense_init(k3, (d_ff, d_model)),
    }


def mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]


def init_moe(key, d_model, d_ff, num_experts, ff_shards: int = 1):
    """Expert weights, stored in the virtual-expert layout: each real expert
    is ``ff_shards`` slices of d_ff (exact partition of the gated-MLP sum;
    routing stays over real experts)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ev, ffv = num_experts * ff_shards, d_ff // ff_shards
    return {
        "router": dense_init(k1, (d_model, num_experts), dtype=jnp.float32),
        "wi": dense_init(k2, (ev, d_model, ffv), in_axis=1),
        "wg": dense_init(k3, (ev, d_model, ffv), in_axis=1),
        "wo": dense_init(k4, (ev, ffv, d_model), in_axis=1),
    }


def _moe_constrain(arr, act_spec, ep: bool):
    """Shard MoE dispatch buffers [B, E, cap, d]: batch over the data axes,
    experts over `model` when EP applies (unconstrained, GSPMD replicates
    the buffers — measured +20 GiB/device on mixtral)."""
    if act_spec is None:
        return arr
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = act_spec.mesh
    b0 = act_spec.spec[0] if len(act_spec.spec) else None
    dims = [None] * arr.ndim
    if b0 is not None and arr.shape[0] % _axes_size(mesh, b0) == 0:
        dims[0] = b0
    if ep:
        dims[1] = "model"
    return jax.lax.with_sharding_constraint(arr, NamedSharding(mesh, P(*dims)))


def _axes_size(mesh, axes):
    if isinstance(axes, str):
        axes = (axes,)
    import numpy as _np

    return int(_np.prod([mesh.shape[a] for a in axes]))


def moe(
    p: dict,
    x: jnp.ndarray,              # [B, S, d]
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    act_spec=None,
    ff_shards: int = 1,
) -> jnp.ndarray:
    """Top-k MoE with capacity-bounded, batch-grouped dispatch (GShard).

    Each batch row is a dispatch group with its own expert capacity, so the
    scatter/gather carries a leading [B] dim that GSPMD partitions over the
    data axes.  (A single flat [T·K, d] scatter is NOT partitionable and
    was replicated — measured 96 GiB/layer all-gathers on dbrx.)  With
    experts sharded over `model` (EP) the group->expert movement lowers to
    all-to-all-style collectives; token overflow beyond the per-group
    capacity is dropped (standard).
    """
    b, s, d = x.shape

    logits = x.astype(jnp.float32) @ p["router"]            # [B, S, E_real]
    gates_full = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(gates_full, top_k)  # [B, S, K]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    if ff_shards > 1:
        # expand to virtual experts: token routed to real expert r goes to
        # slices (r*fs .. r*fs+fs-1), each with the same gate (the combine
        # sums the slices' partial outputs — exact ff partition)
        fs = ff_shards
        gate_idx = (gate_idx[..., None] * fs
                    + jnp.arange(fs, dtype=gate_idx.dtype)).reshape(
                        b, s, top_k * fs)
        gate_vals = jnp.repeat(gate_vals, fs, axis=-1)
        top_k = top_k * fs
    e = num_experts * ff_shards
    cap = int(s * top_k * capacity_factor / e)
    cap = max(cap, top_k)

    # Position of each (token, k) within its expert's per-group capacity.
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)   # [B, S, K, E]
    flat_oh = onehot.reshape(b, s * top_k, e)
    pos_in_e = jnp.cumsum(flat_oh, axis=1) - flat_oh        # exclusive, per row
    pos = jnp.sum(pos_in_e * flat_oh, axis=-1).reshape(b, s, top_k)
    keep = pos < cap                                        # overflow dropped

    ep = act_spec is not None and e % _axes_size(act_spec.mesh, "model") == 0

    slot = gate_idx * cap + jnp.where(keep, pos, 0)         # [B,S,K] in [0,E*cap)
    w8 = jnp.where(keep, 1.0, 0.0).astype(x.dtype)
    src = (x[:, :, None, :] * w8[..., None]).reshape(b, s * top_k, d)
    # The scatter itself must be constrained batch-only: an expert/model
    # sharding on the scatter target is not partitionable (indices span all
    # experts) and GSPMD replicates the whole dispatch.  The EP boundary is
    # owned by the expert einsums below (wi/wg/wo are E-sharded over model),
    # so the model-axis movement happens on the small capacity buffers.
    buf = _moe_constrain(jnp.zeros((b, e * cap, d), x.dtype), act_spec, False)
    # vmap'd 1-D scatter => operand_batching_dims on the HLO scatter, which
    # GSPMD partitions on the batch axis.  (A 2-D scatter indexed with a
    # broadcast arange(b) column loses the batch sharding in the transpose:
    # measured 96 GiB batch-replicated all-reduce in the backward.)
    buf = jax.vmap(lambda bb, ss, vv: bb.at[ss].add(vv))(
        buf, slot.reshape(b, s * top_k), src
    )
    buf = buf.reshape(b, e, cap, d)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["wg"])) * jnp.einsum(
        "becd,edf->becf", buf, p["wi"]
    )
    h = _moe_constrain(h, act_spec, ep)
    out_buf = jnp.einsum("becf,efd->becd", h, p["wo"])
    out_buf = _moe_constrain(out_buf, act_spec, False)
    out_buf = out_buf.reshape(b, e * cap, d)

    gathered = jax.vmap(lambda ob, ss: ob[ss])(
        out_buf, slot.reshape(b, s * top_k)
    )
    gathered = _moe_constrain(gathered, act_spec, False)
    gathered = gathered.reshape(b, s, top_k, d)
    combined = jnp.sum(
        gathered * (gate_vals.astype(x.dtype) * w8)[..., None], axis=2
    )
    return combined


def moe_aux_loss(p: dict, x: jnp.ndarray, *, num_experts: int, top_k: int):
    """Load-balancing auxiliary loss (Switch/Mixtral form)."""
    t = x.shape[0] * x.shape[1]
    logits = x.reshape(t, -1).astype(jnp.float32) @ p["router"]
    gates = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(gates, top_k)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx, num_experts, dtype=jnp.float32), axis=(0, 1)
    )
    frac_prob = jnp.mean(gates, axis=0)
    return num_experts * jnp.sum(frac_tokens * frac_prob)


# ---------------------------------------------------------------------------
# Mamba2 (SSD — state space duality, arXiv:2405.21060)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SSMDims:
    d_model: int
    d_inner: int
    nheads: int
    head_dim: int
    state: int    # N
    conv: int

    @staticmethod
    def from_config(d_model, state, expand=2, head_dim=64, conv=4):
        d_inner = expand * d_model
        return SSMDims(d_model, d_inner, d_inner // head_dim, head_dim, state, conv)


def init_ssm(key, dims: SSMDims):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    zxbcdt = 2 * dims.d_inner + 2 * dims.state + dims.nheads
    return {
        "in_proj": dense_init(k1, (dims.d_model, zxbcdt)),
        "conv_w": dense_init(k2, (dims.conv, dims.d_inner + 2 * dims.state)),
        "A_log": jnp.zeros((dims.nheads,), jnp.float32),
        "D": jnp.ones((dims.nheads,), jnp.float32),
        "dt_bias": jnp.zeros((dims.nheads,), jnp.float32),
        "norm": jnp.zeros((dims.d_inner,), jnp.float32),
        "out_proj": dense_init(k3, (dims.d_inner, dims.d_model)),
    }


def _split_zxbcdt(p, u, dims: SSMDims):
    zxbcdt = u @ p["in_proj"]
    di, n, nh = dims.d_inner, dims.state, dims.nheads
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_state=None):
    """Depthwise causal conv over the sequence.  xbc: [B, S, C]."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros(xbc.shape[:1] + (k - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xbc], axis=1)  # [B, S+k-1, C]
    out = sum(
        xp[:, i : i + xbc.shape[1]] * conv_w[i][None, None, :] for i in range(k)
    )
    new_state = xp[:, -(k - 1):] if k > 1 else pad
    return jax.nn.silu(out.astype(jnp.float32)).astype(xbc.dtype), new_state


def ssd_scan(
    p: dict,
    u: jnp.ndarray,              # [B, S, d_model]
    dims: SSMDims,
    chunk: int = 128,
    init_state=None,             # ([B, nh, hp, N], conv_state) or None
):
    """Chunked SSD forward (training / prefill).

    Implements the Mamba2 block: in_proj -> causal conv -> selective state
    update, with the quadratic-intra-chunk / recurrent-inter-chunk
    decomposition.  Returns (y [B,S,d_model], (ssm_state, conv_state)).
    """
    b, s, _ = u.shape
    di, n, nh, hp = dims.d_inner, dims.state, dims.nheads, dims.head_dim
    z, xbc, dt = _split_zxbcdt(p, u, dims)
    conv_in_state = None if init_state is None else init_state[1]
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], conv_in_state)
    x, B_, C_ = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [B,S,nh]
    a = -jnp.exp(p["A_log"])                                      # [nh]
    dA = dt * a                                                   # log-decay
    xh = x.reshape(b, s, nh, hp).astype(jnp.float32)
    xdt = xh * dt[..., None]                                      # [B,S,nh,hp]
    Bf = B_.astype(jnp.float32)                                   # [B,S,N]
    Cf = C_.astype(jnp.float32)

    chunk = min(chunk, s)
    nc = s // chunk
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    r = lambda t_, tail: t_.reshape((b, nc, chunk) + tail)  # noqa: E731
    dA_c = r(dA, (nh,))
    x_c = r(xdt, (nh, hp))
    B_c = r(Bf, (n,))
    C_c = r(Cf, (n,))

    # within-chunk cumulative log decay
    lt = jnp.cumsum(dA_c, axis=2)                                 # [B,nc,Q,nh]
    # intra-chunk (quadratic in Q): M[i,j] = exp(lt_i - lt_j) for i >= j
    diff = lt[:, :, :, None, :] - lt[:, :, None, :, :]            # [B,nc,Q,Q,nh]
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))[None, None, :, :, None]
    # mask BEFORE exp: the upper triangle has large positive diffs that would
    # overflow to inf (and inf * 0 = NaN after masking)
    M = jnp.exp(jnp.where(tri, diff, NEG_INF))
    cb = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)                  # [B,nc,Q,Q]
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, M, x_c)

    # inter-chunk recurrence over states [B, nh, hp, N]
    decay_end = jnp.exp(lt[:, :, -1:, :] - lt)                    # [B,nc,Q,nh]
    chunk_states = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", decay_end, x_c, B_c)
    chunk_decay = jnp.exp(lt[:, :, -1, :])                        # [B,nc,nh]

    s0 = (
        jnp.zeros((b, nh, hp, n), jnp.float32)
        if init_state is None
        else init_state[0].astype(jnp.float32)
    )

    def step(state, inp):
        cdecay, cstate = inp  # [B,nh], [B,nh,hp,N]
        out_state = state
        state = state * cdecay[:, :, None, None] + cstate
        return state, out_state

    final_state, prev_states = jax.lax.scan(
        step,
        s0,
        (chunk_decay.swapaxes(0, 1), chunk_states.swapaxes(0, 1)),
    )
    prev_states = prev_states.swapaxes(0, 1)                      # [B,nc,nh,hp,N]
    y_inter = jnp.einsum(
        "bcqn,bcqh,bchpn->bcqhp", C_c, jnp.exp(lt), prev_states
    )

    y = (y_intra + y_inter).reshape(b, s, nh, hp)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(b, s, di)
    # gated RMSNorm (mamba2's norm-before-out_proj)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm"])
    return (y.astype(u.dtype) @ p["out_proj"]), (final_state, conv_state)


def ssd_step(p: dict, u: jnp.ndarray, state, dims: SSMDims):
    """Single-token decode: recurrent state update.  u: [B, 1, d_model]."""
    b = u.shape[0]
    di, n, nh, hp = dims.d_inner, dims.state, dims.nheads, dims.head_dim
    ssm_state, conv_state = state                                 # [B,nh,hp,N]
    z, xbc, dt = _split_zxbcdt(p, u, dims)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], conv_state)
    x, B_, C_ = jnp.split(xbc[:, 0], [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,nh]
    a = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * a)                                          # [B,nh]
    xh = x.reshape(b, nh, hp).astype(jnp.float32)
    Bf = B_.astype(jnp.float32)
    Cf = C_.astype(jnp.float32)

    upd = jnp.einsum("bhp,bn->bhpn", xh * dt[..., None], Bf)
    new_state = ssm_state.astype(jnp.float32) * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cf) + p["D"][None, :, None] * xh
    y = y.reshape(b, 1, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm"])
    return (y.astype(u.dtype) @ p["out_proj"]), (new_state.astype(ssm_state.dtype), conv_state)
