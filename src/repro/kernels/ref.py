"""Pure-jnp oracles for every Pallas kernel in this package.

Each oracle computes exactly what the kernel computes, with plain XLA ops and
no tiling — the correctness reference for the interpret-mode sweeps in
tests/.

Expiry (DESIGN.md §15) is invisible here by design: TTL-aware replay scrubs
expired lanes to EMPTY_KEY before every probe, so the probe oracles (like
the probe kernels) see only live or empty lanes and need no expiry
semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policies import Policy

NEG_INF = jnp.float32(-3.0e38)
POS_INF = jnp.float32(3.0e38)


# ---------------------------------------------------------------------------
# kway_probe oracle
# ---------------------------------------------------------------------------

def _scores(policy, keys_u32, meta_a, meta_b, now):
    a = meta_a.astype(jnp.float32)
    if policy == Policy.RANDOM:
        # The single shared definition (core/policies.victim_scores uses the
        # same call).  The Pallas kernel keeps a hand-inlined copy — a
        # pallas_call body cannot close over hashing's module-level jnp
        # constants — and the kernel-vs-oracle sweeps guard that copy
        # against drift.
        from repro.core import hashing
        h = hashing.hash_u32(keys_u32 ^ now.astype(jnp.uint32), seed=0xBADA)
        return h.astype(jnp.float32)
    if policy == Policy.HYPERBOLIC:
        age = (now - meta_b).astype(jnp.float32) + 1.0
        return a / age
    return a


def _fp_i32(keys_i32):
    """hashing.fingerprint on bit-cast int32 keys, as int32 (the kernels'
    lane dtype)."""
    from repro.core import hashing
    fp = hashing.fingerprint(keys_i32.astype(jnp.uint32))
    return fp.astype(jnp.int32)


def kway_probe_ref(keys, fprint, meta_a, meta_b, sets, qkeys, times, *,
                   policy, ways, full_order=False, need_victims=True):
    """Oracle for kernels.kway_probe (identical outputs, any kp >= ways).

    The probe applies the same fingerprint pre-filter + full-key confirm as
    the kernel (KW-WFSC Algorithm 5): with consistent fingerprints the
    result is bit-identical to a plain full-key compare, and a *stale*
    fingerprint masks the same hits in both implementations.

    With ``full_order=True`` additionally returns vorder int32 [B, kp]: the
    victim order worst-first (entries past ``ways`` hold the kp sentinel),
    matching the kernel's masked min-extraction tie-breaking exactly (stable
    argsort == iterative lowest-lane extraction).  With
    ``need_victims=False`` (the pure-get read path) only (hit, way) are
    returned and no victim scoring happens.
    """
    kp = keys.shape[1]
    lane = jnp.arange(kp, dtype=jnp.int32)[None, :]
    row_keys = keys[sets]                        # [B, kp]
    row_fpr = fprint[sets]
    valid = lane < ways
    occupied = (row_keys != -1) & valid
    eq = (row_fpr == _fp_i32(qkeys)[:, None]) & \
        (row_keys == qkeys[:, None]) & occupied
    hit = jnp.any(eq, axis=-1)
    way = jnp.min(jnp.where(eq, lane, kp), axis=-1)
    way = jnp.where(hit, way, 0)
    if not need_victims:
        return hit.astype(jnp.int32), way.astype(jnp.int32)

    row_a = meta_a[sets]
    row_b = meta_b[sets]
    sc = _scores(policy, row_keys.astype(jnp.uint32), row_a, row_b, times[:, None])
    sc = jnp.where(occupied, sc, NEG_INF)
    sc = jnp.where(valid, sc, POS_INF)
    vscore = jnp.min(sc, axis=-1, keepdims=True)
    vway = jnp.min(jnp.where(sc == vscore, lane, kp), axis=-1)
    vkey = jnp.take_along_axis(row_keys, vway[:, None], axis=-1)[:, 0]
    out = (
        hit.astype(jnp.int32),
        way.astype(jnp.int32),
        vway.astype(jnp.int32),
        vkey.astype(jnp.int32),
    )
    if full_order:
        order = jnp.argsort(sc, axis=-1).astype(jnp.int32)  # stable: lane ties
        order = jnp.where(jnp.arange(kp)[None, :] < ways, order, kp)
        out = out + (order,)
    return out


def kway_fused_probe_ref(keys, fprint, meta_a, meta_b, sets, qkeys, times_get,
                         times_put, en, *, policy, ways):
    """Oracle for kernels.kway_fused_probe: (hit, way, vorder) with the
    victim order scored on the hit-updated metadata at the put-phase times.
    Applies the kernel's fingerprint pre-filter + full-key confirm.

    The kernel applies hit transitions sequentially in batch order; the
    equivalent batched form is a scatter-add (LFU/HYPERBOLIC counts) or
    scatter-max (LRU timestamps — batch times are increasing, so the last
    sequential write IS the max).  FIFO/RANDOM take no hit transition.
    """
    kp = keys.shape[1]
    lane = jnp.arange(kp, dtype=jnp.int32)[None, :]
    row_keys = keys[sets]                        # [B, kp]
    row_fpr = fprint[sets]
    valid = lane < ways
    occupied = (row_keys != -1) & valid
    eq = (row_fpr == _fp_i32(qkeys)[:, None]) & \
        (row_keys == qkeys[:, None]) & occupied
    hit = jnp.any(eq, axis=-1)
    way = jnp.min(jnp.where(eq, lane, kp), axis=-1)

    do = hit & (en != 0)
    way_c = jnp.clip(way, 0, kp - 1)
    if policy == Policy.LRU:
        ma1 = meta_a.at[sets, way_c].max(
            jnp.where(do, times_get, -(2**31 - 1)))
    elif policy in (Policy.LFU, Policy.HYPERBOLIC):
        ma1 = meta_a.at[sets, way_c].add(jnp.where(do, 1, 0))
    else:
        ma1 = meta_a                             # FIFO / RANDOM: identity

    sc = _scores(policy, row_keys.astype(jnp.uint32), ma1[sets],
                 meta_b[sets], times_put[:, None])
    sc = jnp.where(occupied, sc, NEG_INF)
    sc = jnp.where(valid, sc, POS_INF)
    order = jnp.argsort(sc, axis=-1).astype(jnp.int32)   # stable: lane ties
    order = jnp.where(jnp.arange(kp)[None, :] < ways, order, kp)
    return (hit.astype(jnp.int32),
            jnp.where(hit, way, 0).astype(jnp.int32),
            order)


# ---------------------------------------------------------------------------
# paged_attention oracle
# ---------------------------------------------------------------------------

def paged_attention_ref(
    q: jnp.ndarray,           # [B, H, D]
    k_pages: jnp.ndarray,     # [KVH, P, page, D]  (head-major page pool)
    v_pages: jnp.ndarray,     # [KVH, P, page, D]
    page_table: jnp.ndarray,  # [B, pages_per_seq] int32
    seq_lens: jnp.ndarray,    # [B] int32
    *,
    scale: float | None = None,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Single-token decode attention over a paged KV cache (GQA).

    Gathers each sequence's pages, masks beyond seq_len, standard softmax.
    Empty sequences (seq_len == 0) return zeros, matching the kernel.
    """
    b, h, d = q.shape
    kvh, _, page, _ = k_pages.shape
    pps = page_table.shape[1]
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5

    k = k_pages[:, page_table]                   # [KVH, B, pps, page, D]
    v = v_pages[:, page_table]
    k = k.reshape(kvh, b, pps * page, d)
    v = v.reshape(kvh, b, pps * page, d)
    pos = jnp.arange(pps * page)[None, :]
    mask = pos < seq_lens[:, None]               # [B, T]

    qg = q.reshape(b, kvh, g, d)
    logits = jnp.einsum("bkgd,kbtd->bkgt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.where(mask[:, None, None, :], jnp.exp(logits - m), 0.0)
    l = jnp.sum(e, axis=-1, keepdims=True)
    w = e / jnp.where(l > 0.0, l, 1.0)           # zeros for empty sequences
    o = jnp.einsum("bkgt,kbtd->bkgd", w, v.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)
