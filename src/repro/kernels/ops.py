"""Jit'd public wrappers around the Pallas kernels.

These adapt the framework's pytree state to the kernels' padded VMEM layouts
and pick interpret mode automatically (interpret=True off-TPU, compiled on
TPU).  The rest of the framework calls only these entry points.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core.kway import KWayConfig, KWayState
from repro.kernels import kway_probe as _kp
from repro.kernels import paged_attention as _pa
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_ways(arr: jnp.ndarray, lanes: int = _kp.LANES,
              fill: int = -1) -> jnp.ndarray:
    s, k = arr.shape
    if k == lanes:
        return arr
    pad = jnp.full((s, lanes - k), fill, arr.dtype)
    return jnp.concatenate([arr, pad], axis=1)


def _probe_impl(cfg, state, qkeys, use_kernel: bool, full_order: bool,
                need_victims: bool = True):
    """Shared probe core: sanitize + route + pad to the qt=8 query tile.

    Padding with dummy probes keeps the kernel on every batch size (probing
    is read-only, so padding lanes are harmless); outputs are sliced back
    to B.  Returns (qkeys_sanitized, sets, outs) with outs = the kernel's
    output tuple, already sliced.
    """
    qkeys = hashing.sanitize_keys(qkeys)
    sets = hashing.set_index(qkeys, cfg.num_sets, cfg.seed)
    b = qkeys.shape[0]
    times = state.clock + jnp.arange(b, dtype=jnp.int32)

    keys_i = _pad_ways(state.keys.astype(jnp.int32))
    fpr = _pad_ways(state.fprint.astype(jnp.int32), fill=0)
    ma = _pad_ways(state.meta_a)
    mb = _pad_ways(state.meta_b)
    qk_i = qkeys.astype(jnp.int32)

    qt = 8
    if use_kernel:
        pad = (-b) % qt
        zpad = jnp.zeros((pad,), jnp.int32)
        outs = _kp.kway_probe(
            keys_i, fpr, ma, mb,
            jnp.concatenate([sets, zpad]),
            jnp.concatenate([qk_i, zpad]),
            jnp.concatenate([times, zpad]),
            policy=int(cfg.policy), ways=cfg.ways, qt=qt,
            interpret=not _on_tpu(), full_order=full_order,
            need_victims=need_victims,
        )
    else:
        outs = _ref.kway_probe_ref(
            keys_i, fpr, ma, mb, sets, qk_i, times,
            policy=int(cfg.policy), ways=cfg.ways, full_order=full_order,
            need_victims=need_victims,
        )
    return qkeys, sets, tuple(o[:b] for o in outs)


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("use_kernel",))
def probe(
    cfg: KWayConfig,
    state: KWayState,
    qkeys: jnp.ndarray,
    *,
    use_kernel: bool = True,
):
    """Kernel-accelerated probe of the K-way cache.

    Returns (qkeys_sanitized uint32[B], sets int32[B], hit bool[B],
    way int32[B], victim_way int32[B], victim_key uint32[B]) — the decisions
    the caller's scatter applies.  ``use_kernel=False`` selects the pure-jnp
    oracle.
    """
    qkeys, sets, (hit, way, vway, vkey) = _probe_impl(
        cfg, state, qkeys, use_kernel, full_order=False)
    return (qkeys, sets, hit.astype(jnp.bool_), way, vway,
            vkey.astype(jnp.uint32))


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("use_kernel",))
def probe_hits(
    cfg: KWayConfig,
    state: KWayState,
    qkeys: jnp.ndarray,
    *,
    use_kernel: bool = True,
):
    """Read-path probe: hit decisions only, no victim selection.

    The pure-get path never consumes victim scores, so this variant skips
    the score computation and the victim-extraction rounds entirely
    (``need_victims=False`` in the kernel).  Returns (qkeys_sanitized
    uint32[B], sets int32[B], hit bool[B], way int32[B]).
    """
    qkeys, sets, (hit, way) = _probe_impl(
        cfg, state, qkeys, use_kernel, full_order=False, need_victims=False)
    return qkeys, sets, hit.astype(jnp.bool_), way


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("use_kernel",))
def fused_probe(
    cfg: KWayConfig,
    state: KWayState,
    qkeys: jnp.ndarray,
    enabled: jnp.ndarray = None,
    *,
    use_kernel: bool = True,
):
    """Single-launch fused probe for ``access`` (get; on miss, put).

    One kernel launch serves both phases: hit decisions come from the probe,
    and the full victim order is scored inside the kernel on a hit-updated
    VMEM copy of ``meta_a`` at the put-phase timestamps (t+B+i) — exactly
    what ``probe`` followed by ``probe_orders`` on the post-get state would
    produce, at half the launches and HBM traffic.

    Returns (qkeys_sanitized uint32[B], sets int32[B], hit bool[B] (raw,
    unmasked by ``enabled``), way int32[B], order int32[B, ways]) — what
    ``core/kway.apply_access`` consumes.
    """
    qkeys = hashing.sanitize_keys(qkeys)
    sets = hashing.set_index(qkeys, cfg.num_sets, cfg.seed)
    b = qkeys.shape[0]
    times_get = state.clock + jnp.arange(b, dtype=jnp.int32)
    times_put = times_get + jnp.int32(b)
    en = (jnp.ones((b,), jnp.int32) if enabled is None
          else enabled.astype(jnp.int32))

    keys_i = _pad_ways(state.keys.astype(jnp.int32))
    fpr = _pad_ways(state.fprint.astype(jnp.int32), fill=0)
    ma = _pad_ways(state.meta_a)
    mb = _pad_ways(state.meta_b)
    qk_i = qkeys.astype(jnp.int32)

    qt = 8
    if use_kernel:
        pad = (-b) % qt
        zpad = jnp.zeros((pad,), jnp.int32)
        # padding lanes carry en=0: they must not apply hit updates
        outs = _kp.kway_fused_probe(
            keys_i, fpr, ma, mb,
            jnp.concatenate([sets, zpad]),
            jnp.concatenate([qk_i, zpad]),
            jnp.concatenate([times_get, zpad]),
            jnp.concatenate([times_put, zpad]),
            jnp.concatenate([en, zpad]),
            policy=int(cfg.policy), ways=cfg.ways, qt=qt,
            interpret=not _on_tpu(),
        )
    else:
        outs = _ref.kway_fused_probe_ref(
            keys_i, fpr, ma, mb, sets, qk_i, times_get, times_put, en,
            policy=int(cfg.policy), ways=cfg.ways,
        )
    hit, way, order = (o[:b] for o in outs)
    return (qkeys, sets, hit.astype(jnp.bool_), way, order[:, : cfg.ways])


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("use_kernel",))
def probe_orders(
    cfg: KWayConfig,
    state: KWayState,
    qkeys: jnp.ndarray,
    *,
    use_kernel: bool = True,
):
    """Kernel probe + full victim order — the PallasBackend's write phase.

    Returns (qkeys_sanitized uint32[B], sets int32[B], hit bool[B],
    way int32[B], order int32[B, ways]) where ``order`` lists each query's
    set's ways worst-victim-first, exactly what core/kway.apply_put consumes.
    Requires cfg.ways <= LANES and cfg.sample == 0 (enforced by the backend).
    """
    qkeys, sets, (hit, way, _, _, order) = _probe_impl(
        cfg, state, qkeys, use_kernel, full_order=True)
    return qkeys, sets, hit.astype(jnp.bool_), way, order[:, : cfg.ways]


def replay_resident(cfg: KWayConfig, state: KWayState, chunks, enabled,
                    tinylfu=None, sketch=None, ttls=None):
    """Whole-trace replay in ONE pallas launch (kernels/replay.py).

    ``chunks`` uint32 [steps, B] / ``enabled`` bool [steps, B] — the
    ``router.pad_chunks`` layout.  The cache state lanes stay VMEM-resident
    for the entire trace; the per-chunk transitions are bit-identical to
    scanning the chunks through the fused ``access`` (with the TinyLFU
    record → peek → admit phases of the batched replay when ``tinylfu``).
    ``ttls`` (int32 [steps, B], optional) turns on the expiry lane
    (DESIGN.md §15); requires ``state.expiry`` and excludes TinyLFU.

    Returns (hits int32 [steps], evs int32 [steps], state', sketch'|None).
    """
    from repro.kernels import replay as _rp

    hits, evs, lanes, sketch_out = _rp.replay_resident(
        state.keys, state.fprint, state.vals, state.meta_a, state.meta_b,
        state.clock,
        jnp.asarray(chunks, jnp.uint32), jnp.asarray(enabled, jnp.bool_),
        policy=int(cfg.policy), ways=cfg.ways, num_sets=cfg.num_sets,
        seed=cfg.seed, tinylfu=tinylfu, sketch=sketch,
        expiry=state.expiry, ttls=ttls,
        interpret=not _on_tpu(),
    )
    keys, fpr, vals, ma, mb, clock = lanes[:6]
    state_out = KWayState(keys=keys, fprint=fpr, vals=vals, meta_a=ma,
                          meta_b=mb, clock=clock,
                          expiry=lanes[6] if len(lanes) > 6 else None)
    return hits, evs, state_out, sketch_out


def replay_hierarchical(cfg: KWayConfig, hier, state, chunks, enabled,
                        ttls=None):
    """Whole-trace replay through the L1-over-L2 hierarchy in ONE pallas
    launch (kernels/replay.py, hierarchical megakernel).

    ``state`` is a :class:`repro.core.hierarchy.HierState`; ``chunks`` /
    ``enabled`` the ``router.pad_chunks`` layout.  Bit-identical to the
    jnp twin ``core/hierarchy.replay_l1_over_l2`` (the differential
    oracle) — same per-chunk hit/eviction counts and final tier states.
    ``ttls`` (int32 [steps, B], optional) turns on the per-lane expiry
    path (DESIGN.md §15): rows are lazily scrubbed at the batch-exit
    horizon before probing, so an expired key is never a hit on either
    tier; requires tier states built with expiry lanes.

    Returns (hits int32 [steps], evs int32 [steps], HierState', None).
    """
    from repro.core.hierarchy import HierState
    from repro.core.kway import KWayState as _KWS
    from repro.kernels import replay as _rp

    l1, l2 = state.l1, state.l2
    hits, evs, l1_f, l2_f, clock_f = _rp.replay_hierarchical(
        l1.keys, l1.fprint, l1.vals, l1.meta_a, l1.meta_b,
        l2.keys, l2.fprint, l2.vals, l2.meta_a, l2.meta_b,
        l2.clock,
        jnp.asarray(chunks, jnp.uint32), jnp.asarray(enabled, jnp.bool_),
        policy=int(cfg.policy), l1_ways=hier.l1_ways, l2_ways=cfg.ways,
        l1_sets=hier.l1_sets, l2_sets=cfg.num_sets, seed=cfg.seed,
        promote=hier.promote, demote=hier.demote,
        l1_exp=l1.expiry, l2_exp=l2.expiry, ttls=ttls,
        interpret=not _on_tpu(),
    )

    def unpack(lanes):
        k, f, v, a, b = lanes[:5]
        return _KWS(keys=k.astype(jnp.uint32), fprint=f.astype(jnp.uint32),
                    vals=v, meta_a=a, meta_b=b, clock=clock_f,
                    expiry=lanes[5] if len(lanes) > 5 else None)

    return hits, evs, HierState(l1=unpack(l1_f), l2=unpack(l2_f)), None


def attend_paged(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,
    seq_lens: jnp.ndarray,
    *,
    scale: float | None = None,
    softcap: float = 0.0,
    use_kernel: bool | None = None,
) -> jnp.ndarray:
    """Paged GQA decode attention (see kernels/paged_attention.py).

    ``use_kernel=None`` picks per accelerator: the Pallas kernel on TPU, the
    vectorized jnp reference elsewhere.  Off-TPU the kernel only runs in
    interpret mode — a per-grid-point Python loop that is a correctness
    oracle, not an execution path (a [32, 4, 32] decode grid is ~4k
    interpreted kernel evals per layer); the reference is a single fused XLA
    computation there.  Pass an explicit bool to force either path.
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        return _pa.paged_attention(
            q, k_pages, v_pages, page_table, seq_lens,
            scale=scale, softcap=softcap, interpret=not _on_tpu(),
        )
    return _ref.paged_attention_ref(
        q, k_pages, v_pages, page_table, seq_lens, scale=scale, softcap=softcap
    )
