"""Pallas TPU kernel: flash-decode attention over a K-way-managed paged KV.

The serving-side consumer of the paper's cache: KV pages live in a dense
page pool; the K-way set-associative page table (core/kway.py) decides which
pages are resident.  This kernel computes one decode step of GQA attention
for a batch of sequences whose KV is scattered across pages.

TPU design (vLLM's paged attention re-thought for the TPU pipeline):
  * Grid = (batch, kv_heads, pages_per_seq); the page axis is innermost and
    sequential, so the online-softmax accumulators live in VMEM scratch and
    survive across page steps (flash-decode).
  * The page indirection is resolved by the BlockSpec ``index_map`` reading
    the page table from **scalar prefetch** — the DMA engine fetches page
    ``page_table[b, p]`` HBM→VMEM while the previous page is being consumed.
    This is the TPU-native replacement for the GPU's gather warp: the
    indirection costs nothing on the compute path.
  * Each grid step does one [G, D] x [D, page] MXU matmul (G = q heads per
    kv head) + a VPU online-softmax update — no materialized [B, T] logits.

Numerics: accumulation in f32; masked lanes excluded via explicit where
(never exp(-inf - -inf)); empty sequences (seq_len == 0) produce zeros.

Oracle: ref.paged_attention_ref.  Sweeps in tests/test_paged_attention.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -3.0e38


def _decode_kernel(
    # scalar prefetch
    page_table_ref,   # int32 [B, PPS]
    seq_lens_ref,     # int32 [B]
    # VMEM in
    q_ref,            # [1, 1, G, D]
    k_ref,            # [1, 1, page, D]
    v_ref,            # [1, 1, page, D]
    # VMEM out
    o_ref,            # [1, 1, G, D]
    # scratch
    m_ref,            # f32 [G, 1]
    l_ref,            # f32 [G, 1]
    acc_ref,          # f32 [G, D]
    *,
    scale: float,
    softcap: float,
    page: int,
    pps: int,
):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = seq_lens_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)        # [G, D]
    k = k_ref[0, 0].astype(jnp.float32)        # [page, D]
    v = v_ref[0, 0].astype(jnp.float32)        # [page, D]

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                   # [G, page]
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap

    pos = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    valid = pos < seq_len                       # [1, page]
    logits = jnp.where(valid, logits, NEG_INF)

    m_prev = m_ref[...]                         # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    probs = jnp.where(valid, jnp.exp(logits - m_new), 0.0)  # [G, page]
    l_new = alpha * l_ref[...] + jnp.sum(probs, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        probs, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(p == pps - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "softcap", "interpret"),
)
def paged_attention(
    q: jnp.ndarray,           # [B, H, D]
    k_pages: jnp.ndarray,     # [KVH, P, page, D]  (head-major page pool)
    v_pages: jnp.ndarray,     # [KVH, P, page, D]
    page_table: jnp.ndarray,  # [B, PPS] int32
    seq_lens: jnp.ndarray,    # [B] int32
    *,
    scale: float | None = None,
    softcap: float = 0.0,
    interpret: bool = True,
) -> jnp.ndarray:
    """One decode step of paged GQA attention.  Returns [B, H, D]."""
    b, h, d = q.shape
    kvh, _, page, _ = k_pages.shape
    pps = page_table.shape[1]
    g = h // kvh
    scale = float(scale if scale is not None else d ** -0.5)

    qg = q.reshape(b, kvh, g, d)

    kernel = functools.partial(
        _decode_kernel, scale=scale, softcap=float(softcap), page=page, pps=pps
    )

    def kv_index(bi, khi, pi, table_ref, lens_ref):
        return (khi, table_ref[bi, pi], 0, 0)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, kvh, pps),
            in_specs=[
                pl.BlockSpec((1, 1, g, d), lambda bi, khi, pi, *_: (bi, khi, 0, 0)),
                pl.BlockSpec((1, 1, page, d), kv_index),
                pl.BlockSpec((1, 1, page, d), kv_index),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, g, d), lambda bi, khi, pi, *_: (bi, khi, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        interpret=interpret,
    )(page_table, seq_lens, qg, k_pages, v_pages)
    return out.reshape(b, h, d)
