"""Pallas TPU kernels for the perf-critical hot spots.

    kway_probe      — batched set probe + victim select (the paper's O(k) scan)
    paged_attention — flash-decode GQA over the K-way-managed paged KV pool
    ops             — public jit'd wrappers (auto interpret off-TPU)
    ref             — pure-jnp oracles for allclose validation
"""
