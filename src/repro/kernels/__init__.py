"""Pallas TPU kernels for the perf-critical hot spots.

    kway_probe      — batched set probe + victim select (the paper's O(k) scan)
    replay          — trace-resident replay megakernel: a whole chunked trace
                      in ONE pallas_call with the cache state pinned in VMEM
    paged_attention — flash-decode GQA over the K-way-managed paged KV pool
    ops             — public jit'd wrappers (auto interpret off-TPU)
    ref             — pure-jnp oracles for allclose validation
"""
