"""Pallas TPU megakernel: whole-trace replay in ONE ``pallas_call``.

The paper's throughput headline rests on the cache being a "short continuous
region of memory" that the hot loop keeps close to the cores.  The chunked
replay path (PR 3/4) still round-trips all five state lanes through HBM
between chunks: every chunk is a kernel launch plus an XLA scatter pass.
This kernel retires that split for the replay workload (DESIGN.md §10):

  * the grid iterates over trace *chunks*; the cache state lanes
    (``keys`` / ``fprint`` / ``vals`` / ``meta_a`` / ``meta_b``) live in VMEM
    for the entire trace — they are outputs with a constant index map,
    initialised from the input state on the first grid step and mutated
    in place until the final flush;
  * requests are streamed from HBM via a chunk-indexed BlockSpec
    (one ``[1, B]`` row of keys / set ids / enabled flags per grid step);
  * the per-chunk hit/insert transitions of ``core/kway.apply_access`` are
    applied **in-kernel** (no read-kernel/write-scatter split), bit-identical
    to the chunked-scan replay: hits update metadata sequentially in batch
    order (== the scatter-add/-max), inserts are buffered during victim
    selection so scoring always sees the post-hit / pre-insert state, then
    applied in batch order (== the packed insert scatter);
  * the TinyLFU admission phases (record → peek victim → admit) run
    in-kernel on a VMEM-resident sketch, replicating the batched
    ``admission.record``/``admit`` semantics (pre-chunk doorkeeper reads,
    max-merged counter increments, post-chunk aging);
  * the only per-step outputs are two scalar counters (hits, evictions) —
    one int32 each per chunk.

Equivalence contract: for any trace, ``replay_resident`` produces the same
per-chunk hit counts, eviction counts and final state as scanning the same
chunks through ``CacheBackend.access`` (the fused path) with the TinyLFU
phases of ``simulate._replay_batched_scan``.  tests/test_resident.py pins
this across all pallas-supported policies × ±TinyLFU.

Payload convention: the replay workload stores ``val == key`` (as int32),
matching every replay loop in this repo; the kernel derives values from the
key stream instead of carrying a third stream.
"""
from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.kway import NO_EXPIRY
from repro.core.policies import Policy
from repro.kernels.kway_probe import (LANES, NEG_INF, POS_INF,
                                      _fingerprint_i32, _hash_u32,
                                      _scores_for_policy)

# Trace/launch tally (same pattern as eval/runner.py): the jitted wrapper
# bumps ("trace", ...) once per XLA compilation and ("launch", ...) once per
# dispatch, so tests can assert "a whole replay is exactly one compile and
# one launch" instead of trusting the docstring.
_TRACE_COUNTS: collections.Counter = collections.Counter()


def trace_counts() -> dict:
    """Compile/launch tally of the replay megakernel, keyed by
    (kind, policy, S, ways, steps, batch, tinylfu)."""
    return dict(_TRACE_COUNTS)


def reset_trace_counts() -> None:
    _TRACE_COUNTS.clear()


def _lane_read(row_ref, blane, i):
    """Scalar read of column ``i`` from a [1, Bp] row ref via a masked
    reduce — no dynamic VMEM addressing, just one VPU select+sum."""
    return jnp.sum(jnp.where(blane == i, row_ref[...], 0))


def _row_select(row, lane, idx):
    """Scalar read of column ``idx`` from an in-register [1, N] row."""
    return jnp.sum(jnp.where(lane == idx, row, 0))


def _replay_kernel(
    # scalar prefetch
    scal_ref,            # int32 [2]  (initial clock, initial sketch additions)
    # VMEM inputs
    qk_ref,              # int32 [1, Bp]  sanitized query keys (chunk t)
    sets_ref,            # int32 [1, Bp]  set index per query
    en_ref,              # int32 [1, Bp]  1 = live lane
    keys0_ref,           # int32 [S, kp]  initial state lanes
    fpr0_ref,
    vals0_ref,
    ma0_ref,
    mb0_ref,
    *rest,
    policy: int,
    ways: int,
    batch: int,
    tl: tuple | None,    # (width, door_bits, sample) or None
    ttl: bool,           # expiry lane + per-request TTL stream present
    empty_key: int,
):
    # remaining refs: [tt, exp0] + [pk0, dr0] + outputs + scratch — unpack
    # by shape of the static configuration.  With ``ttl`` False nothing
    # TTL-related is in the argument list, so the compiled graph is the
    # pre-expiry kernel verbatim.
    k = 0
    if ttl:
        tt_ref, exp0_ref = rest[k], rest[k + 1]
        k += 2
    if tl is not None:
        pk0_ref, dr0_ref = rest[k], rest[k + 1]
        k += 2
    hits_ref, evs_ref = rest[k], rest[k + 1]
    keys_ref, fpr_ref, vals_ref, ma_ref, mb_ref = rest[k + 2:k + 7]
    k += 7
    if ttl:
        exp_ref = rest[k]
        k += 1
    if tl is not None:
        pk_ref, dr_ref, adds_ref = rest[k], rest[k + 1], rest[k + 2]
        k += 3
    ins_s, ins_w, ins_k, ins_t = rest[k:k + 4]
    k += 4
    if ttl:
        ins_e = rest[k]
        k += 1
    if tl is not None:
        adm_row, pk_new, dr_delta = rest[k], rest[k + 1], rest[k + 2]

    t = pl.program_id(0)
    base = scal_ref[0] + jnp.int32(2 * batch) * t   # chunk t's clock origin
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)
    valid_way = lane < ways
    bp = qk_ref.shape[1]
    blane = jax.lax.broadcasted_iota(jnp.int32, (1, bp), 1)

    # ---- first grid step: pull the initial state into the resident buffers
    @pl.when(t == 0)
    def _init():
        keys_ref[...] = keys0_ref[...]
        fpr_ref[...] = fpr0_ref[...]
        vals_ref[...] = vals0_ref[...]
        ma_ref[...] = ma0_ref[...]
        mb_ref[...] = mb0_ref[...]
        if ttl:
            exp_ref[...] = exp0_ref[...]
        if tl is not None:
            pk_ref[...] = pk0_ref[...]
            dr_ref[...] = dr0_ref[...]
            adds_ref[0] = scal_ref[1]

    # ---- chunk-entry expiry scrub (kway.scrub_expired semantics): reclaim
    # every lane whose deadline falls at or before the chunk-exit clock
    # BEFORE any probe, so an expired key is never a hit and its lane
    # scores as empty — the preferred victim.  Reclaim is not an eviction.
    if ttl:
        horizon = base + jnp.int32(2 * batch)
        occ_all = (keys_ref[...] != empty_key) & valid_way
        dead = occ_all & (exp_ref[...] <= horizon)
        keys_ref[...] = jnp.where(dead, empty_key, keys_ref[...])
        fpr_ref[...] = jnp.where(dead, 0, fpr_ref[...])
        vals_ref[...] = jnp.where(dead, 0, vals_ref[...])
        ma_ref[...] = jnp.where(dead, 0, ma_ref[...])
        mb_ref[...] = jnp.where(dead, 0, mb_ref[...])
        exp_ref[...] = jnp.where(dead, NO_EXPIRY, exp_ref[...])

    def probe(s, qk):
        """Probe one set row: fingerprint pre-filter, full-key confirm.
        Returns (hit bool, way i32, row_keys [1,kp], occupied [1,kp])."""
        row_keys = keys_ref[pl.ds(s, 1), :]
        row_fpr = fpr_ref[pl.ds(s, 1), :]
        occupied = (row_keys != empty_key) & valid_way
        qfp = _fingerprint_i32(qk.astype(jnp.uint32))
        eq = (row_fpr == qfp) & (row_keys == qk) & occupied
        hit = jnp.any(eq)
        way = jnp.min(jnp.where(eq, lane, LANES))
        return hit, way, row_keys, occupied

    def masked_scores(row_keys, row_a, row_b, occupied, now):
        sc = _scores_for_policy(policy, row_keys, row_a, row_b, now)
        sc = jnp.where(occupied, sc, NEG_INF)    # empty ways evict first
        return jnp.where(valid_way, sc, POS_INF)  # padding ways never

    # ------------------------------------------------------------------
    # TinyLFU phase A: record the whole chunk (admission.record semantics:
    # doorkeeper reads against the PRE-chunk door, counter increments
    # computed on PRE-chunk counters and max-merged, then one aging check).
    # ------------------------------------------------------------------
    if tl is not None:
        width, door_bits, sample = tl
        wp = pk_ref.shape[1]
        wlane = jax.lax.broadcasted_iota(jnp.int32, (1, wp), 1)
        dp = dr_ref.shape[1]
        dlane = jax.lax.broadcasted_iota(jnp.int32, (1, dp), 1)

        def sketch_pos(key_u32):
            """(door word/bit, per-row counter word/shift) for one key."""
            dh = _hash_u32(key_u32, 0xD00E) & jnp.uint32(door_bits - 1)
            dword = (dh >> 5).astype(jnp.int32)
            dbit = dh & jnp.uint32(31)
            rows = []
            for r in range(4):
                idx = _hash_u32(key_u32, 0xA000 + r) & jnp.uint32(width - 1)
                rows.append(((idx >> 3).astype(jnp.int32),
                             (idx & jnp.uint32(7)) * jnp.uint32(4)))
            return dword, dbit, rows

        def door_bit(dword, dbit):
            cur = _row_select(dr_ref[...], dlane, dword).astype(jnp.uint32)
            return ((cur >> dbit) & jnp.uint32(1)).astype(jnp.int32)

        def estimate(key_u32):
            """admission.estimate on the resident sketch: min over the 4
            count-min rows + the doorkeeper bit."""
            dword, dbit, rows = sketch_pos(key_u32)
            est = jnp.int32(0x7FFFFFFF)
            for r, (word, shift) in enumerate(rows):
                cur = _row_select(pk_ref[pl.ds(r, 1), :], wlane,
                                  word).astype(jnp.uint32)
                nib = ((cur >> shift) & jnp.uint32(0xF)).astype(jnp.int32)
                est = jnp.minimum(est, nib)
            return est + door_bit(dword, dbit)

        dr_delta[...] = jnp.zeros_like(dr_delta)
        pk_new[...] = pk_ref[...]

        def rec_body(i, adds_inc):
            en_i = _lane_read(en_ref, blane, i)
            live = en_i != 0
            key_u = _lane_read(qk_ref, blane, i).astype(jnp.uint32)
            dword, dbit, rows = sketch_pos(key_u)
            in_door = door_bit(dword, dbit) != 0
            # admission.record scatter-SETs ``pre | dmask`` per lane, so for
            # duplicate door words only the LAST enabled lane's bit survives
            # the chunk (the documented batched coalescing).  Overwrite —
            # don't OR — the word's delta to replicate that bit-for-bit.
            bit = (jnp.uint32(1) << dbit).astype(dr_delta.dtype)
            dr_delta[...] = jnp.where((dlane == dword) & live, bit,
                                      dr_delta[...])
            for r, (word, shift) in enumerate(rows):
                row_pre = pk_ref[pl.ds(r, 1), :]
                cur = _row_select(row_pre, wlane, word).astype(jnp.uint32)
                nib = (cur >> shift) & jnp.uint32(0xF)
                do_inc = live & in_door & (nib < jnp.uint32(15))
                neww = cur + (jnp.uint32(1) << shift)
                row_acc = pk_new[pl.ds(r, 1), :]
                upd = (wlane == word) & do_inc
                # the scatter-max of admission.record compares whole words
                # as uint32 — merge in that domain (a set nibble 7 makes the
                # int32 view negative)
                merged = jnp.maximum(row_acc.astype(jnp.uint32),
                                     neww).astype(jnp.int32)
                pk_new[pl.ds(r, 1), :] = jnp.where(upd, merged, row_acc)
            return adds_inc + en_i

        adds_inc = jax.lax.fori_loop(0, batch, rec_body, jnp.int32(0))
        dr_ref[...] = dr_ref[...] | dr_delta[...]
        for r in range(4):
            pk_ref[pl.ds(r, 1), :] = pk_new[pl.ds(r, 1), :]
        adds = adds_ref[0] + adds_inc
        aged = adds >= jnp.int32(sample)
        adds_ref[0] = jnp.where(aged, jnp.int32(0), adds)
        # TinyLFU reset: halve every 4-bit counter, clear the doorkeeper
        halved = jnp.right_shift(
            pk_ref[...].astype(jnp.uint32), jnp.uint32(1)
        ) & jnp.uint32(0x77777777)
        pk_ref[...] = jnp.where(aged, halved.astype(jnp.int32), pk_ref[...])
        dr_ref[...] = jnp.where(aged, jnp.zeros_like(dr_ref), dr_ref[...])

        # ---- TinyLFU phase B: peek each lane's prospective victim on the
        # PRE-hit state at time base+i and gate admission on the
        # post-record sketch (the phase order of the chunked scan).
        def adm_body(i, _):
            qk = _lane_read(qk_ref, blane, i)
            s = _lane_read(sets_ref, blane, i)
            hit, _, row_keys, occupied = probe(s, qk)
            row_a = ma_ref[pl.ds(s, 1), :]
            row_b = mb_ref[pl.ds(s, 1), :]
            sc = masked_scores(row_keys, row_a, row_b, occupied, base + i)
            vway = jnp.min(jnp.where(sc == jnp.min(sc), lane, LANES))
            vkey = _row_select(row_keys, lane, vway)
            vvalid = (vkey != empty_key) & ~hit
            ce = estimate(qk.astype(jnp.uint32))
            ve = estimate(vkey.astype(jnp.uint32))
            ok = (~vvalid) | (ce > ve)
            adm_row[...] = jnp.where(blane == i, ok.astype(jnp.int32),
                                     adm_row[...])
            return 0

        jax.lax.fori_loop(0, batch, adm_body, 0)

    # ------------------------------------------------------------------
    # hit phase (apply_access get semantics at times base+i): sequential
    # on_hit transitions == the batched scatter-add (LFU/HYPERBOLIC) and
    # scatter-max (LRU — batch times are increasing).
    # ------------------------------------------------------------------
    def hit_body(i, hits_acc):
        qk = _lane_read(qk_ref, blane, i)
        s = _lane_read(sets_ref, blane, i)
        en_i = _lane_read(en_ref, blane, i)
        hit, way, _, _ = probe(s, qk)
        if policy not in (Policy.FIFO, Policy.RANDOM):  # on_hit is identity
            do = hit & (en_i != 0)
            row_a = ma_ref[pl.ds(s, 1), :]
            upd = lane == way            # all-false when way == LANES
            if policy == Policy.LRU:
                new_a = jnp.where(upd, base + i, row_a)
            else:                        # LFU / HYPERBOLIC: count += 1
                new_a = jnp.where(upd, row_a + 1, row_a)
            ma_ref[pl.ds(s, 1), :] = jnp.where(do, new_a, row_a)
        return hits_acc + (hit & (en_i != 0)).astype(jnp.int32)

    hits = jax.lax.fori_loop(0, batch, hit_body, jnp.int32(0))

    # ------------------------------------------------------------------
    # insert phase (apply_access miss semantics at times base+batch+i).
    # Inserts are *buffered*: victim scoring must see the post-hit /
    # pre-insert state (exactly what the batched _victim_order_arrays
    # scores), so the state lanes stay untouched until the apply loop.
    # The buffers double as the conflict resolution of _resolve_inserts:
    #   * dedupe — a key already buffered was this batch's first
    #     occurrence (keys lanes are pre-chunk, so a re-probe cannot see
    #     it; the buffer scan is the CAS-race outcome);
    #   * rank  — the number of buffered inserts into the same set, and
    #     the rank-th lane takes the rank-th worst victim of ITS OWN
    #     victim order (per-lane put timestamps — RANDOM/HYPERBOLIC
    #     orders are time-dependent);
    #   * cap   — rank >= ways lanes are not admitted.
    # ------------------------------------------------------------------
    ins_s[...] = jnp.full_like(ins_s, -1)   # -1 never matches a real set
    ins_k[...] = jnp.full_like(ins_k, -1)   # sanitized keys are never -1

    def ins_body(i, carry):
        n, evs = carry
        qk = _lane_read(qk_ref, blane, i)
        s = _lane_read(sets_ref, blane, i)
        en_i = _lane_read(en_ref, blane, i)
        adm_i = (_lane_read(adm_row, blane, i) if tl is not None
                 else jnp.int32(1))
        hit, _, row_keys, occupied = probe(s, qk)
        dup = jnp.any(ins_k[...] == qk)
        rank = jnp.sum((ins_s[...] == s).astype(jnp.int32))
        do = (~hit) & (en_i != 0) & (adm_i != 0) & (~dup) & (rank < ways)

        t_put = base + jnp.int32(batch) + i
        row_a = ma_ref[pl.ds(s, 1), :]
        row_b = mb_ref[pl.ds(s, 1), :]
        work = masked_scores(row_keys, row_a, row_b, occupied, t_put)
        # rank-th worst victim: `ways` rounds of masked min-extraction,
        # keeping the round that matches this lane's rank (ties break
        # toward the lowest lane — the stable argsort of the jnp path).
        vway = jnp.int32(0)
        for r in range(ways):
            m = jnp.min(work)
            w = jnp.min(jnp.where(work == m, lane, LANES))
            vway = jnp.where(jnp.int32(r) == rank, w, vway)
            work = jnp.where(lane == w, POS_INF, work)

        evk = _row_select(row_keys, lane, vway)
        ev = do & (evk != empty_key)

        # buffer slot n (no-op when ~do: the sentinel column Bp matches
        # no lane)
        slot = jnp.where(do, n, jnp.int32(bp))
        sel = blane == slot
        ins_s[...] = jnp.where(sel, s, ins_s[...])
        ins_w[...] = jnp.where(sel, vway, ins_w[...])
        ins_k[...] = jnp.where(sel, qk, ins_k[...])
        ins_t[...] = jnp.where(sel, t_put, ins_t[...])
        if ttl:
            # insert deadline = chunk base + 2B + ttl (kway.insert_deadlines)
            tt_i = _lane_read(tt_ref, blane, i)
            dl = jnp.where(tt_i > 0, base + jnp.int32(2 * batch) + tt_i,
                           jnp.int32(NO_EXPIRY))
            ins_e[...] = jnp.where(sel, dl, ins_e[...])
        return n + do.astype(jnp.int32), evs + ev.astype(jnp.int32)

    n_ins, evs = jax.lax.fori_loop(0, batch, ins_body,
                                   (jnp.int32(0), jnp.int32(0)))

    # ---- apply the buffered inserts in batch order (== the packed insert
    # scatter of apply_access; duplicate (set, way) pairs resolve
    # last-write-wins in batch order, matching the XLA scatter)
    def app_body(j, _):
        live = j < n_ins
        s = jnp.where(live, _lane_read(ins_s, blane, j), 0)
        w = _lane_read(ins_w, blane, j)
        key = _lane_read(ins_k, blane, j)
        t_put = _lane_read(ins_t, blane, j)
        upd = (lane == w) & live
        fp = _fingerprint_i32(key.astype(jnp.uint32))
        # on_insert metadata (policies.on_insert, specialized statically)
        if policy in (Policy.LRU, Policy.FIFO):
            ia, ib = t_put, jnp.int32(0)
        elif policy == Policy.LFU:
            ia, ib = jnp.int32(1), jnp.int32(0)
        elif policy == Policy.RANDOM:
            ia, ib = jnp.int32(0), jnp.int32(0)
        else:                                   # HYPERBOLIC: (n=1, t0=now)
            ia, ib = jnp.int32(1), t_put
        writes = [(keys_ref, key), (fpr_ref, fp), (vals_ref, key),
                  (ma_ref, ia), (mb_ref, ib)]
        if ttl:
            writes.append((exp_ref, _lane_read(ins_e, blane, j)))
        for ref, val in writes:
            row = ref[pl.ds(s, 1), :]
            ref[pl.ds(s, 1), :] = jnp.where(upd, val, row)
        return 0

    jax.lax.fori_loop(0, batch, app_body, 0)

    hits_ref[0] = hits
    evs_ref[0] = evs


@functools.partial(
    jax.jit,
    static_argnames=("policy", "ways", "num_sets", "seed", "tl", "ttl",
                     "interpret"))
def _replay_resident_jit(
    keys, fpr, vals, ma, mb, clock,      # state (unpadded [S, ways] lanes)
    chunks, enabled,                     # uint32 [T, B], bool [T, B]
    pk, dr, adds,                        # sketch arrays (dummies when tl None)
    exp, tt,                             # expiry lane + ttl stream (ttl only)
    *,
    policy: int,
    ways: int,
    num_sets: int,
    seed: int,
    tl: tuple | None,                    # (width, door_bits, sample) | None
    ttl: bool,
    interpret: bool,
):
    steps, batch = chunks.shape
    _TRACE_COUNTS[("trace", int(policy), num_sets, ways, steps, batch,
                   tl is not None)] += 1

    # ---- streams: sanitize + route once, pad columns to the 128-lane width
    from repro.core import hashing
    qk = hashing.sanitize_keys(chunks.reshape(-1))
    sets = hashing.set_index(qk, num_sets, seed).reshape(steps, batch)
    qk = qk.astype(jnp.int32).reshape(steps, batch)
    en = enabled.astype(jnp.int32)
    bp = -(-batch // LANES) * LANES
    if bp != batch:
        pad = jnp.zeros((steps, bp - batch), jnp.int32)
        qk = jnp.concatenate([qk, pad], axis=1)
        sets = jnp.concatenate([sets, pad], axis=1)
        en = jnp.concatenate([en, pad], axis=1)

    # ---- state lanes: pad ways to the LANES register width, bit-cast int32
    def pad_ways(arr, fill):
        s, k = arr.shape
        if k == LANES:
            return arr.astype(jnp.int32)
        return jnp.concatenate(
            [arr.astype(jnp.int32),
             jnp.full((s, LANES - k), fill, jnp.int32)], axis=1)

    keys_i = pad_ways(keys, -1)
    fpr_i = pad_ways(fpr, 0)
    vals_i = pad_ways(vals, 0)
    ma_i = pad_ways(ma, 0)
    mb_i = pad_ways(mb, 0)
    s = keys_i.shape[0]

    scal = jnp.stack([clock.astype(jnp.int32), adds.astype(jnp.int32)])

    kernel = functools.partial(
        _replay_kernel, policy=int(policy), ways=ways, batch=batch,
        tl=tl, ttl=ttl, empty_key=-1)

    chunk_row = lambda: pl.BlockSpec((1, bp), lambda t, *_: (t, 0))  # noqa: E731
    full = lambda a: pl.BlockSpec(a.shape, lambda t, *_: (0,) * a.ndim)  # noqa: E731
    cnt = lambda: pl.BlockSpec((1,), lambda t, *_: (t,))  # noqa: E731

    in_arrays = [qk, sets, en, keys_i, fpr_i, vals_i, ma_i, mb_i]
    in_specs = [chunk_row(), chunk_row(), chunk_row(),
                full(keys_i), full(fpr_i), full(vals_i), full(ma_i),
                full(mb_i)]
    out_shape = [jax.ShapeDtypeStruct((steps,), jnp.int32),
                 jax.ShapeDtypeStruct((steps,), jnp.int32)] + [
        jax.ShapeDtypeStruct((s, LANES), jnp.int32) for _ in range(5)]
    out_specs = [cnt(), cnt()] + [full(keys_i) for _ in range(5)]
    scratch = [pltpu.VMEM((1, bp), jnp.int32) for _ in range(4)]

    if ttl:
        # ttl stream padded like the other chunk rows; expiry lane padded
        # to the register width with NO_EXPIRY (padding ways never expire)
        tt_i = tt.astype(jnp.int32)
        if bp != batch:
            tt_i = jnp.concatenate(
                [tt_i, jnp.zeros((steps, bp - batch), jnp.int32)], axis=1)
        exp_i = pad_ways(exp, NO_EXPIRY)
        in_arrays += [tt_i, exp_i]
        in_specs += [chunk_row(), full(exp_i)]
        out_shape += [jax.ShapeDtypeStruct((s, LANES), jnp.int32)]
        out_specs += [full(exp_i)]
        scratch += [pltpu.VMEM((1, bp), jnp.int32)]       # ins_e

    if tl is not None:
        pk_i = pk.astype(jnp.int32)
        dr_i = dr.astype(jnp.int32)
        in_arrays += [pk_i, dr_i]
        in_specs += [full(pk_i), full(dr_i)]
        out_shape += [jax.ShapeDtypeStruct(pk_i.shape, jnp.int32),
                      jax.ShapeDtypeStruct(dr_i.shape, jnp.int32),
                      jax.ShapeDtypeStruct((1,), jnp.int32)]
        out_specs += [full(pk_i), full(dr_i),
                      pl.BlockSpec((1,), lambda t, *_: (0,))]
        scratch += [pltpu.VMEM((1, bp), jnp.int32),       # adm_row
                    pltpu.VMEM(pk_i.shape, jnp.int32),    # pk_new
                    pltpu.VMEM(dr_i.shape, jnp.int32)]    # dr_delta

    outs = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(steps,),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=scratch,
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(scal, *in_arrays)

    hits, evs = outs[0], outs[1]
    keys_f, fpr_f, vals_f, ma_f, mb_f = outs[2:7]
    unpad = lambda a: a[:, :ways]  # noqa: E731
    state_out = (unpad(keys_f).astype(jnp.uint32),
                 unpad(fpr_f).astype(jnp.uint32),
                 unpad(vals_f), unpad(ma_f), unpad(mb_f),
                 clock + jnp.int32(2 * batch * steps))
    idx = 7
    if ttl:
        state_out = state_out + (unpad(outs[idx]),)
        idx += 1
    if tl is not None:
        sketch_out = (outs[idx].astype(jnp.uint32),
                      outs[idx + 1].astype(jnp.uint32),
                      outs[idx + 2][0])
    else:
        sketch_out = None
    return hits, evs, state_out, sketch_out


def replay_resident(
    keys, fpr, vals, ma, mb, clock,
    chunks, enabled,
    *,
    policy: int,
    ways: int,
    num_sets: int,
    seed: int,
    tinylfu=None,                 # TinyLFUConfig | None
    sketch=None,                  # TinyLFUState | None (fresh when None)
    expiry=None,                  # int32 [S, ways] | None
    ttls=None,                    # int32 [T, B] | None
    interpret: bool = True,
):
    """Run the replay megakernel: ONE launch for the whole chunked trace.

    ``ttls`` (with the state's ``expiry`` lane) turns on the expiry path
    (DESIGN.md §15): chunk-entry scrub + deadline-stamped inserts, kept in
    a VMEM-resident sixth lane; excludes TinyLFU.  Returns (hits int32
    [steps], evs int32 [steps], (keys, fprint, vals, meta_a, meta_b,
    clock[, expiry]) final state lanes, TinyLFUState' | None).
    """
    from repro.core import admission

    steps, batch = chunks.shape
    ttl = ttls is not None
    if ttl:
        if tinylfu is not None:
            raise ValueError(
                "per-request TTLs and TinyLFU admission are mutually "
                "exclusive (the sketch has no expiry-aware semantics)")
        if expiry is None:
            raise ValueError(
                "replay_resident: ttls given but no expiry lane — build "
                "the state with make_cache(cfg, ttl=True)")
    if tinylfu is not None:
        if sketch is None:
            sketch = admission.make_sketch(tinylfu)
        pk, dr, adds = (sketch.packed, sketch.door[None, :],
                        sketch.additions)
        tl = (tinylfu.width, tinylfu.door_bits, tinylfu.sample)
        # pad sketch rows to the 128-lane register width
        wp = -(-pk.shape[1] // LANES) * LANES
        if wp != pk.shape[1]:
            pk = jnp.concatenate(
                [pk, jnp.zeros((pk.shape[0], wp - pk.shape[1]), pk.dtype)],
                axis=1)
        dpad = -(-dr.shape[1] // LANES) * LANES
        dw = dr.shape[1]
        if dpad != dw:
            dr = jnp.concatenate(
                [dr, jnp.zeros((1, dpad - dw), dr.dtype)], axis=1)
    else:
        tl = None
        pk = jnp.zeros((4, LANES), jnp.uint32)
        dr = jnp.zeros((1, LANES), jnp.uint32)
        adds = jnp.zeros((), jnp.int32)
        dw = 0

    _TRACE_COUNTS[("launch", int(policy), num_sets, ways, steps, batch,
                   tinylfu is not None)] += 1
    if ttl:
        exp_in = jnp.asarray(expiry, jnp.int32)
        tt_in = jnp.asarray(ttls, jnp.int32)
    else:
        exp_in = jnp.zeros((), jnp.int32)     # unused dummies (DCE'd)
        tt_in = jnp.zeros((), jnp.int32)
    hits, evs, state_out, sketch_out = _replay_resident_jit(
        keys, fpr, vals, ma, mb, clock, chunks, enabled, pk, dr, adds,
        exp_in, tt_in,
        policy=int(policy), ways=ways, num_sets=num_sets, seed=seed,
        tl=tl, ttl=ttl, interpret=interpret)

    if tinylfu is not None:
        pk_f, dr_f, adds_f = sketch_out
        sketch_out = admission.TinyLFUState(
            packed=pk_f[:, :tinylfu.width // 8],
            door=dr_f[0, :dw], additions=adds_f)
    return hits, evs, state_out, sketch_out


# ===========================================================================
# hierarchical megakernel: VMEM-resident L1 over HBM-resident L2
# ===========================================================================
#
# Past RESIDENT_VMEM_BUDGET the flat kernel above cannot run — its five
# state lanes no longer fit in VMEM.  The hierarchical variant keeps only a
# small high-associativity L1 resident as ONE packed int32 [l1_sets, ROW_W]
# array (five state sections + the scalar mailbox, see core/hierarchy.py)
# and leaves the full L2 in slow memory (``memory_space=ANY``) in the same
# packed layout, so a set's whole row moves in a single DMA.  Per lane the
# kernel runs the SAME four phase transitions as the jnp twin — L1 hit,
# L2 hit/promote, L1 fill, L2 demote — fetching one row, storing its
# replacement, and reading cross-phase scalars back from the stored row's
# mailbox (the in-place-update discipline core/hierarchy.py documents).
# The hot path (L1 hits) touches HBM only for the row round-trips of
# misses — the paper's "short continuous region of memory" argument
# applied to the HBM→VMEM hierarchy itself.
#
# Equivalence contract: bit-identical per-chunk hit/eviction counts and
# final tier states vs ``core/hierarchy.replay_l1_over_l2`` (the jitted
# chunked-scan twin) — pinned by tests/test_hierarchy.py.

def _hier_replay_kernel(
    # scalar prefetch
    scal_ref,            # int32 [1]  initial clock
    # VMEM inputs
    qk_ref,              # int32 [1, Bp]  sanitized query keys (chunk t)
    s1_ref,              # int32 [1, Bp]  L1 set index per query
    s2_ref,              # int32 [1, Bp]  L2 set index per query
    en_ref,              # int32 [1, Bp]  1 = live lane
    tt_ref,              # int32 [1, Bp]  per-request TTL (zeros w/o ttl)
    l1in_ref,            # int32 [S1, ROW_W]  packed L1 rows (initial)
    l2in_ref,            # ANY   [S2, ROW_W]  packed L2 rows (initial)
    # outputs
    hits_ref,            # int32 [1]  per-chunk hits
    evs_ref,             # int32 [1]  per-chunk evictions
    l1_ref,              # int32 [S1, ROW_W]  packed L1 rows (resident)
    l2out_ref,           # ANY   [S2, ROW_W]  packed L2 rows (resident)
    # scratch
    rowA,                # VMEM [1, ROW_W]  DMA staging row
    sem,                 # DMA semaphore
    *,
    policy: int,
    l1_ways: int,
    l2_ways: int,
    l2_sets: int,
    seed: int,
    batch: int,
    promote: bool,
    demote: bool,
    ttl: bool,
    interpret: bool,
):
    from repro.core.hierarchy import (SC_DA, SC_DB, SC_DE, SC_DF, SC_DK,
                                      SC_DV, SC_DVALID, SC_EV, SC_HIT1,
                                      SC_L2HIT, SC_PA, SC_PB, SC_PEXP,
                                      SC_PVAL, _l1_fill_row, _l1_hit_row,
                                      _l2_demote_row, _l2_hit_row, _sc_get,
                                      _set_index_i32)

    t = pl.program_id(0)
    base = scal_ref[0] + jnp.int32(2 * batch) * t
    bp = qk_ref.shape[1]
    blane = jax.lax.broadcasted_iota(jnp.int32, (1, bp), 1)
    # chunk-exit clock: the lazy-scrub horizon and deadline base (§15)
    hz = base + jnp.int32(2 * batch) if ttl else None

    # ---- first grid step: L1 into VMEM, L2 packed rows into the resident
    # slow-memory buffer (one whole-array DMA)
    @pl.when(t == 0)
    def _init():
        l1_ref[...] = l1in_ref[...]
        cp = pltpu.make_async_copy(l2in_ref, l2out_ref, sem)
        cp.start()
        cp.wait()

    # ---- L2 row glue.  The interpret path indexes the resident ref
    # directly (the emulator charges ~30 µs per DMA op, which would
    # dominate the lane loop); the TPU path stages the row through VMEM
    # scratch with real DMAs.  ``store`` returns the POST-store row —
    # the lane loop is sequential, so on the DMA path the value just
    # written IS the post-store row and no read-back is needed.
    if interpret:
        def fetch_l2(s, scratch):
            return l2out_ref[pl.ds(s, 1), :]

        def store_l2(s, scratch, row):
            l2out_ref[pl.ds(s, 1), :] = row
            return l2out_ref[pl.ds(s, 1), :]
    else:
        def fetch_l2(s, scratch):
            cp = pltpu.make_async_copy(l2out_ref.at[pl.ds(s, 1), :],
                                       scratch.at[pl.ds(0, 1), :], sem)
            cp.start()
            cp.wait()
            return scratch[...]

        def store_l2(s, scratch, row):
            scratch[...] = row
            cp = pltpu.make_async_copy(scratch.at[pl.ds(0, 1), :],
                                       l2out_ref.at[pl.ds(s, 1), :], sem)
            cp.start()
            cp.wait()
            return row

    # ---- sequential lane loop (hierarchy semantics: lane i sees lane
    # i-1's inserts; see core/hierarchy.py).  Lane i runs as steps 2i
    # (phases A+B) and 2i+1 (phases C+D) — the twin's even/odd interleave
    # verbatim, so each step does ONE row round-trip per tier (on the
    # interpret path a second round-trip on the same buffer would
    # re-introduce the defensive full-array copy) and cross-phase scalars
    # ride the loop carry / the stored row's mailbox.
    def body(step, carry):
        hits, evs, hit1_c, l2_c, pval_c, pa_c, pb_c, pexp_c = carry
        i = step >> 1
        is_even = (step & jnp.int32(1)) == 0
        qk = _lane_read(qk_ref, blane, i)
        s1 = _lane_read(s1_ref, blane, i)
        s2 = _lane_read(s2_ref, blane, i)
        en = _lane_read(en_ref, blane, i) != 0
        fp = _fingerprint_i32(qk.astype(jnp.uint32))
        t_get = base + i
        t_put = base + jnp.int32(batch) + i
        if ttl:
            tt_i = _lane_read(tt_ref, blane, i)
            dl_i = jnp.where(tt_i > 0, hz + tt_i, jnp.int32(NO_EXPIRY))
        else:
            dl_i = None

        # L1 round-trip: phase A (even) / phase C (odd), both on s1
        r1 = l1_ref[pl.ds(s1, 1), :]
        row_a = _l1_hit_row(policy, r1, qk, fp, t_get, en, l1_ways,
                            ttl=ttl, horizon=hz)
        row_c = _l1_fill_row(policy, promote, r1, qk, fp, hit1_c != 0,
                             l2_c != 0, pval_c, pa_c, pb_c, t_put, en,
                             l1_ways, ttl=ttl, horizon=hz, pexp=pexp_c,
                             dl=dl_i)
        l1_ref[pl.ds(s1, 1), :] = jnp.where(is_even, row_a, row_c)
        r1p = l1_ref[pl.ds(s1, 1), :]
        hit1 = _sc_get(r1p, SC_HIT1) != 0       # even-step mailbox
        dvalid = _sc_get(r1p, SC_DVALID) != 0   # odd-step mailbox
        dk = _sc_get(r1p, SC_DK)

        # L2 round-trip: phase B (even, set s2) / phase D (odd, the
        # displaced victim's own set; the even store lands before the odd
        # fetch, so s2v == s2 aliasing reads the post-promote row)
        if demote:
            s2v = _set_index_i32(dk, l2_sets, seed)
            sl2 = jnp.where(is_even, s2, s2v)
        else:
            sl2 = s2
        r2 = fetch_l2(sl2, rowA)
        row_b = _l2_hit_row(policy, promote, r2, qk, fp, hit1, t_get, en,
                            l2_ways, ttl=ttl, horizon=hz)
        if demote:
            df = _sc_get(r1p, SC_DF)
            dv = _sc_get(r1p, SC_DV)
            da = _sc_get(r1p, SC_DA)
            db = _sc_get(r1p, SC_DB)
            de = _sc_get(r1p, SC_DE)
            row_d = _l2_demote_row(policy, r2, dk, df, dv, da, db,
                                   dvalid, t_put, l2_ways,
                                   ttl=ttl, horizon=hz, de=de)
        else:
            row_d = r2                          # odd step: no-op store
        r2p = store_l2(sl2, rowA, jnp.where(is_even, row_b, row_d))
        l2_hit = _sc_get(r2p, SC_L2HIT) != 0
        pval = _sc_get(r2p, SC_PVAL)
        pa = _sc_get(r2p, SC_PA)
        pb = _sc_get(r2p, SC_PB)
        pexp = _sc_get(r2p, SC_PEXP)
        if demote:
            ev = _sc_get(r2p, SC_EV)
        else:
            ev = dvalid.astype(jnp.int32)

        hit = (en & (hit1 | l2_hit)).astype(jnp.int32)
        hits = hits + jnp.where(is_even, hit, 0)
        evs = evs + jnp.where(is_even, jnp.int32(0), ev)
        hit1_c = jnp.where(is_even, hit1.astype(jnp.int32), hit1_c)
        l2_c = jnp.where(is_even, l2_hit.astype(jnp.int32), l2_c)
        pval_c = jnp.where(is_even, pval, pval_c)
        pa_c = jnp.where(is_even, pa, pa_c)
        pb_c = jnp.where(is_even, pb, pb_c)
        pexp_c = jnp.where(is_even, pexp, pexp_c)
        return hits, evs, hit1_c, l2_c, pval_c, pa_c, pb_c, pexp_c

    z = jnp.int32(0)
    hits, evs, *_ = jax.lax.fori_loop(0, 2 * batch, body,
                                      (z, z, z, z, z, z, z, z))
    hits_ref[0] = hits
    evs_ref[0] = evs


@functools.partial(
    jax.jit,
    static_argnames=("policy", "l1_ways", "l2_ways", "l1_sets", "l2_sets",
                     "seed", "promote", "demote", "ttl", "carry_exp",
                     "interpret"))
def _replay_hier_jit(
    l1_keys, l1_fpr, l1_vals, l1_ma, l1_mb, l1_exp,  # [S1, l1_ways] lanes
    l2_keys, l2_fpr, l2_vals, l2_ma, l2_mb, l2_exp,  # [S2, l2_ways] lanes
    clock,
    chunks, enabled, tt,                           # uint32/bool/int32 [T, B]
    *,
    policy: int,
    l1_ways: int,
    l2_ways: int,
    l1_sets: int,
    l2_sets: int,
    seed: int,
    promote: bool,
    demote: bool,
    ttl: bool,
    carry_exp: bool,
    interpret: bool,
):
    from repro.core import hashing
    from repro.core.hierarchy import (ROW_W, L1_SEED_SALT, _pack_lanes,
                                      _unpack_expiry, _unpack_lanes)

    steps, batch = chunks.shape
    _TRACE_COUNTS[("trace-hier", int(policy), l1_sets, l1_ways, l2_sets,
                   l2_ways, steps, batch, promote, demote)] += 1

    # ---- streams: sanitize + route BOTH tiers once, pad to lane width
    qk = hashing.sanitize_keys(chunks.reshape(-1))
    s1 = hashing.set_index(qk, l1_sets,
                           seed ^ L1_SEED_SALT).reshape(steps, batch)
    s2 = hashing.set_index(qk, l2_sets, seed).reshape(steps, batch)
    qk = qk.astype(jnp.int32).reshape(steps, batch)
    en = enabled.astype(jnp.int32)
    tt = tt.astype(jnp.int32)
    bp = -(-batch // LANES) * LANES
    if bp != batch:
        pad = jnp.zeros((steps, bp - batch), jnp.int32)
        qk = jnp.concatenate([qk, pad], axis=1)
        s1 = jnp.concatenate([s1, pad], axis=1)
        s2 = jnp.concatenate([s2, pad], axis=1)
        en = jnp.concatenate([en, pad], axis=1)
        tt = jnp.concatenate([tt, pad], axis=1)

    # ---- both tiers packed [S, ROW_W]: L1 VMEM-resident, L2 row-per-DMA
    l1p = _pack_lanes(l1_keys, l1_fpr, l1_vals, l1_ma, l1_mb, l1_exp)
    l2p = _pack_lanes(l2_keys, l2_fpr, l2_vals, l2_ma, l2_mb, l2_exp)

    scal = clock.astype(jnp.int32).reshape(1)

    kernel = functools.partial(
        _hier_replay_kernel, policy=int(policy), l1_ways=l1_ways,
        l2_ways=l2_ways, l2_sets=l2_sets, seed=seed, batch=batch,
        promote=promote, demote=demote, ttl=ttl, interpret=interpret)

    chunk_row = lambda: pl.BlockSpec((1, bp), lambda t, *_: (t, 0))  # noqa: E731
    full = lambda a: pl.BlockSpec(a.shape, lambda t, *_: (0,) * a.ndim)  # noqa: E731
    cnt = lambda: pl.BlockSpec((1,), lambda t, *_: (t,))  # noqa: E731
    anyspace = lambda: pl.BlockSpec(memory_space=pltpu.ANY)  # noqa: E731

    outs = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(steps,),
            in_specs=[chunk_row(), chunk_row(), chunk_row(), chunk_row(),
                      chunk_row(), full(l1p), anyspace()],
            out_specs=[cnt(), cnt(), full(l1p), anyspace()],
            scratch_shapes=[pltpu.VMEM((1, ROW_W), jnp.int32),
                            pltpu.SemaphoreType.DMA],
        ),
        out_shape=[jax.ShapeDtypeStruct((steps,), jnp.int32),
                   jax.ShapeDtypeStruct((steps,), jnp.int32),
                   jax.ShapeDtypeStruct((l1_sets, ROW_W), jnp.int32),
                   jax.ShapeDtypeStruct((l2_sets, ROW_W), jnp.int32)],
        interpret=interpret,
    )(scal, qk, s1, s2, en, tt, l1p, l2p)

    hits, evs = outs[0], outs[1]
    clock_f = clock + jnp.int32(2 * batch * steps)
    l1_out = _unpack_lanes(outs[2], l1_ways)
    l2_out = _unpack_lanes(outs[3], l2_ways)
    if carry_exp:
        l1_out = l1_out + (_unpack_expiry(outs[2], l1_ways),)
        l2_out = l2_out + (_unpack_expiry(outs[3], l2_ways),)
    return hits, evs, l1_out, l2_out, clock_f


def replay_hierarchical(
    l1_keys, l1_fpr, l1_vals, l1_ma, l1_mb,
    l2_keys, l2_fpr, l2_vals, l2_ma, l2_mb,
    clock,
    chunks, enabled,
    *,
    policy: int,
    l1_ways: int,
    l2_ways: int,
    l1_sets: int,
    l2_sets: int,
    seed: int,
    promote: bool = True,
    demote: bool = True,
    l1_exp=None,
    l2_exp=None,
    ttls=None,
    interpret: bool = True,
):
    """Run the hierarchical replay megakernel: ONE launch, L1 pinned in
    VMEM, L2 in slow memory behind per-set row DMAs.

    ``l1_exp``/``l2_exp`` are optional int32 [S, ways] per-lane expiry
    deadlines; ``ttls`` is an optional int32 [steps, B] per-request TTL
    stream (0 = never expires).  When either is present the expiry lane
    is carried through the kernel (fetched rows are scrubbed at the
    batch-exit horizon before probing — an expired entry is never a hit
    and its lane is the preferred victim) and each tier's returned lane
    tuple gains a sixth expiry member.

    Returns (hits int32 [steps], evs int32 [steps],
    (keys, fprint, vals, meta_a, meta_b[, expiry]) L1 lanes,
    (keys, fprint, vals, meta_a, meta_b[, expiry]) L2 lanes, clock') —
    key/fprint lanes in the int32 bit-cast domain (callers re-cast to
    uint32).
    """
    steps, batch = chunks.shape
    _TRACE_COUNTS[("launch-hier", int(policy), l1_sets, l1_ways, l2_sets,
                   l2_ways, steps, batch, promote, demote)] += 1
    carry_exp = (l1_exp is not None or l2_exp is not None
                 or ttls is not None)
    ttl = ttls is not None
    tt = (jnp.zeros((steps, batch), jnp.int32) if ttls is None
          else jnp.asarray(ttls, jnp.int32))
    return _replay_hier_jit(
        l1_keys, l1_fpr, l1_vals, l1_ma, l1_mb, l1_exp,
        l2_keys, l2_fpr, l2_vals, l2_ma, l2_mb, l2_exp, clock,
        jnp.asarray(chunks, jnp.uint32), jnp.asarray(enabled, jnp.bool_),
        tt,
        policy=int(policy), l1_ways=l1_ways, l2_ways=l2_ways,
        l1_sets=l1_sets, l2_sets=l2_sets, seed=seed,
        promote=promote, demote=demote, ttl=ttl, carry_exp=carry_exp,
        interpret=interpret)
