"""Pallas TPU kernel: batched K-way set probe + policy victim selection.

This is the paper's hot loop — "scan the k ways of one set, find the key or
the policy victim" (Algorithms 2/3/5/6) — as a VMEM-tiled TPU kernel.

TPU adaptation (DESIGN.md §2):
  * The cache's SoA lanes (keys / fprint / meta_a / meta_b / vals) are
    VMEM-resident:
    a hot cache of S×k ≤ 64Ki entries is ≤ 1 MiB per lane — the software
    analogue of the paper's "short continuous region of memory" argument,
    transplanted to the HBM→VMEM hierarchy.  BlockSpecs map each full lane
    into VMEM once; every grid step reuses it (index_map is constant).
  * Each grid step processes ``qt`` queries.  Per query, the set row is
    fetched with a dynamic slice (``pl.ds``) — the TPU equivalent of the
    paper's pointer-free set scan; ways are padded to the 128-lane register
    width so the k-wide compare/reduce is a single VPU op.
  * Set indices arrive via scalar prefetch (PrefetchScalarGridSpec) so they
    are available to index VMEM before the vector body runs.

The kernel returns probe *decisions* (hit, way, victim way, victim key);
applying them is a single XLA scatter done by the caller (``ops.py``) — a
clean read-kernel / write-scatter split that keeps the kernel free of
scatter hazards (the paper's CAS loop lives in the caller's deterministic
conflict resolution, see core/kway.py).

Expiry (DESIGN.md §15) never reaches this kernel: TTL-aware replay scrubs
expired lanes to EMPTY_KEY *before* probing (``kway.scrub_expired``), so by
the time the probe runs an expired entry is an ordinary empty lane — it can
neither hit nor outrank an empty-way victim.  The probe therefore needs no
expiry lane and no functional change for TTLs.

Validated in ``interpret=True`` mode against ``ref.py`` (pure jnp oracle)
over shape/dtype/policy sweeps in tests/test_kway_kernel.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.policies import Policy

NEG_INF = -3.0e38  # python literal: jnp module-level constants would be
POS_INF = 3.0e38   # captured by the kernel trace and rejected by pallas_call
LANES = 128  # TPU vector register lane width


def _hash_u32(x, seed: int):
    """core/hashing.hash_u32 (seeded premix + fmix32), inlined with literal
    constants: a pallas_call body cannot close over hashing's module-level
    jnp constants (rejected at trace time), but pure-function reuse is fine —
    this is the ONE kernel-side copy, shared by the victim-score RANDOM
    branch, the fingerprint pre-filter, and the replay megakernel's TinyLFU
    sketch (kernels/replay.py).  The kernel-vs-oracle sweeps in
    tests/test_kernels.py call hashing directly, so drift here fails loudly.
    """
    x = x.astype(jnp.uint32)
    x = (x + jnp.uint32(seed) * jnp.uint32(0x9E3779B1)) * jnp.uint32(0x85EBCA77)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _scores_for_policy(policy: int, keys, meta_a, meta_b, now):
    """Victim scores, lower == evict first.  Bit-identical to
    core/policies.victim_scores (the backend-equivalence suite relies on it),
    written with only Pallas-TPU-lowerable ops (no gather, no PRNG)."""
    a = meta_a.astype(jnp.float32)
    if policy == Policy.RANDOM:
        h = _hash_u32(keys.astype(jnp.uint32) ^ now.astype(jnp.uint32),
                      0xBADA)
        return h.astype(jnp.float32)
    if policy == Policy.HYPERBOLIC:
        age = (now - meta_b).astype(jnp.float32) + 1.0
        return a / age
    return a  # LRU / LFU / FIFO share "argmin meta_a"


def _full_order_row(scores, lane, ways):
    """Full victim order, worst-first: `ways` rounds of masked min-extraction
    (the paper's O(k) scan, k unrolled VPU reduces).  Ties break toward the
    lowest lane — identical to the stable argsort in core/kway._victim_order.
    Returns (ord_row [1, LANES], vway scalar)."""
    work = scores
    ord_row = jnp.full((1, LANES), LANES, jnp.int32)
    vway = None
    for r in range(ways):
        m = jnp.min(work)
        w = jnp.min(jnp.where(work == m, lane, LANES))
        ord_row = jnp.where(lane == r, w, ord_row)
        work = jnp.where(lane == w, POS_INF, work)
        if r == 0:
            vway = w
    return ord_row, vway


def _fingerprint_i32(key_u32):
    """core/hashing.fingerprint as int32 (the kernels' bit-cast lane
    dtype)."""
    return (_hash_u32(key_u32, 0xF19E) & jnp.uint32(0xFFFF)).astype(jnp.int32)


def _probe_kernel(
    # scalar prefetch
    sets_ref,            # int32 [B]    set index per query
    # VMEM inputs
    keys_ref,            # int32 [S, kp]   stored keys (bit-cast uint32)
    fprint_ref,          # int32 [S, kp]   16-bit fingerprints
    meta_a_ref,          # int32 [S, kp]
    meta_b_ref,          # int32 [S, kp]
    qkeys_ref,           # int32 [qt]      query keys for this tile
    times_ref,           # int32 [qt]      logical timestamps
    # VMEM outputs
    hit_ref,             # int32 [qt]
    way_ref,             # int32 [qt]
    *rest,               # (vway_ref, vkey_ref[, vorder_ref]) when need_victims
    policy: int,
    ways: int,
    qt: int,
    empty_key: int,
    need_victims: bool,
):
    vway_ref = rest[0] if need_victims else None
    vkey_ref = rest[1] if need_victims else None
    vorder_ref = rest[2] if need_victims and len(rest) > 2 else None
    tile = pl.program_id(0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)
    valid_way = lane < ways

    for i in range(qt):  # unrolled: qt dynamic row slices per grid step
        q = tile * qt + i
        s = sets_ref[q]
        row_keys = keys_ref[pl.ds(s, 1), :]          # [1, kp]
        row_fpr = fprint_ref[pl.ds(s, 1), :]
        qk = qkeys_ref[i]

        occupied = (row_keys != empty_key) & valid_way
        # KW-WFSC Algorithm 5: the 16-bit fingerprint pre-filters the scan;
        # a fingerprint match is confirmed on the full key, so the result is
        # bit-identical to the plain full-key compare.
        eq = (row_fpr == _fingerprint_i32(qk)) & (row_keys == qk) & occupied
        hit = jnp.any(eq)
        # first matching way (stable argmax over the 128-lane mask)
        way = jnp.min(jnp.where(eq, lane, LANES))

        hit_ref[i] = hit.astype(jnp.int32)
        way_ref[i] = jnp.where(hit, way, 0)

        if not need_victims:
            # Pure-get probe: skip the victim-selection rounds entirely —
            # the read path never consumes them.
            continue

        row_a = meta_a_ref[pl.ds(s, 1), :]
        row_b = meta_b_ref[pl.ds(s, 1), :]
        now = times_ref[i]
        scores = _scores_for_policy(policy, row_keys, row_a, row_b, now)
        scores = jnp.where(occupied, scores, NEG_INF)  # empty ways first
        scores = jnp.where(valid_way, scores, POS_INF)  # padding ways last
        if vorder_ref is None:
            vscore = jnp.min(scores)
            vway = jnp.min(jnp.where(scores == vscore, lane, LANES))
        else:
            ord_row, vway = _full_order_row(scores, lane, ways)
            vorder_ref[pl.ds(i, 1), :] = ord_row

        vway_ref[i] = vway
        vkey_ref[i] = jnp.sum(
            jnp.where(lane == vway, row_keys, 0).astype(jnp.int32)
        )


@functools.partial(
    jax.jit, static_argnames=("policy", "ways", "qt", "interpret",
                              "full_order", "need_victims")
)
def kway_probe(
    keys: jnp.ndarray,     # int32 [S, kp] (ways padded to LANES multiple.. or any kp>=ways)
    fprint: jnp.ndarray,   # int32 [S, kp] 16-bit fingerprints of the keys
    meta_a: jnp.ndarray,   # int32 [S, kp]
    meta_b: jnp.ndarray,   # int32 [S, kp]
    sets: jnp.ndarray,     # int32 [B]
    qkeys: jnp.ndarray,    # int32 [B]
    times: jnp.ndarray,    # int32 [B]
    *,
    policy: int,
    ways: int,
    qt: int = 8,
    interpret: bool = True,
    full_order: bool = False,
    need_victims: bool = True,
):
    """Run the probe kernel.  B must be a multiple of qt; kp (padded ways)
    must equal LANES (one VREG row per set).

    With ``full_order=True`` a fifth output is returned: vorder int32
    [B, LANES], the per-query victim order worst-first (entries past ``ways``
    hold the LANES sentinel) — what the batched conflict resolution in
    core/kway.apply_put consumes for rank>0 same-set collisions.

    With ``need_victims=False`` (the pure-get read path) the victim-selection
    rounds are skipped entirely and only (hit, way) are returned.
    """
    s, kp = keys.shape
    b = sets.shape[0]
    assert kp == LANES, f"pad ways to {LANES} lanes (got {kp})"
    assert b % qt == 0
    assert need_victims or not full_order, \
        "full_order requires need_victims=True"
    grid = (b // qt,)

    kernel = functools.partial(
        _probe_kernel,
        policy=policy,
        ways=ways,
        qt=qt,
        empty_key=-1,  # EMPTY_KEY 0xFFFFFFFF viewed as int32
        need_victims=need_victims,
    )
    n_scalar_outs = 4 if need_victims else 2
    out_shape = [jax.ShapeDtypeStruct((b,), jnp.int32)] * n_scalar_outs
    full = lambda: pl.BlockSpec((s, kp), lambda i, *_: (0, 0))  # noqa: E731
    qtile = lambda: pl.BlockSpec((qt,), lambda i, *_: (i,))  # noqa: E731
    out_specs = [qtile()] * n_scalar_outs
    if full_order:
        out_shape = out_shape + [jax.ShapeDtypeStruct((b, LANES), jnp.int32)]
        out_specs = out_specs + [pl.BlockSpec((qt, LANES), lambda i, *_: (i, 0))]
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[full(), full(), full(), full(), qtile(), qtile()],
            out_specs=out_specs,
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(sets, keys, fprint, meta_a, meta_b, qkeys, times)


# ---------------------------------------------------------------------------
# fused access kernel: both phases of `access` in ONE launch
# ---------------------------------------------------------------------------

def _fused_kernel(
    # scalar prefetch
    sets_ref,            # int32 [B]    set index per query
    # VMEM inputs
    keys_ref,            # int32 [S, kp]
    fprint_ref,          # int32 [S, kp]
    meta_a_ref,          # int32 [S, kp]
    meta_b_ref,          # int32 [S, kp]
    qkeys_ref,           # int32 [qt]
    tg_ref,              # int32 [qt]   get-phase timestamps (t + i)
    tp_ref,              # int32 [qt]   put-phase timestamps (t + B + i)
    en_ref,              # int32 [qt]   1 = live lane (enabled & not padding)
    # VMEM outputs
    hit_ref,             # int32 [qt]
    way_ref,             # int32 [qt]
    vorder_ref,          # int32 [qt, LANES]
    # VMEM scratch
    scratch_a,           # int32 [S, kp]  hit-updated meta_a
    *,
    policy: int,
    ways: int,
    qt: int,
    empty_key: int,
):
    """Two grid phases over the same query tiles (grid = (2, B/qt)):

      phase 0 — probe every query and apply its hit-phase ``on_hit``
        metadata transition to a VMEM scratch copy of ``meta_a`` (queries
        run in batch order, so colliding hits accumulate exactly like the
        scatter-add/-max in core/kway.apply_access);
      phase 1 — re-derive (hit, way) from the untouched key lanes and emit
        the full victim order scored on the *post-hit* scratch metadata at
        the put-phase timestamps — what the second launch of the two-phase
        path would compute, without re-reading the cache from HBM.
    """
    phase = pl.program_id(0)
    tile = pl.program_id(1)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)
    valid_way = lane < ways

    @pl.when(jnp.logical_and(phase == 0, tile == 0))
    def _init_scratch():
        scratch_a[...] = meta_a_ref[...]

    for i in range(qt):  # unrolled: qt dynamic row slices per grid step
        q = tile * qt + i
        s = sets_ref[q]
        row_keys = keys_ref[pl.ds(s, 1), :]          # [1, kp]
        row_fpr = fprint_ref[pl.ds(s, 1), :]
        qk = qkeys_ref[i]

        occupied = (row_keys != empty_key) & valid_way
        # fingerprint pre-filter + full-key confirm (see _probe_kernel)
        eq = (row_fpr == _fingerprint_i32(qk)) & (row_keys == qk) & occupied
        hit = jnp.any(eq)
        way = jnp.min(jnp.where(eq, lane, LANES))    # LANES when no hit

        if policy not in (Policy.FIFO, Policy.RANDOM):  # on_hit is identity
            @pl.when(phase == 0)
            def _hit_update():
                do = jnp.logical_and(hit, en_ref[i] != 0)
                row_a = scratch_a[pl.ds(s, 1), :]
                upd = lane == way            # all-false when way == LANES
                if policy == Policy.LRU:
                    new_a = jnp.where(upd, tg_ref[i], row_a)
                else:                        # LFU / HYPERBOLIC: count += 1
                    new_a = jnp.where(upd, row_a + 1, row_a)
                scratch_a[pl.ds(s, 1), :] = jnp.where(do, new_a, row_a)

        @pl.when(phase == 1)
        def _score():
            row_a = scratch_a[pl.ds(s, 1), :]
            row_b = meta_b_ref[pl.ds(s, 1), :]
            scores = _scores_for_policy(policy, row_keys, row_a, row_b,
                                        tp_ref[i])
            scores = jnp.where(occupied, scores, NEG_INF)
            scores = jnp.where(valid_way, scores, POS_INF)
            ord_row, _ = _full_order_row(scores, lane, ways)
            vorder_ref[pl.ds(i, 1), :] = ord_row
            hit_ref[i] = hit.astype(jnp.int32)
            way_ref[i] = jnp.where(hit, way, 0)


@functools.partial(
    jax.jit, static_argnames=("policy", "ways", "qt", "interpret")
)
def kway_fused_probe(
    keys: jnp.ndarray,     # int32 [S, kp]
    fprint: jnp.ndarray,   # int32 [S, kp] 16-bit fingerprints of the keys
    meta_a: jnp.ndarray,   # int32 [S, kp]
    meta_b: jnp.ndarray,   # int32 [S, kp]
    sets: jnp.ndarray,     # int32 [B]
    qkeys: jnp.ndarray,    # int32 [B]
    times_get: jnp.ndarray,  # int32 [B]  t + i
    times_put: jnp.ndarray,  # int32 [B]  t + B + i
    en: jnp.ndarray,       # int32 [B]  1 = live lane (enabled, not padding)
    *,
    policy: int,
    ways: int,
    qt: int = 8,
    interpret: bool = True,
):
    """Single-launch fused probe for ``access``: hit decisions plus the full
    victim order scored on the hit-updated metadata (see ``_fused_kernel``).

    Returns (hit int32 [B], way int32 [B], vorder int32 [B, LANES]).  ``hit``
    is the raw probe outcome, unmasked by ``en`` — ``en`` only gates which
    lanes apply their hit-phase metadata transition (disabled and padding
    lanes must not perturb victim scores).
    """
    s, kp = keys.shape
    b = sets.shape[0]
    assert kp == LANES, f"pad ways to {LANES} lanes (got {kp})"
    assert b % qt == 0
    grid = (2, b // qt)

    kernel = functools.partial(
        _fused_kernel,
        policy=policy,
        ways=ways,
        qt=qt,
        empty_key=-1,
    )
    full = lambda: pl.BlockSpec((s, kp), lambda p, i, *_: (0, 0))  # noqa: E731
    qtile = lambda: pl.BlockSpec((qt,), lambda p, i, *_: (i,))  # noqa: E731
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[full(), full(), full(), full(),
                      qtile(), qtile(), qtile(), qtile()],
            out_specs=[qtile(), qtile(),
                       pl.BlockSpec((qt, LANES), lambda p, i, *_: (i, 0))],
            scratch_shapes=[pltpu.VMEM((s, kp), jnp.int32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b, LANES), jnp.int32),
        ],
        interpret=interpret,
    )(sets, keys, fprint, meta_a, meta_b, qkeys, times_get, times_put, en)
