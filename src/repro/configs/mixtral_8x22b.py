"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088; hf]."""
from repro.configs.base import ArchSpec, ModelConfig

SPEC = ArchSpec(
    config=ModelConfig(
        name="mixtral-8x22b", family="moe",
        num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=16384, vocab_size=32768, head_dim=128,
        num_experts=8, top_k=2, sliding_window=4096, moe_ff_shards=2,
        rope_theta=1e6,
    ),
    smoke=ModelConfig(
        name="mixtral-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16,
        num_experts=4, top_k=2, sliding_window=32,
    ),
    supports_long_context=True,  # SWA bounds live attention state
    source="arXiv:2401.04088; hf",
)
