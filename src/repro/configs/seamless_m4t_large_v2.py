"""seamless-m4t-large-v2 [audio] — enc-dec multimodal [arXiv:2308.11596; hf].

Backbone only: the speech frontend is a STUB — input_specs() provides
precomputed frame embeddings for the encoder.  A shape cell's seq_len is
split enc:dec = 1:1 (enc frames = dec tokens = seq_len // 2).
"""
from repro.configs.base import ArchSpec, ModelConfig

SPEC = ArchSpec(
    config=ModelConfig(
        name="seamless-m4t-large-v2", family="audio",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=8192, vocab_size=256206, head_dim=64,
        enc_layers=24, frontend="frames",
    ),
    smoke=ModelConfig(
        name="seamless-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, head_dim=16,
        enc_layers=2, frontend="frames",
    ),
    supports_long_context=False,
    source="arXiv:2308.11596; hf",
)
