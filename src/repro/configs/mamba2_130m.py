"""mamba2-130m [ssm] — SSD, attention-free [arXiv:2405.21060; unverified].

The paper's KV-page-cache technique is INAPPLICABLE here (no KV pages) —
see DESIGN.md §4.  Implemented without it; the K-way cache still serves this
arch as a host-side object cache in the serving examples.
"""
from repro.configs.base import ArchSpec, ModelConfig

SPEC = ArchSpec(
    config=ModelConfig(
        name="mamba2-130m", family="ssm",
        num_layers=24, d_model=768, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    ),
    smoke=ModelConfig(
        name="mamba2-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=512,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16,
    ),
    supports_long_context=True,  # O(1) state
    source="arXiv:2405.21060; unverified",
)
