"""hymba-1.5b [hybrid] — parallel attn+mamba heads [arXiv:2411.13676; hf]."""
from repro.configs.base import ArchSpec, ModelConfig

SPEC = ArchSpec(
    config=ModelConfig(
        name="hymba-1.5b", family="hybrid",
        num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
        d_ff=5504, vocab_size=32001, head_dim=64,
        ssm_state=16, ssm_expand=2, ssm_head_dim=64,
        sliding_window=1024,  # hymba: SWA on most attention layers
    ),
    smoke=ModelConfig(
        name="hymba-smoke", family="hybrid",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16,
        ssm_state=8, ssm_expand=2, ssm_head_dim=16, sliding_window=32,
    ),
    supports_long_context=True,  # SSM + sliding-window attention
    source="arXiv:2411.13676; hf",
)
