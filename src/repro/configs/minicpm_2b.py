"""minicpm-2b [dense] — llama-like arch, WSD schedule [arXiv:2404.06395; hf]."""
from repro.configs.base import ArchSpec, ModelConfig

SPEC = ArchSpec(
    config=ModelConfig(
        name="minicpm-2b", family="dense",
        num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
        d_ff=5760, vocab_size=122753, head_dim=64,
        tie_embeddings=True, scale_emb=12.0,
    ),
    smoke=ModelConfig(
        name="minicpm-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, head_dim=16,
        tie_embeddings=True, scale_emb=12.0,
    ),
    supports_long_context=False,
    source="arXiv:2404.06395; hf",
)
