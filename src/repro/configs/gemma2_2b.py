"""gemma2-2b [dense] — local+global alternating, logit softcaps [arXiv:2408.00118]."""
from repro.configs.base import ArchSpec, ModelConfig

SPEC = ArchSpec(
    config=ModelConfig(
        name="gemma2-2b", family="dense",
        num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4,
        d_ff=9216, vocab_size=256000, head_dim=256,
        alt_local_global=True, sliding_window=4096,
        attn_softcap=50.0, final_softcap=30.0, tie_embeddings=True,
    ),
    smoke=ModelConfig(
        name="gemma2-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16,
        alt_local_global=True, sliding_window=32,
        attn_softcap=50.0, final_softcap=30.0, tie_embeddings=True,
    ),
    supports_long_context=True,  # half the layers are sliding-window
    source="arXiv:2408.00118; hf",
)
