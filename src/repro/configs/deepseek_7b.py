"""deepseek-7b [dense] — llama-arch [arXiv:2401.02954; hf]."""
from repro.configs.base import ArchSpec, ModelConfig

SPEC = ArchSpec(
    config=ModelConfig(
        name="deepseek-7b", family="dense",
        num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32,
        d_ff=11008, vocab_size=102400, head_dim=128,
    ),
    smoke=ModelConfig(
        name="deepseek-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, head_dim=16,
    ),
    supports_long_context=False,
    source="arXiv:2401.02954; hf",
)
