"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base]."""
from repro.configs.base import ArchSpec, ModelConfig

SPEC = ArchSpec(
    config=ModelConfig(
        name="dbrx-132b", family="moe",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=10752, vocab_size=100352, head_dim=128,
        num_experts=16, top_k=4, rope_theta=5e5,
    ),
    smoke=ModelConfig(
        name="dbrx-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=512, head_dim=16,
        num_experts=8, top_k=4,
    ),
    supports_long_context=False,  # pure full attention — long_500k skipped
    source="hf:databricks/dbrx-base; unverified",
)
