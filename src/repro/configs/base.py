"""Config schema: model architecture, input shapes, mesh, cache.

One ``ModelConfig`` describes any of the 10 assigned architectures (dense /
MoE / hybrid / SSM / VLM-backbone / audio enc-dec).  ``ShapeConfig`` is one
(seq_len, global_batch, kind) cell; ``ArchSpec`` binds a ModelConfig to its
shape set and smoke-test reduction.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int               # 0 for attention-free (ssm)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    # TPU adaptation (EXPERIMENTS.md §Perf iter 7): slice each expert's ff
    # into `moe_ff_shards` "virtual experts" so the expert count divides the
    # model mesh axis (mixtral: 8 experts x 2 = 16).  Exact: the gated-MLP
    # ff sum partitions cleanly; routing still happens over real experts.
    moe_ff_shards: int = 1

    # --- attention variants ---
    sliding_window: int = 0      # 0 = full attention
    alt_local_global: bool = False  # gemma2: even layers local(SWA), odd global
    attn_softcap: float = 0.0    # gemma2 attn logit softcap
    final_softcap: float = 0.0   # gemma2 final logit softcap
    rope_theta: float = 10000.0

    # --- SSM (mamba2 / hymba) ---
    ssm_state: int = 0           # N (state size); 0 = no ssm
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4

    # --- encoder-decoder (seamless) ---
    enc_layers: int = 0          # >0 = enc-dec; num_layers is decoder depth

    # --- frontends (stubs per instructions) ---
    frontend: str = "none"       # none | patch (vlm) | frames (audio)
    frontend_len: int = 0        # prefix length contributed by the frontend

    # --- misc ---
    tie_embeddings: bool = False
    scale_emb: float = 1.0       # minicpm embeds scaling
    norm_eps: float = 1e-5

    def __post_init__(self):
        if self.num_heads:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def num_virtual_experts(self) -> int:
        return self.num_experts * self.moe_ff_shards

    @property
    def virtual_d_ff(self) -> int:
        return self.d_ff // self.moe_ff_shards

    @property
    def is_ssm_only(self) -> bool:
        return self.family == "ssm"

    @property
    def has_attention(self) -> bool:
        return self.num_heads > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    # --- parameter counting (for roofline MODEL_FLOPS = 6·N·D) ---

    def param_count(self, active_only: bool = False) -> int:
        """Total (or MoE-active) parameter count, embeddings included."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd, h, kvh = self.hd, self.num_heads, self.num_kv_heads
        per_layer = 0
        if self.has_attention:
            per_layer += d * (h * hd) + 2 * d * (kvh * hd) + (h * hd) * d
        if self.has_ssm:
            d_in = self.ssm_expand * d
            n = self.ssm_state
            nh = d_in // self.ssm_head_dim
            # in_proj (z,x,B,C,dt) + out_proj + conv
            per_layer += d * (2 * d_in + 2 * n + nh) + d_in * d + d_in * self.ssm_conv
        if self.is_moe:
            e = self.num_experts if not active_only else self.top_k
            per_layer += e * 3 * d * ff + d * self.num_experts  # experts + router
        elif ff > 0:
            per_layer += 3 * d * ff  # gated mlp
        per_layer += 2 * d  # norms
        total = self.num_layers * per_layer
        if self.enc_layers:
            enc_per = d * (h * hd) + 2 * d * (kvh * hd) + (h * hd) * d + 3 * d * ff + 2 * d
            cross = d * (h * hd) + 2 * d * (kvh * hd) + (h * hd) * d + d
            total += self.enc_layers * enc_per + self.num_layers * cross
        total += v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # lm head
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The four LM shape cells assigned to every architecture.
LM_SHAPES = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in LM_SHAPES}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    config: ModelConfig
    smoke: ModelConfig           # reduced same-family config for CPU tests
    # long_500k applicability (DESIGN.md §4): False for pure full-attention
    supports_long_context: bool = False
    source: str = ""

    @property
    def name(self) -> str:
        return self.config.name

    def shapes(self):
        for s in LM_SHAPES:
            if s.name == "long_500k" and not self.supports_long_context:
                continue
            yield s

    def skipped_shapes(self):
        for s in LM_SHAPES:
            if s.name == "long_500k" and not self.supports_long_context:
                yield s
