"""Architecture registry + input_specs (ShapeDtypeStruct stand-ins).

``--arch <id>`` everywhere resolves through ``get(id)``.  ``input_specs``
builds allocation-free input descriptions for lower()/compile() — the
dry-run's only view of the data.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import (  # noqa: F401
    LM_SHAPES,
    SHAPES_BY_NAME,
    ArchSpec,
    ModelConfig,
    ShapeConfig,
)

_MODULES = {
    "mixtral-8x22b": "mixtral_8x22b",
    "dbrx-132b": "dbrx_132b",
    "hymba-1.5b": "hymba_1p5b",
    "internvl2-2b": "internvl2_2b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "stablelm-3b": "stablelm_3b",
    "gemma2-2b": "gemma2_2b",
    "minicpm-2b": "minicpm_2b",
    "deepseek-7b": "deepseek_7b",
    "mamba2-130m": "mamba2_130m",
}

ARCH_IDS = tuple(_MODULES)


def get(arch_id: str) -> ArchSpec:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.SPEC


def all_specs():
    return [get(a) for a in ARCH_IDS]


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct — no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract inputs for one (arch × shape) cell.

    train/prefill: {tokens, labels?, prefix_embeds?, enc_embeds?}
    decode:        {token, pos} (the KV/state cache comes from cache_specs).
    Frontend stubs: precomputed patch/frame embeddings per instructions.
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"token": _sds((b,), jnp.int32), "pos": _sds((b,), jnp.int32)}

    specs = {}
    s_tok = s
    if cfg.frontend == "patch":
        s_tok = s - cfg.frontend_len
        specs["prefix_embeds"] = _sds((b, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    if cfg.enc_layers > 0:
        s_tok = s // 2
        specs["enc_embeds"] = _sds((b, s - s_tok, cfg.d_model), jnp.bfloat16)
    specs["tokens"] = _sds((b, s_tok), jnp.int32)
    if shape.kind == "train":
        specs["labels"] = _sds((b, s), jnp.int32)
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract decode-cache pytree (mirrors models.lm.init_cache)."""
    from repro.models import lm

    b, s = shape.global_batch, shape.seq_len
    return jax.eval_shape(
        lambda: lm.init_cache(cfg, b, s)
    )


def param_specs(cfg: ModelConfig) -> dict:
    """Abstract parameter pytree via eval_shape (no allocation)."""
    from repro.models import lm

    return jax.eval_shape(lambda: lm.init_params(cfg, jax.random.key(0)))
