"""stablelm-3b [dense] [hf:stabilityai/stablelm-2-1_6b family; unverified]."""
from repro.configs.base import ArchSpec, ModelConfig

SPEC = ArchSpec(
    config=ModelConfig(
        name="stablelm-3b", family="dense",
        num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
        d_ff=6912, vocab_size=50304, head_dim=80,
    ),
    smoke=ModelConfig(
        name="stablelm-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, head_dim=16,
    ),
    supports_long_context=False,
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
)
