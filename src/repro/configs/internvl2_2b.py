"""internvl2-2b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

The ViT frontend is a STUB per instructions: input_specs() provides
precomputed patch embeddings [B, 256, d_model] consumed as a prefix.
"""
from repro.configs.base import ArchSpec, ModelConfig

SPEC = ArchSpec(
    config=ModelConfig(
        name="internvl2-2b", family="vlm",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
        d_ff=8192, vocab_size=92553, head_dim=128,
        frontend="patch", frontend_len=256,
    ),
    smoke=ModelConfig(
        name="internvl2-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16,
        frontend="patch", frontend_len=8,
    ),
    supports_long_context=False,
    source="arXiv:2404.16821; hf",
)
