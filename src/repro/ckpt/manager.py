"""Fault-tolerant checkpointing: atomic commits, resharding restore.

Layout of one checkpoint::

    <dir>/step_000042/
        manifest.json        # treedef, shapes, dtypes, step, data_state
        leaf_00000.npy ...   # one file per pytree leaf

Guarantees:
  * **atomicity** — written to ``step_N.tmp`` then ``os.rename``d; a crash
    mid-write can never corrupt the latest valid checkpoint;
  * **restart** — ``latest_step`` finds the newest committed step; the data
    pipeline state rides in the manifest (one int — see data/pipeline.py);
  * **elastic restore** — arrays are saved unsharded and ``restore`` places
    them with the *target* mesh's shardings, so the job can come back on a
    different topology (tested: 8-device save -> 4-device restore).

At real 1000-node scale the per-leaf ``np.save`` would be a per-shard
distributed write (Orbax/TensorStore); the manager interface (save /
restore / latest_step / gc) is the same — swapping the IO layer does not
touch the training loop.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def save(root: str, step: int, tree, extra: dict | None = None,
         keep_last: int = 3, commit: bool = True) -> str:
    """Atomically persist a pytree.  Returns the committed directory.

    ``commit=False`` writes every leaf but skips the atomic rename —
    the fault injector's crash-mid-commit hook (robust/faults.py): the
    orphaned ``.tmp`` must be invisible to ``latest_step``/``restore``.
    """
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = [leaf for _, leaf in flat]
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        # per-leaf identity so restore can name a divergence instead of
        # failing deep in np.load
        "paths": [jax.tree_util.keystr(kp) for kp, _ in flat],
        "shapes": [list(np.shape(leaf)) for leaf in leaves],
        "dtypes": [str(np.asarray(leaf).dtype) for leaf in leaves],
        "extra": extra or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub":
            # np.save can't round-trip bf16/ml_dtypes (kind 'V'): widen to
            # f32; restore casts back to the target leaf's dtype
            arr = arr.astype(np.float32)
        np.save(os.path.join(tmp, _leaf_name(i)), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if not commit:
        return tmp  # crash before the rename: checkpoint never happened
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    _gc(root, keep_last)
    return final


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = []
    for d in os.listdir(root):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(root, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(root: str, step: int, like_tree, shardings=None):
    """Load a checkpoint into the structure of ``like_tree``.

    ``shardings`` (optional pytree of NamedSharding) reshards on load —
    this is the elastic-restart path: the saved arrays are full, the target
    mesh decides the placement.
    Returns (tree, extra).
    """
    d = os.path.join(root, f"step_{step:09d}")
    if not os.path.isdir(d):
        raise ValueError(
            f"no committed checkpoint step_{step:09d} under {root!r} "
            f"(latest committed: {latest_step(root)})")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = [leaf for _, leaf in flat]
    paths = [jax.tree_util.keystr(kp) for kp, _ in flat]
    ck_paths = manifest.get("paths")
    if ck_paths is not None and ck_paths != paths:
        missing = [p for p in paths if p not in ck_paths]
        extra_l = [p for p in ck_paths if p not in paths]
        raise ValueError(
            f"checkpoint {d} does not match the target structure: "
            f"missing from checkpoint: {missing or 'none'}; "
            f"extra in checkpoint: {extra_l or 'none'}"
            + ("" if missing or extra_l else
               f"; leaf order differs: {ck_paths} vs {paths}"))
    if manifest["num_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint {d} has {manifest['num_leaves']} leaves, target "
            f"structure has {len(leaves)} — structures diverged (manifest "
            "predates per-leaf paths, so the divergent leaf cannot be "
            "named)")
    ck_shapes = manifest.get("shapes")
    if ck_shapes is not None:
        for i, ref in enumerate(leaves):
            if tuple(ck_shapes[i]) != tuple(np.shape(ref)):
                raise ValueError(
                    f"checkpoint {d} leaf {paths[i]!r} has shape "
                    f"{tuple(ck_shapes[i])}, target expects "
                    f"{tuple(np.shape(ref))}")
    loaded = []
    shard_leaves = (
        jax.tree.leaves(shardings) if shardings is not None else [None] * len(leaves)
    )
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(d, _leaf_name(i)))
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(
                f"checkpoint {d} leaf {paths[i]!r} ({_leaf_name(i)}) has "
                f"shape {tuple(arr.shape)}, target expects "
                f"{tuple(np.shape(ref))}")
        jarr = jax.numpy.asarray(arr).astype(ref.dtype)
        if shd is not None:
            jarr = jax.device_put(jarr, shd)
        loaded.append(jarr)
    return jax.tree.unflatten(treedef, loaded), manifest["extra"]


def _gc(root: str, keep_last: int):
    steps = sorted(
        int(d.split("_")[1])
        for d in os.listdir(root)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(root, f"step_{s:09d}"), ignore_errors=True)
