"""Fault-tolerant checkpointing: atomic commits, resharding restore.

Layout of one checkpoint::

    <dir>/step_000042/
        manifest.json        # treedef, shapes, dtypes, step, data_state
        leaf_00000.npy ...   # one file per pytree leaf

Guarantees:
  * **atomicity** — written to ``step_N.tmp`` then ``os.rename``d; a crash
    mid-write can never corrupt the latest valid checkpoint;
  * **restart** — ``latest_step`` finds the newest committed step; the data
    pipeline state rides in the manifest (one int — see data/pipeline.py);
  * **elastic restore** — arrays are saved unsharded and ``restore`` places
    them with the *target* mesh's shardings, so the job can come back on a
    different topology (tested: 8-device save -> 4-device restore).

At real 1000-node scale the per-leaf ``np.save`` would be a per-shard
distributed write (Orbax/TensorStore); the manager interface (save /
restore / latest_step / gc) is the same — swapping the IO layer does not
touch the training loop.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def save(root: str, step: int, tree, extra: dict | None = None,
         keep_last: int = 3) -> str:
    """Atomically persist a pytree.  Returns the committed directory."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = jax.tree.flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "extra": extra or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub":
            # np.save can't round-trip bf16/ml_dtypes (kind 'V'): widen to
            # f32; restore casts back to the target leaf's dtype
            arr = arr.astype(np.float32)
        np.save(os.path.join(tmp, _leaf_name(i)), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    _gc(root, keep_last)
    return final


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = []
    for d in os.listdir(root):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(root, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(root: str, step: int, like_tree, shardings=None):
    """Load a checkpoint into the structure of ``like_tree``.

    ``shardings`` (optional pytree of NamedSharding) reshards on load —
    this is the elastic-restart path: the saved arrays are full, the target
    mesh decides the placement.
    Returns (tree, extra).
    """
    d = os.path.join(root, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(like_tree)
    assert manifest["num_leaves"] == len(leaves), (
        f"checkpoint has {manifest['num_leaves']} leaves, "
        f"target structure has {len(leaves)}"
    )
    loaded = []
    shard_leaves = (
        jax.tree.leaves(shardings) if shardings is not None else [None] * len(leaves)
    )
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(d, _leaf_name(i)))
        assert tuple(arr.shape) == tuple(ref.shape), (i, arr.shape, ref.shape)
        jarr = jax.numpy.asarray(arr).astype(ref.dtype)
        if shd is not None:
            jarr = jax.device_put(jarr, shd)
        loaded.append(jarr)
    return jax.tree.unflatten(treedef, loaded), manifest["extra"]


def _gc(root: str, keep_last: int):
    steps = sorted(
        int(d.split("_")[1])
        for d in os.listdir(root)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(root, f"step_{s:09d}"), ignore_errors=True)
