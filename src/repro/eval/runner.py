"""Declarative sweep runner — the measurement engine behind every figure.

A sweep is a grid of (trace family × policy × associativity × backend ×
admission × seed) points, all replayed with the exact sequential semantics of
``core/simulate.replay`` (B=1: get at logical time t, put-on-miss at t+1).

The speed trick (DESIGN.md §7): points whose cache *shape* matches are
stacked along a leading config axis and replayed by ONE compiled
``lax.scan`` whose step is ``vmap``-ed over the stack.  Two things make the
stack wide:

  * traces are data — every (family, seed) pair rides the same compilation;
  * the eviction policy is data too — ``policies.victim_scores_dyn`` and
    friends dispatch on a *traced* policy index, so LRU/LFU/FIFO/RANDOM/
    HYPERBOLIC all share one program (jnp path).

The pallas path keeps the policy static (the kernel specializes victim
scoring at trace time), so its groups are per (shape × policy) — still
independent of families and seeds.  Net effect: a quick grid of
``4 families × 3 policies × 5 associativities × 2 backends`` compiles
O(shapes) programs, not O(configs); ``trace_counts()`` exposes the actual
compile tally and tests assert on it.

Replay here *is* the jnp/pallas backend semantics at batch size 1 — the
equivalence test (tests/test_eval_runner.py) pins runner hit counts to
``simulate.replay`` bit-for-bit, per policy, including sampled and
fully-associative shapes.
"""
from __future__ import annotations

import collections
import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admission, hashing, kway, traces
from repro.core.hashing import EMPTY_KEY
from repro.core.kway import NEG_INF, KWayConfig
from repro.core.policies import (Policy, on_hit, on_hit_dyn, on_insert,
                                 on_insert_dyn, victim_scores_dyn)

HASH_SEED = KWayConfig.__dataclass_fields__["seed"].default

# Trace-time side effect: each body below bumps its group key once per XLA
# compilation, so tests can assert "O(shapes), not O(configs)" directly.
_TRACE_COUNTS: collections.Counter = collections.Counter()


def trace_counts() -> dict:
    """Compilation tally of the stacked replay kernels, keyed by group."""
    return dict(_TRACE_COUNTS)


def reset_trace_counts() -> None:
    _TRACE_COUNTS.clear()


# ---------------------------------------------------------------------------
# sweep grid
# ---------------------------------------------------------------------------

#: associativity descriptors: name -> (num_sets, ways, sample) for a capacity
def assoc_shape(assoc: str, capacity: int) -> tuple[int, int, int]:
    """Resolve an associativity descriptor ("k8", "sampled8", "full")."""
    if assoc == "full":
        return 1, capacity, 0
    if assoc.startswith("sampled"):
        return 1, capacity, int(assoc[len("sampled"):])
    if assoc.startswith("k"):
        k = int(assoc[1:])
        if capacity % k:
            raise ValueError(f"capacity {capacity} not divisible by k={k}")
        return capacity // k, k, 0
    raise ValueError(f"unknown associativity descriptor {assoc!r}")


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One cell of a hit-ratio grid (a single replay)."""

    family: str
    policy: Policy
    assoc: str                 # "k4" | "sampled8" | "full" | ...
    capacity: int
    backend: str = "jnp"
    admission: str = "none"    # "none" | "tinylfu"
    seed: int = 42
    n: int = 60_000

    @property
    def shape(self) -> tuple[int, int, int]:
        return assoc_shape(self.assoc, self.capacity)

    @property
    def record_id(self) -> str:
        """Stable identity for baseline joins (seed-independent)."""
        return (f"{self.family}/{self.policy.name}/{self.assoc}"
                f"/{self.backend}/{self.admission}")


@dataclasses.dataclass(frozen=True)
class HitRatioSpec:
    """A declarative grid; ``expand()`` yields the supported points."""

    families: tuple = ("zipf", "zipf_shift", "scan_loop", "oltp_mix")
    policies: tuple = (Policy.LRU, Policy.LFU, Policy.HYPERBOLIC)
    assoc: tuple = ("k4", "k8", "k32", "sampled8", "full")
    backends: tuple = ("jnp",)
    admissions: tuple = ("none",)
    capacity: int = 1024
    n: int = 60_000
    seeds: tuple = (42,)
    # family -> extra kwargs for traces.generate, e.g.
    # {"scan_loop": {"working": 1536, "noise": 0.1}}
    trace_kwargs: dict = dataclasses.field(default_factory=dict)

    def expand(self) -> tuple[list[SweepPoint], list[str]]:
        """-> (points, skipped) — skipped lists unsupported combos loudly."""
        points, skipped = [], []
        for fam in self.families:
            for pol in self.policies:
                for assoc in self.assoc:
                    s, k, sample = assoc_shape(assoc, self.capacity)
                    for be in self.backends:
                        reason = _backend_unsupported(be, k, sample)
                        if reason:
                            skipped.append(
                                f"{fam}/{pol.name}/{assoc}/{be}: {reason}")
                            continue
                        for adm in self.admissions:
                            for seed in self.seeds:
                                points.append(SweepPoint(
                                    family=fam, policy=pol, assoc=assoc,
                                    capacity=self.capacity, backend=be,
                                    admission=adm, seed=seed, n=self.n))
        return points, sorted(set(skipped))

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["policies"] = [p.name for p in self.policies]
        return d


def _backend_unsupported(backend: str, ways: int, sample: int) -> Optional[str]:
    if backend == "pallas":
        from repro.kernels import kway_probe as _kp
        if sample:
            return "pallas backend does not support sampled policies"
        if ways > _kp.LANES:
            return f"pallas backend requires ways <= {_kp.LANES}"
    elif backend == "ref":
        return ("ref backend is the sequential Python oracle, not a sweep "
                "substrate (use the golden differential tests)")
    elif backend != "jnp":
        return f"unknown backend {backend!r}"
    return None


# ---------------------------------------------------------------------------
# stacked replay kernels
#
# State is a stack of per-config caches: keys/meta [C, S, K], clock [C].
# One scan step replays one request per config lane, reproducing the
# sequential backend semantics exactly: get at time `clock` (hit -> on_hit
# metadata), put-on-miss at time `clock + 1` (victim scored then), clock += 2.
# ---------------------------------------------------------------------------

def _victim_way(num_sets, ways, sample, pidx, keys_row, ma_row, mb_row, now):
    """Victim way of one set row at logical time `now` (B=1 semantics of
    core/kway._victim_order: empty ways first, sampled draw when sample>0)."""
    if 0 < sample < ways:
        way_ids = kway.sampled_way_ids(sample, ways, now)
        ks = keys_row[way_ids]
        scores = victim_scores_dyn(
            pidx, ma_row[way_ids], mb_row[way_ids], now, ks)
        scores = jnp.where(ks == EMPTY_KEY, NEG_INF, scores)
        return way_ids[jnp.argmin(scores)]
    scores = victim_scores_dyn(pidx, ma_row, mb_row, now, keys_row)
    scores = jnp.where(keys_row == EMPTY_KEY, NEG_INF, scores)
    return jnp.argmin(scores).astype(jnp.int32)


def _scan_replay(init_lane, step_lane, trace_cn, tinylfu):
    """Shared scan harness: vmap `step_lane` over the config stack."""
    C, _ = trace_cn.shape
    lanes = jax.vmap(init_lane)(jnp.arange(C))
    sketch = (jax.vmap(lambda _: admission.make_sketch(tinylfu))(jnp.arange(C))
              if tinylfu else jnp.zeros((C,), jnp.int32))
    vstep = jax.vmap(step_lane)

    def step(carry, keys_c):
        lanes, sketch, hits = carry
        lanes, sketch, hit = vstep(lanes, sketch, keys_c)
        return (lanes, sketch, hits + hit.astype(jnp.int32)), ()

    (_, _, hits), _ = jax.lax.scan(
        step, (lanes, sketch, jnp.zeros((C,), jnp.int32)), trace_cn.T)
    return hits


@partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def _replay_group_jnp(num_sets, ways, sample, hash_seed, tinylfu,
                      pidx, trace_cn):
    """One compiled replay for a stack of same-shape jnp configs.

    pidx int32 [C] (traced policy index), trace_cn uint32 [C, N] -> hits [C].
    """
    _TRACE_COUNTS[("jnp", num_sets, ways, sample, trace_cn.shape[1],
                   tinylfu is not None)] += 1

    # The per-lane policy index rides inside the lane tuple so one vmap maps
    # state, sketch, keys and policy together.
    def init_lane(i):
        return (jnp.full((num_sets, ways), EMPTY_KEY, jnp.uint32),
                jnp.zeros((num_sets, ways), jnp.int32),
                jnp.zeros((num_sets, ways), jnp.int32),
                jnp.zeros((), jnp.int32),
                pidx[i])

    def step_lane(lane, sketch, raw):
        keys, ma, mb, clock, p = lane
        (keys, ma, mb, clock), sketch, hit = _step_jnp(
            num_sets, ways, sample, hash_seed, tinylfu,
            p, keys, ma, mb, clock, sketch, raw)
        return (keys, ma, mb, clock, p), sketch, hit

    return _scan_replay(init_lane, step_lane, trace_cn, tinylfu)


def _step_jnp(num_sets, ways, sample, hash_seed, tinylfu,
              pidx1, keys, ma, mb, clock, sketch, raw):
    """One request through one config lane (jnp probe, dynamic policy)."""
    qkey = hashing.sanitize_keys(raw[None])[0]
    s = hashing.set_index(qkey[None], num_sets, hash_seed)[0]
    row = keys[s]
    eq = (row == qkey) & (row != EMPTY_KEY)
    hit = jnp.any(eq)
    way = jnp.argmax(eq).astype(jnp.int32)

    ok = jnp.bool_(True)
    if tinylfu is not None:
        # Phase order of simulate._replay_scan: record, peek victim at time
        # `clock` (pre-get), admission-gate the miss insert.
        sketch = admission.record(tinylfu, sketch, qkey[None])
        vway0 = _victim_way(num_sets, ways, sample, pidx1, row, ma[s], mb[s],
                            clock)
        vkey0 = row[vway0]
        vvalid = (vkey0 != EMPTY_KEY) & ~hit
        ok = admission.admit(tinylfu, sketch, qkey[None], vkey0[None],
                             vvalid[None])[0]

    # get phase at time `clock`
    ha, hb = on_hit_dyn(pidx1, ma[s, way], mb[s, way], clock)
    ma = ma.at[s, way].set(jnp.where(hit, ha, ma[s, way]))
    mb = mb.at[s, way].set(jnp.where(hit, hb, mb[s, way]))

    # put phase at time `clock + 1`, miss lanes only (hit lanes are disabled
    # in access(); a miss leaves the metadata untouched, so scoring the
    # post-get state equals scoring the pre-get state here)
    t_put = clock + 1
    vway = _victim_way(num_sets, ways, sample, pidx1, row, ma[s], mb[s], t_put)
    ia, ib = on_insert_dyn(pidx1, t_put)
    do = ~hit & ok
    keys = keys.at[s, vway].set(jnp.where(do, qkey, keys[s, vway]))
    ma = ma.at[s, vway].set(jnp.where(do, ia, ma[s, vway]))
    mb = mb.at[s, vway].set(jnp.where(do, ib, mb[s, vway]))
    return (keys, ma, mb, clock + 2), sketch, hit


@partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def _replay_group_pallas(num_sets, ways, hash_seed, policy, tinylfu, trace_cn):
    """One compiled replay for a stack of same-shape pallas configs.

    The kernel specializes the policy at trace time, so the stack spans
    (family × seed) only; trace_cn uint32 [C, N] -> hits [C].
    """
    from repro.kernels import kway_probe as _kp
    _TRACE_COUNTS[("pallas", num_sets, ways, 0, trace_cn.shape[1],
                   tinylfu is not None, int(policy))] += 1
    interpret = jax.default_backend() != "tpu"
    qt = 8

    def pad_ways(arr, fill=-1):
        s, k = arr.shape
        if k == _kp.LANES:
            return arr
        return jnp.concatenate(
            [arr, jnp.full((s, _kp.LANES - k), fill, arr.dtype)], axis=1)

    def probe1(keys, fpr, ma, mb, qkey, t):
        """Kernel probe of one query; scalar outputs (s, hit, way, vway)."""
        sets = hashing.set_index(qkey[None], num_sets, hash_seed)
        zpad = jnp.zeros((qt - 1,), jnp.int32)
        hit, way, vway, _ = _kp.kway_probe(
            pad_ways(keys.astype(jnp.int32)),
            pad_ways(fpr.astype(jnp.int32), fill=0),
            pad_ways(ma), pad_ways(mb),
            jnp.concatenate([sets, zpad]),
            jnp.concatenate([qkey[None].astype(jnp.int32), zpad]),
            jnp.concatenate([t[None], zpad]),
            policy=int(policy), ways=ways, qt=qt, interpret=interpret,
            full_order=False)
        return sets[0], hit[0].astype(jnp.bool_), way[0], vway[0]

    def init_lane(_):
        return (jnp.full((num_sets, ways), EMPTY_KEY, jnp.uint32),
                jnp.zeros((num_sets, ways), jnp.uint32),   # fingerprints
                jnp.zeros((num_sets, ways), jnp.int32),
                jnp.zeros((num_sets, ways), jnp.int32),
                jnp.zeros((), jnp.int32))

    def step_lane(lane, sketch, raw):
        keys, fpr, ma, mb, clock = lane
        qkey = hashing.sanitize_keys(raw[None])[0]
        t_put = clock + 1
        # One probe at t_put serves both phases: hit/way are time-independent
        # and a miss leaves the get-phase metadata untouched, so the victim
        # scored on the pre-get state at t_put matches PallasBackend.put.
        s, hit, way, vway = probe1(keys, fpr, ma, mb, qkey, t_put)

        ok = jnp.bool_(True)
        if tinylfu is not None:
            # peek_victims probes at time `clock` (pre-get) — a separate
            # kernel probe because RANDOM victim scores depend on the time.
            sketch = admission.record(tinylfu, sketch, qkey[None])
            _, _, _, vway0 = probe1(keys, fpr, ma, mb, qkey, clock)
            vkey0 = keys[s, vway0]
            vvalid = (vkey0 != EMPTY_KEY) & ~hit
            ok = admission.admit(tinylfu, sketch, qkey[None], vkey0[None],
                                 vvalid[None])[0]

        ha, hb = on_hit(policy, ma[s, way], mb[s, way], clock)
        ma = ma.at[s, way].set(jnp.where(hit, ha, ma[s, way]))
        mb = mb.at[s, way].set(jnp.where(hit, hb, mb[s, way]))
        ia, ib = on_insert(policy, t_put)
        do = ~hit & ok
        keys = keys.at[s, vway].set(jnp.where(do, qkey, keys[s, vway]))
        fpr = fpr.at[s, vway].set(jnp.where(
            do, hashing.fingerprint(qkey[None])[0], fpr[s, vway]))
        ma = ma.at[s, vway].set(jnp.where(do, ia, ma[s, vway]))
        mb = mb.at[s, vway].set(jnp.where(do, ib, mb[s, vway]))
        return (keys, fpr, ma, mb, clock + 2), sketch, hit

    return _scan_replay(init_lane, step_lane, trace_cn, tinylfu)


# ---------------------------------------------------------------------------
# sharded replay of grid points
# ---------------------------------------------------------------------------

def replay_sharded_point(point: SweepPoint, shards: int, batch: int = 256,
                         trace: Optional[np.ndarray] = None) -> float:
    """Hit ratio of one sweep-grid point replayed through the set-sharded
    batched path (``simulate.replay_batched`` with ``shards=D`` — a single
    jitted ``lax.scan`` with device-resident routing since PR 4).

    Batched conflict resolution perturbs hit ratios slightly relative to the
    grid's exact B=1 replay, so callers gate these values against the B=1
    baselines with a small band (DESIGN.md §9), not bit-exactly.
    """
    from repro.core import simulate, traces as _traces

    s, k, sample = point.shape
    cfg = KWayConfig(num_sets=s, ways=k, policy=point.policy, sample=sample)
    tlfu = (admission.for_capacity(point.capacity)
            if point.admission == "tinylfu" else None)
    if trace is None:
        trace = _traces.generate(point.family, point.n, seed=point.seed)
    sim = simulate.SimConfig(cache=cfg, tinylfu=tlfu, backend=point.backend)
    return simulate.replay_batched(sim, trace, batch=batch, shards=shards)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _trace_cache(points: list[SweepPoint], trace_kwargs: dict) -> dict:
    cache = {}
    for p in points:
        key = (p.family, p.seed, p.n)
        if key not in cache:
            cache[key] = traces.generate(
                p.family, p.n, seed=p.seed, **trace_kwargs.get(p.family, {}))
    return cache


def run_hit_ratio_sweep(spec: HitRatioSpec, progress=None):
    """Execute the grid.  Returns (records, skipped).

    Each record aggregates one grid cell over ``spec.seeds``:
    ``{"id", "figure"-free config fields, "metric": "hit_ratio",
    "value": mean, "per_seed": [...], "comparable": True}``.
    """
    points, skipped = spec.expand()
    tr = _trace_cache(points, spec.trace_kwargs)
    tlfu = admission.for_capacity(spec.capacity)

    groups: dict = collections.defaultdict(list)
    for p in points:
        s, k, sample = p.shape
        adm = tlfu if p.admission == "tinylfu" else None
        if p.backend == "pallas":
            gkey = ("pallas", s, k, sample, p.n, adm, p.policy)
        else:
            gkey = ("jnp", s, k, sample, p.n, adm)
        groups[gkey].append(p)

    counts_before = collections.Counter(_TRACE_COUNTS)
    hit_ratio: dict[SweepPoint, float] = {}
    for gkey, pts in groups.items():
        backend, s, k, sample, n, adm = gkey[:6]
        if progress:
            progress(f"group {backend}/S{s}xK{k}"
                     f"{f'/sample{sample}' if sample else ''} "
                     f"({len(pts)} configs stacked)")
        trace_cn = jnp.asarray(
            np.stack([tr[(p.family, p.seed, p.n)] for p in pts]))
        if backend == "pallas":
            hits = _replay_group_pallas(s, k, HASH_SEED, gkey[6], adm,
                                        trace_cn)
        else:
            pidx = jnp.asarray([int(p.policy) for p in pts], jnp.int32)
            hits = _replay_group_jnp(s, k, sample, HASH_SEED, adm,
                                     pidx, trace_cn)
        for p, h in zip(pts, np.asarray(hits)):
            hit_ratio[p] = float(h) / p.n

    # Compile economy invariant: the (now fused single-probe) stacked replay
    # must still compile once per cache *shape* group, never once per config.
    # Each group triggers at most one fresh trace (jit may also reuse an
    # earlier sweep's program, hence <=, not ==); a regression that makes the
    # step retrace per stacked lane would blow past len(groups) immediately.
    new_compiles = sum((collections.Counter(_TRACE_COUNTS)
                        - counts_before).values())
    assert new_compiles <= len(groups), (
        f"stacked sweep compiled {new_compiles} replay programs for "
        f"{len(groups)} shape groups — the fused replay step is being "
        "retraced per config instead of once per cache shape")

    records = []
    seen = set()
    for p in points:
        if p.record_id in seen:
            continue
        seen.add(p.record_id)
        per_seed = [hit_ratio[dataclasses.replace(p, seed=sd)]
                    for sd in spec.seeds]
        s, k, sample = p.shape
        records.append({
            "id": p.record_id,
            "family": p.family, "policy": p.policy.name, "assoc": p.assoc,
            "num_sets": s, "ways": k, "sample": sample,
            "capacity": p.capacity, "backend": p.backend,
            "admission": p.admission, "n": p.n, "seeds": list(spec.seeds),
            "metric": "hit_ratio",
            "value": float(np.mean(per_seed)),
            "per_seed": per_seed,
            "comparable": True,
        })
    return records, skipped
