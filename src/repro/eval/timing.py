"""Timing helpers shared by the throughput figures and benchmark shims.

Every percentile timer follows the same warmup-discard + steady-state
protocol: ``warmup`` repetitions are run and *discarded* (compilation,
allocator ramp-up, cache warm-up), then ``iters`` steady-state repetitions
are timed, each blocking on its result before the next starts.  PR 4 noted
tick-p50 jitter on shared CI boxes, so the discard counts are part of the
measurement's provenance: each timer reports ``reps_discarded`` in its
result dict and tallies into a module counter that the artifact writer
snapshots into the ``env`` block (``artifacts.make_artifact``).
"""
import time

import jax

#: running tally of the current process's timing protocol — snapshotted into
#: every artifact's env block so a baseline diff can see how many warmup
#: repetitions were discarded (and how many steady-state samples were kept)
#: for the numbers it is comparing.
_PROVENANCE = {"reps_discarded": 0, "steady_reps": 0, "timers": 0}


def timing_provenance() -> dict:
    """Snapshot of the warmup-discard / steady-state tallies."""
    return dict(_PROVENANCE)


def reset_timing_provenance() -> None:
    for k in _PROVENANCE:
        _PROVENANCE[k] = 0


def _tally(warmup: int, iters: int) -> None:
    _PROVENANCE["reps_discarded"] += warmup
    _PROVENANCE["steady_reps"] += iters
    _PROVENANCE["timers"] += 1


def _steady_state_samples(fn, *args, iters=20, warmup=5):
    """Per-repetition wall times of an already-jitted fn, seconds.

    Every repetition (the discarded warmup included) blocks on the result
    before the next starts, so each sample is one complete dispatch+execute
    round trip — the single wall-clock-over-n-calls number this replaces hid
    dispatch pipelining and was noisy across CI machines.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    _tally(warmup, iters)
    return samples


def _percentile(sorted_samples, p):
    """Nearest-rank percentile of an already-sorted sample list."""
    n = len(sorted_samples)
    idx = min(n - 1, max(0, int(round(p / 100.0 * (n - 1)))))
    return sorted_samples[idx]


def time_jitted(fn, *args, iters=20, warmup=5):
    """Median (p50) wall time per call of an already-jitted fn (seconds)."""
    samples = sorted(_steady_state_samples(fn, *args, iters=iters,
                                           warmup=warmup))
    return _percentile(samples, 50)


def time_jitted_percentiles(fn, *args, iters=30, warmup=5):
    """Steady-state timing distribution of an already-jitted fn.

    Returns {"p50": s, "p90": s, "iters": n, "reps_discarded": warmup} —
    p50 is the headline, p90 exposes tail jitter (GC, scheduler) that a
    single mean hides, and ``reps_discarded`` records how many warmup
    repetitions were dropped before the steady-state window.
    """
    samples = sorted(_steady_state_samples(fn, *args, iters=iters,
                                           warmup=warmup))
    return {"p50": _percentile(samples, 50),
            "p90": _percentile(samples, 90),
            "iters": len(samples),
            "reps_discarded": warmup}


def time_chained_percentiles(step, iters=30, warmup=5):
    """Like ``time_jitted_percentiles`` for *state-chaining* callables.

    ``step()`` must advance its own state (e.g. rebinding a donated cache
    state) and return something blockable.  Used for the buffer-donating
    access path, where re-passing the same argument would poke a donated
    (deleted) buffer.
    """
    for _ in range(warmup):
        jax.block_until_ready(step())
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(step())
        samples.append(time.perf_counter() - t0)
    samples.sort()
    _tally(warmup, iters)
    return {"p50": _percentile(samples, 50),
            "p90": _percentile(samples, 90),
            "iters": len(samples),
            "reps_discarded": warmup}


def time_replay_percentiles(replay, iters=5, warmup=1):
    """p50/p90 wall time of a whole-trace replay callable (seconds).

    For the scanned/resident replay paths: ``replay()`` runs an entire
    trace inside one jitted call (or one megakernel launch) and blocks
    exactly once (converting the hit count to a Python int *is* the single
    host synchronization) — so each sample covers the full replay with no
    per-chunk dispatch or transfers, which is what the figure's
    no-host-sync rows certify.

    The timer blocks on ``replay()``'s return value itself: a callable that
    returns an unrealized device array would otherwise be timed
    dispatch-only (JAX dispatch is async on every backend, CPU included).
    For callables that already sync — returning a Python int/float — the
    block is a no-op.
    """
    for _ in range(warmup):
        jax.block_until_ready(replay())
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(replay())
        samples.append(time.perf_counter() - t0)
    samples.sort()
    _tally(warmup, iters)
    return {"p50": _percentile(samples, 50),
            "p90": _percentile(samples, 90),
            "iters": len(samples),
            "reps_discarded": warmup}


def time_host(fn, *args, iters=3):
    """Mean wall time per call of a host-side (non-jitted) callable."""
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    dt = (time.perf_counter() - t0) / iters
    _tally(0, iters)
    return dt
