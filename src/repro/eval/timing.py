"""Timing helpers shared by the throughput figures and benchmark shims."""
import time

import jax


def time_jitted(fn, *args, iters=20, warmup=3):
    """Median wall time per call of an already-jitted fn (seconds)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def time_host(fn, *args, iters=3):
    """Mean wall time per call of a host-side (non-jitted) callable."""
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters
