"""Schema-versioned benchmark artifacts + baseline regression gating.

Every figure run produces one JSON artifact (``BENCH_<figure>.json`` by
default) that is machine-joinable against a checked-in baseline:

    {
      "schema_version": 1,
      "kind": "repro.eval.artifact",
      "figure": "hit_ratio_vs_associativity",
      "env":    {python/jax/numpy versions, platform, device kind/count},
      "spec":   {the declarative sweep grid, incl. seeds and trace families},
      "skipped": ["...unsupported combos, never silently dropped..."],
      "records": [{"id": "zipf/LRU/k8/jnp/none", "metric": "hit_ratio",
                   "value": 0.83, "per_seed": [...], "comparable": true,
                   ...config fields...}, ...]
    }

``records[*].id`` is the stable join key.  Records with ``comparable: true``
(deterministic metrics — hit ratios) are tolerance-gated against the
baseline; timing records (``mops_per_s``, ``tok_per_s``) carry
``comparable: false`` and are stored for trend inspection only, because CI
machines differ.  Baseline workflow: see DESIGN.md §7.
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time

SCHEMA_VERSION = 1
KIND = "repro.eval.artifact"
DEFAULT_TOL = 0.01  # hit ratios are deterministic; tol absorbs lib drift


def environment() -> dict:
    import jax
    import numpy as np
    try:
        import jaxlib
        jaxlib_version = jaxlib.__version__
    except Exception:  # pragma: no cover - jaxlib always ships with jax
        jaxlib_version = "unknown"
    from repro.eval import timing
    return {
        "python": sys.version.split()[0],
        "jax": jax.__version__,
        "jaxlib": jaxlib_version,
        "numpy": np.__version__,
        "platform": platform.platform(),
        "jax_backend": jax.default_backend(),
        "device_count": jax.device_count(),
        # warmup-discard / steady-state tallies of every timer that ran in
        # this process before the artifact was written (eval/timing.py) —
        # the jitter provenance PR 4's tick-p50 wobble called for
        "timing": timing.timing_provenance(),
    }


def make_artifact(figure: str, spec: dict, records: list,
                  skipped: list | None = None) -> dict:
    return {
        "schema_version": SCHEMA_VERSION,
        "kind": KIND,
        "figure": figure,
        "created_unix": int(time.time()),
        "env": environment(),
        "spec": spec,
        "skipped": skipped or [],
        "records": records,
    }


def write_artifact(path: str, artifact: dict) -> str:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(artifact, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def load_artifact(path: str) -> dict:
    with open(path) as f:
        art = json.load(f)
    if art.get("kind") != KIND:
        raise ValueError(f"{path}: not a {KIND} file")
    if art.get("schema_version") != SCHEMA_VERSION:
        raise ValueError(
            f"{path}: schema_version {art.get('schema_version')} != "
            f"{SCHEMA_VERSION} — regenerate the baseline "
            "(python -m repro.eval ... --out <baseline>)")
    return art


def compare_to_baseline(fresh: dict, baseline: dict,
                        tol: float = DEFAULT_TOL) -> list[str]:
    """Diff a fresh artifact against a baseline.  Returns breach strings
    (empty == pass).  Rules:

      * every ``comparable`` baseline record must exist in the fresh run
        (missing coverage is a breach, not a skip);
      * |fresh - baseline| must be <= the record's ``tol`` (or ``tol`` arg);
      * non-comparable (timing) records are ignored.
    """
    if fresh.get("figure") != baseline.get("figure"):
        return [f"figure mismatch: fresh={fresh.get('figure')!r} "
                f"baseline={baseline.get('figure')!r}"]
    fresh_by_id = {r["id"]: r for r in fresh["records"]}
    breaches = []
    for base in baseline["records"]:
        if not base.get("comparable", False):
            continue
        rid = base["id"]
        new = fresh_by_id.get(rid)
        if new is None:
            breaches.append(f"{rid}: present in baseline, missing from run")
            continue
        limit = base.get("tol", tol)
        delta = new["value"] - base["value"]
        if abs(delta) > limit:
            breaches.append(
                f"{rid}: {base['metric']} {new['value']:.4f} vs baseline "
                f"{base['value']:.4f} (delta {delta:+.4f} > tol {limit})")
    return breaches
