"""repro.eval — the paper-figure sweep subsystem.

One measurement path for every figure the paper's evidence rests on:

  * ``runner``    — declarative sweep grids (trace family × policy × ways ×
    backend × admission), replayed through a config-stacked, vmapped
    ``lax.scan`` that compiles once per cache *shape* instead of once per
    config (DESIGN.md §7).
  * ``figures``   — figure-by-figure reproduction entry points
    (``hit_ratio_vs_associativity``, ``throughput_vs_batch``,
    ``sampled_vs_limited``, ``admission_ablation``, ...).
  * ``artifacts`` — schema-versioned ``BENCH_*.json`` artifacts with
    env/seed/config provenance, plus baseline comparison with tolerance
    gating (the CI regression guard).
  * ``python -m repro.eval --fig <name> [--quick] [--baseline f.json]`` —
    the CLI over all of the above.

The ad-hoc ``benchmarks/*.py`` scripts are thin shims over this package.
"""
from repro.eval import artifacts, figures, runner  # noqa: F401
