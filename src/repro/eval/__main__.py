"""CLI: reproduce a paper figure, emit its artifact, gate against a baseline.

    PYTHONPATH=src python -m repro.eval --fig hit_ratio --quick
    PYTHONPATH=src python -m repro.eval --fig hit_ratio --quick \
        --baseline benchmarks/baselines/quick.json        # exit 2 on breach

Exit codes: 0 ok, 1 usage/figure error, 2 baseline tolerance breach.
Baseline update workflow: DESIGN.md §7 (run with --out pointed at the
checked-in baseline and commit the diff after review).
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.eval import artifacts
from repro.eval.figures import FIGURES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.eval",
        description="Paper-figure sweep harness (see DESIGN.md §7).")
    ap.add_argument("--fig", required=True,
                    choices=sorted(FIGURES) + ["all"],
                    help="figure family to reproduce")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized grid: fewer requests and a single seed")
    ap.add_argument("--out", default=None,
                    help="artifact path (default BENCH_<figure>.json)")
    ap.add_argument("--baseline", default=None,
                    help="compare against this artifact; non-zero exit on "
                         "tolerance breach")
    ap.add_argument("--tol", type=float, default=artifacts.DEFAULT_TOL,
                    help="default |delta| tolerance for comparable records "
                         f"(default {artifacts.DEFAULT_TOL})")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    names = sorted(FIGURES) if args.fig == "all" else [args.fig]
    if args.fig == "all" and args.out:
        ap.error("--out is per-figure; drop it with --fig all")
    if args.fig == "all" and args.baseline:
        ap.error("--baseline is per-figure; pick one --fig")

    rc = 0
    for name in names:
        fn, figure = FIGURES[name]
        progress = None if args.quiet else (
            lambda msg, _n=name: print(f"  [{_n}] {msg}", flush=True))
        t0 = time.time()
        if not args.quiet:
            print(f"== {figure} ({'quick' if args.quick else 'full'}) ==",
                  flush=True)
        spec, records, skipped = fn(quick=args.quick, progress=progress)
        art = artifacts.make_artifact(figure, spec, records, skipped)
        out = args.out or f"BENCH_{figure}.json"
        artifacts.write_artifact(out, art)
        if not args.quiet:
            for s in skipped:
                print(f"  skipped: {s}")
            print(f"  {len(records)} records -> {out} "
                  f"({time.time() - t0:.1f}s)", flush=True)

        if args.baseline:
            base = artifacts.load_artifact(args.baseline)
            breaches = artifacts.compare_to_baseline(art, base, tol=args.tol)
            if breaches:
                print(f"BASELINE BREACH vs {args.baseline}:",
                      file=sys.stderr)
                for b in breaches:
                    print(f"  {b}", file=sys.stderr)
                rc = 2
            elif not args.quiet:
                n_cmp = sum(1 for r in base["records"]
                            if r.get("comparable", False))
                print(f"  baseline ok: {n_cmp} comparable records within "
                      f"tolerance of {args.baseline}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
