"""Figure-by-figure reproduction entry points (paper Figs. 4-30).

Each function runs one figure family's sweep and returns
``(spec_dict, records, skipped)`` ready for ``artifacts.make_artifact``:

  * ``hit_ratio_vs_associativity`` — Figs. 4-13: hit ratio of k ∈ {4,8,32},
    sampled-8 and fully-associative caches per trace family × policy.
  * ``sampled_vs_limited``         — the Redis-style sampled-k full cache vs
    the paper's limited-associativity k-way cache at matched k.
  * ``admission_ablation``         — TinyLFU on/off at k=8 (paper §5.2).
  * ``throughput_vs_batch``        — Figs. 14-26 analogue: batch size stands
    in for thread count; layouts, backends and the sharded layer.
  * ``throughput_vs_shards``       — the threads-vs-throughput scaling plot:
    shards stand in for threads, each bringing its own per-tick request
    stream; includes the single-scan no-host-sync replay rows.
  * ``showdown``                   — Fig. 1 analogue: req/s vs thread count
    for production caches (cachetools + global lock, lock-striped k-way)
    next to our batched/resident device paths, with gateable hit-ratio
    parity records.
  * ``synthetic_mix``              — Figs. 27-30: fixed hit-rate workloads.
  * ``serving``                    — end-to-end prefix-cache serving rows.
  * ``serving_engine``             — host-loop vs device-resident jitted
    serving tick: req/s + tok/s percentiles and token/hit-ratio parity.

Hit-ratio figures run on the stacked sweep runner (one compile per cache
shape); throughput figures are wall-clock timed per configuration and are
marked non-comparable in artifacts (timings do not gate baselines).
"""
from __future__ import annotations

import numpy as np

from repro.core.policies import Policy
from repro.eval import runner
from repro.eval.runner import HitRatioSpec
from repro.eval.timing import (time_chained_percentiles, time_host,
                               time_jitted, time_jitted_percentiles,
                               time_replay_percentiles)

QUICK_N = 6_000
FULL_N = 60_000


def _run(spec: HitRatioSpec, progress=None):
    records, skipped = runner.run_hit_ratio_sweep(spec, progress=progress)
    return spec.to_dict(), records, skipped


def hit_ratio_vs_associativity(quick: bool = False, progress=None,
                               backends=("jnp", "pallas")):
    """Paper Figs. 4-13: the k=8 line sits on the fully-associative line."""
    spec = HitRatioSpec(
        families=("zipf", "zipf_shift", "scan_loop", "oltp_mix")
        if quick else ("zipf", "zipf_shift", "scan_loop", "oltp_mix",
                       "recency"),
        policies=(Policy.LRU, Policy.LFU, Policy.HYPERBOLIC),
        assoc=("k4", "k8", "k32", "sampled8", "full"),
        backends=tuple(backends),
        capacity=1024,
        n=QUICK_N if quick else FULL_N,
        seeds=(42,) if quick else (42, 43, 44),
    )
    return _run(spec, progress)


def sampled_vs_limited(quick: bool = False, progress=None):
    """Sampled-k full-associativity (Redis style) vs limited-associativity
    k-way at matched k — the paper's 'sampling is the wrong shortcut' plot."""
    spec = HitRatioSpec(
        families=("zipf", "scan_loop", "oltp_mix", "recency"),
        policies=(Policy.LRU, Policy.LFU),
        assoc=("k4", "sampled4", "k8", "sampled8", "k16", "sampled16",
               "full"),
        backends=("jnp",),
        capacity=1024,
        n=QUICK_N if quick else FULL_N,
        seeds=(42,) if quick else (42, 43, 44),
    )
    return _run(spec, progress)


def admission_ablation(quick: bool = False, progress=None,
                       admissions=("none", "tinylfu")):
    """TinyLFU admission on/off at k=8 (the paper pairs it with LFU)."""
    spec = HitRatioSpec(
        families=("zipf", "zipf_shift", "scan_loop", "oltp_mix"),
        policies=(Policy.LRU, Policy.LFU, Policy.HYPERBOLIC),
        assoc=("k8",),
        backends=("jnp",),
        admissions=tuple(admissions),
        capacity=1024,
        n=QUICK_N if quick else FULL_N,
        seeds=(42,) if quick else (42, 43, 44),
    )
    return _run(spec, progress)


# ---------------------------------------------------------------------------
# throughput figures (wall-clock; non-comparable in artifacts)
# ---------------------------------------------------------------------------

THROUGHPUT_CAPACITY = 4096


def _throughput_impls(policy):
    from repro.core.kway import KWayConfig, fully_associative
    return {
        "kway-soa": KWayConfig(num_sets=THROUGHPUT_CAPACITY // 8, ways=8,
                               policy=policy, layout="soa"),
        "kway-aos": KWayConfig(num_sets=THROUGHPUT_CAPACITY // 8, ways=8,
                               policy=policy, layout="aos"),
        "sampled": KWayConfig(num_sets=THROUGHPUT_CAPACITY // 128, ways=128,
                              policy=policy, sample=8),
        "full": fully_associative(THROUGHPUT_CAPACITY, policy),
    }


def _tp_record(name: str, batch: int, mops: float, **extra) -> dict:
    rec = {"id": f"{name}/batch{batch}", "impl": name, "batch": batch,
           "metric": "mops_per_s", "value": round(mops, 3),
           "comparable": False}
    rec.update(extra)
    return rec


def throughput_vs_batch(quick: bool = False, progress=None,
                        backends=("jnp", "pallas", "ref"), shards=(1, 4)):
    """Paper Figs. 14-26 analogue: ops/sec vs batch size (thread analogue)
    across layouts, the CacheBackend substrates, and the sharded layer."""
    import jax
    import jax.numpy as jnp
    from repro.core import kway, traces
    from repro.core.backend import make_backend
    from repro.core.sharded import ShardedCache, ShardedConfig

    batches = (64, 256) if quick else (64, 256, 1024)
    policy = Policy.LRU
    n_warm = 20_480
    tr = traces.generate("zipf", n_warm + 4096, seed=7, catalog=1 << 14)
    records = []

    def warm(cfg):
        state = kway.make_cache(cfg)
        for chunk in jnp.asarray(tr[:n_warm].reshape(-1, 512)):
            state, *_ = kway.access(cfg, state, chunk,
                                    chunk.astype(jnp.int32))
        return state

    soa_state = None
    for name, cfg in _throughput_impls(policy).items():
        if progress:
            progress(f"throughput impl {name}")
        state = warm(cfg)
        if name == "kway-soa":
            soa_state = state
        for b in batches:
            keys = jnp.asarray(tr[n_warm:n_warm + b])
            vals = keys.astype(jnp.int32)
            fn = jax.jit(lambda s, k, v: kway.access(cfg, s, k, v)[0])
            dt = time_jitted(fn, state, keys, vals)
            records.append(_tp_record(name, b, b / dt / 1e6))

    # unified backend layer: fused single-probe access vs the two-phase
    # get-then-put oracle, per backend, p50/p90 steady-state per repetition
    cfg = _throughput_impls(policy)["kway-soa"]
    state = soa_state if soa_state is not None else warm(cfg)
    for bname in backends:
        if progress:
            progress(f"throughput backend {bname}")
        be = make_backend(bname, cfg)
        # interpret-mode pallas compiles slowly at large B; the ref oracle is
        # sequential Python — keep their batches proportionate.
        bl = {"jnp": batches, "pallas": tuple(b for b in batches if b <= 256),
              "ref": (64,)}.get(bname, batches)
        for b in bl:
            keys = jnp.asarray(tr[n_warm:n_warm + b])
            vals = keys.astype(jnp.int32)
            if bname == "ref":
                # the sequential oracle has no fused path; one two-phase row
                dt = time_host(be.access, state, keys, vals)
                records.append(_tp_record("backend-ref-twophase", b,
                                          b / dt / 1e6))
                continue
            p50 = {}
            for vname, acc in (("fused", be.access),
                               ("twophase", be.access_two_phase)):
                fn = jax.jit(lambda s, k, v, _a=acc: _a(s, k, v)[0])
                st = time_jitted_percentiles(fn, state, keys, vals)
                p50[vname] = st["p50"]
                records.append(_tp_record(
                    f"backend-{bname}-{vname}", b, b / st["p50"] / 1e6,
                    p90_mops=round(b / st["p90"] / 1e6, 3),
                    p50_req_s=round(b / st["p50"], 1),
                    p90_req_s=round(b / st["p90"], 1)))
            records.append(_tp_record(
                f"backend-{bname}-fused-speedup", b,
                p50["twophase"] / p50["fused"], metric="speedup_x"))
        if bname == "jnp":
            # buffer-donating fused path: the state is consumed and rebound
            # every step (KWayState updated in place), so the timing loop
            # chains it instead of re-passing one donated buffer
            for b in bl:
                keys = jnp.asarray(tr[n_warm:n_warm + b])
                vals = keys.astype(jnp.int32)
                st_d = jax.tree_util.tree_map(lambda x: x.copy(), state)

                def step_d():
                    nonlocal st_d
                    st_d, *_ = kway.access_donated(cfg, st_d, keys, vals)
                    return st_d

                st = time_chained_percentiles(step_d)
                records.append(_tp_record(
                    "backend-jnp-fused-donated", b, b / st["p50"] / 1e6,
                    p90_mops=round(b / st["p90"] / 1e6, 3),
                    p50_req_s=round(b / st["p50"], 1),
                    p90_req_s=round(b / st["p90"], 1)))

    # set-sharded execution: 1 shard vs N shards (fused access, donated
    # shard-state leaves — every chunk rebinds the returned state)
    b = max(batches)
    for ns in shards:
        if progress:
            progress(f"throughput sharded x{ns}")
        sc = ShardedCache(ShardedConfig(cache=cfg, num_shards=ns,
                                        donate=True))
        st = sc.init()
        chunk0 = np.asarray(tr[:b], np.uint32)
        for _ in range(3):  # warm the jit caches + shard states
            st, *_ = sc.access(st, chunk0, chunk0.astype(np.int32))

        def run_chunks(n_chunks):
            nonlocal st
            for i in range(n_chunks):
                off = n_warm + (i * b) % 4096
                chunk = np.asarray(tr[off:off + b], np.uint32)
                if len(chunk) < b:
                    chunk = chunk0
                st, *_ = sc.access(st, chunk, chunk.astype(np.int32))
            # access() returns device arrays now (the router runs on
            # device); block so the timed region covers the execution,
            # not just the async dispatch
            jax.block_until_ready(st.keys)

        n_chunks = 10
        dt = time_host(run_chunks, n_chunks, iters=1) / n_chunks
        records.append(_tp_record(f"sharded-{ns}shard", b, b / dt / 1e6))

    # trace-resident replay megakernel vs the chunked-scan replay on the
    # kernel path (headline rows; the full sweep + bit-identity gate live
    # in throughput_resident / benchmarks.throughput --resident-compare)
    if "pallas" in backends:
        from repro.core.simulate import SimConfig, replay_batched
        n_rep, b_rep = 16_384, 256
        tr_rep = tr[:n_rep]
        sim = SimConfig(cache=cfg, backend="pallas")
        rp50 = {}
        for mode, resident in (("scan", False), ("resident", True)):
            if progress:
                progress(f"replay {mode} pallas")
            st = time_replay_percentiles(
                lambda _r=resident: replay_batched(sim, tr_rep, batch=b_rep,
                                                   resident=_r),
                iters=3)
            rp50[mode] = st["p50"]
            records.append(_tp_record(
                f"replay-{mode}-pallas", b_rep, n_rep / st["p50"] / 1e6,
                n=n_rep, p50_req_s=round(n_rep / st["p50"], 1),
                p90_req_s=round(n_rep / st["p90"], 1),
                reps_discarded=st["reps_discarded"]))
        records.append(_tp_record(
            "replay-resident-speedup-pallas", b_rep,
            rp50["scan"] / rp50["resident"], metric="speedup_x"))

    spec = {"quick": quick, "batches": list(batches),
            "policy": policy.name, "backends": list(backends),
            "shards": list(shards), "capacity": THROUGHPUT_CAPACITY}
    return spec, records, []


def throughput_resident(quick: bool = False, progress=None,
                        backends=("jnp", "pallas")):
    """Trace-resident replay megakernel vs the chunked-scan replay
    (DESIGN.md §10): whole-trace replay req/s, p50/p90 steady-state.

    Rows per backend:

      * ``replay-scan-{b}``     — the chunked ``lax.scan`` replay (one
        jitted scan; on pallas, one kernel launch + scatter pass per chunk);
      * ``replay-resident-{b}`` — ``CacheBackend.replay``: on pallas the
        megakernel (ONE launch for the whole trace, state lanes pinned in
        VMEM, zero HBM state round-trips), on jnp the scanned default (the
        comparison anchor);
      * ``replay-resident-speedup-{b}`` — resident p50 over scan p50.

    Plus comparable ``resident-eq/...`` hit-ratio records over a small
    (family × policy × ±TinyLFU) grid: ``value`` is the resident hit ratio
    and ``scan_value`` the chunked-scan one — the two must be EXACTLY equal
    (tol 0.0; the megakernel is bit-identical by construction), which is
    what the CI ``--resident-compare`` gate enforces.
    """
    from repro.core import admission, traces
    from repro.core.kway import KWayConfig
    from repro.core.simulate import SimConfig, replay_batched

    policy = Policy.LRU
    batch = 256
    n = 16_384 if quick else 65_536
    kcfg = KWayConfig(num_sets=THROUGHPUT_CAPACITY // 8, ways=8,
                      policy=policy)
    tr = traces.generate("zipf", n, seed=7, catalog=1 << 14)
    records = []
    p50 = {}
    for bname in backends:
        sim = SimConfig(cache=kcfg, backend=bname)
        for mode, resident in (("scan", False), ("resident", True)):
            if progress:
                progress(f"replay {mode} {bname}")
            st = time_replay_percentiles(
                lambda _r=resident: replay_batched(sim, tr, batch=batch,
                                                   resident=_r),
                iters=3 if quick else 5)
            p50[(bname, mode)] = st["p50"]
            records.append(_tp_record(
                f"replay-{mode}-{bname}", batch, n / st["p50"] / 1e6,
                n=n, mode=mode, backend=bname,
                p50_req_s=round(n / st["p50"], 1),
                p90_req_s=round(n / st["p90"], 1),
                reps_discarded=st["reps_discarded"]))
        records.append(_tp_record(
            f"replay-resident-speedup-{bname}", batch,
            p50[(bname, "scan")] / p50[(bname, "resident")],
            metric="speedup_x", backend=bname))

    # bit-identity records: resident (pallas megakernel) vs chunked scan
    n_eq = QUICK_N if quick else FULL_N
    eq_backend = "pallas" if "pallas" in backends else backends[0]
    tlfu = admission.for_capacity(1024)
    for family in ("zipf", "scan_loop"):
        tre = traces.generate(family, n_eq, seed=42)
        for pol in (Policy.LRU, Policy.LFU):
            for adm in ("none", "tinylfu"):
                if progress:
                    progress(f"resident-eq {family}/{pol.name}/{adm}")
                cfg = KWayConfig(num_sets=128, ways=8, policy=pol)
                sim = SimConfig(cache=cfg, backend=eq_backend,
                                tinylfu=tlfu if adm == "tinylfu" else None)
                hr_res = replay_batched(sim, tre, batch=batch, resident=True)
                hr_scan = replay_batched(sim, tre, batch=batch,
                                         resident=False)
                records.append({
                    "id": f"resident-eq/{family}/{pol.name}/{adm}",
                    "family": family, "policy": pol.name,
                    "admission": adm, "backend": eq_backend,
                    "batch": batch, "n": n_eq, "capacity": 1024,
                    "metric": "hit_ratio", "value": hr_res,
                    "scan_value": hr_scan,
                    "comparable": True, "tol": 0.0,
                })
    spec = {"quick": quick, "backends": list(backends), "batch": batch,
            "n": n, "n_eq": n_eq, "policy": policy.name,
            "capacity": THROUGHPUT_CAPACITY}
    return spec, records, []


def throughput_vs_shards(quick: bool = False, progress=None,
                         shards=(1, 2, 4, 8)):
    """The paper's threads-vs-throughput plot (Figs. 14-26 headline), with
    set shards standing in for threads: each shard is one consumer bringing
    its own fixed-size request stream per serving tick, so the offered load
    per tick is ``D × tick_batch`` — exactly the paper's methodology, where
    every added thread drives its own request loop.

    Rows per shard count (jnp backend, LRU, k=8):

      * ``sharded-jnp-shard{D}`` — p50/p90 req/s of the routed serving tick
        (ONE jitted call: device router + per-shard fused access + unscatter,
        shard states donated and rebound).  The scaling headline: per-tick
        dispatch cost is flat while the routed tick carries D× requests.
      * ``scan-shard{D}``        — whole-trace replay as a single jitted
        ``lax.scan`` (``ShardedCache.replay``): ONE host sync for the entire
        trace, no per-chunk bucketing or transfers (the no-host-sync row).
      * ``scaling-shard{D}``     — tick p50 speedup over shard1.

    Plus comparable hit-ratio records for shards ∈ {1, 4} on a slice of the
    baseline grid (tol-gated against benchmarks/baselines/quick.json by the
    CI perf-smoke step — batched replay tracks the B=1 baseline within a
    small band, it is not bit-equal).
    """
    import numpy as np

    from repro.core import traces
    from repro.core.kway import KWayConfig
    from repro.core.sharded import ShardedCache, ShardedConfig
    from repro.eval.runner import SweepPoint, replay_sharded_point

    policy = Policy.LRU
    kcfg = KWayConfig(num_sets=THROUGHPUT_CAPACITY // 8, ways=8,
                      policy=policy)
    tick_batch = 32                      # per-shard per-tick lane budget
    n_scan = 65_536 if quick else 262_144
    tr = traces.generate("zipf", n_scan, seed=7, catalog=1 << 14)
    records = []
    tick_p50 = {}

    for d in shards:
        if progress:
            progress(f"shards={d} (tick + scan)")
        bg = d * tick_batch
        sc = ShardedCache(ShardedConfig(cache=kcfg, num_shards=d,
                                        donate=True))
        st = sc.init()
        offs = [(i * bg) % (n_scan - bg) for i in range(64)]
        it = {"i": 0}

        def tick():
            chunk = tr[offs[it["i"] % len(offs)]:][:bg]
            it["i"] += 1
            nonlocal_state = tick.state
            st2, hit, *_ = sc.access(nonlocal_state, chunk,
                                     chunk.astype(np.int32))
            tick.state = st2
            return hit

        tick.state = st
        stats = time_chained_percentiles(tick)
        tick_p50[d] = bg / stats["p50"]
        records.append(_tp_record(
            f"sharded-jnp-shard{d}", bg, bg / stats["p50"] / 1e6,
            shards=d, per_shard_batch=tick_batch,
            p90_mops=round(bg / stats["p90"] / 1e6, 3),
            p50_req_s=round(bg / stats["p50"], 1),
            p90_req_s=round(bg / stats["p90"], 1)))

        # no-host-sync row: the whole trace in one scan, one sync at the end
        sc2 = ShardedCache(ShardedConfig(cache=kcfg, num_shards=d))
        rstats = time_replay_percentiles(
            lambda: sc2.replay(tr, bg), iters=3 if quick else 5)
        records.append(_tp_record(
            f"scan-shard{d}", bg, n_scan / rstats["p50"] / 1e6,
            shards=d, host_syncs_per_replay=1, n=n_scan,
            p50_req_s=round(n_scan / rstats["p50"], 1),
            p90_req_s=round(n_scan / rstats["p90"], 1)))

    for d in shards:
        records.append(_tp_record(
            f"scaling-shard{d}", d * tick_batch,
            tick_p50[d] / tick_p50[1], metric="speedup_x", shards=d))

    # comparable hit-ratio rows: the sharded batched replay vs the B=1 grid
    n_hr = QUICK_N if quick else FULL_N
    for d in (1, 4):
        for family in ("zipf", "scan_loop"):
            for pol in (Policy.LRU, Policy.LFU):
                if progress:
                    progress(f"hit-ratio {family}/{pol.name}/shard{d}")
                p = SweepPoint(family=family, policy=pol, assoc="k8",
                               capacity=1024, n=n_hr)
                hr = replay_sharded_point(p, shards=d, batch=256)
                records.append({
                    "id": f"{family}/{pol.name}/k8/jnp/shard{d}",
                    "family": family, "policy": pol.name, "assoc": "k8",
                    "shards": d, "batch": 256, "n": n_hr,
                    "capacity": p.capacity, "seed": p.seed,
                    "metric": "hit_ratio", "value": hr,
                    "comparable": True, "tol": 0.02,
                })

    spec = {"quick": quick, "shards": list(shards),
            "tick_batch": tick_batch, "n_scan": n_scan,
            "policy": policy.name, "capacity": THROUGHPUT_CAPACITY,
            "backend": "jnp"}
    return spec, records, []


def showdown(quick: bool = False, progress=None, threads=(1, 2, 4, 8),
             families=("zipf", "oltp_mix", "lirs_two_pools"),
             policies=("lru", "lfu")):
    """The paper's Fig. 1 analogue: req/s vs thread count, production caches
    next to our batched/resident paths (DESIGN.md §12).

    External rows (per family × policy), one per thread count in
    ``threads``:

      * ``cachetools-{policy}/threads{T}`` — ``cachetools.LRUCache``/
        ``LFUCache`` behind the documented global lock, T pool workers each
        replaying a contiguous trace slice against the shared cache;
      * ``striped-{policy}/threads{T}``   — the lock-striped pure-Python
        k-way baseline (one lock per set, same set hash as the device
        paths): limited associativity's structural benefit without SIMD.

    Our rows (same trace, same total capacity, k=8):

      * ``jnp-batched-{policy}/batch{B}``     — the chunked-scan batched
        replay (one jitted scan, one host sync);
      * ``pallas-resident-{policy}/batch{B}`` — the trace-resident replay
        megakernel (ONE launch, state pinned in VMEM).

    All throughput rows are wall-clock and ``comparable: false``.  The
    gateable output is the ``showdown-hr/...`` records: deterministic
    single-threaded hit ratios per library (cachetools is full-assoc
    LRU/LFU, striped and ours are k=8), ``comparable: true`` — CI diffs
    them against the committed baseline via the shared ``_baseline_gate``
    contract (exit 3 on breach).
    """
    from repro.core import trace_io, traces
    from repro.core.kway import KWayConfig
    from repro.core.simulate import SimConfig, replay_batched
    from repro.showdown import make_baseline, replay_threaded
    from repro.showdown import hit_ratio as baseline_hit_ratio

    capacity, ways, batch, seed = THROUGHPUT_CAPACITY, 8, 256, 7
    n = 8_192 if quick else 65_536
    iters = 2 if quick else 5
    trace_io.register_fixture_traces()   # lirs_two_pools rides as a family
    pol_enum = {"lru": Policy.LRU, "lfu": Policy.LFU}
    records = []
    trace_fp = {}

    def rec(rid, value, **extra):
        r = {"id": rid, "metric": "req_per_s", "value": round(value, 1),
             "capacity": capacity, "n": n, "comparable": False}
        r.update(extra)
        records.append(r)

    for family in families:
        tr = traces.generate(family, n, seed=seed)
        trace_fp[family] = trace_io.trace_fingerprint(tr)
        for policy in policies:
            # -- external libraries under threads -------------------------
            for lib in ("cachetools", "striped"):
                for t in threads:
                    if progress:
                        progress(f"{family}/{lib}-{policy} threads={t}")
                    cache = make_baseline(lib, capacity, policy, ways=ways)
                    st = replay_threaded(cache, tr, t, iters=iters)
                    rec(f"showdown/{family}/{lib}-{policy}/threads{t}",
                        st["req_s_p50"], family=family, lib=lib,
                        policy=policy, threads=t,
                        p90_req_s=round(st["req_s_p90"], 1),
                        reps_discarded=st["reps_discarded"])

            # -- our device paths (same trace, same capacity, k=8) --------
            kcfg = KWayConfig(num_sets=capacity // ways, ways=ways,
                              policy=pol_enum[policy])
            ours = (("jnp-batched", "jnp", False),
                    ("pallas-resident", "pallas", True))
            hr_ours = {}
            for name, backend, resident in ours:
                if progress:
                    progress(f"{family}/{name}-{policy}")
                sim = SimConfig(cache=kcfg, backend=backend)
                hr_ours[name] = replay_batched(sim, tr, batch=batch,
                                               resident=resident)  # + warm
                st = time_replay_percentiles(
                    lambda sim=sim, r=resident: replay_batched(
                        sim, tr, batch=batch, resident=r),
                    iters=iters, warmup=1)
                rec(f"showdown/{family}/{name}-{policy}/batch{batch}",
                    n / st["p50"], family=family, lib=name, policy=policy,
                    batch=batch, p90_req_s=round(n / st["p90"], 1),
                    reps_discarded=st["reps_discarded"])

            # -- deterministic hit-ratio parity records (the gated rows) --
            hr = {
                "cachetools": baseline_hit_ratio(
                    make_baseline("cachetools", capacity, policy), tr),
                "striped": baseline_hit_ratio(
                    make_baseline("striped", capacity, policy, ways=ways),
                    tr),
                "jnp-batched": hr_ours["jnp-batched"],
                "pallas-resident": hr_ours["pallas-resident"],
            }
            for lib, value in hr.items():
                records.append({
                    "id": f"showdown-hr/{family}/{policy}/{lib}",
                    "family": family, "policy": policy, "lib": lib,
                    "capacity": capacity, "n": n, "seed": seed,
                    "batch": batch if lib.startswith(("jnp", "pallas"))
                    else None,
                    "metric": "hit_ratio", "value": round(float(value), 6),
                    "comparable": True, "tol": 1e-6,
                })

    spec = {"quick": quick, "families": list(families),
            "policies": list(policies), "threads": list(threads),
            "capacity": capacity, "ways": ways, "batch": batch,
            "n": n, "seed": seed, "trace_fingerprints": trace_fp}
    return spec, records, []


def synthetic_mix(quick: bool = False, progress=None, kinds=None):
    """Paper Figs. 27-30: fixed-hit-rate workloads per implementation."""
    if kinds is None:
        kinds = (("miss100", "hit95") if quick
                 else ("miss100", "hit100", "hit95", "hit90"))
    import jax
    import jax.numpy as jnp
    from repro.core import kway
    from repro.core.kway import KWayConfig, fully_associative

    capacity, batch = 4096, 512
    rng = np.random.default_rng(11)

    def mk_stream(kind, n):
        if kind == "miss100":   # every key unique
            return rng.permutation(np.arange(n, dtype=np.uint32) + (1 << 20))
        resident = rng.integers(0, capacity // 2, n).astype(np.uint32)
        if kind == "hit100":
            return resident
        p_miss = {"hit95": 0.05, "hit90": 0.10}[kind]
        miss = np.arange(n, dtype=np.uint32) + (1 << 20)
        take_miss = rng.random(n) < p_miss
        return np.where(take_miss, miss, resident).astype(np.uint32)

    impls = {
        "kway-soa": KWayConfig(num_sets=capacity // 8, ways=8,
                               policy=Policy.LRU),
        "sampled": KWayConfig(num_sets=capacity // 128, ways=128,
                              policy=Policy.LRU, sample=8),
        "full": fully_associative(capacity, Policy.LRU),
    }
    records = []
    for kind in kinds:
        if progress:
            progress(f"synthetic_mix {kind}")
        stream = mk_stream(kind, batch)
        for name, cfg in impls.items():
            state = kway.make_cache(cfg)
            resident = jnp.asarray(
                rng.integers(0, capacity // 2, capacity).astype(np.uint32))
            for chunk in resident.reshape(-1, 512):
                state, *_ = kway.access(cfg, state, chunk,
                                        chunk.astype(jnp.int32))
            keys = jnp.asarray(stream)
            fn = jax.jit(lambda s, k: kway.access(cfg, s, k,
                                                  k.astype(jnp.int32))[0])
            dt = time_jitted(fn, state, keys)
            records.append(_tp_record(f"{kind}/{name}", batch,
                                      batch / dt / 1e6))
    spec = {"quick": quick, "kinds": list(kinds), "capacity": capacity,
            "batch": batch}
    return spec, records, []


def serving(quick: bool = False, progress=None, requests=None, prefix_len=48):
    """End-to-end prefix-cache serving: tok/s, hit ratio, evictions."""
    import time as _time

    if requests is None:
        requests = 6 if quick else 12

    import jax
    from repro import configs
    from repro.models import lm
    from repro.serve.engine import Engine, EngineConfig

    cfg = configs.get("deepseek-7b").smoke
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    shared = rng.integers(2, 400, prefix_len)
    prompts = [np.concatenate([shared, rng.integers(2, 400, 8)])
               for _ in range(requests)]
    records = []
    for policy in (Policy.LRU, Policy.LFU):
        if progress:
            progress(f"serving {policy.name}")
        eng = Engine(cfg, params, EngineConfig(
            page=8, num_sets=32, ways=8, policy=policy, max_batch=4,
            max_seq=256, private_pages=128))
        t0 = _time.time()
        for pr in prompts:
            eng.submit(pr, max_new=8)
        fin = eng.run()
        dt = _time.time() - t0
        toks = sum(len(r.generated) for r in fin.values())
        records.append({
            "id": f"{policy.name}/tok_per_s", "policy": policy.name,
            "metric": "tok_per_s", "value": round(toks / dt, 1),
            "comparable": False})
        records.append({
            "id": f"{policy.name}/prefix_hit_ratio", "policy": policy.name,
            "metric": "prefix_hit_ratio", "value": round(eng.hit_ratio(), 3),
            "comparable": True, "tol": 0.02})
        records.append({
            "id": f"{policy.name}/evictions", "policy": policy.name,
            "metric": "evictions", "value": int(eng.stats["evictions"]),
            "comparable": False})
    spec = {"quick": quick, "requests": requests, "prefix_len": prefix_len,
            "model": "deepseek-7b/smoke"}
    return spec, records, []


def serving_engine(quick: bool = False, progress=None, slots=None,
                   requests=None, max_new=4, decode_block=4):
    """Device-resident serving tick vs host-loop engine (DESIGN.md §11).

    Rows ``engine-{hostloop,jitted}-slots{S}``: p50/p90 requests/s and
    sustained tok/s over a shared-prefix continuous-batching workload, using
    the steady-state run-once protocol of ``time_replay_percentiles`` (each
    sample builds a FRESH engine and serves the whole request mix — the
    hostloop's per-request dispatches and the jitted engine's one-dispatch
    ticks are both inside the timed window; compiles are in the discarded
    warmup).  Short decodes (``max_new=4``) keep the workload
    admission-heavy — the regime where per-tick host round-trips dominate
    and the one-traced-program tick pays off.  Both engines run the same
    ``decode_block`` burst schedule (multi-step scheduling), so the speedup
    isolates dispatch/sync economics, not a schedule difference.

    Plus parity rows (comparable, tol 0): emitted tokens equal and identical
    prefix hit ratio between the two engines — the speedup headline is only
    meaningful if the jitted tick is indistinguishable semantically.
    """
    import jax

    from repro import configs
    from repro.eval.timing import time_replay_percentiles
    from repro.models import lm
    from repro.serve import Engine, EngineConfig

    if slots is None:
        slots = (32,) if quick else (8, 32)
    if requests is None:
        requests = 128 if quick else 192

    cfg = configs.get("deepseek-7b").smoke
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    shared = rng.integers(2, cfg.vocab_size - 1, 48)
    prompts = [np.concatenate([shared,
                               rng.integers(2, cfg.vocab_size - 1,
                                            int(rng.integers(4, 16)))])
               for _ in range(requests)]

    def engine(s, jitted):
        return Engine(cfg, params, EngineConfig(
            page=8, num_sets=64, ways=8, max_batch=s, max_seq=256,
            private_pages=512, max_prompt=128, decode_block=decode_block,
            jitted=jitted))

    def serve_all(s, jitted):
        eng = engine(s, jitted)
        for pr in prompts:
            eng.submit(pr, max_new=max_new)
        fin = eng.run()
        return eng, fin

    records = []
    for s in slots:
        stats = {}
        toks = {}
        gen = {}
        for jitted in (False, True):
            mode = "jitted" if jitted else "hostloop"
            if progress:
                progress(f"engine-{mode}-slots{s}")
            eng, fin = serve_all(s, jitted)      # parity + token count run
            gen[mode] = ({rid: list(r.generated) for rid, r in fin.items()},
                         eng.hit_ratio())
            toks[mode] = sum(len(r.generated) for r in fin.values())
            stats[mode] = time_replay_percentiles(
                lambda jitted=jitted: serve_all(s, jitted),
                iters=3 if quick else 5, warmup=1)
            records.append({
                "id": f"engine-{mode}-slots{s}/req_per_s",
                "impl": f"engine-{mode}", "slots": s,
                "requests": requests, "max_new": max_new,
                "metric": "req_per_s",
                "value": round(requests / stats[mode]["p50"], 1),
                "p90_req_s": round(requests / stats[mode]["p90"], 1),
                "tok_per_s": round(toks[mode] / stats[mode]["p50"], 1),
                "comparable": False})
        records.append({
            "id": f"engine-jitted-speedup-slots{s}",
            "slots": s, "metric": "speedup_x",
            "value": round(stats["hostloop"]["p50"] / stats["jitted"]["p50"],
                           2),
            "comparable": False})
        records.append({
            "id": f"engine-parity-slots{s}/tokens_equal",
            "slots": s, "metric": "tokens_equal",
            "value": float(gen["hostloop"][0] == gen["jitted"][0]),
            "comparable": True, "tol": 0.0})
        records.append({
            "id": f"engine-parity-slots{s}/hit_ratio",
            "slots": s, "metric": "prefix_hit_ratio",
            "value": round(gen["jitted"][1], 6),
            "scan_value": round(gen["hostloop"][1], 6),
            "comparable": True, "tol": 0.0})
    spec = {"quick": quick, "slots": list(slots), "requests": requests,
            "max_new": max_new, "decode_block": decode_block,
            "prefix_len": 48, "model": "deepseek-7b/smoke"}
    return spec, records, []


def robustness(quick: bool = False, progress=None, ttl: bool = False):
    """DESIGN.md §13: validator coverage, recovery cost, ladder
    observability, and validator overhead.

    Four record groups (``ttl=True`` adds a fifth, DESIGN.md §15):

      * ``robust-clean/{policy}/{backend}/violations`` — the invariant
        validator over the final state of the golden 512-request zipf
        trace, all 5 policies on the jnp and pallas backends (plus the
        sequential ref oracle on LRU; every policy in full mode).  Pinned
        at 0.0 with tol 0 — the zero-false-positive contract.
      * ``robust-scrub/{site}/...`` — inject one seeded bit-flip at the
        replay midpoint, scrub-and-invalidate, replay on: the recovered
        hit ratio and the forced-eviction tally, both deterministic from
        ``(seed, site, step)`` and pinned against the committed band.
      * ``robust-ladder/vmem-breach/...`` — replay under a forced
        zero-VMEM budget: the ladder must land on the chunked-scan rung,
        record observable degradation events, and still produce the clean
        run's exact hit count (rungs are pinned bit-identical).
      * ``robust-overhead/validated-replay/pct`` — wall-clock cost of
        fusing the validator into the replay scan at the quick cadence,
        vs the plain scan (``comparable: false``; the CLI gates the
        absolute <5% target).
      * ``robust-ttl/...`` (``ttl=True``) — the expiry lane: TTL replay
        of the golden trace with seeded per-request TTLs pinned clean
        under the STRICT expiry mode on jnp and pallas, backend hit
        parity pinned at zero diff, and the expiry-scrub chaos loop
        (``clock_skew``/``stale_entry`` injection -> strict scrub ->
        replay on) with its recovered hit ratio and forced-eviction
        tallies as the deterministic cost band.
    """
    from repro.core import backend as backend_mod
    from repro.core import trace_io, traces
    from repro.core.kway import KWayConfig
    from repro.core.router import pad_chunks
    from repro.robust import check_cache, events, faults, resilient_replay
    from repro.robust.ladder import RUNGS
    from repro.robust.recovery import scrub, validated_replay

    num_sets, ways, batch, seed = 16, 4, 8, 2026
    # the golden-trace recipe (tests/test_golden_trace.py)
    tr = traces.generate("zipf", 512, seed=seed, catalog=96)
    tr[::13] = 0
    chunks, enabled = pad_chunks(tr, batch)
    n = int(len(tr))
    records = []
    policies = {"lru": Policy.LRU, "lfu": Policy.LFU, "fifo": Policy.FIFO,
                "random": Policy.RANDOM, "hyperbolic": Policy.HYPERBOLIC}

    def cfg_for(pol):
        return KWayConfig(num_sets=num_sets, ways=ways, policy=pol)

    # ---- clean validator: zero false positives -------------------------
    for pname, pol in policies.items():
        cfg = cfg_for(pol)
        for backend in ("jnp", "pallas"):
            if progress:
                progress(f"clean {pname}/{backend}")
            be = backend_mod.make_backend(backend, cfg)
            _, _, st, _ = be.replay(be.init(), chunks, enabled)
            bad = int((np.asarray(check_cache(cfg, st, vals_mode="key")
                                  .lane_bits) != 0).sum())
            records.append({
                "id": f"robust-clean/{pname}/{backend}/violations",
                "policy": pname, "backend": backend, "n": n,
                "metric": "violating_lanes", "value": float(bad),
                "comparable": True, "tol": 0.0})
        ref_policies = ("lru",) if quick else tuple(policies)
        if pname in ref_policies:
            if progress:
                progress(f"clean {pname}/ref")
            be = backend_mod.make_backend("ref", cfg)
            st = be.init()
            for i in range(chunks.shape[0]):
                keys_i = np.asarray(chunks[i], np.uint32)
                st, _, _, _, _ = be.access(
                    st, keys_i, keys_i.astype(np.int32),
                    enabled=np.asarray(enabled[i]))
            bad = int((np.asarray(check_cache(cfg, st, vals_mode="key")
                                  .lane_bits) != 0).sum())
            records.append({
                "id": f"robust-clean/{pname}/ref/violations",
                "policy": pname, "backend": "ref", "n": n,
                "metric": "violating_lanes", "value": float(bad),
                "comparable": True, "tol": 0.0})

    # ---- scrub recovery: inject -> detect -> repair -> replay on -------
    cfg = cfg_for(Policy.LRU)
    be = backend_mod.make_backend("jnp", cfg)
    hits_clean, _, _, _ = be.replay(be.init(), chunks, enabled)
    hr_clean = float(np.asarray(hits_clean).sum()) / n
    records.append({
        "id": "robust-scrub/clean/hit_ratio", "site": None, "n": n,
        "metric": "hit_ratio", "value": round(hr_clean, 6),
        "comparable": True, "tol": 1e-6})
    half = chunks.shape[0] // 2
    for site in ("keys", "fprint", "meta_a"):
        if progress:
            progress(f"scrub {site}")
        h1, _, st, _ = be.replay(be.init(), chunks[:half], enabled[:half])
        st, _ = faults.flip_bit(st, site, seed=seed, step=half)
        st, forced, _ = scrub(cfg, st, vals_mode="key")
        h2, _, st, _ = be.replay(st, chunks[half:], enabled[half:])
        hr = (float(np.asarray(h1).sum()) + float(np.asarray(h2).sum())) / n
        records.append({
            "id": f"robust-scrub/{site}/hit_ratio", "site": site, "n": n,
            "seed": seed, "step": half, "metric": "hit_ratio",
            "value": round(hr, 6), "clean_value": round(hr_clean, 6),
            "comparable": True, "tol": 1e-6})
        records.append({
            "id": f"robust-scrub/{site}/forced_evictions", "site": site,
            "seed": seed, "step": half, "metric": "forced_evictions",
            "value": float(int(forced)), "comparable": True, "tol": 0.0})

    # ---- degradation ladder under a forced VMEM breach -----------------
    if progress:
        progress("ladder vmem-breach")
    c0 = events.cursor()
    with backend_mod.vmem_budget(0):
        out = resilient_replay(cfg, chunks, enabled)
    n_events = len(events.since(c0))
    records.append({
        "id": "robust-ladder/vmem-breach/rung", "metric": "ladder_rung",
        "rung": out.rung, "value": float(RUNGS.index(out.rung)),
        "comparable": True, "tol": 0.0})
    records.append({
        "id": "robust-ladder/vmem-breach/hit_ratio", "metric": "hit_ratio",
        "value": round(float(np.asarray(out.hits).sum()) / n, 6),
        "clean_value": round(hr_clean, 6),
        "comparable": True, "tol": 1e-6})
    records.append({
        "id": "robust-ladder/vmem-breach/events", "metric": "event_count",
        "value": float(n_events), "comparable": False})

    # ---- expiry lane: TTL parity + expiry-scrub cost band (§15) --------
    if ttl:
        from repro.core.simulate import _pad_ttl_chunks

        ttl_rng = np.random.default_rng(seed + 1)
        tt = _pad_ttl_chunks(ttl_rng.integers(0, 200, n).astype(np.int32),
                             batch)
        ttl_hits = {}
        for backend in ("jnp", "pallas"):
            if progress:
                progress(f"ttl clean {backend}")
            be_t = backend_mod.make_backend(backend, cfg)
            h, _, st, _ = be_t.replay(be_t.init(ttl=True), chunks, enabled,
                                      ttls=tt)
            ttl_hits[backend] = float(np.asarray(h).sum())
            bad = int((np.asarray(check_cache(cfg, st, vals_mode="key")
                                  .lane_bits) != 0).sum())
            records.append({
                "id": f"robust-ttl/clean/{backend}/violations",
                "backend": backend, "n": n, "metric": "violating_lanes",
                "value": float(bad), "comparable": True, "tol": 0.0})
        hr_ttl = ttl_hits["jnp"] / n
        records.append({
            "id": "robust-ttl/parity/hit_ratio", "n": n,
            "metric": "hit_ratio", "value": round(hr_ttl, 6),
            "comparable": True, "tol": 1e-6})
        records.append({
            "id": "robust-ttl/parity/backend_max_diff", "n": n,
            "metric": "hit_diff",
            "value": abs(ttl_hits["jnp"] - ttl_hits["pallas"]),
            "comparable": True, "tol": 0.0})
        for site_name, inject in (("clock_skew", faults.clock_skew),
                                  ("stale_entry", faults.stale_entry)):
            if progress:
                progress(f"ttl scrub {site_name}")
            h1, _, st, _ = be.replay(be.init(ttl=True), chunks[:half],
                                     enabled[:half], ttls=tt[:half])
            st, _ = inject(st, seed=seed, step=half)
            st, forced, _ = scrub(cfg, st, vals_mode="key")
            h2, _, st, _ = be.replay(st, chunks[half:], enabled[half:],
                                     ttls=tt[half:])
            hr = (float(np.asarray(h1).sum())
                  + float(np.asarray(h2).sum())) / n
            records.append({
                "id": f"robust-ttl/scrub/{site_name}/hit_ratio",
                "site": site_name, "n": n, "seed": seed, "step": half,
                "metric": "hit_ratio", "value": round(hr, 6),
                "clean_value": round(hr_ttl, 6),
                "comparable": True, "tol": 1e-6})
            records.append({
                "id": f"robust-ttl/scrub/{site_name}/forced_evictions",
                "site": site_name, "seed": seed, "step": half,
                "metric": "forced_evictions", "value": float(int(forced)),
                "comparable": True, "tol": 0.0})

    # ---- validator overhead on the quick replay ------------------------
    interval = 1
    ov_sets, ov_ways, ov_batch = 512, 8, 256
    ov_n = 8_192 if quick else 65_536
    iters = 3 if quick else 5
    if progress:
        progress(f"overhead n={ov_n} interval={interval}")
    ov_cfg = KWayConfig(num_sets=ov_sets, ways=ov_ways, policy=Policy.LRU)
    ov_tr = traces.generate("zipf", ov_n, seed=7)
    ov_chunks, ov_enabled = pad_chunks(ov_tr, ov_batch)
    ov_be = backend_mod.make_backend("jnp", ov_cfg)

    def plain():
        h, _, _, _ = ov_be.replay(ov_be.init(), ov_chunks, ov_enabled)
        return int(np.asarray(h).sum())

    def validated():
        h, _, _, _, alarm = validated_replay(
            ov_cfg, ov_chunks, ov_enabled, interval=interval,
            vals_mode="key")
        return int(np.asarray(h).sum()) + int(alarm) * 0

    t_plain = time_replay_percentiles(plain, iters=iters, warmup=1)
    t_val = time_replay_percentiles(validated, iters=iters, warmup=1)
    pct = (t_val["p50"] - t_plain["p50"]) / t_plain["p50"] * 100.0
    records.append({
        "id": "robust-overhead/validated-replay/pct",
        "metric": "overhead_pct", "value": round(pct, 2),
        "interval": interval, "n": ov_n, "batch": ov_batch,
        "capacity": ov_sets * ov_ways,
        "plain_p50_s": round(t_plain["p50"], 6),
        "validated_p50_s": round(t_val["p50"], 6),
        "comparable": False})

    spec = {"quick": quick, "ttl": ttl, "num_sets": num_sets, "ways": ways,
            "batch": batch, "n": n, "seed": seed,
            "trace_fingerprint": trace_io.trace_fingerprint(tr),
            "scrub_sites": ["keys", "fprint", "meta_a"],
            "overhead": {"num_sets": ov_sets, "ways": ov_ways,
                         "batch": ov_batch, "n": ov_n,
                         "interval": interval}}
    return spec, records, []


def hierarchy(quick: bool = False, progress=None):
    """Two-level replay hierarchy (DESIGN.md §14): throughput and hit ratio
    vs total capacity across the L1-size knob.

    Timing rows (``hier-tp/...``, not comparable): whole-trace replay req/s
    at two L2 capacities — one where the flat megakernel still fits its
    VMEM budget (the hierarchy must not cost much) and one past the
    capacity cliff where the flat path has demoted to the chunked scan
    (the hierarchy must win big, because its VMEM footprint is set by
    ``l1_sets`` alone).  ``hier-tp/speedup/s{S}`` is flat-p50 over
    l1l2-p50; past the cliff the CI gate pins it >= 2x.

    Hit-ratio rows (``hier-hr/{family}/l1-{K}``, comparable): a fixed
    64x8 L2 with the L1-size knob swept over {0, 16, 64} sets x 16 ways.
    ``l1-0`` records carry ``scan_value`` (the flat replay on the same
    config) and tol 0.0 — the disabled hierarchy IS the flat path,
    bit-exact.  Enabled records carry ``flat_value`` — a flat cache of
    the same TOTAL capacity (64x12 / 64x24) — as the oracle reference,
    and gate against the checked-in baseline with tol 0.02.
    """
    from repro.core import backend as backend_mod
    from repro.core import trace_io, traces
    from repro.core.hierarchy import HierarchyConfig, hier_footprint_bytes
    from repro.core.kway import KWayConfig
    from repro.core.simulate import SimConfig, replay_batched

    policy = Policy.LRU
    batch = 256
    n = 16_384 if quick else 65_536
    hier = HierarchyConfig(l1_sets=64, l1_ways=16)
    l2_sets_sweep = (512, 4096)
    tr = traces.generate("zipf", n, seed=7, catalog=1 << 17)
    records = []

    for l2_sets in l2_sets_sweep:
        cfg = KWayConfig(num_sets=l2_sets, ways=8, policy=policy)
        pb = backend_mod.make_backend("pallas", cfg)
        flat_fits = pb.resident_fits()
        sim = SimConfig(cache=cfg, backend="pallas")
        p50 = {}
        for mode, hcfg, path in (
                ("flat", None,
                 "pallas-resident" if flat_fits else "pallas-scan"),
                ("l1l2", hier, "pallas-resident-l1l2")):
            if progress:
                progress(f"hier timing {mode} s{l2_sets}")
            st = time_replay_percentiles(
                lambda _h=hcfg: replay_batched(sim, tr, batch=batch,
                                               hierarchy=_h),
                iters=3 if quick else 5)
            p50[mode] = st["p50"]
            records.append(_tp_record(
                f"hier-tp/{mode}/s{l2_sets}", batch, n / st["p50"] / 1e6,
                n=n, mode=mode, path=path, l2_sets=l2_sets,
                l2_capacity=cfg.capacity, over_budget=not flat_fits,
                p50_req_s=round(n / st["p50"], 1),
                p90_req_s=round(n / st["p90"], 1),
                reps_discarded=st["reps_discarded"]))
        records.append(_tp_record(
            f"hier-tp/speedup/s{l2_sets}", batch,
            p50["flat"] / p50["l1l2"],
            metric="speedup_x", l2_sets=l2_sets,
            over_budget=not flat_fits))

    # hit ratio vs total capacity across the L1-size knob
    trace_io.register_fixture_traces()
    n_hr = QUICK_N if quick else 16_384
    hr_batch = 64
    l2_hr = KWayConfig(num_sets=64, ways=8, policy=policy)
    for family in ("zipf", "lirs_two_pools"):
        kwargs = {"catalog": 4096} if family == "zipf" else {}
        trh = traces.generate(family, n_hr, seed=7, **kwargs)
        sim = SimConfig(cache=l2_hr, backend="pallas")
        for l1_sets in (0, 16, 64):
            if progress:
                progress(f"hier-hr {family} l1-{l1_sets}")
            hcfg = HierarchyConfig(l1_sets=l1_sets, l1_ways=16)
            hr = replay_batched(sim, trh, batch=hr_batch, hierarchy=hcfg)
            total = l2_hr.capacity + hcfg.l1_capacity
            rec = {
                "id": f"hier-hr/{family}/l1-{l1_sets}",
                "family": family, "policy": policy.name,
                "l1_sets": l1_sets, "l1_ways": hcfg.l1_ways,
                "l2_capacity": l2_hr.capacity, "total_capacity": total,
                "batch": hr_batch, "n": n_hr,
                "metric": "hit_ratio", "value": hr, "comparable": True,
            }
            if l1_sets == 0:
                rec["scan_value"] = replay_batched(sim, trh, batch=hr_batch)
                rec["tol"] = 0.0
            else:
                flat = KWayConfig(num_sets=64, ways=total // 64,
                                  policy=policy)
                rec["flat_value"] = replay_batched(
                    SimConfig(cache=flat, backend="pallas"), trh,
                    batch=hr_batch)
                rec["tol"] = 0.02
            records.append(rec)

    spec = {"quick": quick, "batch": batch, "n": n, "n_hr": n_hr,
            "hr_batch": hr_batch, "policy": policy.name,
            "l2_sets": list(l2_sets_sweep), "l2_ways": 8,
            "l1_sets": hier.l1_sets, "l1_ways": hier.l1_ways,
            "l1_footprint_bytes": hier_footprint_bytes(hier),
            "vmem_budget": backend_mod.RESIDENT_VMEM_BUDGET}
    return spec, records, []


#: CLI name -> (function, canonical figure name)
FIGURES = {
    "hit_ratio": (hit_ratio_vs_associativity, "hit_ratio_vs_associativity"),
    "sampled_vs_limited": (sampled_vs_limited, "sampled_vs_limited"),
    "admission": (admission_ablation, "admission_ablation"),
    "throughput": (throughput_vs_batch, "throughput_vs_batch"),
    "throughput_resident": (throughput_resident, "throughput_resident"),
    "throughput_shards": (throughput_vs_shards, "throughput_vs_shards"),
    "showdown": (showdown, "showdown"),
    "synthetic_mix": (synthetic_mix, "synthetic_mix"),
    "serving": (serving, "serving"),
    "serving_engine": (serving_engine, "serving_engine"),
    "robustness": (robustness, "robustness"),
    "hierarchy": (hierarchy, "hierarchy"),
}
