"""Deterministic, shard-aware, checkpointable synthetic data pipeline.

Production posture without a filesystem dataset: batches are a *stateless
function of (seed, step, shard)* — a counter-mode generator.  This gives,
for free, the three properties a 1000-node pipeline must have:

  * exact restart: the checkpoint stores only the step counter;
  * elastic resharding: when the data-parallel world size changes, shards
    are re-derived from (step, new_world) with no coordination;
  * no stragglers from input skew: every host computes its own shard
    locally in O(batch).

Token streams are Zipf-ish over the vocab with document structure (BOS every
~doc_len tokens), enough to give the LM a learnable non-uniform target
distribution in examples/train_small.py.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    doc_len: int = 512
    zipf_alpha: float = 1.1


@dataclasses.dataclass
class DataState:
    """The ENTIRE pipeline state — one integer.  Checkpoint-trivial."""
    step: int = 0


class SyntheticPipeline:
    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        # Fixed Zipf table (derived from seed only — identical on all hosts).
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_alpha)
        self._p = p / p.sum()
        self._perm = rng.permutation(cfg.vocab_size)

    def batch(self, state: DataState):
        """(tokens, labels) for this host's shard at ``state.step``."""
        cfg = self.cfg
        per = cfg.global_batch // self.num_shards
        # counter-mode: rng seeded by (seed, step, shard) — stateless.
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, state.step, self.shard])
        )
        flat = rng.choice(cfg.vocab_size, size=per * (cfg.seq_len + 1), p=self._p)
        toks = self._perm[flat].reshape(per, cfg.seq_len + 1).astype(np.int32)
        # document boundaries
        bos_mask = rng.random((per, cfg.seq_len + 1)) < (1.0 / cfg.doc_len)
        toks = np.where(bos_mask, 1, toks)
        return toks[:, :-1], toks[:, 1:]

    def advance(self, state: DataState) -> DataState:
        return DataState(step=state.step + 1)

    def reshard(self, state: DataState, shard: int, num_shards: int):
        """Elastic resize: same stream, new world size (exact, stateless)."""
        return SyntheticPipeline(self.cfg, shard, num_shards), DataState(state.step)
