"""End-to-end serving driver (deliverable b): a small LM served with batched
requests through the K-way paged KV cache engine.

    PYTHONPATH=src python examples/serve_prefix_cache.py

Simulates a chat-like workload: many requests share a system-prompt prefix.
The K-way set-associative page table (the paper's technique) deduplicates
the shared prefix KV across requests; the run prints the prefix hit ratio
and the throughput with/without the cache warm, then re-serves the same
workload through the device-resident jitted serving tick (DESIGN.md §11)
and checks the two engines emit identical tokens.
"""
import time

import jax
import numpy as np

from repro import configs
from repro.core.policies import Policy
from repro.models import lm
from repro.serve.engine import Engine, EngineConfig


def main():
    cfg = configs.get("deepseek-7b").smoke
    params = lm.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, EngineConfig(
        page=8, num_sets=64, ways=8, policy=Policy.LRU,
        max_batch=8, max_seq=256, private_pages=512,
    ))
    rng = np.random.default_rng(0)
    system_prompt = rng.integers(2, 400, 64)   # shared by every request

    def burst(n, label):
        t0 = time.time()
        before_hits = eng.stats["prefix_hits"]
        before_lk = eng.stats["prefix_lookups"]
        for _ in range(n):
            user = rng.integers(2, 400, int(rng.integers(4, 20)))
            eng.submit(np.concatenate([system_prompt, user]), max_new=12)
        fin_before = len(eng.finished)
        eng.run()
        dt = time.time() - t0
        done = len(eng.finished) - fin_before
        hits = eng.stats["prefix_hits"] - before_hits
        lk = eng.stats["prefix_lookups"] - before_lk
        print(f"{label}: {done} requests in {dt:.1f}s, "
              f"prefix hit ratio {hits}/{lk} = {hits/max(lk,1):.2f}")

    burst(4, "cold burst")
    burst(8, "warm burst")
    print("engine stats:", eng.stats)
    sample = next(iter(eng.finished.values()))
    print("sample generation:", sample.generated)

    # same workload through the device-resident serving tick: the whole
    # admit -> probe -> allocate -> decode -> retire step is ONE traced
    # program with a 4-step decode burst — one host sync per tick — and it
    # must emit token-for-token what the host loop emitted above
    def serve_all(jitted):
        e = Engine(cfg, params, EngineConfig(
            page=8, num_sets=64, ways=8, policy=Policy.LRU,
            max_batch=8, max_seq=256, private_pages=512, max_prompt=128,
            decode_block=4, jitted=jitted,
        ))
        r = np.random.default_rng(1)
        for _ in range(12):
            user = r.integers(2, 400, int(r.integers(4, 20)))
            e.submit(np.concatenate([system_prompt, user]), max_new=12)
        t0 = time.time()
        fin = e.run()
        return ({rid: list(q.generated) for rid, q in fin.items()},
                time.time() - t0)

    gen_host, dt_host = serve_all(jitted=False)
    serve_all(jitted=True)                   # compile warmup (one trace)
    gen_jit, dt_jit = serve_all(jitted=True)
    assert gen_jit == gen_host, "jitted tick diverged from host loop"
    print(f"jitted tick: identical tokens, {dt_host/dt_jit:.1f}x faster "
          f"({dt_host:.1f}s host loop -> {dt_jit:.1f}s jitted)")


if __name__ == "__main__":
    main()
