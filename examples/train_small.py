"""Train a small LM for a few hundred steps on the synthetic pipeline
(deliverable b): loss goes down, checkpoints are written and resumable.

    PYTHONPATH=src python examples/train_small.py [--steps 200]

Uses the gemma2-family smoke config (local/global attention + softcaps) so
the run exercises the non-trivial attention variants too.
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/kway_train_small")
    args = ap.parse_args()
    return train_main([
        "--arch", "gemma2-2b", "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--lr", "3e-3", "--schedule", "wsd",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
    ])


if __name__ == "__main__":
    raise SystemExit(main())
