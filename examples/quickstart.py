"""Quickstart: the K-way cache public API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a k-way set-associative cache, replays a Zipf trace under several
policies, compares against the fully-associative oracle and the sampled
baseline, and shows the TinyLFU admission filter — the paper's §5.2 in
miniature.
"""
import numpy as np

from repro.core import admission, traces
from repro.core.kway import KWayConfig, fully_associative
from repro.core.policies import Policy
from repro.core.simulate import SimConfig, replay

CAPACITY = 1024
N = 50_000


def main():
    trace = traces.generate("zipf", N, seed=0, catalog=1 << 14, alpha=1.0)

    print(f"capacity={CAPACITY}, trace=zipf({N})\n")
    print(f"{'config':34s} hit ratio")
    for policy in (Policy.LRU, Policy.LFU, Policy.HYPERBOLIC):
        for k in (4, 8, 16):
            cfg = KWayConfig(num_sets=CAPACITY // k, ways=k, policy=policy)
            print(f"{policy.name:12s} {k:3d}-way            "
                  f"  {replay(SimConfig(cfg), trace):.4f}")
        full = fully_associative(CAPACITY, policy)
        print(f"{policy.name:12s} fully associative    "
              f"  {replay(SimConfig(full), trace):.4f}")
        samp = KWayConfig(num_sets=CAPACITY // 128, ways=128, policy=policy,
                          sample=8)
        print(f"{policy.name:12s} sampled-8 (Redis)    "
              f"  {replay(SimConfig(samp), trace):.4f}")
        print()

    # W-TinyLFU-style: LFU eviction + TinyLFU admission, k=8
    cfg8 = KWayConfig(num_sets=CAPACITY // 8, ways=8, policy=Policy.LFU)
    hr = replay(SimConfig(cfg8, admission.for_capacity(CAPACITY)), trace)
    print(f"{'LFU+TinyLFU':12s} 8-way                  {hr:.4f}")
    print("\nPaper's claim to verify: the 8-way lines sit within ~1pt of the"
          " fully-associative lines.")


if __name__ == "__main__":
    main()
