"""Associativity sweep (paper Figs. 4-13 in one script): hit ratio vs k for
every trace family and policy, printed as aligned tables.

    PYTHONPATH=src python examples/hit_ratio_study.py [--n 100000]
"""
import argparse

from repro.core import traces
from repro.core.kway import KWayConfig, fully_associative
from repro.core.policies import Policy
from repro.core.simulate import SimConfig, replay

CAPACITY = 1024


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=60_000)
    ap.add_argument("--ks", default="4,8,16,32,64")
    args = ap.parse_args()
    ks = [int(x) for x in args.ks.split(",")]

    for fam in traces.FAMILIES:
        tr = traces.generate(fam, args.n, seed=9)
        print(f"\n=== {fam} (capacity {CAPACITY}) ===")
        header = "policy      " + "".join(f"  k={k:<5d}" for k in ks) + "  full"
        print(header)
        for pol in (Policy.LRU, Policy.LFU, Policy.FIFO, Policy.RANDOM,
                    Policy.HYPERBOLIC):
            row = f"{pol.name:12s}"
            for k in ks:
                cfg = KWayConfig(num_sets=CAPACITY // k, ways=k, policy=pol)
                row += f"  {replay(SimConfig(cfg), tr):.4f}"
            row += f"  {replay(SimConfig(fully_associative(CAPACITY, pol)), tr):.4f}"
            print(row)


if __name__ == "__main__":
    main()
