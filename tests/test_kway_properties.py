"""Hypothesis property tests for the K-way cache (oracle agreement).

Skipped cleanly when `hypothesis` is absent (it is a dev-only dependency;
`pip install -r requirements-dev.txt` brings it in).
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import kway  # noqa: E402
from repro.core.kway import KWayConfig  # noqa: E402
from repro.core.policies import Policy  # noqa: E402
from repro.core.refimpl import RefKWay  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(
    data=st.data(),
    policy=st.sampled_from([Policy.LRU, Policy.LFU, Policy.FIFO]),
    num_sets=st.sampled_from([2, 8]),
    ways=st.integers(1, 6),
)
def test_property_oracle_agreement(data, policy, num_sets, ways):
    """Hypothesis: arbitrary short traces agree with the serial oracle."""
    trace = data.draw(st.lists(st.integers(0, 60), min_size=1, max_size=80))
    cfg = KWayConfig(num_sets=num_sets, ways=ways, policy=policy)
    ref = RefKWay(num_sets, ways, policy)
    st_ = kway.make_cache(cfg)
    for t in trace:
        st_, h, _, _, _ = kway.access(
            cfg, st_, jnp.array([t], jnp.uint32), jnp.array([t], jnp.int32)
        )
        assert bool(h[0]) == ref.access(t, t)
    jax_keys = {int(x) for x in np.asarray(st_.keys).ravel() if x != 0xFFFFFFFF}
    assert jax_keys == ref.contents()
