"""Property test of Theorem 4.1: a 2C-sized k-way cache stores any C items
with probability ≥ 1 - (C'/k)·e^{-k/6} (balls-into-bins / Chernoff)."""
import math

import numpy as np
import pytest

try:  # hypothesis is a dev-only extra (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import hashing
import jax.numpy as jnp


def overflow_prob_bound(cprime: int, k: int) -> float:
    return (cprime / k) * math.exp(-k / 6.0)


def _check_no_overflow_64way(seed):
    """64-way, C'=2C=16384: bound gives ~0.6% failure — with margin for the
    10-example run, assert overflow in <2 sets on average."""
    k, cprime = 64, 16384
    num_sets = cprime // k
    c = cprime // 2
    rng = np.random.default_rng(seed)
    items = rng.choice(1 << 30, size=c, replace=False).astype(np.uint32)
    sets = np.asarray(hashing.set_index(jnp.asarray(items), num_sets))
    loads = np.bincount(sets, minlength=num_sets)
    assert (loads > k).sum() <= 1, f"overflowing sets: {(loads > k).sum()}"


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_balls_into_bins_no_overflow_64way(seed):
        _check_no_overflow_64way(seed)
else:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 42, 1234, 9999])
    def test_balls_into_bins_no_overflow_64way(seed):
        _check_no_overflow_64way(seed)


def test_paper_numeric_example():
    """'a 64-way cache of size 200k can store any 100k items with
    probability over 99%' — empirical check over 50 trials."""
    # Note: like the paper's own implementation (which masks with
    # numberOfSets-1, Algorithm 2 line 2), the set count must be a power of
    # two, so 200k/64 = 3125 sets rounds UP to 4096 (cache 262k >= 2C: the
    # theorem's premise still holds).
    k = 64
    num_sets = 4096
    fails = 0
    trials = 50
    for seed in range(trials):
        rng = np.random.default_rng(seed)
        items = rng.choice(1 << 31, size=100_000, replace=False).astype(np.uint32)
        sets = np.asarray(hashing.set_index(jnp.asarray(items), num_sets))
        loads = np.bincount(sets, minlength=num_sets)
        if (loads > k).any():
            fails += 1
    assert fails / trials <= 0.10  # generous vs the paper's 1% claim


def test_hash_uniformity():
    """Avalanche quality: chi-square of set distribution ~ uniform."""
    n, num_sets = 1 << 16, 1 << 8
    keys = np.arange(n, dtype=np.uint32)  # worst case: sequential keys
    sets = np.asarray(hashing.set_index(jnp.asarray(keys), num_sets))
    loads = np.bincount(sets, minlength=num_sets)
    expected = n / num_sets
    chi2 = ((loads - expected) ** 2 / expected).sum()
    # dof=255; mean 255, sd ~22.6; allow 6 sigma
    assert chi2 < 255 + 6 * 22.6, chi2
