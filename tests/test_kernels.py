"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policies import Policy
from repro.kernels import ref
from repro.kernels.kway_probe import kway_probe
from repro.kernels.paged_attention import paged_attention

POLICIES = [Policy.LRU, Policy.LFU, Policy.FIFO, Policy.RANDOM, Policy.HYPERBOLIC]


def _mk_cache(rng, s, ways, kp=128, fill=0.7):
    from repro.core import hashing
    keys = np.full((s, kp), -1, np.int32)
    occ = rng.random((s, ways)) < fill
    vals = rng.integers(0, 5000, (s, ways)).astype(np.int32)
    keys[:, :ways] = np.where(occ, vals, -1)
    # consistent fingerprints (what every live state carries); the probes
    # pre-filter on them and confirm on the full key
    fpr = np.asarray(hashing.fingerprint(
        jnp.asarray(keys).astype(jnp.uint32))).astype(np.int32)
    ma = rng.integers(0, 100, (s, kp)).astype(np.int32)
    mb = rng.integers(0, 50, (s, kp)).astype(np.int32)
    return keys, fpr, ma, mb


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("s,ways,b", [(16, 4, 16), (64, 8, 32), (128, 16, 64)])
def test_kway_probe_sweep(policy, s, ways, b, rng):
    keys, fpr, ma, mb = _mk_cache(rng, s, ways)
    sets = rng.integers(0, s, b).astype(np.int32)
    qk = np.where(
        rng.random(b) < 0.5,
        keys[sets, rng.integers(0, ways, b)],
        rng.integers(0, 5000, b),
    ).astype(np.int32)
    times = (np.arange(b) + 7).astype(np.int32)
    args = [jnp.asarray(a) for a in (keys, fpr, ma, mb, sets, qk, times)]
    out_k = kway_probe(*args, policy=int(policy), ways=ways, qt=8)
    out_r = ref.kway_probe_ref(*args, policy=int(policy), ways=ways)
    for name, a, b_ in zip(["hit", "way", "vway", "vkey"], out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_), err_msg=name)


@pytest.mark.parametrize("policy", POLICIES)
def test_kway_probe_full_order(policy, rng):
    """full_order=True: the kernel's iterative min-extraction equals the
    oracle's stable argsort, way for way, over the first `ways` entries."""
    s, ways, b = 32, 8, 24
    keys, fpr, ma, mb = _mk_cache(rng, s, ways)
    sets = rng.integers(0, s, b).astype(np.int32)
    qk = rng.integers(0, 5000, b).astype(np.int32)
    # times > meta_b everywhere: a real cache never has an insert time in the
    # future (HYPERBOLIC ages must stay positive, as in live states)
    times = (np.arange(b) + 60).astype(np.int32)
    args = [jnp.asarray(a) for a in (keys, fpr, ma, mb, sets, qk, times)]
    out_k = kway_probe(*args, policy=int(policy), ways=ways, qt=8,
                       full_order=True)
    out_r = ref.kway_probe_ref(*args, policy=int(policy), ways=ways,
                               full_order=True)
    assert len(out_k) == len(out_r) == 5
    for name, a, b_ in zip(["hit", "way", "vway", "vkey"], out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_),
                                      err_msg=name)
    np.testing.assert_array_equal(
        np.asarray(out_k[4])[:, :ways], np.asarray(out_r[4])[:, :ways])
    # order[0] is the victim way
    np.testing.assert_array_equal(np.asarray(out_k[4])[:, 0],
                                  np.asarray(out_k[2]))


@pytest.mark.parametrize("policy", [Policy.LRU, Policy.RANDOM])
def test_kway_probe_need_victims_false(policy, rng):
    """The read-path variant skips victim selection and returns exactly the
    (hit, way) of the full probe — kernel and oracle alike."""
    s, ways, b = 32, 8, 24
    keys, fpr, ma, mb = _mk_cache(rng, s, ways)
    sets = rng.integers(0, s, b).astype(np.int32)
    qk = np.where(
        rng.random(b) < 0.5,
        keys[sets, rng.integers(0, ways, b)],
        rng.integers(0, 5000, b),
    ).astype(np.int32)
    times = (np.arange(b) + 7).astype(np.int32)
    args = [jnp.asarray(a) for a in (keys, fpr, ma, mb, sets, qk, times)]
    out_lean = kway_probe(*args, policy=int(policy), ways=ways, qt=8,
                          need_victims=False)
    out_full = kway_probe(*args, policy=int(policy), ways=ways, qt=8)
    out_ref = ref.kway_probe_ref(*args, policy=int(policy), ways=ways,
                                 need_victims=False)
    assert len(out_lean) == len(out_ref) == 2
    for name, lean, full_, r in zip(["hit", "way"], out_lean, out_full,
                                    out_ref):
        np.testing.assert_array_equal(np.asarray(lean), np.asarray(full_),
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(lean), np.asarray(r),
                                      err_msg=name)


@pytest.mark.parametrize("policy", POLICIES)
def test_kway_fused_probe_sweep(policy, rng):
    """Single-launch fused probe == oracle: raw hits, hit ways, and the
    full victim order scored on hit-updated metadata at put-phase times —
    including disabled lanes (en=0) that must not perturb the scores."""
    from repro.kernels.kway_probe import kway_fused_probe

    s, ways, b = 32, 8, 24
    keys, fpr, ma, mb = _mk_cache(rng, s, ways)
    sets = rng.integers(0, s, b).astype(np.int32)
    qk = np.where(
        rng.random(b) < 0.5,
        keys[sets, rng.integers(0, ways, b)],
        rng.integers(0, 5000, b),
    ).astype(np.int32)
    # times > meta_b everywhere (live-state invariant; see full_order test)
    tg = (np.arange(b) + 60).astype(np.int32)
    tp = tg + b
    en = (rng.random(b) < 0.8).astype(np.int32)
    args = [jnp.asarray(a) for a in (keys, fpr, ma, mb, sets, qk, tg, tp, en)]
    out_k = kway_fused_probe(*args, policy=int(policy), ways=ways, qt=8)
    out_r = ref.kway_fused_probe_ref(*args, policy=int(policy), ways=ways)
    np.testing.assert_array_equal(np.asarray(out_k[0]), np.asarray(out_r[0]),
                                  err_msg="hit")
    np.testing.assert_array_equal(np.asarray(out_k[1]), np.asarray(out_r[1]),
                                  err_msg="way")
    np.testing.assert_array_equal(
        np.asarray(out_k[2])[:, :ways], np.asarray(out_r[2])[:, :ways],
        err_msg="vorder")


def test_kway_probe_empty_cache(rng):
    keys = np.full((8, 128), -1, np.int32)
    zeros = np.zeros((8, 128), np.int32)
    sets = np.zeros(8, np.int32)
    qk = np.arange(8, dtype=np.int32)
    t = np.arange(8, dtype=np.int32)
    hit, way, vway, vkey = kway_probe(
        *[jnp.asarray(a) for a in (keys, zeros, zeros, zeros, sets, qk, t)],
        policy=int(Policy.LRU), ways=8, qt=8)
    assert not np.asarray(hit).any()
    assert (np.asarray(vway) == 0).all()  # first empty way


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kvh,d,page,pages,pps",
    [(2, 4, 2, 32, 8, 16, 4), (4, 8, 8, 64, 16, 32, 6), (1, 8, 1, 128, 16, 8, 2)],
)
def test_paged_attention_sweep(b, h, kvh, d, page, pages, pps, dtype, rng):
    q = rng.standard_normal((b, h, d)).astype(np.float32)
    kp = rng.standard_normal((kvh, pages, page, d)).astype(np.float32)
    vp = rng.standard_normal((kvh, pages, page, d)).astype(np.float32)
    pt = rng.integers(0, pages, (b, pps)).astype(np.int32)
    sl = rng.integers(0, pps * page + 1, b).astype(np.int32)
    sl[0] = 0  # empty sequence edge case
    args = (jnp.asarray(q, dtype), jnp.asarray(kp, dtype), jnp.asarray(vp, dtype),
            jnp.asarray(pt), jnp.asarray(sl))
    out_k = paged_attention(*args)
    out_r = ref.paged_attention_ref(*args)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        atol=tol, rtol=tol)


def test_paged_attention_softcap(rng):
    b, h, kvh, d, page, pages, pps = 2, 4, 2, 32, 8, 16, 4
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((kvh, pages, page, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((kvh, pages, page, d)), jnp.float32)
    pt = jnp.asarray(rng.integers(0, pages, (b, pps)), jnp.int32)
    sl = jnp.asarray([13, 32], jnp.int32)
    for cap in (0.0, 30.0, 5.0):
        o1 = paged_attention(q, kp, vp, pt, sl, softcap=cap)
        o2 = ref.paged_attention_ref(q, kp, vp, pt, sl, softcap=cap)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5, rtol=2e-5)


def test_paged_vs_contiguous_attention(rng):
    """Paged decode == contiguous decode when pages are laid out in order."""
    from repro.models import layers as L
    b, h, kvh, d, page, pps = 2, 4, 2, 32, 8, 4
    t = page * pps
    pages = pps * b
    k_cont = rng.standard_normal((b, t, kvh, d)).astype(np.float32)
    v_cont = rng.standard_normal((b, t, kvh, d)).astype(np.float32)
    kp = np.moveaxis(k_cont.reshape(b * pps, page, kvh, d), 2, 0).copy()
    vp = np.moveaxis(v_cont.reshape(b * pps, page, kvh, d), 2, 0).copy()
    pt = np.arange(pages).reshape(b, pps).astype(np.int32)
    sl = np.array([t, t - 5], np.int32)
    q = rng.standard_normal((b, h, d)).astype(np.float32)

    out_p = paged_attention(jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
                            jnp.asarray(pt), jnp.asarray(sl))
    # contiguous reference: plain softmax attention with length mask
    g = h // kvh
    qg = q.reshape(b, kvh, g, d)
    logits = np.einsum("bkgd,btkd->bkgt", qg, k_cont) * (d ** -0.5)
    mask = np.arange(t)[None] < sl[:, None]
    logits = np.where(mask[:, None, None], logits, -1e30)
    w = np.exp(logits - logits.max(-1, keepdims=True))
    w /= w.sum(-1, keepdims=True)
    out_c = np.einsum("bkgt,btkd->bkgd", w, v_cont).reshape(b, h, d)
    np.testing.assert_allclose(np.asarray(out_p), out_c, atol=2e-5, rtol=2e-5)
