"""TinyLFU admission + trace-replay behaviour (paper §5.2 machinery)."""
import jax.numpy as jnp
import numpy as np

from repro.core import admission, traces
from repro.core.kway import KWayConfig, fully_associative
from repro.core.policies import Policy
from repro.core.simulate import SimConfig, replay, replay_batched


def test_sketch_estimates_monotone():
    cfg = admission.TinyLFUConfig(width=256, door_bits=512, sample=100_000)
    st = admission.make_sketch(cfg)
    key = jnp.array([42], jnp.uint32)
    prev = 0
    for i in range(10):
        st = admission.record(cfg, st, key)
        est = int(admission.estimate(cfg, st, key)[0])
        assert est >= prev
        prev = est
    assert prev >= 5  # doorkeeper + sketch count several of the 10


def test_sketch_aging_halves():
    cfg = admission.TinyLFUConfig(width=64, door_bits=128, sample=16)
    st = admission.make_sketch(cfg)
    key = jnp.array([7], jnp.uint32)
    for _ in range(10):
        st = admission.record(cfg, st, key)
    before = int(admission.estimate(cfg, st, key)[0])
    # trigger aging with other keys
    other = jnp.arange(100, 120, dtype=jnp.uint32)
    st = admission.record(cfg, st, other)
    after = int(admission.estimate(cfg, st, key)[0])
    assert after < before


def test_admit_prefers_frequent():
    cfg = admission.TinyLFUConfig(width=256, door_bits=512, sample=100_000)
    st = admission.make_sketch(cfg)
    hot, cold = jnp.array([1], jnp.uint32), jnp.array([2], jnp.uint32)
    for _ in range(8):
        st = admission.record(cfg, st, hot)
    st = admission.record(cfg, st, cold)
    # hot candidate vs cold victim: admit
    assert bool(admission.admit(cfg, st, hot, cold, jnp.array([True]))[0])
    # cold candidate vs hot victim: reject
    assert not bool(admission.admit(cfg, st, cold, hot, jnp.array([True]))[0])


def test_replay_kway_close_to_full(rng):
    """Paper conclusion: k=8 hit ratio within ~2pts of fully associative."""
    tr = traces.generate("zipf", 30_000, seed=3, catalog=1 << 13, alpha=1.0)
    cap = 512
    h8 = replay(SimConfig(KWayConfig(num_sets=cap // 8, ways=8, policy=Policy.LRU)), tr)
    hf = replay(SimConfig(fully_associative(cap, Policy.LRU)), tr)
    assert abs(h8 - hf) < 0.03
    assert h8 > 0.2  # sanity: the trace is cacheable


def test_replay_batched_close_to_serial(rng):
    tr = traces.generate("zipf", 20_000, seed=5, catalog=1 << 12, alpha=1.0)
    cfg = KWayConfig(num_sets=64, ways=8, policy=Policy.LRU)
    hs = replay(SimConfig(cfg), tr)
    hb = replay_batched(SimConfig(cfg), tr, batch=64)
    assert abs(hs - hb) < 0.03


def test_tinylfu_helps_on_scan(rng):
    """Admission filter shields the cache from scan pollution."""
    tr_hot = traces.generate("zipf", 15_000, seed=7, catalog=1 << 10, alpha=1.2)
    tr_scan = traces.generate("scan_loop", 15_000, seed=8, working=1 << 14,
                              noise=0.0, catalog=1 << 15)
    tr = np.empty(30_000, np.uint32)
    tr[0::2] = tr_hot
    tr[1::2] = tr_scan + np.uint32(1 << 20)
    cap = 512
    cfg = KWayConfig(num_sets=cap // 8, ways=8, policy=Policy.LFU)
    plain = replay(SimConfig(cfg), tr)
    gated = replay(SimConfig(cfg, admission.for_capacity(cap)), tr)
    assert gated >= plain - 0.01  # TinyLFU should not hurt, usually helps


def test_batched_tinylfu_matches_sequential(rng):
    """The batched replay path must honour SimConfig.tinylfu (it used to
    drop it silently): batched+TinyLFU ≈ sequential+TinyLFU hit ratio."""
    tr_hot = traces.generate("zipf", 10_000, seed=7, catalog=1 << 10,
                             alpha=1.2)
    tr_scan = traces.generate("scan_loop", 10_000, seed=8, working=1 << 14,
                              noise=0.0, catalog=1 << 15)
    tr = np.empty(20_000, np.uint32)
    tr[0::2] = tr_hot
    tr[1::2] = tr_scan + np.uint32(1 << 20)
    cap = 512
    cfg = KWayConfig(num_sets=cap // 8, ways=8, policy=Policy.LFU)
    tl = admission.for_capacity(cap)
    hs = replay(SimConfig(cfg, tl), tr)
    hb = replay_batched(SimConfig(cfg, tl), tr, batch=64)
    assert abs(hs - hb) < 0.03
    # ... and the filter visibly bites in the batched path too: without it
    # the scan pollutes the LFU cache (same direction as the serial test).
    plain = replay_batched(SimConfig(cfg), tr, batch=64)
    assert hb >= plain - 0.03


def test_batched_tinylfu_unsupported_paths_raise():
    """Only the sequential-Python ref oracle stays excluded: TinyLFU and
    two_phase now compose with the set-sharded layer (PR 4), so the old
    TinyLFU×shards / two_phase×shards guards are gone."""
    import pytest

    cfg = KWayConfig(num_sets=8, ways=8, policy=Policy.LFU)
    tl = admission.for_capacity(64)
    tr = traces.generate("zipf", 256, seed=1)
    with pytest.raises(ValueError, match="ref backend"):
        replay_batched(SimConfig(cfg, tl, backend="ref"), tr, batch=64)
    with pytest.raises(ValueError, match="ref backend"):
        replay(SimConfig(cfg, tl, backend="ref"), tr)
    with pytest.raises(ValueError, match="sharded"):
        replay_batched(SimConfig(cfg, backend="ref"), tr, batch=64, shards=2)
    # ... and the previously guarded combinations now replay fine:
    assert 0.0 <= replay_batched(SimConfig(cfg, tl), tr, batch=64,
                                 shards=2) <= 1.0
    assert 0.0 <= replay_batched(SimConfig(cfg, two_phase=True), tr,
                                 batch=64, shards=2) <= 1.0


def test_all_trace_families_generate():
    for fam in traces.FAMILIES:
        t = traces.generate(fam, 2000, seed=1)
        assert t.shape == (2000,) and t.dtype == np.uint32
