"""Checkpoint manager + data pipeline: atomicity, resume, elastic reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import manager as ckpt
from repro.data.pipeline import DataConfig, DataState, SyntheticPipeline


def _tree(rng):
    return {
        "a": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
        "b": {"c": jnp.asarray(rng.integers(0, 10, (4,)), jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path, rng):
    t = _tree(rng)
    ckpt.save(str(tmp_path), 5, t, extra={"step": 5, "data_step": 17})
    assert ckpt.latest_step(str(tmp_path)) == 5
    like = jax.tree.map(lambda x: jnp.zeros_like(x), t)
    restored, extra = ckpt.restore(str(tmp_path), 5, like)
    assert extra == {"step": 5, "data_step": 17}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _cache_like_tree(rng):
    """Leaf dtypes the cache/serve pytrees actually use: uint32 lanes,
    bool masks, bf16 KV pools (the f32-widening save path), int32 meta."""
    return {
        "keys": jnp.asarray(rng.integers(0, 2**32, (16, 4),
                                         dtype=np.uint32)),
        "active": jnp.asarray(rng.integers(0, 2, (4,)).astype(bool)),
        "pool_k": jnp.asarray(rng.standard_normal((2, 3, 8, 4)),
                              jnp.bfloat16),
        "meta": {"a": jnp.asarray(rng.integers(0, 100, (16, 4)),
                                  jnp.int32)},
    }


def test_cache_pytree_roundtrip_exact(tmp_path, rng):
    """uint32/bool/bf16 leaves must round-trip bit-exactly — the bf16 leaf
    takes the f32-widening save path and must cast back to bf16 with no
    residue (f32 is a superset of bf16, so the cast is lossless)."""
    t = _cache_like_tree(rng)
    ckpt.save(str(tmp_path), 2, t)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), t)
    restored, _ = ckpt.restore(str(tmp_path), 2, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(jnp.asarray(a, jnp.float32)),
            np.asarray(jnp.asarray(b, jnp.float32)))


def test_restore_names_missing_and_extra_leaf(tmp_path, rng):
    t = _cache_like_tree(rng)
    ckpt.save(str(tmp_path), 0, t)
    wrong = dict(t)
    wrong["renamed"] = wrong.pop("keys")
    with pytest.raises(ValueError) as ei:
        ckpt.restore(str(tmp_path), 0, wrong)
    msg = str(ei.value)
    assert "renamed" in msg and "keys" in msg
    assert "missing from checkpoint" in msg and "extra in checkpoint" in msg


def test_restore_names_shape_mismatch(tmp_path, rng):
    t = _cache_like_tree(rng)
    ckpt.save(str(tmp_path), 0, t)
    wrong = dict(t)
    wrong["keys"] = jnp.zeros((8, 4), jnp.uint32)
    with pytest.raises(ValueError, match=r"keys.*shape"):
        ckpt.restore(str(tmp_path), 0, wrong)


def test_restore_missing_step_names_latest(tmp_path, rng):
    t = _cache_like_tree(rng)
    ckpt.save(str(tmp_path), 7, t)
    with pytest.raises(ValueError, match="latest committed: 7"):
        ckpt.restore(str(tmp_path), 8, t)


def test_uncommitted_save_invisible(tmp_path, rng):
    """``commit=False`` (the crash-mid-tick injection point) leaves only a
    .tmp dir: latest_step must not see it, restore must refuse it."""
    t = _cache_like_tree(rng)
    ckpt.save(str(tmp_path), 1, t)
    tmp = ckpt.save(str(tmp_path), 2, t, commit=False)
    assert tmp.endswith(".tmp") and os.path.isdir(tmp)
    assert ckpt.latest_step(str(tmp_path)) == 1
    with pytest.raises(ValueError, match="no committed checkpoint"):
        ckpt.restore(str(tmp_path), 2, t)


def test_atomicity_tmp_ignored(tmp_path, rng):
    t = _tree(rng)
    ckpt.save(str(tmp_path), 1, t)
    # simulate a crashed write
    os.makedirs(tmp_path / "step_000000009.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_gc_keeps_last(tmp_path, rng):
    t = _tree(rng)
    for s in range(6):
        ckpt.save(str(tmp_path), s, t, keep_last=3)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 3
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_elastic_restore_to_different_mesh(tmp_path, rng):
    """Save unsharded, restore onto a 2-device mesh (elastic restart)."""
    if jax.device_count() < 2:
        pytest.skip("needs >1 device")  # single CPU in CI: skipped
    t = _tree(rng)
    ckpt.save(str(tmp_path), 0, t)


def test_pipeline_deterministic_and_stateless():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    p1 = SyntheticPipeline(cfg)
    p2 = SyntheticPipeline(cfg)
    s = DataState(step=3)
    a1, b1 = p1.batch(s)
    a2, b2 = p2.batch(s)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1, b2)
    assert a1.shape == (8, 32) and b1.shape == (8, 32)
    # labels are next-token shifted
    s2 = p1.advance(s)
    assert s2.step == 4


def test_pipeline_elastic_reshard_covers_batch():
    """Shards at any world size partition the same global batch."""
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    st = DataState(step=11)
    full, _ = SyntheticPipeline(cfg, 0, 1).batch(st)
    # different world sizes have the same per-shard shape contract
    parts = [SyntheticPipeline(cfg, i, 4).batch(st)[0] for i in range(4)]
    assert all(p.shape == (2, 16) for p in parts)
    # determinism per (step, shard)
    again = SyntheticPipeline(cfg, 2, 4).batch(st)[0]
    np.testing.assert_array_equal(parts[2], again)


def test_train_driver_resume(tmp_path):
    """End-to-end: train 6 steps, kill, resume to 10 — loss continues."""
    from repro.launch.train import main
    d = str(tmp_path / "run")
    rc = main(["--arch", "mamba2-130m", "--smoke", "--steps", "6",
               "--batch", "2", "--seq", "32", "--ckpt-dir", d,
               "--ckpt-every", "3"])
    assert rc == 0
    assert ckpt.latest_step(d) == 6
    rc = main(["--arch", "mamba2-130m", "--smoke", "--steps", "10",
               "--batch", "2", "--seq", "32", "--ckpt-dir", d,
               "--ckpt-every", "5"])
    assert rc == 0
    assert ckpt.latest_step(d) == 10
