"""Checkpoint manager + data pipeline: atomicity, resume, elastic reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import manager as ckpt
from repro.data.pipeline import DataConfig, DataState, SyntheticPipeline


def _tree(rng):
    return {
        "a": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
        "b": {"c": jnp.asarray(rng.integers(0, 10, (4,)), jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path, rng):
    t = _tree(rng)
    ckpt.save(str(tmp_path), 5, t, extra={"step": 5, "data_step": 17})
    assert ckpt.latest_step(str(tmp_path)) == 5
    like = jax.tree.map(lambda x: jnp.zeros_like(x), t)
    restored, extra = ckpt.restore(str(tmp_path), 5, like)
    assert extra == {"step": 5, "data_step": 17}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_tmp_ignored(tmp_path, rng):
    t = _tree(rng)
    ckpt.save(str(tmp_path), 1, t)
    # simulate a crashed write
    os.makedirs(tmp_path / "step_000000009.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_gc_keeps_last(tmp_path, rng):
    t = _tree(rng)
    for s in range(6):
        ckpt.save(str(tmp_path), s, t, keep_last=3)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 3
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_elastic_restore_to_different_mesh(tmp_path, rng):
    """Save unsharded, restore onto a 2-device mesh (elastic restart)."""
    if jax.device_count() < 2:
        pytest.skip("needs >1 device")  # single CPU in CI: skipped
    t = _tree(rng)
    ckpt.save(str(tmp_path), 0, t)


def test_pipeline_deterministic_and_stateless():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    p1 = SyntheticPipeline(cfg)
    p2 = SyntheticPipeline(cfg)
    s = DataState(step=3)
    a1, b1 = p1.batch(s)
    a2, b2 = p2.batch(s)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1, b2)
    assert a1.shape == (8, 32) and b1.shape == (8, 32)
    # labels are next-token shifted
    s2 = p1.advance(s)
    assert s2.step == 4


def test_pipeline_elastic_reshard_covers_batch():
    """Shards at any world size partition the same global batch."""
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    st = DataState(step=11)
    full, _ = SyntheticPipeline(cfg, 0, 1).batch(st)
    # different world sizes have the same per-shard shape contract
    parts = [SyntheticPipeline(cfg, i, 4).batch(st)[0] for i in range(4)]
    assert all(p.shape == (2, 16) for p in parts)
    # determinism per (step, shard)
    again = SyntheticPipeline(cfg, 2, 4).batch(st)[0]
    np.testing.assert_array_equal(parts[2], again)


def test_train_driver_resume(tmp_path):
    """End-to-end: train 6 steps, kill, resume to 10 — loss continues."""
    from repro.launch.train import main
    d = str(tmp_path / "run")
    rc = main(["--arch", "mamba2-130m", "--smoke", "--steps", "6",
               "--batch", "2", "--seq", "32", "--ckpt-dir", d,
               "--ckpt-every", "3"])
    assert rc == 0
    assert ckpt.latest_step(d) == 6
    rc = main(["--arch", "mamba2-130m", "--smoke", "--steps", "10",
               "--batch", "2", "--seq", "32", "--ckpt-dir", d,
               "--ckpt-every", "5"])
    assert rc == 0
    assert ckpt.latest_step(d) == 10
