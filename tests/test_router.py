"""Device-resident request router + end-to-end scanned sharded replay
(core/router.py, core/sharded.py rewrite — DESIGN.md §9).

Covers the PR-4 contracts:
  * router unit semantics (owner bits, arrival order, overflow-defer,
    unscatter inverse);
  * sharded-vs-unsharded bit parity for the timestamp-order-invariant
    policies across batch boundaries at shards ∈ {1, 2, 4, 8};
  * fixed-capacity layout compile stability (≤ 1 compile per shape via
    ``sharded.trace_counts`` — the old ``counts.max()`` bucketing recompiled
    per batch);
  * per-shard TinyLFU privatization tracking the global sketch;
  * two_phase through the shard step;
  * donated-state aliasing on the scanned path.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import admission, router, sharded, traces
from repro.core.backend import make_backend
from repro.core.kway import KWayConfig
from repro.core.policies import Policy
from repro.core.sharded import ShardedCache, ShardedConfig
from repro.core.simulate import SimConfig, replay_batched


# ---------------------------------------------------------------------------
# router units
# ---------------------------------------------------------------------------

def test_route_owner_is_high_bits(rng):
    from repro.core import hashing
    keys = jnp.asarray(rng.integers(0, 1 << 30, 300).astype(np.uint32))
    owner = router.owner_of(keys, 64, 8, 0x51CA)
    gset = hashing.set_index(keys, 64, 0x51CA)
    np.testing.assert_array_equal(np.asarray(owner), np.asarray(gset) // 8)


def test_route_unscatter_roundtrip(rng):
    keys = rng.integers(0, 10_000, 128).astype(np.uint32)
    owner = router.owner_of(jnp.asarray(keys), 32, 4, 0x51CA)
    plan = router.route(owner, 4, 128)
    vb = router.bucket(plan, jnp.asarray(keys), 4, 128, jnp.uint32(0))
    back = router.unscatter(plan, vb, jnp.uint32(0))
    np.testing.assert_array_equal(np.asarray(back), keys)
    # the enabled mask marks exactly the landed lanes
    eb = router.bucket_mask(plan, 4, 128)
    assert int(np.asarray(eb).sum()) == len(keys)


def test_route_overflow_defer_semantics():
    # 10 keys, all owned by shard 0, capacity 4: the first 4 (in arrival
    # order) route, the rest defer — deterministically, never dropped.
    owner = jnp.zeros((10,), jnp.int32)
    plan = router.route(owner, 2, 4)
    defer = np.asarray(plan.deferred)
    np.testing.assert_array_equal(defer, np.arange(10) >= 4)
    assert np.asarray(plan.pos)[:4].tolist() == [0, 1, 2, 3]
    # bucketing drops exactly the deferred lanes
    eb = router.bucket_mask(plan, 2, 4)
    assert int(np.asarray(eb).sum()) == 4


def test_route_disabled_lanes_never_displace(rng):
    # disabled lanes rank last: they never push an enabled lane past the
    # capacity, and they never land in a bucket
    owner = jnp.zeros((8,), jnp.int32)
    enabled = jnp.asarray([True, False, True, False, True, True, True, True])
    plan = router.route(owner, 2, 6, enabled)
    assert not np.asarray(plan.deferred)[np.asarray(enabled)].any()
    eb = router.bucket_mask(plan, 2, 6)
    assert int(np.asarray(eb).sum()) == int(np.asarray(enabled).sum())


def test_route_single_shard_is_identity():
    owner = jnp.zeros((16,), jnp.int32)
    plan = router.route(owner, 1, 16)
    np.testing.assert_array_equal(np.asarray(plan.pos), np.arange(16))
    assert not np.asarray(plan.deferred).any()


# ---------------------------------------------------------------------------
# sharded-vs-unsharded parity (the paper's disjoint-union claim)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", [Policy.LRU, Policy.LFU, Policy.FIFO])
@pytest.mark.parametrize("num_shards", [1, 2, 4, 8])
def test_scanned_replay_bit_parity(policy, num_shards):
    """replay_batched(shards=D) — ONE jitted lax.scan with device routing —
    produces the exact unsharded hit count for the timestamp-order-invariant
    policies, across batch boundaries and including the padded tail chunk
    (trace length deliberately not a batch multiple)."""
    tr = traces.generate("zipf", 2000, seed=5, catalog=1 << 11)  # 2000 % 64 != 0
    sim = SimConfig(KWayConfig(num_sets=32, ways=4, policy=policy))
    h1 = replay_batched(sim, tr, batch=64)
    hd = replay_batched(sim, tr, batch=64, shards=num_shards)
    assert h1 == pytest.approx(hd, abs=1e-12)


def test_scanned_replay_final_state_matches_access_loop(rng):
    """The single-scan replay and a per-chunk access() loop are the same
    computation: identical hit totals and identical final shard states."""
    gcfg = KWayConfig(num_sets=16, ways=4, policy=Policy.LRU)
    tr = traces.generate("zipf", 1024, seed=9, catalog=1 << 10)
    sc = ShardedCache(ShardedConfig(cache=gcfg, num_shards=4))
    hits_scan, defers, st_scan = sc.replay(tr, 64)
    assert defers == 0
    st = sc.init()
    hits_loop = 0
    for i in range(0, 1024, 64):
        chunk = tr[i:i + 64]
        st, hit, *_ = sc.access(st, chunk, chunk.astype(np.int32))
        hits_loop += int(np.asarray(hit).sum())
    assert hits_scan == hits_loop
    np.testing.assert_array_equal(np.asarray(st_scan.keys),
                                  np.asarray(st.keys))
    np.testing.assert_array_equal(np.asarray(st_scan.meta_a),
                                  np.asarray(st.meta_a))


def test_sharded_two_phase_matches_fused():
    """two_phase (the unfused get-then-put oracle) now threads through the
    shard step and stays bit-identical to the fused sharded path."""
    tr = traces.generate("oltp_mix", 3000, seed=3)
    cfg = KWayConfig(num_sets=64, ways=4, policy=Policy.LRU)
    h_fused = replay_batched(SimConfig(cfg), tr, batch=64, shards=4)
    h_two = replay_batched(SimConfig(cfg, two_phase=True), tr, batch=64,
                           shards=4)
    assert h_fused == pytest.approx(h_two, abs=1e-12)


# ---------------------------------------------------------------------------
# overflow-defer through the cache layer
# ---------------------------------------------------------------------------

def test_access_overflow_defer_reported(rng):
    gcfg = KWayConfig(num_sets=16, ways=4, policy=Policy.LRU)
    sc = ShardedCache(ShardedConfig(cache=gcfg, num_shards=4,
                                    route_capacity=2))
    st = sc.init()
    keys = rng.integers(0, 1 << 20, 32).astype(np.uint32)
    st, hit, vals, ek, ev, defer = sc.access(
        st, keys, keys.astype(np.int32), return_deferred=True)
    defer = np.asarray(defer)
    assert defer.any()                      # 32 keys into 4x2 lanes must defer
    # deferred lanes are untouched: no hit, no value, no eviction
    assert not (np.asarray(hit) & defer).any()
    assert (np.asarray(vals)[defer] == -1).all()
    assert not (np.asarray(ev) & defer).any()
    # a deferred key was NOT inserted: replaying it alone hits iff routed
    gv = sc.global_view(st)
    routed_keys = keys[~defer]
    present = np.isin(routed_keys, np.asarray(gv.keys).ravel())
    assert present.all()
    deferred_keys = keys[defer]
    assert not np.isin(deferred_keys, np.asarray(gv.keys).ravel()).any()


def test_replay_overflow_defer_counted():
    tr = traces.generate("zipf", 512, seed=2, catalog=1 << 10)
    gcfg = KWayConfig(num_sets=16, ways=4, policy=Policy.LRU)
    sc = ShardedCache(ShardedConfig(cache=gcfg, num_shards=4,
                                    route_capacity=4))
    hits, defers, _ = sc.replay(tr, 32)
    assert defers > 0                       # 32-per-chunk into 4x4 lanes
    sc_full = ShardedCache(ShardedConfig(cache=gcfg, num_shards=4))
    hits_full, defers_full, _ = sc_full.replay(tr, 32)
    assert defers_full == 0                 # default capacity never defers


# ---------------------------------------------------------------------------
# compile stability (the recompile-churn regression)
# ---------------------------------------------------------------------------

def test_fixed_capacity_compiles_once_across_skewed_batches(rng):
    """The old host bucketing derived the bucket shape from each chunk's
    ``counts.max()``, so skew changed the jitted shapes chunk to chunk.  The
    router's fixed [D, capacity] layout must compile ONCE per shape no
    matter how the batch skews across shards."""
    gcfg = KWayConfig(num_sets=64, ways=4, policy=Policy.LRU)
    sc = ShardedCache(ShardedConfig(cache=gcfg, num_shards=4))
    st = sc.init()
    sharded.reset_trace_counts()
    all_owner = sc.owner_of(np.arange(4096, dtype=np.uint32))
    batches = [
        rng.integers(0, 1 << 20, 64).astype(np.uint32),        # balanced-ish
        np.arange(4096, dtype=np.uint32)[all_owner == 0][:64]  # all shard 0
        .astype(np.uint32),
        np.arange(4096, dtype=np.uint32)[all_owner == 3][:64]  # all shard 3
        .astype(np.uint32),
        np.repeat(rng.integers(0, 1 << 20, 2), 32).astype(np.uint32),  # dups
    ]
    for keys in batches:
        assert keys.shape == (64,)
        st, *_ = sc.access(st, keys, keys.astype(np.int32))
    counts = sharded.trace_counts()
    assert len(counts) == 1 and all(v == 1 for v in counts.values()), (
        f"router step retraced across same-shape batches: {counts}")


def test_scanned_replay_compiles_once_per_shape():
    tr = traces.generate("zipf", 2048, seed=1, catalog=1 << 10)
    gcfg = KWayConfig(num_sets=64, ways=4, policy=Policy.LRU)
    sc = ShardedCache(ShardedConfig(cache=gcfg, num_shards=4))
    sharded.reset_trace_counts()
    sc.replay(tr, 64)
    sc.replay(tr[:1999], 64)    # different trace length, same chunk shape
    counts = {k: v for k, v in sharded.trace_counts().items()
              if k[0] == "replay"}
    assert len(counts) == 1 and all(v == 1 for v in counts.values()), (
        f"scanned replay retraced for an unchanged chunk shape: {counts}")


# ---------------------------------------------------------------------------
# per-shard TinyLFU privatization
# ---------------------------------------------------------------------------

def test_per_shard_tinylfu_tracks_global_sketch():
    """Privatized sketches see 1/D of the traffic each; the admission
    decisions drift from the global-sketch path, but the hit ratio must stay
    in a tight band — and the filter must still visibly shield the cache
    from scan pollution."""
    tr_hot = traces.generate("zipf", 8000, seed=7, catalog=1 << 10, alpha=1.2)
    tr_scan = traces.generate("scan_loop", 8000, seed=8, working=1 << 14,
                              noise=0.0, catalog=1 << 15)
    tr = np.empty(16_000, np.uint32)
    tr[0::2] = tr_hot
    tr[1::2] = tr_scan + np.uint32(1 << 20)
    cap = 512
    cfg = KWayConfig(num_sets=cap // 8, ways=8, policy=Policy.LFU)
    tl = admission.for_capacity(cap)
    h_global = replay_batched(SimConfig(cfg, tl), tr, batch=64)
    h_shard = replay_batched(SimConfig(cfg, tl), tr, batch=64, shards=4)
    assert abs(h_global - h_shard) < 0.03
    plain = replay_batched(SimConfig(cfg), tr, batch=64, shards=4)
    assert h_shard >= plain - 0.03          # the filter still bites


def test_sharded_access_threads_sketches(rng):
    """The stacked [D, ...] sketch leaves ride through access() and come
    back updated (additions only count enabled lanes)."""
    gcfg = KWayConfig(num_sets=16, ways=4, policy=Policy.LFU)
    tl = admission.TinyLFUConfig(width=256, door_bits=512, sample=100_000)
    sc = ShardedCache(ShardedConfig(cache=gcfg, num_shards=4))
    st = sc.init()
    sk = sc.init_sketches(tl)
    keys = rng.integers(0, 500, 32).astype(np.uint32)
    st, hit, vals, ek, ev, sk = sc.access(
        st, keys, keys.astype(np.int32), tinylfu=tl, sketches=sk)
    adds = np.asarray(sk.additions)
    assert adds.shape == (4,) and adds.sum() == 32  # every lane, once, somewhere
    owner = sc.owner_of(keys)
    np.testing.assert_array_equal(adds, np.bincount(owner, minlength=4))


# ---------------------------------------------------------------------------
# slot-id globalization (the serving contract)
# ---------------------------------------------------------------------------

def test_put_slot_value_stays_global_when_lanes_share_a_way():
    """Regression: two active put lanes may legally share a (set, way) — a
    present key being refreshed plus an insert victimizing that key's way.
    The global-id lift must be idempotent (scatter-set of the recomputed id,
    not scatter-add of an offset, which would apply the shard offset twice
    and corrupt the stored page id)."""
    from repro.core import hashing
    from repro.core.hashing import EMPTY_KEY

    gcfg = KWayConfig(num_sets=8, ways=1, policy=Policy.LRU)
    sc = ShardedCache(ShardedConfig(cache=gcfg, num_shards=2))
    # two keys owned by shard 1 that collide on one global set
    cand = np.arange(1, 20_000, dtype=np.uint32)
    gset = np.asarray(hashing.set_index(jnp.asarray(cand), 8, gcfg.seed))
    hot = np.bincount(gset, minlength=8)
    target = int(np.argmax(hot[4:]) + 4)           # a shard-1 set (>= S/D)
    k1, k2 = cand[gset == target][:2]
    st = sc.init()
    st, *_ = sc.put(st, np.asarray([k1]), np.zeros(1, np.int32),
                    slot_value=True)
    # k1 present (refresh) + k2 insert victimizing k1's only way, one batch
    st, ek, ev, ss, sw = sc.put(
        st, np.asarray([k1, k2]), np.zeros(2, np.int32), slot_value=True)
    assert (np.asarray(ss) == target).all() and (np.asarray(sw) == 0).all()
    gv = sc.global_view(st)
    keys, vals = np.asarray(gv.keys), np.asarray(gv.vals)
    stored = keys != np.uint32(EMPTY_KEY)
    assert stored.any()
    # every stored payload is exactly its own global slot id
    slot_ids = (np.arange(8)[:, None] * 1 + np.arange(1)[None, :])
    np.testing.assert_array_equal(vals[stored], slot_ids[stored])
    # and a get through the sharded path returns that same global id
    for key in (k1, k2):
        if (keys == key).any():
            st, hit, v = sc.get(st, np.asarray([key], np.uint32))
            assert bool(np.asarray(hit)[0])
            assert int(np.asarray(v)[0]) == target


# ---------------------------------------------------------------------------
# donated-state aliasing on the scanned path
# ---------------------------------------------------------------------------

def test_replay_donates_initial_state():
    """``replay`` donates the initial shard state to the scan: the caller's
    buffers are consumed (deleted) and the result matches a fresh run."""
    tr = traces.generate("zipf", 1024, seed=4, catalog=1 << 10)
    gcfg = KWayConfig(num_sets=32, ways=4, policy=Policy.LRU)
    sc = ShardedCache(ShardedConfig(cache=gcfg, num_shards=4))
    hits_ref, _, _ = sc.replay(tr, 64)
    st = sc.init()
    jax.block_until_ready(st.keys)
    hits, _, st2 = sc.replay(tr, 64, state=st)
    assert hits == hits_ref
    assert st.keys.is_deleted(), \
        "initial state leaves must be donated to the scanned replay"
    assert not st2.keys.is_deleted()


def test_access_donation_consumes_state(rng):
    gcfg = KWayConfig(num_sets=16, ways=4, policy=Policy.LRU)
    sc = ShardedCache(ShardedConfig(cache=gcfg, num_shards=2, donate=True))
    sc_ref = ShardedCache(ShardedConfig(cache=gcfg, num_shards=2))
    st = sc.init()
    st_ref = sc_ref.init()
    for _ in range(4):
        keys = rng.integers(0, 300, 16).astype(np.uint32)
        st, h1, *_ = sc.access(st, keys, keys.astype(np.int32))
        st_ref, h2, *_ = sc_ref.access(st_ref, keys, keys.astype(np.int32))
        np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_array_equal(np.asarray(st.keys),
                                  np.asarray(st_ref.keys))
