"""Chaos suite for the robust subsystem (DESIGN.md §13).

Contract under test: every injected fault class is either *detected* by
the invariant validator (with zero false positives on the clean golden
trace, all 5 policies x 3 backends) or *survived* by a recovery path —
scrub-and-invalidate keeps replaying within a banded hit-ratio loss,
crash-mid-tick restore resumes with bit-identical tokens, and the
degradation ladder lands on a slower rung with the event observable.
"""
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import admission, traces
from repro.core import backend as backend_mod
from repro.core.backend import make_backend
from repro.core.hashing import EMPTY_KEY
from repro.core.kway import KWayConfig
from repro.core.policies import Policy
from repro.core.router import pad_chunks
from repro.robust import (
    check_cache,
    check_hier,
    check_serve,
    events,
    explain_cache,
    explain_hier,
    explain_serve,
    faults,
    resilient_replay,
    restore_engine,
    save_engine,
    scrub,
    scrub_hier,
    validated_replay,
    watch,
    WatchdogTimeout,
)
from repro.robust.invariants import sketch_bits
from repro.robust.ladder import RUNGS

CONFIG = dict(num_sets=16, ways=4)
SEED = 2026


def golden_trace():
    tr = traces.generate("zipf", 512, seed=SEED, catalog=96)
    tr[::13] = 0
    return tr


def _chunks(batch=8):
    return pad_chunks(golden_trace(), batch)


def _replayed_state(policy=Policy.LRU, backend="jnp", tinylfu=None):
    cfg = KWayConfig(policy=policy, **CONFIG)
    be = make_backend(backend, cfg)
    chunks, enabled = _chunks()
    hits, evs, st, sk = be.replay(be.init(), chunks, enabled,
                                  tinylfu=tinylfu)
    return cfg, st, int(np.asarray(hits).sum()), sk


# ---------------------------------------------------------------------------
# validator: zero false positives
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", list(Policy))
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_clean_golden_trace_no_false_positives(policy, backend):
    cfg, st, _, _ = _replayed_state(policy, backend)
    rep = check_cache(cfg, st, vals_mode="key")
    assert rep.clean(), explain_cache(rep)


@pytest.mark.parametrize("policy", list(Policy))
def test_clean_golden_trace_ref_backend(policy):
    cfg = KWayConfig(policy=policy, **CONFIG)
    be = make_backend("ref", cfg)
    chunks, enabled = _chunks()
    st = be.init()
    for i in range(chunks.shape[0]):
        keys = np.asarray(chunks[i], np.uint32)
        st, _, _, _, _ = be.access(st, keys, keys.astype(np.int32),
                                   enabled=np.asarray(enabled[i]))
    rep = check_cache(cfg, st, vals_mode="key")
    assert rep.clean(), explain_cache(rep)


def test_clean_with_tinylfu_sketch():
    cfg = KWayConfig(**CONFIG)
    tl = admission.for_capacity(cfg.capacity)
    cfg, st, _, sk = _replayed_state(tinylfu=tl)
    assert check_cache(cfg, st, vals_mode="key").clean()
    assert int(sketch_bits(tl, sk)) == 0


# ---------------------------------------------------------------------------
# fault injection: every lane site detected, reproducibly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("site", faults.LANE_SITES)
def test_bit_flip_detected_and_localized(site):
    cfg, st, _, _ = _replayed_state()
    st2, rep_f = faults.flip_bit(st, site, seed=7)
    rep = check_cache(cfg, st2, vals_mode="key")
    assert not rep.clean(), f"undetected {site} flip: {rep_f}"
    # explain names the corrupted lane's set/way (the flip may shadow its
    # whole set, but the injected coordinate must be among the named ones)
    s, w = rep_f.index
    lane_bits = np.asarray(rep.lane_bits)
    if int(lane_bits[s, w]) == 0:
        # a key flipped onto EMPTY_KEY surfaces as empty_lane_dirty on the
        # same coordinates — either way the lane must be named
        assert any(f"set {s} way {w}" in line for line in explain_cache(rep))
    assert any(f"set {s}" in line for line in explain_cache(rep))


def test_fault_reproducible_from_seed_site_step():
    cfg, st, _, _ = _replayed_state()
    a1, r1 = faults.flip_bit(st, "keys", seed=11, step=3)
    a2, r2 = faults.flip_bit(st, "keys", seed=11, step=3)
    assert r1 == r2
    np.testing.assert_array_equal(np.asarray(a1.keys), np.asarray(a2.keys))
    _, r3 = faults.flip_bit(st, "keys", seed=11, step=4)
    assert (r3.index, r3.bit) != (r1.index, r1.bit) or r3.step != r1.step


def test_empty_lane_dirty_detected():
    cfg = KWayConfig(**CONFIG)
    from repro.core import kway
    st = kway.make_cache(cfg)
    meta = np.array(st.meta_a)
    meta[3, 2] = 99
    rep = check_cache(cfg, dataclasses.replace(st, meta_a=jnp.asarray(meta)))
    assert not rep.clean()
    assert any("set 3 way 2: empty_lane_dirty" in line
               for line in explain_cache(rep))


def test_sketch_bounds_detected():
    cfg = KWayConfig(**CONFIG)
    tl = admission.for_capacity(cfg.capacity)
    sk = admission.make_sketch(tl)
    bad = dataclasses.replace(sk, additions=jnp.asarray(tl.sample, jnp.int32))
    assert int(sketch_bits(tl, bad)) & 1
    bad2 = dataclasses.replace(
        sk, door=jnp.ones_like(sk.door) * jnp.uint32(0xFF))
    assert int(sketch_bits(tl, bad2)) & 2


# ---------------------------------------------------------------------------
# recovery: scrub-and-invalidate, banded divergence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("site", ["keys", "fprint", "meta_a", "vals"])
def test_inject_detect_scrub_replay_band(site):
    """The full chaos loop: replay half the golden trace, corrupt, detect,
    scrub (forced evictions tallied), replay on — final state clean and
    the recovered hit ratio inside the band around the clean run."""
    cfg = KWayConfig(**CONFIG)
    be = make_backend("jnp", cfg)
    chunks, enabled = _chunks()
    hc, _, _, _ = be.replay(be.init(), chunks, enabled)
    hr_clean = float(np.asarray(hc).sum()) / 512

    half = chunks.shape[0] // 2
    h1, _, st, _ = be.replay(be.init(), chunks[:half], enabled[:half])
    st, _ = faults.flip_bit(st, site, seed=SEED, step=half)
    assert not check_cache(cfg, st, vals_mode="key").clean()
    st, forced, _ = scrub(cfg, st, vals_mode="key")
    assert int(forced) > 0
    assert check_cache(cfg, st, vals_mode="key").clean()
    h2, _, st, _ = be.replay(st, chunks[half:], enabled[half:])
    assert check_cache(cfg, st, vals_mode="key").clean()
    hr = (float(np.asarray(h1).sum()) + float(np.asarray(h2).sum())) / 512
    # scrubbing resets at most a few sets of a 64-lane cache: the loss
    # band is re-warming those sets, far below 5 points on this trace
    assert hr <= hr_clean + 1e-9
    assert hr_clean - hr < 0.05, (hr, hr_clean, int(forced))


def test_scrub_noop_on_clean_state():
    cfg, st, _, _ = _replayed_state()
    st2, forced, _ = scrub(cfg, st, vals_mode="key")
    assert int(forced) == 0
    for f in ("keys", "fprint", "vals", "meta_a", "meta_b"):
        np.testing.assert_array_equal(np.asarray(getattr(st, f)),
                                      np.asarray(getattr(st2, f)))


def test_validated_replay_alarms_on_corrupt_start():
    cfg = KWayConfig(**CONFIG)
    chunks, enabled = _chunks()
    _, st, _, _ = _replayed_state()
    st, _ = faults.flip_bit(st, "keys", seed=5)
    *_, alarm = validated_replay(cfg, chunks[:2], enabled[:2], state=st,
                                 interval=1, vals_mode="any")
    assert int(alarm) != 0
    *_, alarm = validated_replay(cfg, chunks, enabled, interval=4,
                                 vals_mode="key")
    assert int(alarm) == 0


# ---------------------------------------------------------------------------
# request-stream faults: survived, not detected
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["dup", "poison"])
def test_trace_faults_survived(kind):
    tr, rep_f = faults.corrupt_trace(golden_trace(), kind, seed=3)
    assert rep_f.kind == kind
    cfg = KWayConfig(**CONFIG)
    be = make_backend("jnp", cfg)
    chunks, enabled = pad_chunks(tr, 8)
    _, _, st, _ = be.replay(be.init(), chunks, enabled)
    rep = check_cache(cfg, st, vals_mode="key")
    # poison keys include the EMPTY_KEY sentinel: sanitize_keys must fold
    # it, never store it raw — the state stays structurally clean
    assert rep.clean(), explain_cache(rep)
    assert not np.any(np.asarray(st.keys)[np.asarray(st.keys) != EMPTY_KEY]
                      == np.uint32(0xFFFFFFFF))


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------

def test_forced_vmem_breach_takes_scan_rung_with_event():
    """Satellite: the silent RESIDENT_VMEM_BUDGET fallback is now an
    observable degradation event, and the fallback rung still matches the
    resident path's golden-trace results bit-for-bit."""
    cfg = KWayConfig(**CONFIG)
    be = make_backend("pallas", cfg)
    chunks, enabled = _chunks()
    h_ref, e_ref, st_ref, _ = be.replay(be.init(), chunks, enabled)

    c0 = events.cursor()
    with backend_mod.vmem_budget(0):
        h, e, st, _ = be.replay(be.init(), chunks, enabled)
    evs = [ev for ev in events.since(c0) if ev.component == "pallas.replay"]
    assert len(evs) == 1 and evs[0].reason == "vmem_budget"
    assert evs[0].fallback_from == "pallas-resident"
    assert evs[0].fallback_to == "chunked-scan"
    np.testing.assert_array_equal(np.asarray(h), np.asarray(h_ref))
    np.testing.assert_array_equal(np.asarray(e), np.asarray(e_ref))
    np.testing.assert_array_equal(np.asarray(st.keys),
                                  np.asarray(st_ref.keys))


def test_ladder_vmem_breach():
    cfg = KWayConfig(**CONFIG)
    chunks, enabled = _chunks()
    out_fast = resilient_replay(cfg, chunks, enabled)
    assert out_fast.rung == "pallas-resident"

    c0 = events.cursor()
    with backend_mod.vmem_budget(0):
        out = resilient_replay(cfg, chunks, enabled)
    assert out.rung == "pallas-scan"
    assert ("pallas-resident", "vmem_budget") in out.attempts
    assert events.count(component="ladder.replay", reason="vmem_budget",
                        start=c0) == 1
    np.testing.assert_array_equal(np.asarray(out.hits),
                                  np.asarray(out_fast.hits))


def test_ladder_kernel_failure(monkeypatch):
    from repro.kernels import ops

    def boom(*a, **k):
        raise RuntimeError("injected kernel fault")

    monkeypatch.setattr(ops, "replay_resident", boom)
    cfg = KWayConfig(**CONFIG)
    chunks, enabled = _chunks()
    c0 = events.cursor()
    out = resilient_replay(cfg, chunks, enabled)
    assert out.rung == "pallas-scan"
    assert ("pallas-resident", "kernel_failure") in out.attempts
    ev = [e for e in events.since(c0) if e.reason == "kernel_failure"][0]
    assert "injected kernel fault" in ev.detail


def test_ladder_validator_alarm_descends_then_raises():
    cfg = KWayConfig(**CONFIG)
    chunks, enabled = _chunks()
    rejected = []

    def reject_pallas(st, sk, _n=[0]):
        _n[0] += 1
        rejected.append(_n[0])
        return (_n[0] > 2), "forced alarm"   # fail the two pallas rungs

    out = resilient_replay(cfg, chunks, enabled, validate_fn=reject_pallas)
    assert out.rung == "jnp-scan"
    assert ("pallas-resident", "validator_alarm") in out.attempts
    assert ("pallas-scan", "validator_alarm") in out.attempts

    with pytest.raises(RuntimeError, match="last ladder rung"):
        resilient_replay(cfg, chunks, enabled,
                         validate_fn=lambda st, sk: (False, "always bad"))


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------

def test_watchdog_passthrough_and_slow_recovery():
    assert watch(lambda: 41 + 1, timeout_s=0) == 42        # disabled
    c0 = events.cursor()
    out = watch(lambda: (time.sleep(0.25), "done")[1], timeout_s=0.05,
                retries=5, backoff=2.0, component="test.slow")
    assert out == "done"
    assert events.count(component="test.slow", reason="sync_timeout",
                        start=c0) >= 1


def test_watchdog_gives_up_and_propagates():
    hang = threading.Event()
    with pytest.raises(WatchdogTimeout):
        watch(hang.wait, timeout_s=0.02, retries=1, component="test.hang")
    hang.set()

    def boom():
        raise ValueError("inner")

    with pytest.raises(ValueError, match="inner"):
        watch(boom, timeout_s=1.0)


def test_threaded_replay_watchdog():
    from repro.showdown.harness import ThreadedReplay

    class SleepyCache:
        def access(self, k):
            time.sleep(0.05)
            return False

    tr = np.arange(64, dtype=np.uint32)
    with ThreadedReplay(SleepyCache(), tr, threads=2, timeout_s=0.03,
                        retries=0) as rep:
        with pytest.raises(WatchdogTimeout):
            rep()

    class FastCache:
        def access(self, k):
            return True

    with ThreadedReplay(FastCache(), tr, threads=2, timeout_s=5.0) as rep:
        assert rep() == 64


# ---------------------------------------------------------------------------
# serving engine: ServeState validation, faults, checkpoint/restore
# ---------------------------------------------------------------------------

BASE = dict(page=8, num_sets=16, ways=4, max_batch=4, max_seq=128,
            private_pages=96, max_prompt=80)


@pytest.fixture(scope="module")
def small_model():
    from repro import configs
    from repro.models import lm
    cfg = configs.get("deepseek-7b").smoke
    return cfg, lm.init_params(cfg, jax.random.key(0))


def _engine(small_model, **kw):
    from repro.serve import Engine, EngineConfig
    cfg, params = small_model
    e = dict(BASE)
    e.update(kw)
    return Engine(cfg, params, EngineConfig(jitted=True, **e))


def _submit_mix(eng, vocab, seed=0, n=6, max_new=8):
    rng = np.random.default_rng(seed)
    shared = rng.integers(2, vocab - 1, 40)
    for _ in range(n):
        tail = rng.integers(2, vocab - 1, int(rng.integers(3, 14)))
        eng.submit(np.concatenate([shared, tail]), max_new=max_new)


def test_serve_state_clean_mid_run_and_drained(small_model):
    eng = _engine(small_model)
    _submit_mix(eng, small_model[0].vocab_size)
    for _ in range(3):
        eng.step()
    rep = check_serve(eng.ecfg, eng._sstate)
    assert rep.clean(), explain_serve(rep)
    assert bool(np.asarray(eng._sstate.active).any())
    eng.run(max_steps=60)
    rep = check_serve(eng.ecfg, eng._sstate)
    assert rep.clean(), explain_serve(rep)


def test_serve_faults_detected(small_model):
    eng = _engine(small_model)
    _submit_mix(eng, small_model[0].vocab_size)
    for _ in range(3):
        eng.step()
    st = eng._sstate

    st2, _ = faults.double_book_page(eng.ecfg, st, seed=3)
    rep = check_serve(eng.ecfg, st2)
    assert not rep.clean()
    assert any("double_booked" in line or "dup_page_in_row" in line
               for line in explain_serve(rep))

    st3, rep_f = faults.stale_owner(eng.ecfg, st, seed=5)
    rep = check_serve(eng.ecfg, st3)
    assert not rep.clean()
    assert any(f"private page {rep_f.index[0]}" in line
               for line in explain_serve(rep))

    pk, _ = faults.inject_nan(st.pool_k, seed=1)
    rep = check_serve(eng.ecfg, dataclasses.replace(st, pool_k=pk))
    assert any("nan_in_kv" in line for line in explain_serve(rep))


def test_crash_mid_tick_restore_bit_identical(small_model, tmp_path):
    """Tentpole: commit at tick 3, run tick 4, crash before its checkpoint
    commits — restore must come back from tick 3 and re-emit exactly the
    uninterrupted run's tokens."""
    from repro.ckpt import manager

    ref = _engine(small_model)
    _submit_mix(ref, small_model[0].vocab_size)
    ref.run(max_steps=60)
    gold = {rid: list(r.generated) for rid, r in ref.finished.items()}

    eng = _engine(small_model)
    _submit_mix(eng, small_model[0].vocab_size)
    root = str(tmp_path / "ckpt")
    for _ in range(3):
        eng.step()
    save_engine(eng, root, 3)
    eng.step()                                    # tick 4 runs...
    faults.crashed_save(eng._sstate, root, 4)     # ...its commit never lands
    assert manager.latest_step(root) == 3

    eng2 = _engine(small_model)
    assert restore_engine(eng2, root) == 3
    eng2.run(max_steps=60)
    got = {rid: list(r.generated) for rid, r in eng2.finished.items()}
    assert got == gold
    assert check_serve(eng2.ecfg, eng2._sstate).clean()


def test_checkpointed_engine_cadence(small_model, tmp_path):
    from repro.ckpt import manager
    from repro.robust import CheckpointedEngine

    eng = _engine(small_model)
    _submit_mix(eng, small_model[0].vocab_size, n=4, max_new=4)
    ck = CheckpointedEngine(eng, str(tmp_path), every=2, keep_last=2)
    fin = ck.run(max_steps=40)
    assert len(fin) == 4
    assert ck.last_committed is not None
    assert manager.latest_step(str(tmp_path)) == ck.last_committed


def test_engine_duplicate_and_reordered_submits(small_model):
    """Request-stream faults: duplicate submits are distinct requests (new
    rid each) and complete exactly once each."""
    eng = _engine(small_model)
    prompt = np.arange(2, 44, dtype=np.int32)
    r1 = eng.submit(prompt, max_new=4)
    r2 = eng.submit(prompt, max_new=4)   # duplicate submit
    assert r1 != r2
    fin = eng.run(max_steps=40)
    assert set(fin) == {r1, r2}
    assert list(fin[r1].generated) == list(fin[r2].generated)
    assert check_serve(eng.ecfg, eng._sstate).clean()


def test_engine_degradation_events_in_stats(small_model):
    eng = _engine(small_model)
    assert eng.stats["degradation_events"] == 0
    events.record(component="test.engine", reason="synthetic")
    assert eng.stats["degradation_events"] == 1


def test_engine_sync_watchdog_normal_path(small_model):
    """With the watchdog armed, a healthy tick behaves identically."""
    eng = _engine(small_model, sync_timeout_s=30.0)
    _submit_mix(eng, small_model[0].vocab_size, n=2, max_new=3)
    fin = eng.run(max_steps=30)
    assert len(fin) == 2
    assert check_serve(eng.ecfg, eng._sstate).clean()


# ---------------------------------------------------------------------------
# expiry lane (DESIGN.md §15): TTL semantics, differential pins, chaos loop
# ---------------------------------------------------------------------------

def _ttls():
    rng = np.random.default_rng(SEED + 1)
    return rng.integers(0, 200, 512).astype(np.int32)


def _ttl_chunks(batch=8):
    from repro.core.simulate import _pad_ttl_chunks
    chunks, enabled = _chunks(batch)
    return chunks, enabled, jnp.asarray(_pad_ttl_chunks(_ttls(), batch))


def test_expired_key_never_hits_and_lane_reclaimed():
    """The tentpole guarantee in minimal form: a key inserted with a short
    TTL stops hitting once the clock passes its deadline, and its lane is
    scrubbed back to EMPTY (an ordinary preferred victim).  Hits do not
    refresh the deadline."""
    cfg = KWayConfig(**CONFIG)
    be = make_backend("jnp", cfg)
    st = be.init(ttl=True)
    k = jnp.asarray(np.asarray([42], np.uint32))
    v = k.astype(jnp.int32)
    short = jnp.asarray([4], jnp.int32)          # deadline = 0 + 2 + 4 = 6
    st, hit, *_ = be.access(st, k, v, ttls=short)
    assert not bool(np.asarray(hit)[0])
    st, hit, *_ = be.access(st, k, v, ttls=short)
    assert bool(np.asarray(hit)[0])              # clock 2: still live
    other = jnp.asarray(np.asarray([7], np.uint32))
    # clock 4: this access's scrub horizon (4 + 2 = 6) reaches the deadline
    st, _, _, _, _ = be.access(st, other, other.astype(jnp.int32),
                               ttls=jnp.asarray([0], jnp.int32))
    assert not np.any(np.asarray(st.keys) == 42)  # lane reclaimed to EMPTY
    st, hit, *_ = be.access(st, k, v, ttls=short)
    assert not bool(np.asarray(hit)[0])           # expired key never served


def test_ttl_differential_flat_backends():
    """TTL-enabled replay pinned bit-identical across the flat paths:
    jnp scan == pallas scan == pallas trace-resident megakernel — hits,
    evictions, and every final state lane including expiry."""
    from repro.core import kway
    from repro.kernels import ops

    cfg = KWayConfig(**CONFIG)
    chunks, enabled, tt = _ttl_chunks()
    outs = {}
    for name in ("jnp", "pallas"):
        be = make_backend(name, cfg)
        outs[name] = be.replay(be.init(ttl=True), chunks, enabled, ttls=tt)
    outs["resident"] = ops.replay_resident(
        cfg, kway.make_cache(cfg, ttl=True), chunks, enabled, ttls=tt)
    h0, e0, st0, _ = outs["jnp"]
    assert int(np.asarray(h0).sum()) > 0
    for name in ("pallas", "resident"):
        h, e, st, _ = outs[name]
        np.testing.assert_array_equal(np.asarray(h), np.asarray(h0))
        np.testing.assert_array_equal(np.asarray(e), np.asarray(e0))
        for f in ("keys", "fprint", "vals", "meta_a", "meta_b", "expiry"):
            np.testing.assert_array_equal(
                np.asarray(getattr(st, f)), np.asarray(getattr(st0, f)),
                err_msg=f"{name}.{f}")


def test_ttl_sharded_matches_unsharded():
    from repro.core.sharded import ShardedCache, ShardedConfig

    cfg = KWayConfig(**CONFIG)
    be = make_backend("jnp", cfg)
    chunks, enabled, tt = _ttl_chunks()
    h0, _, _, _ = be.replay(be.init(ttl=True), chunks, enabled, ttls=tt)
    for resident in (False, True):
        sh = ShardedCache(ShardedConfig(cache=cfg, num_shards=2))
        hits, deferred, _ = sh.replay(golden_trace(), batch=8, ttls=_ttls(),
                                      resident=resident)
        assert int(deferred) == 0
        assert int(hits) == int(np.asarray(h0).sum()), f"resident={resident}"


def test_ttl_ref_oracle_matches_jnp():
    """The host-python ref backend replays the TTL trace request-for-
    request identically to the jnp path at batch 1."""
    cfg = KWayConfig(**CONFIG)
    tr, tt = golden_trace()[:128], _ttls()[:128]
    jb, rb = make_backend("jnp", cfg), make_backend("ref", cfg)
    sj, sr = jb.init(ttl=True), rb.init(ttl=True)
    for i in range(len(tr)):
        k = np.asarray([tr[i]], np.uint32)
        t = np.asarray([tt[i]], np.int32)
        sj, hj, *_ = jb.access(sj, jnp.asarray(k), jnp.asarray(k, jnp.int32),
                               ttls=jnp.asarray(t))
        sr, hr, *_ = rb.access(sr, k, k.astype(np.int32), ttls=t)
        assert bool(np.asarray(hj)[0]) == bool(np.asarray(hr)[0]), f"req {i}"
    for f in ("keys", "fprint", "vals", "meta_a", "meta_b", "expiry"):
        np.testing.assert_array_equal(np.asarray(getattr(sr, f)),
                                      np.asarray(getattr(sj, f)), err_msg=f)


def test_ttl_zeros_bit_identical_to_plain():
    """ttl=0 means "never expires": an all-zero TTL replay on a TTL state
    matches the plain TTL-free replay bit-for-bit on every lane."""
    from repro.core.kway import NO_EXPIRY

    cfg = KWayConfig(**CONFIG)
    chunks, enabled = _chunks()
    be = make_backend("jnp", cfg)
    h0, e0, st0, _ = be.replay(be.init(), chunks, enabled)
    tt = jnp.zeros(chunks.shape, jnp.int32)
    h1, e1, st1, _ = be.replay(be.init(ttl=True), chunks, enabled, ttls=tt)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h0))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e0))
    for f in ("keys", "fprint", "vals", "meta_a", "meta_b"):
        np.testing.assert_array_equal(np.asarray(getattr(st1, f)),
                                      np.asarray(getattr(st0, f)), err_msg=f)
    assert st0.expiry is None
    assert np.all(np.asarray(st1.expiry) == NO_EXPIRY)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_ttl_clean_replay_no_false_positives(backend):
    """Zero-false-positive pin for the expiry bits: a healthy eager-scrub
    TTL replay is clean under the STRICT expiry mode (expired_hit and
    expired_resident both)."""
    cfg = KWayConfig(**CONFIG)
    chunks, enabled, tt = _ttl_chunks()
    be = make_backend(backend, cfg)
    _, _, st, _ = be.replay(be.init(ttl=True), chunks, enabled, ttls=tt)
    rep = check_cache(cfg, st, vals_mode="key", expiry_mode="strict")
    assert rep.clean(), explain_cache(rep)


def test_ttl_hierarchy_kernel_matches_twin_and_clean():
    from repro.core import hierarchy as hier_mod
    from repro.kernels import ops

    cfg = KWayConfig(**CONFIG)
    hier = hier_mod.HierarchyConfig(l1_sets=4, l1_ways=4)
    chunks, enabled, tt = _ttl_chunks()
    ht, et, out_t, _ = hier_mod.replay_l1_over_l2(
        cfg, hier, hier_mod.make_hier(cfg, hier, ttl=True), chunks, enabled,
        ttls=tt)
    hk, ek, out_k, _ = ops.replay_hierarchical(
        cfg, hier, hier_mod.make_hier(cfg, hier, ttl=True), chunks, enabled,
        ttls=tt)
    np.testing.assert_array_equal(np.asarray(hk), np.asarray(ht))
    np.testing.assert_array_equal(np.asarray(ek), np.asarray(et))
    for tier in ("l1", "l2"):
        for f in ("keys", "fprint", "vals", "meta_a", "meta_b", "expiry"):
            np.testing.assert_array_equal(
                np.asarray(getattr(getattr(out_k, tier), f)),
                np.asarray(getattr(getattr(out_t, tier), f)),
                err_msg=f"{tier}.{f}")
    # the hierarchy scrubs lazily (untouched rows may hold expired-but-
    # unreachable entries) — check_hier validates in lazy mode and must
    # see a clean state with zero false positives
    rep = check_hier(cfg, hier, out_k, vals_mode="key")
    assert rep.clean(), explain_hier(rep)


def test_clock_skew_detected_scrubbed_recovered():
    """Chaos round trip for the clock_skew site: inject -> the strict
    expired_resident bit fires -> scrub reclaims (forced evictions
    tallied) -> replay on -> hit ratio inside the recovery band."""
    cfg = KWayConfig(**CONFIG)
    be = make_backend("jnp", cfg)
    chunks, enabled, tt = _ttl_chunks()
    hc, _, _, _ = be.replay(be.init(ttl=True), chunks, enabled, ttls=tt)
    hr_clean = float(np.asarray(hc).sum()) / 512

    half = chunks.shape[0] // 2
    h1, _, st, _ = be.replay(be.init(ttl=True), chunks[:half],
                             enabled[:half], ttls=tt[:half])
    st, rep_f = faults.clock_skew(st, seed=SEED)
    assert rep_f.kind == "clock_skew"
    rep = check_cache(cfg, st, vals_mode="key")
    assert not rep.clean()
    assert any("expired_resident" in ln for ln in explain_cache(rep))
    st, forced, _ = scrub(cfg, st, vals_mode="key")
    assert int(forced) > 0
    assert check_cache(cfg, st, vals_mode="key").clean()
    h2, _, st, _ = be.replay(st, chunks[half:], enabled[half:],
                             ttls=tt[half:])
    assert check_cache(cfg, st, vals_mode="key").clean()
    hr = (float(np.asarray(h1).sum()) + float(np.asarray(h2).sum())) / 512
    # the skewed clock ages every deadline at once, so the band is wider
    # than the structural-flip band but still a recovery, not a collapse
    assert abs(hr - hr_clean) < 0.15, (hr, hr_clean)


def test_clock_skew_reproducible():
    cfg = KWayConfig(**CONFIG)
    be = make_backend("jnp", cfg)
    chunks, enabled, tt = _ttl_chunks()
    _, _, st, _ = be.replay(be.init(ttl=True), chunks, enabled, ttls=tt)
    _, r1 = faults.clock_skew(st, seed=3, step=7)
    _, r2 = faults.clock_skew(st, seed=3, step=7)
    assert r1 == r2
    _, r3 = faults.stale_entry(st, seed=3, step=7)
    assert r3 == faults.stale_entry(st, seed=3, step=7)[1]


def test_stale_entry_detected_lane_local_scrub():
    """The stale_entry forgery trips expired_hit on exactly the forged
    lane, and the scrub's blast radius is that single lane — expiry bits
    are lane-local, unlike structural key corruption."""
    cfg = KWayConfig(**CONFIG)
    be = make_backend("jnp", cfg)
    chunks, enabled, tt = _ttl_chunks()
    _, _, st, _ = be.replay(be.init(ttl=True), chunks, enabled, ttls=tt)
    st2, rep_f = faults.stale_entry(st, seed=3)
    s, w = rep_f.index
    rep = check_cache(cfg, st2, vals_mode="key")
    assert not rep.clean()
    assert any("expired_hit" in ln for ln in explain_cache(rep))
    assert any(f"set {s} way {w}" in ln for ln in explain_cache(rep))
    st3, forced, _ = scrub(cfg, st2, vals_mode="key")
    assert int(forced) == 1
    keys2, keys3 = np.asarray(st2.keys), np.asarray(st3.keys)
    assert keys3[s, w] == EMPTY_KEY
    assert (keys2 != keys3).sum() == 1            # lane-granular reclaim
    assert check_cache(cfg, st3, vals_mode="key").clean()


def test_double_resident_detected_and_scrubbed():
    """Hierarchy exclusivity chaos loop: inject an L1/L2 double residency,
    check_hier names it, scrub_hier repairs by clearing the L1 copy while
    the L2 keeps the entry."""
    from repro.core import hierarchy as hier_mod

    cfg = KWayConfig(**CONFIG)
    hier = hier_mod.HierarchyConfig(l1_sets=4, l1_ways=4)
    chunks, enabled = _chunks()
    _, _, st, _ = hier_mod.replay_l1_over_l2(
        cfg, hier, hier_mod.make_hier(cfg, hier), chunks, enabled)
    assert check_hier(cfg, hier, st, vals_mode="key").clean()

    st2, rep_f = faults.double_resident(cfg, st, seed=11)
    assert rep_f.kind == "double_resident"
    dup_key = np.uint32(int(rep_f.after))
    rep = check_hier(cfg, hier, st2, vals_mode="key")
    assert not rep.clean()
    assert any("double_resident" in ln for ln in explain_hier(rep))

    st3, forced, _ = scrub_hier(cfg, hier, st2, vals_mode="key")
    assert int(forced) >= 1
    assert check_hier(cfg, hier, st3, vals_mode="key").clean()
    assert not np.any(np.asarray(st3.l1.keys) == dup_key)   # L1 copy cleared
    assert np.any(np.asarray(st3.l2.keys) == dup_key)       # L2 keeps it


def test_double_resident_reproducible():
    from repro.core import hierarchy as hier_mod

    cfg = KWayConfig(**CONFIG)
    hier = hier_mod.HierarchyConfig(l1_sets=4, l1_ways=4)
    chunks, enabled = _chunks()
    _, _, st, _ = hier_mod.replay_l1_over_l2(
        cfg, hier, hier_mod.make_hier(cfg, hier), chunks, enabled)
    _, r1 = faults.double_resident(cfg, st, seed=9, step=2)
    _, r2 = faults.double_resident(cfg, st, seed=9, step=2)
    assert r1 == r2


def test_ladder_ttl_healthy_and_stale_served_descent():
    """The ladder replays TTL traces on every rung without alarming on
    healthy runs; a rung whose validation trips an expiry bit descends
    with the dedicated ``stale_served`` reason."""
    from repro.core.hierarchy import HierarchyConfig

    cfg = KWayConfig(**CONFIG)
    chunks, enabled, tt = _ttl_chunks()

    c0 = events.cursor()
    out = resilient_replay(cfg, chunks, enabled, ttls=tt)
    assert out.rung == "pallas-resident"
    assert out.attempts == (("pallas-resident", "ok"),)
    assert events.count(component="ladder.replay", start=c0) == 0

    out = resilient_replay(cfg, chunks, enabled, ttls=tt,
                           hierarchy=HierarchyConfig(l1_sets=4, l1_ways=4))
    assert out.rung == "pallas-resident-l1l2"

    def stale_once(st, sk, _n=[0]):
        _n[0] += 1
        if _n[0] == 1:
            return False, "set 0 way 1: expired_hit (meta_a >= expiry)"
        return True, ""

    c0 = events.cursor()
    out = resilient_replay(cfg, chunks, enabled, ttls=tt,
                           validate_fn=stale_once)
    assert out.rung == "pallas-scan"
    assert ("pallas-resident", "stale_served") in out.attempts
    assert events.count(component="ladder.replay", reason="stale_served",
                        start=c0) == 1


def test_validated_replay_ttl_clean():
    cfg = KWayConfig(**CONFIG)
    chunks, enabled, tt = _ttl_chunks()
    *_, alarm = validated_replay(cfg, chunks, enabled, interval=4,
                                 vals_mode="key", ttls=tt)
    assert int(alarm) == 0


def test_ttl_tinylfu_excluded_everywhere():
    cfg = KWayConfig(**CONFIG)
    tl = admission.for_capacity(cfg.capacity)
    chunks, enabled, tt = _ttl_chunks()
    be = make_backend("jnp", cfg)
    with pytest.raises(ValueError, match="TinyLFU"):
        be.replay(be.init(ttl=True), chunks, enabled, tinylfu=tl, ttls=tt)
    with pytest.raises(ValueError, match="TinyLFU"):
        resilient_replay(cfg, chunks, enabled, tinylfu=tl, ttls=tt)


# ---------------------------------------------------------------------------
# satellite: thread-safe event log ordering
# ---------------------------------------------------------------------------

def test_event_seq_monotonic_across_threads_and_clear():
    """Concurrent recorders get distinct, monotonically increasing seq
    stamps (assigned under the log lock), and the counter survives
    clear() so cross-boundary ordering comparisons stay valid."""
    c0 = events.cursor()
    n_threads, per = 4, 50

    def hammer(i):
        for _ in range(per):
            events.record(component=f"test.seq{i}", reason="synthetic")

    ts = [threading.Thread(target=hammer, args=(i,)) for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    seqs = [ev.seq for ev in events.since(c0)]
    assert len(seqs) == n_threads * per
    assert seqs == sorted(seqs)                  # append order == seq order
    assert len(set(seqs)) == len(seqs)           # no stamp collisions
    last = seqs[-1]
    events.clear()
    assert events.record(component="test.seq", reason="synthetic").seq > last


# ---------------------------------------------------------------------------
# satellite: typed ValueErrors for user-facing guards
# ---------------------------------------------------------------------------

def test_submit_prompt_length_valueerror(small_model):
    eng = _engine(small_model)
    with pytest.raises(ValueError, match="prompt length"):
        eng.submit(np.arange(BASE["max_prompt"] + 1, dtype=np.int32))
    with pytest.raises(ValueError, match="prompt length"):
        eng.submit(np.zeros(0, np.int32))


def test_engine_config_valueerrors(small_model):
    from repro.serve import Engine, EngineConfig
    cfg, params = small_model
    bad = dict(BASE)
    bad["max_seq"] = 130                      # not a page multiple
    with pytest.raises(ValueError, match="max_seq"):
        Engine(cfg, params, EngineConfig(**bad))
    bad = dict(BASE)
    bad["decode_block"] = 0
    with pytest.raises(ValueError, match="decode_block"):
        Engine(cfg, params, EngineConfig(**bad))
    bad = dict(BASE)
    bad["max_prompt"] = 81                    # not a page multiple
    with pytest.raises(ValueError, match="max_prompt"):
        Engine(cfg, params, EngineConfig(**bad))
