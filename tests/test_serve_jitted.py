"""Device-resident serving tick: jitted-vs-hostloop parity, continuous-
batching invariants, compile economy, and a pinned golden serving trace.

The jitted engine (one traced program per tick, DESIGN.md §11) and the host
loop (one jitted call per model op) must be indistinguishable from outside:
identical emitted tokens, hit ratios, eviction counts, and retirement
behaviour.  The host loop is the differential oracle; every test here runs
both and diffs.

Golden update workflow (DESIGN.md §7/§11) — only after deliberately changing
hashing, policy, sampling, or engine-transaction semantics:

    PYTHONPATH=src python tests/test_serve_jitted.py --regen
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.hashing import prefix_block_hashes, prefix_block_hashes_jnp
from repro.core.policies import Policy
from repro.models import lm
from repro.serve import (
    Engine,
    EngineConfig,
    reset_trace_counts,
    trace_counts,
)

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "serve_trace.json")
GOLDEN_KIND = "repro.golden.serve"

BASE = dict(page=8, num_sets=16, ways=4, max_batch=4, max_seq=128,
            private_pages=96, max_prompt=80)


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get("deepseek-7b").smoke
    params = lm.init_params(cfg, jax.random.key(0))
    return cfg, params


def _workload(cfg, eng, seed=0, n=8, shared_len=40, max_new=6):
    """Shared-prefix request mix; returns (per-rid tokens, hit ratio, stats)."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(2, cfg.vocab_size - 1, shared_len)
    for _ in range(n):
        tail = rng.integers(2, cfg.vocab_size - 1, int(rng.integers(3, 14)))
        eng.submit(np.concatenate([shared, tail]), max_new=max_new)
    fin = eng.run()
    return ({rid: list(r.generated) for rid, r in fin.items()},
            eng.hit_ratio(), eng.stats)


def _pair(cfg, params, **kw):
    e = dict(BASE)
    e.update(kw)
    host = Engine(cfg, params, EngineConfig(**e))
    jit = Engine(cfg, params, EngineConfig(**e, jitted=True))
    return host, jit


# ---------------------------------------------------------------------------
# hashing satellite: the traced chain hash is the numpy chain hash
# ---------------------------------------------------------------------------

def test_prefix_hashes_jnp_matches_numpy(rng):
    t = rng.integers(0, 512, 67).astype(np.int32)
    want = prefix_block_hashes(t, 8)           # 8 full blocks of 67 tokens
    padded = np.zeros(80, np.int32)
    padded[:67] = t
    got = np.asarray(prefix_block_hashes_jnp(jnp.asarray(padded), 8))
    assert (got[: len(want)] == want).all()


def test_prefix_hashes_pinned_values():
    """Pin the actual uint32 chain values: any change to the FNV fold, the
    fmix32 avalanche, the position salt or the XOR chain fails HERE (the
    serving analogue of the trace512 golden)."""
    t = np.random.default_rng(0).integers(0, 512, 67).astype(np.int32)
    got = prefix_block_hashes(t, 8)[:4].tolist()
    assert got == [1741624807, 425176065, 3914042232, 652229286]


# ---------------------------------------------------------------------------
# jitted == hostloop (the differential oracle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(),
    dict(policy=Policy.LFU, num_sets=4, ways=2),   # eviction pressure
    dict(tinylfu=True),
    dict(temperature=0.8, sample_seed=3),
    dict(decode_block=3),                          # multi-step decode burst
    dict(decode_block=4, temperature=0.8),         # burst + sampling
], ids=["lru", "lfu-evict", "tinylfu", "sampled", "burst", "burst-sampled"])
def test_jitted_matches_hostloop(small_model, kw):
    cfg, params = small_model
    host, jit = _pair(cfg, params, **kw)
    gh, hrh, sth = _workload(cfg, host)
    gj, hrj, stj = _workload(cfg, jit)
    assert gh == gj
    assert hrh == hrj
    assert sth == stj          # prefix hits/lookups, prefills, evictions...


@pytest.mark.parametrize("db", [1, 3], ids=["db1", "db3"])
def test_jitted_out_of_page_retirement(small_model, db):
    """Page exhaustion mid-decode retires early — at the same step, with the
    same truncated output, in both engines (the sequential allocation scan
    must free retired pages for later slots exactly like the host loop,
    including mid-burst when decode_block > 1)."""
    cfg, params = small_model
    host, jit = _pair(cfg, params, private_pages=7, decode_block=db)
    gh, hrh, sth = _workload(cfg, host, n=10, max_new=50)
    gj, hrj, stj = _workload(cfg, jit, n=10, max_new=50)
    assert gh == gj and hrh == hrj and sth == stj
    lens = sorted(len(g) for g in gh.values())
    assert lens[0] < 51, "scenario must actually exhaust the page pool"


def test_jitted_overflow_queues(small_model):
    """More requests than slots: the fixed-lane engine queues the overflow
    and completes every request exactly once (no drop, no double-finish)."""
    cfg, params = small_model
    host, jit = _pair(cfg, params)
    n = 3 * BASE["max_batch"] + 1
    gh, _, _ = _workload(cfg, host, n=n)
    gj, _, _ = _workload(cfg, jit, n=n)
    assert gh == gj
    assert sorted(gj) == list(range(n))          # every rid finished once
    assert all(len(g) >= 1 for g in gj.values())  # nobody dropped pre-decode


def test_jitted_no_double_decode(small_model):
    """Stepping an idle jitted engine is a no-op: no token emission, no
    counter movement (the all-inactive tick skips the decode branch)."""
    cfg, params = small_model
    _, jit = _pair(cfg, params)
    jit.submit(np.arange(2, 26, dtype=np.int32), max_new=3)
    fin = jit.run()
    before = jit.stats
    toks = {rid: list(r.generated) for rid, r in fin.items()}
    for _ in range(3):
        jit.step()
    assert jit.stats == before
    assert {rid: list(r.generated) for rid, r in fin.items()} == toks


def test_jitted_one_sync_per_tick(small_model, monkeypatch):
    """The tick's host round-trip budget is exactly one device_get."""
    cfg, params = small_model
    _, jit = _pair(cfg, params)
    for i in range(3):
        jit.submit(np.arange(2, 26 + i, dtype=np.int32), max_new=4)
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda x: calls.append(1) or real(x))
    steps = 0
    while (jit.waiting or jit.running) and steps < 50:
        jit.step()
        steps += 1
    assert steps > 1 and len(calls) == steps


def test_jitted_trace_economy(small_model):
    """≤1 compile per engine shape — same-shape engines share one traced
    program (lru-cached step builder + jit cache), so even across every
    jitted engine this module has constructed, each shape key counts exactly
    one trace.  A retrace (shape leak, cache miss) shows up as > 1."""
    cfg, params = small_model
    for seed in (0, 1):
        _, jit = _pair(cfg, params)
        _workload(cfg, jit, seed=seed, n=5)
    counts = trace_counts()
    assert counts, "jitted runs must register a trace key"
    assert all(v == 1 for v in counts.values()), counts


def test_jitted_rejects_untraceable(small_model):
    cfg, params = small_model
    with pytest.raises(ValueError, match="traceable"):
        Engine(cfg, params, EngineConfig(**BASE, jitted=True, backend="ref"))
    with pytest.raises(ValueError, match="unsharded"):
        Engine(cfg, params, EngineConfig(**BASE, jitted=True, shards=2))


# ---------------------------------------------------------------------------
# golden serving trace (pinned end-to-end tokens)
# ---------------------------------------------------------------------------

def _golden_run(cfg, params):
    """The pinned workload: jitted engine, eviction pressure, TinyLFU off."""
    eng = Engine(cfg, params, EngineConfig(
        **{**BASE, "num_sets": 8, "ways": 2}, jitted=True))
    gen, hr, st = _workload(cfg, eng, seed=7, n=10, max_new=5)
    return {"generated": {str(k): v for k, v in gen.items()},
            "hit_ratio": round(hr, 6), "evictions": st["evictions"]}


def regen():
    cfg = configs.get("deepseek-7b").smoke
    params = lm.init_params(cfg, jax.random.key(0))
    golden = {"kind": GOLDEN_KIND, "version": 1,
              "config": {"arch": "deepseek-7b smoke", "workload_seed": 7,
                         "engine": {**BASE, "num_sets": 8, "ways": 2}},
              "run": _golden_run(cfg, params)}
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(golden, f, indent=1)
        f.write("\n")
    return golden


def test_golden_serving_trace(small_model):
    """End-to-end pinned tokens through the jitted engine: any drift in
    hashing, probe order, paging, prefill numerics or sampling fails here
    with a per-request diff.  If intentional, regen per the module header."""
    cfg, params = small_model
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    assert golden["kind"] == GOLDEN_KIND
    got = _golden_run(cfg, params)
    want = golden["run"]
    assert got["generated"] == want["generated"], (
        "serving trace diverged — hashing/policy/numerics change? "
        "If intentional, regen per DESIGN.md §11")
    assert got["hit_ratio"] == want["hit_ratio"]
    assert got["evictions"] == want["evictions"]


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        g = regen()
        print(f"wrote {GOLDEN_PATH}: {len(g['run']['generated'])} requests, "
              f"hit_ratio={g['run']['hit_ratio']}")
    else:
        print(__doc__)
