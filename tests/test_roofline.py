"""Roofline analysis unit tests: HLO parsing, extrapolation, term math."""
import math

from repro.configs.base import SHAPES_BY_NAME
from repro import configs
from repro.roofline import analysis as roof


def test_shape_bytes():
    assert roof._shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert roof._shape_bytes("bf16[2,3,4]") == 24 * 2
    assert roof._shape_bytes("pred[10]") == 10
    assert roof._shape_bytes("(f32[4], s32[8])") == 16 + 32
    assert roof._shape_bytes("f32[]") == 4  # scalar


def test_collective_scrape():
    hlo = """
  %ar = f32[16,4096]{1,0} all-reduce(%x), replica_groups=...
  %ag.1 = bf16[8,128]{1,0} all-gather(%y), dimensions={0}
  %notacoll = f32[2,2]{1,0} add(%a, %b)
  %tup = (f32[4]{0}, f32[4]{0}) all-reduce(%p, %q), to_apply=%add
  %cp = u32[64]{0} collective-permute(%z), source_target_pairs=...
"""
    out = roof.collective_bytes_per_device(hlo)
    assert out["all-reduce"] == 16 * 4096 * 4 + 2 * 16
    assert out["all-gather"] == 8 * 128 * 2
    assert out["collective-permute"] == 64 * 4
    assert "add" not in out


def test_extrapolation_linear():
    # c(p)=fixed+layer, c(2p)=fixed+2*layer -> total(L)=fixed+L*layer
    fixed, layer, L = 100.0, 7.0, 24
    total = roof.extrapolate(fixed + layer, fixed + 2 * layer, L)
    assert math.isclose(total, fixed + L * layer)


def test_cell_terms_and_bottleneck():
    cell = roof.CellRoofline(
        arch="x", shape="train_4k", mesh="16x16", chips=256,
        hlo_flops=256 * roof.PEAK_FLOPS,        # t_compute = 1 s
        hlo_bytes=256 * roof.HBM_BW * 2,        # t_memory = 2 s
        coll_bytes=256 * roof.LINK_BW * 0.5,    # t_collective = 0.5 s
        coll_breakdown={}, model_flops=256 * roof.PEAK_FLOPS * 0.5,
        per_device_peak_memory=0,
    )
    assert math.isclose(cell.t_compute, 1.0)
    assert math.isclose(cell.t_memory, 2.0)
    assert math.isclose(cell.t_collective, 0.5)
    assert cell.bottleneck == "memory"
    assert math.isclose(cell.step_time, 2.0)
    assert math.isclose(cell.useful_flops_ratio, 0.5)
    # frac = model/(step*chips*peak) = 0.5/2 = 0.25
    assert math.isclose(cell.roofline_fraction, 0.25)
    j = cell.to_json()
    assert j["bottleneck"] == "memory" and "step_time" in j


def test_model_flops_conventions():
    cfg = configs.get("deepseek-7b").config
    n = cfg.param_count()
    tr = roof.model_flops(cfg, SHAPES_BY_NAME["train_4k"])
    pf = roof.model_flops(cfg, SHAPES_BY_NAME["prefill_32k"])
    dc = roof.model_flops(cfg, SHAPES_BY_NAME["decode_32k"])
    assert math.isclose(tr, 6.0 * n * 4096 * 256)
    assert math.isclose(pf, 2.0 * n * 32768 * 32)
    assert math.isclose(dc, 2.0 * n * 128)
    # MoE: active < total
    mx = configs.get("mixtral-8x22b").config
    assert mx.param_count(active_only=True) < mx.param_count()


def test_report_renders():
    from repro.roofline.report import render
    fake = {
        "a|train_4k|single": {
            "status": "ok", "arch": "a", "shape": "train_4k",
            "mesh": "16x16", "chips": 256,
            "memory": {"argument_bytes": 1 << 30, "output_bytes": 0,
                       "temp_bytes": 2 << 30, "generated_code_bytes": 0},
            "compile_s": 1.0,
            "roofline": {
                "t_compute": 1.0, "t_memory": 2.0, "t_collective": 0.5,
                "bottleneck": "memory", "model_flops": 1e15,
                "useful_flops_ratio": 0.5, "roofline_fraction": 0.25,
            },
        },
        "a|long_500k|single": {
            "status": "skipped", "arch": "a", "shape": "long_500k",
            "mesh": "single", "reason": "pure full-attention arch",
        },
    }
    txt = render(fake)
    assert "train_4k" in txt and "skip" in txt and "0.250" in txt
