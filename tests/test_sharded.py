"""Set-sharded execution layer (core/sharded.py, DESIGN.md §5).

The shard_map zero-collectives property is proven separately in
tests/test_kway_sharding.py (it needs a multi-device subprocess); here we
verify the semantics on the single-device vmap fallback: host bucketing
routes every key to the shard owning its set, and the sharded cache matches
the unsharded cache request-for-request for the timestamp-order-invariant
policies.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import hashing
from repro.core.backend import make_backend
from repro.core.kway import KWayConfig
from repro.core.policies import Policy
from repro.core.sharded import ShardedCache, ShardedConfig


def test_owner_is_high_bits_of_global_set(rng):
    gcfg = KWayConfig(num_sets=32, ways=2)
    sc = ShardedCache(ShardedConfig(cache=gcfg, num_shards=8))
    keys = rng.integers(0, 1 << 30, 200).astype(np.uint32)
    owner = sc.owner_of(keys)
    gset = np.asarray(hashing.set_index(jnp.asarray(keys), 32, gcfg.seed))
    assert ((owner >= 0) & (owner < 8)).all()
    np.testing.assert_array_equal(owner, gset // 4)


def test_bucketing_preserves_arrival_order(rng):
    from repro.core import router
    gcfg = KWayConfig(num_sets=16, ways=4)
    keys = rng.integers(0, 500, 64).astype(np.uint32)
    owner = np.asarray(router.owner_of(jnp.asarray(keys), 16, 4, gcfg.seed))
    plan = router.route(jnp.asarray(owner), 4, 64)
    pos = np.asarray(plan.pos)
    assert not np.asarray(plan.deferred).any()  # capacity == B never defers
    # (owner, pos) pairs are unique and order-preserving per shard
    pairs = set(zip(owner.tolist(), pos.tolist()))
    assert len(pairs) == len(keys)
    for d in range(4):
        lanes = np.nonzero(owner == d)[0]
        assert (np.diff(pos[lanes]) > 0).all() if len(lanes) > 1 else True


@pytest.mark.parametrize("policy", [Policy.LRU, Policy.LFU, Policy.FIFO])
@pytest.mark.parametrize("num_shards", [2, 4])
def test_sharded_matches_single_device(policy, num_shards, rng):
    """Hits, evictions and final keys/vals are identical to the unsharded
    cache: every set's requests land in one shard in arrival order, so the
    per-set conflict resolution is unchanged (DESIGN.md §5)."""
    gcfg = KWayConfig(num_sets=16, ways=4, policy=policy)
    be = make_backend("jnp", gcfg)
    st_single = be.init()
    sc = ShardedCache(ShardedConfig(cache=gcfg, num_shards=num_shards))
    st_shard = sc.init()
    for step in range(10):
        keys = rng.integers(0, 200, 32).astype(np.uint32)
        keys[0] = keys[1]  # duplicate in batch
        vals = keys.astype(np.int32)
        st_single, h1, v1, ek1, ev1 = be.access(
            st_single, jnp.asarray(keys), jnp.asarray(vals))
        st_shard, h2, v2, ek2, ev2 = sc.access(st_shard, keys, vals)
        np.testing.assert_array_equal(np.asarray(h1), h2)
        np.testing.assert_array_equal(np.asarray(v1), v2)
        np.testing.assert_array_equal(np.asarray(ev1), ev2)
        np.testing.assert_array_equal(np.asarray(ek1)[np.asarray(ev1)],
                                      ek2[ev2])
    gv = sc.global_view(st_shard)
    np.testing.assert_array_equal(np.asarray(gv.keys),
                                  np.asarray(st_single.keys))
    np.testing.assert_array_equal(np.asarray(gv.vals),
                                  np.asarray(st_single.vals))


def test_single_shard_is_plain_backend(rng):
    gcfg = KWayConfig(num_sets=8, ways=2, policy=Policy.LRU)
    be = make_backend("jnp", gcfg)
    st1 = be.init()
    sc = ShardedCache(ShardedConfig(cache=gcfg, num_shards=1))
    st2 = sc.init()
    for _ in range(5):
        keys = rng.integers(0, 64, 16).astype(np.uint32)
        st1, h1, *_ = be.access(st1, jnp.asarray(keys),
                                jnp.asarray(keys.astype(np.int32)))
        st2, h2, *_ = sc.access(st2, keys, keys.astype(np.int32))
        np.testing.assert_array_equal(np.asarray(h1), h2)
    np.testing.assert_array_equal(np.asarray(st1.keys),
                                  np.asarray(sc.global_view(st2).keys))


def test_sharded_config_validation():
    with pytest.raises(AssertionError):
        ShardedConfig(cache=KWayConfig(num_sets=8, ways=2), num_shards=3)
    with pytest.raises(AssertionError):
        ShardedConfig(cache=KWayConfig(num_sets=4, ways=2), num_shards=8)


def test_sharded_rejects_host_python_backend():
    """The ref oracle is host Python — it cannot be vmapped/shard_mapped."""
    cfg = ShardedConfig(cache=KWayConfig(num_sets=8, ways=2), num_shards=2,
                        backend="ref")
    with pytest.raises(ValueError, match="host Python"):
        ShardedCache(cfg)
    from repro.core.simulate import SimConfig, replay_batched
    sim = SimConfig(KWayConfig(num_sets=8, ways=2), backend="ref")
    with pytest.raises(ValueError, match="sharded"):
        replay_batched(sim, np.arange(64, dtype=np.uint32), batch=8, shards=2)


def test_replay_batched_sharded_matches():
    from repro.core.simulate import SimConfig, replay_batched
    from repro.core import traces
    tr = traces.generate("zipf", 4096, seed=5, catalog=1 << 12)
    sim = SimConfig(KWayConfig(num_sets=64, ways=4, policy=Policy.LRU))
    h1 = replay_batched(sim, tr, batch=64)
    h4 = replay_batched(sim, tr, batch=64, shards=4)
    assert h1 == pytest.approx(h4, abs=1e-9)
