"""Real-trace ingestion tests (core/trace_io.py): fixture round trips,
streaming, error paths, and the end-to-end ``generate()`` registry contract
(an ingested file replays through ``simulate.replay_batched`` with no code
changes outside the ingestion layer).
"""
import os

import numpy as np
import pytest

from repro.core import trace_io, traces

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
ARC_PATH = os.path.join(FIXTURES, "sample_arc.trace")
CSV_PATH = os.path.join(FIXTURES, "sample_twitter.csv")

#: pinned parse of sample_arc.trace: plain keys, one 4-column ARC-style
#: line (first field is the key), a blank separator line, and the
#: EMPTY_KEY sentinel folded to 0xFFFFFFFE exactly like sanitize_keys
ARC_KEYS = [1, 2, 3, 1, 0xFFFFFFFE, 2, 7, 3]

#: pinned key-space fingerprints — the uint32 contract for CSV string keys
#: (fmix32 over FNV-1a; frozen so committed artifacts stay joinable)
FP = {"alpha": 2744486511, "beta": 4052878921, "gamma": 2106301210}


# ---------------------------------------------------------------------------
# parsing round trips
# ---------------------------------------------------------------------------

def test_arc_fixture_round_trip():
    arr = trace_io.load_trace(ARC_PATH)
    assert arr.dtype == np.uint32
    np.testing.assert_array_equal(arr, np.asarray(ARC_KEYS, np.uint32))


def test_csv_fixture_round_trip_all_ops():
    arr = trace_io.load_trace(CSV_PATH)
    want = [FP["alpha"], FP["beta"], FP["gamma"], FP["alpha"], FP["beta"],
            FP["alpha"]]
    np.testing.assert_array_equal(arr, np.asarray(want, np.uint32))


def test_csv_ops_filter_reads_only():
    arr = trace_io.load_trace(CSV_PATH, ops=trace_io.READ_OPS)
    want = [FP["alpha"], FP["beta"], FP["alpha"], FP["beta"]]
    np.testing.assert_array_equal(arr, np.asarray(want, np.uint32))


def test_csv_headerless_positional(tmp_path):
    p = tmp_path / "headerless.csv"
    p.write_text("get,alpha,10\nset,beta,20\n")
    np.testing.assert_array_equal(
        trace_io.load_trace(str(p)),
        np.asarray([FP["alpha"], FP["beta"]], np.uint32))


def test_csv_header_any_column_order(tmp_path):
    p = tmp_path / "reordered.csv"
    p.write_text("size,key,op\n10,alpha,get\n20,beta,set\n")
    np.testing.assert_array_equal(
        trace_io.load_trace(str(p)),
        np.asarray([FP["alpha"], FP["beta"]], np.uint32))


def test_streaming_chunks_match_bulk_load():
    chunks = list(trace_io.iter_trace_chunks(ARC_PATH, chunk=3))
    assert [len(c) for c in chunks] == [3, 3, 2]
    np.testing.assert_array_equal(np.concatenate(chunks),
                                  trace_io.load_trace(ARC_PATH))


def test_load_trace_limit_stops_early():
    np.testing.assert_array_equal(
        trace_io.load_trace(ARC_PATH, limit=3),
        np.asarray(ARC_KEYS[:3], np.uint32))


def test_detect_format():
    assert trace_io.detect_format("x/wiki.trace") == "arc"
    assert trace_io.detect_format("x/twitter.CSV") == "csv"
    assert trace_io.detect_format("x/multi1.lirs") == "arc"


def test_fingerprint_keys_pinned_and_deterministic():
    out = trace_io.fingerprint_keys(["alpha", "beta", "gamma"])
    np.testing.assert_array_equal(
        out, np.asarray([FP["alpha"], FP["beta"], FP["gamma"]], np.uint32))
    np.testing.assert_array_equal(
        out, trace_io.fingerprint_keys(["alpha", "beta", "gamma"]))
    # never the EMPTY_KEY sentinel (folded like hashing.sanitize_keys)
    assert not np.any(out == np.uint32(0xFFFFFFFF))


def test_trace_fingerprint_pins_content_and_order():
    arr = trace_io.load_trace(ARC_PATH)
    assert trace_io.trace_fingerprint(arr) == "ba2bac45"
    assert trace_io.trace_fingerprint(arr[::-1].copy()) != "ba2bac45"


# ---------------------------------------------------------------------------
# error paths
# ---------------------------------------------------------------------------

def test_malformed_arc_line_names_file_and_line(tmp_path):
    p = tmp_path / "bad.trace"
    p.write_text("1\n2\nnot-a-key\n4\n")
    with pytest.raises(ValueError, match=r"bad\.trace:3.*malformed"):
        trace_io.load_trace(str(p))


def test_malformed_csv_row_too_few_columns(tmp_path):
    p = tmp_path / "bad.csv"
    p.write_text("op,key,size\nget,alpha,10\njustonefield\n")
    with pytest.raises(ValueError, match=r"bad\.csv:3.*malformed"):
        trace_io.load_trace(str(p))


def test_malformed_csv_row_empty_key(tmp_path):
    p = tmp_path / "bad.csv"
    p.write_text("get,alpha,10\nget,,10\n")
    with pytest.raises(ValueError, match=r"bad\.csv:2.*empty op or key"):
        trace_io.load_trace(str(p))


def test_empty_files_raise(tmp_path):
    arc = tmp_path / "empty.trace"
    arc.write_text("\n\n")
    with pytest.raises(ValueError, match="empty trace"):
        trace_io.load_trace(str(arc))
    csvf = tmp_path / "empty.csv"
    csvf.write_text("")
    with pytest.raises(ValueError, match="empty trace"):
        trace_io.load_trace(str(csvf))


def test_ops_filter_dropping_everything_raises(tmp_path):
    p = tmp_path / "writes.csv"
    p.write_text("op,key,size\nset,alpha,10\nset,beta,20\n")
    with pytest.raises(ValueError, match="op filter"):
        trace_io.load_trace(str(p), ops=trace_io.READ_OPS)


def test_unknown_format_rejected():
    with pytest.raises(ValueError, match="unknown trace format"):
        trace_io.load_trace(ARC_PATH, fmt="parquet")


# ---------------------------------------------------------------------------
# generate() registry + end-to-end replay
# ---------------------------------------------------------------------------

def test_register_generate_truncates_and_tiles():
    trace_io.register_trace("arc_fixture_t", ARC_PATH)
    try:
        np.testing.assert_array_equal(
            traces.generate("arc_fixture_t", 3),
            np.asarray(ARC_KEYS[:3], np.uint32))
        tiled = traces.generate("arc_fixture_t", 20)
        assert tiled.shape == (20,) and tiled.dtype == np.uint32
        np.testing.assert_array_equal(
            tiled, np.tile(np.asarray(ARC_KEYS, np.uint32), 3)[:20])
        # registered names ride the unknown-family error listing
        with pytest.raises(ValueError, match="arc_fixture_t"):
            traces.generate("nope", 8)
    finally:
        trace_io.unregister_trace("arc_fixture_t")


# ---------------------------------------------------------------------------
# TTL column (DESIGN.md §15)
# ---------------------------------------------------------------------------

TTL_CSV_PATH = os.path.join(FIXTURES, "sample_twitter_ttl.csv")


def test_csv_ttl_column_header_named():
    keys, ttls = trace_io.load_trace(TTL_CSV_PATH, with_ttl=True)
    assert len(keys) == len(ttls) == 16
    assert ttls.dtype == np.int32
    assert ttls[:6].tolist() == [4096, 64, 0, 4096, 64, 4096]
    # the key stream is unchanged by TTL parsing
    np.testing.assert_array_equal(keys, trace_io.load_trace(TTL_CSV_PATH))


def test_csv_ttl_ops_filter_keeps_streams_aligned():
    keys, ttls = trace_io.load_trace(TTL_CSV_PATH, ops=trace_io.READ_OPS,
                                     with_ttl=True)
    assert len(keys) == len(ttls) == 13          # the three sets dropped
    assert ttls.tolist() == [4096, 64, 4096, 64, 16, 0, 256, 4096, 64, 16,
                             8, 4096, 256]


def test_csv_ttl_headerless_positional_and_defaults(tmp_path):
    p = tmp_path / "headerless.csv"
    # op,key[,size[,ttl]] — short rows default to ttl 0 (never expires)
    p.write_text("get,alpha,10,5\nget,beta,20\nset,gamma\n")
    keys, ttls = trace_io.load_trace(str(p), with_ttl=True)
    assert ttls.tolist() == [5, 0, 0]
    assert keys[0] == FP["alpha"]


def test_csv_header_without_ttl_column_defaults(tmp_path):
    p = tmp_path / "no_ttl.csv"
    # a header names the columns: no "ttl" column means no TTLs, even
    # though a positional column 3 exists (it is "size" here)
    p.write_text("op,key,extra,size\nget,alpha,x,300\n")
    _, ttls = trace_io.load_trace(str(p), with_ttl=True)
    assert ttls.tolist() == [0]


def test_csv_malformed_ttl_names_file_and_line(tmp_path):
    p = tmp_path / "bad_ttl.csv"
    p.write_text("op,key,ttl\nget,alpha,soon\n")
    with pytest.raises(ValueError, match=r"bad_ttl\.csv:2.*ttl column"):
        trace_io.load_trace(str(p), with_ttl=True)
    # the malformed column is invisible to a TTL-blind load
    assert len(trace_io.load_trace(str(p))) == 1


def test_arc_with_ttl_yields_zeros():
    keys, ttls = trace_io.load_trace(ARC_PATH, with_ttl=True)
    assert len(keys) == len(ttls) and (ttls == 0).all()


def test_register_trace_ttl_tiles_in_lockstep():
    trace_io.register_trace("ttl_fixture_t", TTL_CSV_PATH, ttl=True)
    try:
        keys, ttls = traces.generate_ttl("ttl_fixture_t", 40)
        np.testing.assert_array_equal(keys,
                                      traces.generate("ttl_fixture_t", 40))
        base_k, base_t = trace_io.load_trace(TTL_CSV_PATH, with_ttl=True)
        np.testing.assert_array_equal(ttls, np.tile(base_t, 3)[:40])
        np.testing.assert_array_equal(keys, np.tile(base_k, 3)[:40])
    finally:
        trace_io.unregister_trace("ttl_fixture_t")
        assert "ttl_fixture_t" not in traces.TTL_FAMILIES


def test_ttl_fixture_replays_end_to_end():
    """The §15 acceptance path: TTL-bearing fixture -> generate_ttl ->
    simulate.replay_batched(..., ttls=...) with no changes outside the
    ingestion layer."""
    from repro.core.kway import KWayConfig
    from repro.core.simulate import SimConfig, replay_batched

    trace_io.register_fixture_traces()
    keys, ttls = traces.generate_ttl("sample_twitter_ttl", 64)
    sim = SimConfig(cache=KWayConfig(num_sets=4, ways=4))
    hr = replay_batched(sim, keys, batch=16, ttls=ttls)
    assert 0.0 <= hr <= 1.0
    # zero-TTL rows never expire, so the heavily tiled fixture still hits
    assert hr > 0.3


@pytest.mark.parametrize("name,path,kw", [
    ("arc_fixture_e2e", ARC_PATH, {}),
    ("csv_fixture_e2e", CSV_PATH, {"ops": trace_io.READ_OPS}),
])
def test_ingested_trace_replays_end_to_end(name, path, kw):
    """The acceptance path: fixture file -> generate() registry ->
    simulate.replay_batched, touching nothing outside the ingestion layer."""
    from repro.core.kway import KWayConfig
    from repro.core.policies import Policy
    from repro.core.simulate import SimConfig, replay_batched

    trace_io.register_trace(name, path, **kw)
    try:
        tr = traces.generate(name, 64)
        sim = SimConfig(cache=KWayConfig(num_sets=4, ways=4,
                                         policy=Policy.LRU))
        hr = replay_batched(sim, tr, batch=16)
        assert 0.0 <= hr <= 1.0
        # the tiny fixtures repeat keys heavily once tiled to 64 requests,
        # so the replay must see real hits — an all-miss run would mean the
        # ingested keys never reached the cache
        assert hr > 0.5
    finally:
        trace_io.unregister_trace(name)
