"""Golden differential trace suite — the hash/probe-order tripwire.

A pinned 512-request zipf trace is replayed at batch size 1 through all
three CacheBackends (jnp / pallas / ref) and checked request-for-request
against a checked-in expectation: per-request hit flags, the full eviction
sequence, and the final cache contents.  Any change to the set-index hash,
fingerprinting, victim scoring or probe order now fails HERE with a diff,
instead of silently shifting hit ratios (which is exactly what happened to
the in-memory hash values in PR 1).

Golden update workflow (DESIGN.md §7) — only after deliberately changing
hashing/policy semantics:

    PYTHONPATH=src python tests/test_golden_trace.py --regen
"""
import json
import os

import jax.numpy as jnp
import numpy as np

from repro.core import traces
from repro.core.backend import make_backend
from repro.core.kway import KWayConfig
from repro.core.policies import Policy

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "trace512.json")
GOLDEN_KIND = "repro.golden.trace"
N = 512
CATALOG = 96          # ~1.5x the 64-slot cache: steady eviction pressure
TRACE_SEED = 2026
CONFIG = dict(num_sets=16, ways=4)
# LRU: the paper's default; RANDOM: scores via hash(key, clock) — the most
# hash-sensitive policy, so silent hash changes cannot survive this file.
POLICIES = (Policy.LRU, Policy.RANDOM)


def golden_trace() -> np.ndarray:
    tr = traces.generate("zipf", N, seed=TRACE_SEED, catalog=CATALOG)
    tr[::13] = 0          # key 0 must behave like any other key
    return tr


def replay_events(backend: str, policy: Policy,
                  two_phase: bool = False) -> dict:
    """B=1 replay -> {hits: "0101...", evictions: [[i, key]...],
    final_keys: [...row-major, EMPTY as -1...]}.

    ``access`` is the fused single-probe path on jnp/pallas;
    ``two_phase=True`` replays through the unfused get-then-put oracle
    instead — both must match the same pinned golden (the fused path is
    bit-identical by construction, and this file is the tripwire).
    """
    cfg = KWayConfig(policy=policy, **CONFIG)
    be = make_backend(backend, cfg)
    access = be.access_two_phase if two_phase else be.access
    state = be.init()
    hits, evictions = [], []
    for i, t in enumerate(golden_trace()):
        k = jnp.asarray([t], jnp.uint32)
        state, hit, _, ek, ev = access(state, k, k.astype(jnp.int32))
        hits.append("1" if bool(hit[0]) else "0")
        if bool(ev[0]):
            evictions.append([i, int(ek[0])])
    from repro.core.hashing import EMPTY_KEY
    keys = np.asarray(state.keys).astype(np.int64)
    keys[keys == int(EMPTY_KEY)] = -1
    return {"hits": "".join(hits), "evictions": evictions,
            "final_keys": keys.ravel().tolist()}


def regen() -> dict:
    golden = {
        "kind": GOLDEN_KIND, "version": 1,
        "config": {**CONFIG, "n": N, "catalog": CATALOG,
                   "trace_seed": TRACE_SEED,
                   "policies": [p.name for p in POLICIES],
                   "generator": "jnp backend, batch size 1"},
        "per_policy": {p.name: replay_events("jnp", p) for p in POLICIES},
    }
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(golden, f, indent=1)
        f.write("\n")
    return golden


def _load_golden() -> dict:
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    assert golden["kind"] == GOLDEN_KIND
    return golden


def test_golden_file_is_current_config():
    g = _load_golden()["config"]
    assert (g["num_sets"], g["ways"]) == (CONFIG["num_sets"], CONFIG["ways"])
    assert g["n"] == N and g["trace_seed"] == TRACE_SEED
    assert g["policies"] == [p.name for p in POLICIES]


def _check(backend: str, policy: Policy, two_phase: bool = False):
    want = _load_golden()["per_policy"][policy.name]
    got = replay_events(backend, policy, two_phase=two_phase)
    # hit flags: diff the first divergence for a readable failure
    if got["hits"] != want["hits"]:
        i = next(i for i, (a, b) in
                 enumerate(zip(got["hits"], want["hits"])) if a != b)
        raise AssertionError(
            f"{backend}/{policy.name}: hit sequence diverges at request {i} "
            f"(got {got['hits'][i]}, golden {want['hits'][i]}) — a hash or "
            "probe-order change? If intentional, regen per DESIGN.md §7")
    assert got["evictions"] == want["evictions"], \
        f"{backend}/{policy.name}: eviction sequence drifted"
    assert got["final_keys"] == want["final_keys"], \
        f"{backend}/{policy.name}: final cache contents drifted"


def test_golden_jnp_lru():
    _check("jnp", Policy.LRU)


def test_golden_jnp_random():
    _check("jnp", Policy.RANDOM)


def test_golden_pallas_lru():
    _check("pallas", Policy.LRU)


def test_golden_pallas_random():
    _check("pallas", Policy.RANDOM)


def test_golden_ref_lru():
    _check("ref", Policy.LRU)


def test_golden_ref_random():
    _check("ref", Policy.RANDOM)


# The two-phase oracle must pin to the SAME golden as the (default, fused)
# access path above — together these six + four tests are the fused-access
# bit-identity criterion on the 512-request trace.

def test_golden_jnp_lru_two_phase():
    _check("jnp", Policy.LRU, two_phase=True)


def test_golden_jnp_random_two_phase():
    _check("jnp", Policy.RANDOM, two_phase=True)


def test_golden_pallas_lru_two_phase():
    _check("pallas", Policy.LRU, two_phase=True)


def test_golden_pallas_random_two_phase():
    _check("pallas", Policy.RANDOM, two_phase=True)


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        g = regen()
        n_ev = {p: len(v["evictions"]) for p, v in g["per_policy"].items()}
        print(f"wrote {GOLDEN_PATH}: {N} requests, evictions={n_ev}")
    else:
        print(__doc__)
