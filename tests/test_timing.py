"""Regression tests for the steady-state timing protocol (eval/timing.py).

The PR-7 bug: ``time_replay_percentiles`` never blocked on ``replay()``'s
return value, so a callable returning an unrealized device array was timed
dispatch-only (JAX dispatch is async on every backend, CPU included — a
dispatch returns in microseconds while the computation runs for however
long it likes).  The fake-async test fails on the pre-fix implementation by
construction; the real-JAX test fails on it because dispatch-only p50 is
orders of magnitude below the synced execution time.
"""
import time

import jax
import jax.numpy as jnp
import pytest

from repro.eval import timing


class _FakeAsyncResult:
    """Mimics an unrealized device array: the 'work' only completes when
    block_until_ready() is called (jax.block_until_ready duck-types any
    leaf with that method)."""

    def __init__(self, tally, delay):
        self._tally = tally
        self._delay = delay

    def block_until_ready(self):
        time.sleep(self._delay)
        self._tally["blocks"] += 1
        return self


def test_time_replay_percentiles_blocks_on_async_result():
    delay = 0.01
    tally = {"blocks": 0, "calls": 0}

    def replay():
        tally["calls"] += 1
        return _FakeAsyncResult(tally, delay)

    st = timing.time_replay_percentiles(replay, iters=3, warmup=1)
    # every repetition — warmup included — must sync its result before the
    # next starts; the pre-fix timer never blocked at all (blocks == 0)
    assert tally["calls"] == 4
    assert tally["blocks"] == 4
    # ... and the samples must cover the blocked work, not just dispatch
    assert st["p50"] >= 0.8 * delay
    assert st["p90"] >= st["p50"]
    assert st["iters"] == 3 and st["reps_discarded"] == 1


def test_time_replay_percentiles_times_execution_not_dispatch():
    x = jnp.ones((512, 512))

    @jax.jit
    def heavy(a):
        for _ in range(4):
            a = a @ a / 33.0
        return a

    jax.block_until_ready(heavy(x))          # compile outside the timers

    # dispatch-only wall time of the async call (what the pre-fix timer
    # effectively measured)
    t0 = time.perf_counter()
    y = heavy(x)
    dispatch = time.perf_counter() - t0
    jax.block_until_ready(y)

    # synced wall time of one complete round trip
    t0 = time.perf_counter()
    jax.block_until_ready(heavy(x))
    synced = time.perf_counter() - t0

    st = timing.time_replay_percentiles(lambda: heavy(x), iters=3, warmup=1)
    # the timed samples must be in the synced regime, far above dispatch
    assert st["p50"] >= 0.3 * synced, (st, dispatch, synced)
    if synced > 20 * dispatch:               # async dispatch is real here
        assert st["p50"] > 5 * dispatch, (st, dispatch, synced)


def test_timing_provenance_tallies():
    timing.reset_timing_provenance()
    timing.time_replay_percentiles(lambda: 0, iters=2, warmup=3)
    prov = timing.timing_provenance()
    assert prov == {"reps_discarded": 3, "steady_reps": 2, "timers": 1}


def test_block_is_noop_for_host_values():
    # callables that already sync (returning Python ints/floats) keep
    # working unchanged through the blocking timer
    st = timing.time_replay_percentiles(lambda: 42, iters=2, warmup=1)
    assert st["iters"] == 2 and st["p50"] >= 0.0
