"""Showdown harness tests: baseline cache semantics, hash parity with the
device paths, the striped-vs-device differential anchor, threaded replay
accounting, and the hit-ratio gate contract (dead gate = breach).
"""
import json

import numpy as np
import pytest

cachetools = pytest.importorskip("cachetools")

from repro.core import traces
from repro.showdown import (CachetoolsCache, LockStripedKWay, hit_ratio,
                            make_baseline, replay_threaded)
from repro.showdown.baselines import hash_u32_host
from repro.showdown.harness import ThreadedReplay


def test_host_hash_matches_device_hash():
    from repro.core import hashing
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 1 << 32, size=256, dtype=np.uint32)
    for seed in (0x51CA, 0, 7):
        dev = np.asarray(hashing.hash_u32(keys, seed))
        host = np.asarray([hash_u32_host(int(k), seed) for k in keys],
                          np.uint32)
        np.testing.assert_array_equal(dev, host)


def test_cachetools_lru_semantics():
    c = CachetoolsCache(2, policy="lru")
    assert not c.access(1)
    assert not c.access(2)
    assert c.access(1)              # hit refreshes recency
    assert not c.access(3)          # evicts 2 (the LRU entry)
    assert c.access(1)
    assert not c.access(2)          # 2 was evicted
    assert len(c) == 2


def test_striped_lru_semantics_single_set():
    c = LockStripedKWay(num_sets=1, ways=2, policy="lru")
    assert not c.access(1)
    assert not c.access(2)
    assert c.access(1)
    assert not c.access(3)          # evicts 2
    assert c.access(1)
    assert not c.access(2)
    assert len(c) == 2


def test_striped_lfu_semantics_single_set():
    c = LockStripedKWay(num_sets=1, ways=2, policy="lfu")
    assert not c.access(1)
    assert c.access(1)              # count(1)=2
    assert not c.access(2)          # count(2)=1
    assert not c.access(3)          # evicts 2 (lowest count)
    assert c.access(1)
    assert not c.access(2)


def test_striped_validates_arguments():
    with pytest.raises(ValueError, match="power of two"):
        LockStripedKWay(num_sets=3, ways=2)
    with pytest.raises(ValueError, match="unknown striped policy"):
        LockStripedKWay(num_sets=2, ways=2, policy="fifo")
    with pytest.raises(ValueError, match="unknown baseline library"):
        make_baseline("redis", 64, "lru")
    with pytest.raises(ValueError, match="unknown cachetools policy"):
        CachetoolsCache(8, policy="arc")
    with pytest.raises(ValueError, match="not divisible"):
        make_baseline("striped", 100, "lru", ways=8)


def test_striped_lru_is_bit_exact_with_device_sequential_replay():
    """The differential anchor: same set hash, same sentinel fold, same
    LRU victim rule -> the pure-Python striped cache reproduces the device
    B=1 replay hit ratio EXACTLY (LRU timestamps are unique, so there are
    no ties for tie-breaking to diverge on)."""
    from repro.core.kway import KWayConfig
    from repro.core.policies import Policy
    from repro.core.simulate import SimConfig, replay

    tr = traces.generate("zipf", 6_000, seed=42)
    cfg = KWayConfig(num_sets=128, ways=8, policy=Policy.LRU)
    hr_device = replay(SimConfig(cache=cfg), tr)
    hr_striped = hit_ratio(make_baseline("striped", 1024, "lru", ways=8), tr)
    assert hr_striped == pytest.approx(hr_device, abs=1e-12)


def test_striped_lfu_tracks_device_replay_within_band():
    # LFU counts tie constantly, and the two implementations break ties
    # differently (way order vs insertion order) — a band, not bit parity.
    from repro.core.kway import KWayConfig
    from repro.core.policies import Policy
    from repro.core.simulate import SimConfig, replay

    tr = traces.generate("zipf", 6_000, seed=42)
    cfg = KWayConfig(num_sets=128, ways=8, policy=Policy.LFU)
    hr_device = replay(SimConfig(cache=cfg), tr)
    hr_striped = hit_ratio(make_baseline("striped", 1024, "lfu", ways=8), tr)
    assert abs(hr_striped - hr_device) < 0.05


def test_hit_ratio_is_deterministic():
    tr = traces.generate("oltp_mix", 3_000, seed=1)
    a = hit_ratio(make_baseline("cachetools", 512, "lru"), tr)
    b = hit_ratio(make_baseline("cachetools", 512, "lru"), tr)
    assert a == b
    assert 0.0 < a < 1.0


def test_threaded_replay_covers_every_request():
    tr = traces.generate("zipf", 1_000, seed=2)
    for threads in (1, 2, 3, 8):
        rep = ThreadedReplay(make_baseline("striped", 256, "lru"), tr,
                             threads)
        try:
            assert sum(len(s) for s in rep._slices) == len(tr)
            hits = rep()
            assert 0 <= hits <= len(tr)
        finally:
            rep.close()
    with pytest.raises(ValueError, match="threads"):
        ThreadedReplay(make_baseline("striped", 256, "lru"), tr, 0)


def test_threaded_replay_single_thread_matches_hit_ratio():
    tr = traces.generate("zipf", 2_000, seed=3)
    cache = make_baseline("cachetools", 512, "lfu")
    with ThreadedReplay(cache, tr, 1) as rep:
        hits = rep()
    assert hits / len(tr) == pytest.approx(
        hit_ratio(make_baseline("cachetools", 512, "lfu"), tr), abs=1e-12)


def test_replay_threaded_stats_shape():
    tr = traces.generate("zipf", 1_000, seed=4)
    st = replay_threaded(make_baseline("striped", 256, "lru"), tr, 2,
                         iters=2, warmup=1)
    assert st["n"] == 1_000 and st["iters"] == 2
    assert st["reps_discarded"] == 1
    assert st["req_s_p50"] > 0 and st["req_s_p90"] <= st["req_s_p50"] * 1e6
    assert 0 <= st["hits_last"] <= st["n"]


def test_concurrent_access_is_consistent():
    # 4 threads hammer one striped cache; every access returns a bool and
    # the resident count never exceeds total capacity (per-set locks keep
    # set invariants intact)
    tr = traces.generate("zipf", 8_000, seed=5)
    cache = make_baseline("striped", 256, "lru", ways=8)
    with ThreadedReplay(cache, tr, 4) as rep:
        for _ in range(3):
            rep()
    assert len(cache) <= 256
    for d in cache._sets:
        assert len(d) <= cache.ways


# ---------------------------------------------------------------------------
# gate contract
# ---------------------------------------------------------------------------

def _artifact(records):
    from repro.eval import artifacts
    return artifacts.make_artifact("showdown", {"quick": True}, records)


def _hr_record(rid, value):
    return {"id": rid, "metric": "hit_ratio", "value": value,
            "comparable": True, "tol": 1e-6}


def test_showdown_gate_pass_breach_and_dead(tmp_path):
    from benchmarks.showdown import showdown_hit_ratio_gate
    from repro.eval import artifacts

    base_records = [_hr_record("showdown-hr/zipf/lru/cachetools", 0.5),
                    _hr_record("showdown-hr/zipf/lru/striped", 0.4)]
    base_path = tmp_path / "BENCH_showdown_quick.json"
    artifacts.write_artifact(str(base_path), _artifact(base_records))

    # pass: fresh values match the baseline
    checked, breaches = showdown_hit_ratio_gate(str(base_path), base_records)
    assert checked == 2 and not breaches

    # breach: a diverged hit ratio is reported
    drift = [_hr_record("showdown-hr/zipf/lru/cachetools", 0.5),
             _hr_record("showdown-hr/zipf/lru/striped", 0.47)]
    checked, breaches = showdown_hit_ratio_gate(str(base_path), drift)
    assert checked == 2 and len(breaches) == 1
    assert "striped" in breaches[0]

    # dead gate: fresh ids that match nothing must be a breach, not a pass
    alien = [_hr_record("showdown-hr/other/lru/cachetools", 0.5)]
    checked, breaches = showdown_hit_ratio_gate(str(base_path), alien)
    assert checked == 0 and breaches
    assert "no-op" in breaches[0]


def test_gate_survives_json_round_trip(tmp_path):
    # the committed-baseline workflow: fresh records -> artifact file ->
    # reload -> gate against itself must pass exactly
    from benchmarks.showdown import showdown_hit_ratio_gate
    from repro.eval import artifacts

    tr = traces.generate("zipf", 2_000, seed=7)
    value = hit_ratio(make_baseline("cachetools", 512, "lru"), tr)
    recs = [_hr_record("showdown-hr/zipf/lru/cachetools",
                       round(float(value), 6))]
    path = tmp_path / "base.json"
    artifacts.write_artifact(str(path), _artifact(recs))
    checked, breaches = showdown_hit_ratio_gate(str(path), recs)
    assert checked == 1 and not breaches
