"""Regenerate ``lirs_two_pools.trace`` — run from the repo root:

    python tests/fixtures/make_lirs_two_pools.py

Deterministic stand-in for the public ARC/LIRS loop traces the paper's
hit-ratio studies use (the container has no network, so the fixture is
regenerated from the published workload *shape* rather than downloaded):
a small hot pool of re-referenced blocks interleaved with long sequential
cold scans that sweep a region ~40x the hot pool.  This is the classic
LIRS "two pools" stress: recency-only policies let each scan flush the
hot pool; frequency-aware and hierarchical (small-L1) configurations
hold it.  One decimal block id per line, no header (``trace_io``'s ARC
parser rejects non-decimal lines), 10_000 requests.

All randomness is the 32-bit LCG below (Numerical Recipes constants), so
the file is bit-reproducible everywhere.
"""
from __future__ import annotations

import os

N_REQUESTS = 10_000
HOT_KEYS = 512           # hot pool: ids [1, 512]
COLD_BASE = 100_000      # cold scans sweep ids [COLD_BASE, COLD_BASE+COLD_SPAN)
COLD_SPAN = 20_000
SCAN_LEN = 96            # each cold scan touches this many sequential blocks
HOT_RUN = 160            # hot re-reference burst length between scans
SEED = 0xB10C


def _lcg(x: int) -> int:
    return (x * 1664525 + 1013904223) & 0xFFFFFFFF


def generate() -> list[int]:
    keys: list[int] = []
    x = SEED
    cold_ptr = 0
    while len(keys) < N_REQUESTS:
        for _ in range(HOT_RUN):            # hot burst: LCG-picked hot ids
            x = _lcg(x)
            keys.append(1 + (x >> 16) % HOT_KEYS)
        for _ in range(SCAN_LEN):           # cold scan: sequential sweep
            keys.append(COLD_BASE + cold_ptr)
            cold_ptr = (cold_ptr + 1) % COLD_SPAN
    return keys[:N_REQUESTS]


def main() -> None:
    out = os.path.join(os.path.dirname(__file__), "lirs_two_pools.trace")
    keys = generate()
    with open(out, "w") as f:
        f.write("\n".join(str(k) for k in keys))
        f.write("\n")
    print(f"wrote {out}: {len(keys)} requests, "
          f"{len(set(keys))} distinct keys")


if __name__ == "__main__":
    main()
