"""Trace-resident replay megakernel (kernels/replay.py) differential suite.

The megakernel replays a whole chunked trace in ONE pallas launch with the
cache state lanes (and TinyLFU sketch) pinned in VMEM; its contract is
bit-identity with the chunked-scan replay (``CacheBackend.replay`` default:
one ``lax.scan`` through the fused ``access`` with the batched TinyLFU
phases).  This file pins that contract on the golden trace across every
pallas-supported policy × ±TinyLFU — per-chunk hit counts, per-chunk
eviction counts, the final state (all five lanes + clock) and the final
sketch — plus the compile/launch economy: a whole replay is exactly one
XLA compilation and one launch.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import admission, router, traces
from repro.core.backend import make_backend
from repro.core.kway import KWayConfig
from repro.core.policies import Policy
from repro.kernels import replay as kreplay
from tests.test_golden_trace import CONFIG, golden_trace

PALLAS_POLICIES = [Policy.LRU, Policy.LFU, Policy.FIFO, Policy.RANDOM,
                   Policy.HYPERBOLIC]
BATCH = 32     # golden trace (512 requests) -> 16 chunks


def _golden_chunks():
    return router.pad_chunks(golden_trace(), BATCH)


def _assert_state_equal(a, b, label):
    for f in ("keys", "fprint", "vals", "meta_a", "meta_b", "clock"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{label}: state lane {f} diverged")


def _assert_sketch_equal(a, b, label):
    np.testing.assert_array_equal(np.asarray(a.packed), np.asarray(b.packed),
                                  err_msg=f"{label}: sketch counters")
    np.testing.assert_array_equal(np.asarray(a.door), np.asarray(b.door),
                                  err_msg=f"{label}: sketch doorkeeper")
    assert int(a.additions) == int(b.additions), f"{label}: sketch additions"


@pytest.mark.parametrize("policy", PALLAS_POLICIES)
@pytest.mark.parametrize("admission_on", [False, True],
                         ids=["none", "tinylfu"])
def test_resident_golden_parity(policy, admission_on):
    """Megakernel == chunked-scan replay on the golden trace: per-chunk
    hits and evictions, final state, final sketch — for every
    pallas-supported policy, with and without TinyLFU admission."""
    cfg = KWayConfig(policy=policy, **CONFIG)
    tl = admission.for_capacity(cfg.capacity) if admission_on else None
    chunks, en = _golden_chunks()

    jb = make_backend("jnp", cfg)        # chunked-scan oracle
    pb = make_backend("pallas", cfg)     # the megakernel under test
    assert pb.resident_fits()
    h1, e1, st1, sk1 = jb.replay(jb.init(), chunks, en, tinylfu=tl)
    h2, e2, st2, sk2 = pb.replay(pb.init(), chunks, en, tinylfu=tl)

    label = f"{policy.name}/{'tinylfu' if admission_on else 'none'}"
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2),
                                  err_msg=f"{label}: per-chunk hits")
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2),
                                  err_msg=f"{label}: per-chunk evictions")
    _assert_state_equal(st1, st2, label)
    if admission_on:
        _assert_sketch_equal(sk1, sk2, label)
    else:
        assert sk1 is None and sk2 is None


def test_resident_matches_pallas_scan_oracle():
    """The pallas backend's own chunked-scan fallback (``replay_scan``) is
    the same oracle — resident and scan agree on the kernel substrate too,
    not just across backends."""
    cfg = KWayConfig(policy=Policy.LRU, **CONFIG)
    chunks, en = _golden_chunks()
    pb = make_backend("pallas", cfg)
    h1, e1, st1, _ = pb.replay_scan(pb.init(), chunks, en)
    h2, e2, st2, _ = pb.replay(pb.init(), chunks, en)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))
    _assert_state_equal(st1, st2, "pallas scan vs resident")


def test_resident_odd_tail_padding():
    """A trace whose length is not a batch multiple: the padded tail chunk's
    disabled lanes must not perturb the replay (they still consume logical
    timestamps, like every batched path)."""
    tr = traces.generate("zipf", 501, seed=11, catalog=96)
    chunks, en = router.pad_chunks(tr, BATCH)
    assert not bool(en[-1].all())          # the tail really is padded
    cfg = KWayConfig(policy=Policy.LRU, **CONFIG)
    jb, pb = make_backend("jnp", cfg), make_backend("pallas", cfg)
    h1, e1, st1, _ = jb.replay(jb.init(), chunks, en)
    h2, e2, st2, _ = pb.replay(pb.init(), chunks, en)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))
    _assert_state_equal(st1, st2, "odd tail")


def test_resident_single_compile_single_launch():
    """The compile/launch economy proof: one whole-trace replay is exactly
    ONE pallas launch, and re-running the same shape never re-compiles."""
    cfg = KWayConfig(policy=Policy.LFU, **CONFIG)
    # a chunk width no other test uses, so the jit cache is provably cold
    chunks, en = router.pad_chunks(golden_trace(), 16)
    pb = make_backend("pallas", cfg)

    kreplay.reset_trace_counts()
    pb.replay(pb.init(), chunks, en)
    tc = kreplay.trace_counts()
    compiles = sum(v for k, v in tc.items() if k[0] == "trace")
    launches = sum(v for k, v in tc.items() if k[0] == "launch")
    assert compiles == 1, f"whole replay took {compiles} compiles (want 1)"
    assert launches == 1, f"whole replay took {launches} launches (want 1)"

    # same shape again: one more launch, ZERO fresh compilations
    pb.replay(pb.init(), chunks, en)
    tc = kreplay.trace_counts()
    assert sum(v for k, v in tc.items() if k[0] == "trace") == 1
    assert sum(v for k, v in tc.items() if k[0] == "launch") == 2


def test_resident_simulate_entry_point():
    """simulate.replay_batched(resident=True) == resident=False, both
    backends, ±TinyLFU — the harness-facing equality the CI gate enforces."""
    from repro.core.simulate import SimConfig, replay_batched

    tr = traces.generate("zipf", 2000, seed=3, catalog=2048)
    cfg = KWayConfig(num_sets=64, ways=8, policy=Policy.LRU)
    tl = admission.for_capacity(cfg.capacity)
    for backend in ("jnp", "pallas"):
        for tlc in (None, tl):
            sim = SimConfig(cache=cfg, backend=backend, tinylfu=tlc)
            a = replay_batched(sim, tr, batch=128, resident=False)
            b = replay_batched(sim, tr, batch=128, resident=True)
            assert a == b, (backend, tlc is not None, a, b)


def test_resident_sharded_is_d_launches():
    """Sharded resident replay: D megakernels for the whole trace (not
    D × chunks launches), bit-identical to the sharded scanned replay."""
    from repro.core.sharded import ShardedCache, ShardedConfig

    tr = traces.generate("zipf", 2000, seed=5, catalog=2048)
    cfg = KWayConfig(num_sets=64, ways=8, policy=Policy.LRU)
    d = 4
    h1, df1, st1 = ShardedCache(ShardedConfig(
        cache=cfg, num_shards=d, backend="pallas")).replay(tr, 128)

    kreplay.reset_trace_counts()
    h2, df2, st2 = ShardedCache(ShardedConfig(
        cache=cfg, num_shards=d, backend="pallas")).replay(
            tr, 128, resident=True)
    tc = kreplay.trace_counts()
    assert sum(v for k, v in tc.items() if k[0] == "launch") == d
    assert sum(v for k, v in tc.items() if k[0] == "trace") == 1

    assert (h1, df1) == (h2, df2)
    _assert_state_equal(st1, st2, "sharded resident")


def test_resident_sharded_tinylfu_parity():
    """Per-shard TinyLFU sketches ride inside each shard's megakernel and
    match the scanned shard-body phases exactly."""
    from repro.core.sharded import ShardedCache, ShardedConfig

    tr = traces.generate("zipf", 1999, seed=6, catalog=2048)  # padded tail
    cfg = KWayConfig(num_sets=64, ways=8, policy=Policy.LFU)
    tl = admission.for_capacity(cfg.capacity)
    for d in (1, 2):
        h1, _, st1 = ShardedCache(ShardedConfig(
            cache=cfg, num_shards=d, backend="pallas")).replay(
                tr, 128, tinylfu=tl)
        h2, _, st2 = ShardedCache(ShardedConfig(
            cache=cfg, num_shards=d, backend="pallas")).replay(
                tr, 128, tinylfu=tl, resident=True)
        assert h1 == h2, (d, h1, h2)
        _assert_state_equal(st1, st2, f"sharded tinylfu D={d}")


def test_resident_vmem_fallback():
    """A state too large for the VMEM budget falls back to the chunked-scan
    path — same results, no crash.  Uses the ``vmem_budget`` context
    manager (the budget knob every figure and chaos harness shares)."""
    from repro.core import backend as backend_mod

    cfg = KWayConfig(policy=Policy.LRU, **CONFIG)
    chunks, en = _golden_chunks()
    pb = make_backend("pallas", cfg)
    with backend_mod.vmem_budget(1024):
        assert not pb.resident_fits()
        kreplay.reset_trace_counts()
        h1, e1, st1, _ = pb.replay(pb.init(), chunks, en)
        assert sum(kreplay.trace_counts().values()) == 0  # no megakernel ran
    assert pb.resident_fits()          # budget restored on exit
    jb = make_backend("jnp", cfg)
    h2, e2, st2, _ = jb.replay(jb.init(), chunks, en)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    _assert_state_equal(st1, st2, "vmem fallback")


def test_resident_excludes_two_phase_and_ref():
    """Loud guards: the resident path is the fused access composition and
    needs a traceable backend."""
    from repro.core.simulate import SimConfig, replay_batched

    tr = traces.generate("zipf", 256, seed=1, catalog=96)
    cfg = KWayConfig(policy=Policy.LRU, **CONFIG)
    with pytest.raises(ValueError, match="two_phase"):
        replay_batched(SimConfig(cache=cfg, two_phase=True), tr,
                       batch=32, resident=True)
    with pytest.raises(ValueError, match="ref"):
        replay_batched(SimConfig(cache=cfg, backend="ref"), tr,
                       batch=32, resident=True)
    with pytest.raises(ValueError, match="host Python"):
        be = make_backend("ref", cfg)
        chunks, en = router.pad_chunks(tr, 32)
        be.replay(be.init(), chunks, en)


def test_resident_state_carry_midstream():
    """Replays compose: resident replay of the first half, then the scan
    replay of the second half from the returned state, equals one scanned
    replay of the whole trace (states are interchangeable mid-stream, the
    CacheBackend contract)."""
    tr = traces.generate("zipf", 1024, seed=8, catalog=96)
    cfg = KWayConfig(policy=Policy.LRU, **CONFIG)
    chunks, en = router.pad_chunks(tr, BATCH)
    half = len(chunks) // 2

    jb, pb = make_backend("jnp", cfg), make_backend("pallas", cfg)
    h_a, _, st_mid, _ = pb.replay(pb.init(), chunks[:half], en[:half])
    h_b, _, st_end, _ = jb.replay(st_mid, chunks[half:], en[half:])
    h_full, _, st_full, _ = jb.replay(jb.init(), chunks, en)
    assert int(jnp.sum(h_a) + jnp.sum(h_b)) == int(jnp.sum(h_full))
    _assert_state_equal(st_end, st_full, "midstream carry")


def test_resident_random_traces_sweep():
    """Randomized differential sweep beyond the golden trace: batch sizes
    that exercise intra-chunk collisions (dedupe, rank, per-lane victim
    orders) on the hash-sensitive policies."""
    for seed, batch, policy in ((21, 64, Policy.RANDOM),
                                (22, 64, Policy.HYPERBOLIC),
                                (23, 128, Policy.LRU)):
        tr = traces.generate("zipf", 1500, seed=seed, catalog=512)
        chunks, en = router.pad_chunks(tr, batch)
        cfg = KWayConfig(num_sets=32, ways=8, policy=policy)
        jb, pb = make_backend("jnp", cfg), make_backend("pallas", cfg)
        h1, e1, st1, _ = jb.replay(jb.init(), chunks, en)
        h2, e2, st2, _ = pb.replay(pb.init(), chunks, en)
        label = f"seed={seed}/{policy.name}/B={batch}"
        np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2),
                                      err_msg=label)
        np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2),
                                      err_msg=label)
        _assert_state_equal(st1, st2, label)


def test_resident_nonstandard_sketch_width():
    """TinyLFU widths that do not fill a 128-lane row (the golden config's
    width-64 sketch packs into 8 words) round-trip through the kernel's
    padded layout without corrupting the unpadded words."""
    cfg = KWayConfig(policy=Policy.LRU, **CONFIG)
    tl = admission.TinyLFUConfig(width=64, door_bits=128, sample=96)
    chunks, en = _golden_chunks()
    jb, pb = make_backend("jnp", cfg), make_backend("pallas", cfg)
    h1, _, st1, sk1 = jb.replay(jb.init(), chunks, en, tinylfu=tl)
    h2, _, st2, sk2 = pb.replay(pb.init(), chunks, en, tinylfu=tl)
    # sample=96 < trace length: the aging reset fires mid-replay
    assert int(sk1.additions) < 512
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    _assert_state_equal(st1, st2, "narrow sketch")
    _assert_sketch_equal(sk1, sk2, "narrow sketch")


def test_resident_figure_and_gate():
    """The --resident-compare surface: the figure emits the resident-eq
    records and the equality gate passes on them (and fails loudly on a
    doctored record)."""
    from benchmarks.throughput import resident_equality_gate

    records = [{"id": "resident-eq/zipf/LRU/none", "value": 0.5,
                "scan_value": 0.5}]
    checked, breaches = resident_equality_gate(records)
    assert checked == 1 and not breaches
    records[0]["scan_value"] = 0.25
    checked, breaches = resident_equality_gate(records)
    assert breaches and "diverged" in breaches[0]
    # a run with no eq records is a dead gate, not a pass
    checked, breaches = resident_equality_gate([])
    assert checked == 0 and breaches
