"""Serving engine: prefix reuse, paged-vs-contiguous consistency, policies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.policies import Policy
from repro.models import lm
from repro.serve.engine import Engine, EngineConfig, prefix_block_hashes


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get("deepseek-7b").smoke
    params = lm.init_params(cfg, jax.random.key(0))
    return cfg, params


def _engine(cfg, params, **kw):
    base = dict(page=8, num_sets=16, ways=4, max_batch=4, max_seq=128,
                private_pages=96)
    base.update(kw)
    return Engine(cfg, params, EngineConfig(**base))


def test_prefix_hashes_chain():
    t = np.arange(32, dtype=np.int32)
    h = prefix_block_hashes(t, 8)
    assert len(h) == 4
    # same prefix, different tail -> same leading hashes
    t2 = t.copy()
    t2[-1] += 1
    h2 = prefix_block_hashes(t2, 8)
    assert (h[:3] == h2[:3]).all() and h[3] != h2[3]


def test_prefix_hashes_vectorized_properties(rng):
    """The numpy block-wise fold keeps the content-addressing contract:
    deterministic, prefix-extension-stable, position-sensitive, and never
    the EMPTY_KEY sentinel."""
    t = rng.integers(0, 1 << 16, 67).astype(np.int32)
    h = prefix_block_hashes(t, 8)
    assert len(h) == 8  # trailing partial block is not hashed
    assert (h == prefix_block_hashes(t, 8)).all()            # deterministic
    assert (prefix_block_hashes(t[:32], 8) == h[:4]).all()   # prefix-stable
    # swapping two blocks changes both chains from the first swap onward
    t2 = t.copy()
    t2[0:8], t2[8:16] = t[8:16].copy(), t[0:8].copy()
    h2 = prefix_block_hashes(t2, 8)
    assert h2[0] != h[0] and h2[1] != h[1]
    assert len(prefix_block_hashes(np.empty(0, np.int32), 8)) == 0
    assert not (h == np.uint32(0xFFFFFFFF)).any()


def test_engine_completes_and_reuses(small_model, rng):
    cfg, params = small_model
    eng = _engine(cfg, params)
    shared = rng.integers(2, 400, 32)
    for _ in range(5):
        eng.submit(np.concatenate([shared, rng.integers(2, 400, 8)]), max_new=4)
    fin = eng.run()
    assert len(fin) == 5
    assert eng.hit_ratio() > 0.4  # shared prefix blocks hit after 1st request
    assert all(len(r.generated) >= 4 for r in fin.values())


def test_engine_matches_unpaged_decode(small_model, rng):
    """Greedy generation through the paged engine == contiguous decode."""
    cfg, params = small_model
    prompt = rng.integers(2, 400, 24)
    eng = _engine(cfg, params)
    rid = eng.submit(prompt, max_new=5)
    fin = eng.run()
    got = fin[rid].generated

    # reference: contiguous-cache decode
    cache = lm.init_cache(cfg, 1, 64)
    logits, ks, vs = None, None, None
    from repro.serve.paged_model import prefill_with_kv
    logits, ks, vs = prefill_with_kv(cfg, params, jnp.asarray(prompt[None]))
    # write prefill KV into the contiguous cache
    cache["k"] = cache["k"].at[:, :, :len(prompt)].set(ks)
    cache["v"] = cache["v"].at[:, :, :len(prompt)].set(vs)
    ref = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(5):
        lg, cache = lm.decode_step(
            cfg, params, jnp.asarray([ref[-1]], jnp.int32),
            jnp.asarray([pos], jnp.int32), cache)
        ref.append(int(jnp.argmax(lg[0])))
        pos += 1
    assert got[: len(ref)] == ref[: len(got)]


def test_engine_eviction_under_pressure(small_model, rng):
    cfg, params = small_model
    eng = _engine(cfg, params, num_sets=4, ways=2)  # only 8 shared pages
    for i in range(6):
        eng.submit(rng.integers(2, 400, 24), max_new=2)
    fin = eng.run()
    assert len(fin) == 6
    assert eng.stats["evictions"] > 0  # distinct prompts force evictions


@pytest.mark.parametrize("policy", [Policy.LRU, Policy.LFU, Policy.HYPERBOLIC])
def test_engine_policies(small_model, policy, rng):
    cfg, params = small_model
    eng = _engine(cfg, params, policy=policy)
    shared = rng.integers(2, 400, 16)
    for _ in range(3):
        eng.submit(np.concatenate([shared, rng.integers(2, 400, 8)]), max_new=2)
    fin = eng.run()
    assert len(fin) == 3


def test_engine_tinylfu(small_model, rng):
    cfg, params = small_model
    eng = _engine(cfg, params, tinylfu=True)
    for _ in range(4):
        eng.submit(rng.integers(2, 400, 16), max_new=2)
    assert len(eng.run()) == 4


def test_engine_backends_agree(small_model, rng):
    """The engine produces identical generations and prefix-cache behaviour
    on every CacheBackend (DESIGN.md §3)."""
    cfg, params = small_model
    shared = rng.integers(2, 400, 32)
    prompts = [np.concatenate([shared, rng.integers(2, 400, 8)])
               for _ in range(4)]
    results = {}
    for backend in ("jnp", "pallas", "ref"):
        eng = _engine(cfg, params, backend=backend)
        for p in prompts:
            eng.submit(p, max_new=3)
        fin = eng.run()
        results[backend] = (
            {rid: r.generated for rid, r in fin.items()},
            eng.hit_ratio(),
            eng.stats["evictions"],
        )
    assert results["jnp"] == results["pallas"] == results["ref"]
    assert results["jnp"][1] > 0.4  # shared prefix blocks hit


def test_engine_sharded_prefix_cache_matches(small_model, rng):
    """EngineConfig.shards > 1 runs the prefix cache set-sharded (device
    router, global slot ids): generations, hit ratio and evictions must all
    match the unsharded engine (LRU is timestamp-order-invariant)."""
    cfg, params = small_model
    shared = rng.integers(2, 400, 32)
    prompts = [np.concatenate([shared, rng.integers(2, 400, 8)])
               for _ in range(4)]
    results = {}
    for shards in (1, 2, 4):
        eng = _engine(cfg, params, shards=shards)
        for p in prompts:
            eng.submit(p, max_new=3)
        fin = eng.run()
        results[shards] = (
            {rid: r.generated for rid, r in fin.items()},
            eng.hit_ratio(),
            eng.stats["evictions"],
        )
    assert results[1] == results[2] == results[4]
    assert results[1][1] > 0.4


def test_probe_prefix_first_miss_vectorized(small_model):
    """The prefix transaction stops its hit chain at the first miss (later
    blocks cannot be valid without their prefix) — the vectorized
    cumulative-AND must honour that, not count disjoint later hits."""
    cfg, params = small_model
    eng = _engine(cfg, params)
    # insert blocks 0,1 and block 3 — leaving a hole at block 2
    hashes = np.asarray([11, 22, 33, 44], np.uint32)
    eng.kstate, _, _, ss, sw = eng.backend.put(
        eng.kstate, jnp.asarray(hashes[[0, 1, 3]]),
        jnp.zeros(3, jnp.int32), slot_value=True)
    slots = np.asarray(ss) * eng.kcfg.ways + np.asarray(sw)
    n_hit, pages = eng._prefix_transaction(hashes)
    assert n_hit == 2 and len(pages) == 4
    # hits return the stored page ids; the chain-broken blocks 2 and 3 are
    # still resolved to pages (insert-on-miss) so the engine can place them
    assert list(pages[:2]) == list(slots[:2])
    assert (pages >= 0).all()


def test_engine_rejects_ssm():
    cfg = configs.get("mamba2-130m").smoke
    params = lm.init_params(cfg, jax.random.key(0))
    with pytest.raises(ValueError, match="decoder-only"):
        _engine(cfg, params)
