"""Differential backend-equivalence suite (DESIGN.md §3).

Replays zipf traces through the `jnp`, `pallas` (interpret) and `ref`
backends and asserts identical hits, evictions and final state:

  * at batch size 1 all three are bit-identical across the policy ×
    layout × ways sweep (the ref oracle serializes batches, so B=1 is its
    exactness domain);
  * at any batch size `jnp` and `pallas` are bit-identical, including
    intra-batch duplicate keys and same-set collision ranks (they share one
    conflict-resolution apply; the kernel emits the same probe decisions).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import traces
from repro.core.backend import available_backends, make_backend
from repro.core.kway import KWayConfig
from repro.core.policies import Policy

ALL_POLICIES = [Policy.LRU, Policy.LFU, Policy.FIFO, Policy.RANDOM,
                Policy.HYPERBOLIC]
STATE_LEAVES = ("keys", "fprint", "vals", "meta_a", "meta_b", "clock")


def _assert_states_equal(sa, sb, msg=""):
    for leaf in STATE_LEAVES:
        a, b = np.asarray(getattr(sa, leaf)), np.asarray(getattr(sb, leaf))
        np.testing.assert_array_equal(a, b, err_msg=f"{msg}: {leaf}")


def _zipf(n, seed=11, catalog=256):
    return np.asarray(traces.generate("zipf", n, seed=seed, catalog=catalog),
                      np.uint32)


def test_registry():
    assert available_backends() == ["jnp", "pallas", "ref"]
    with pytest.raises(ValueError):
        make_backend("cuda", KWayConfig(num_sets=4, ways=2))


def test_pallas_rejects_unsupported():
    with pytest.raises(ValueError):
        make_backend("pallas", KWayConfig(num_sets=2, ways=256))
    with pytest.raises(ValueError):
        make_backend("pallas", KWayConfig(num_sets=1, ways=64, sample=8))


@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("layout", ["soa", "aos"])
def test_serial_equivalence_policies(policy, layout):
    """B=1 zipf replay: identical hit/eviction sequences and final state."""
    cfg = KWayConfig(num_sets=8, ways=4, policy=policy, layout=layout)
    bes = {n: make_backend(n, cfg) for n in ("jnp", "pallas", "ref")}
    states = {n: be.init() for n, be in bes.items()}
    trace = _zipf(150, seed=int(policy), catalog=120)
    trace[::13] = 0          # key 0 must behave like any other key
    for t in trace:
        k = jnp.asarray([t], jnp.uint32)
        v = jnp.asarray([int(t)], jnp.int32)
        res = {}
        for n, be in bes.items():
            states[n], hit, vals, ek, ev = be.access(states[n], k, v)
            res[n] = (bool(hit[0]), int(vals[0]), bool(ev[0]),
                      int(ek[0]) if bool(ev[0]) else -1)
        assert res["jnp"] == res["pallas"] == res["ref"], (policy, layout, t)
    _assert_states_equal(states["jnp"], states["pallas"], f"{policy}/pallas")
    _assert_states_equal(states["jnp"], states["ref"], f"{policy}/ref")


@pytest.mark.parametrize("ways", [1, 2, 8])
def test_serial_equivalence_ways(ways):
    cfg = KWayConfig(num_sets=4, ways=ways, policy=Policy.LRU)
    bes = {n: make_backend(n, cfg) for n in ("jnp", "pallas", "ref")}
    states = {n: be.init() for n, be in bes.items()}
    for t in _zipf(120, seed=ways, catalog=60):
        k = jnp.asarray([t], jnp.uint32)
        v = jnp.asarray([int(t)], jnp.int32)
        hits = set()
        for n, be in bes.items():
            states[n], hit, _, _, _ = be.access(states[n], k, v)
            hits.add(bool(hit[0]))
        assert len(hits) == 1
    _assert_states_equal(states["jnp"], states["pallas"], f"w{ways}/pallas")
    _assert_states_equal(states["jnp"], states["ref"], f"w{ways}/ref")


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_batched_jnp_vs_pallas(policy, rng):
    """Any batch size: jnp and pallas agree bit-for-bit, with duplicates,
    same-set collision ranks, and batches that don't tile the kernel."""
    cfg = KWayConfig(num_sets=4, ways=4, policy=policy)
    bj, bp = make_backend("jnp", cfg), make_backend("pallas", cfg)
    sj, sp = bj.init(), bp.init()
    for step in range(12):
        b = [1, 7, 8, 32][step % 4]
        keys = rng.integers(0, 48, b).astype(np.uint32)
        keys[: b // 3] = keys[0]                      # forced duplicates
        vals = jnp.asarray(keys.astype(np.int32))
        kj = jnp.asarray(keys)
        sj, hj, vj, ekj, evj = bj.access(sj, kj, vals)
        sp, hp, vp, ekp, evp = bp.access(sp, kj, vals)
        np.testing.assert_array_equal(np.asarray(hj), np.asarray(hp))
        np.testing.assert_array_equal(np.asarray(vj), np.asarray(vp))
        np.testing.assert_array_equal(np.asarray(evj), np.asarray(evp))
        np.testing.assert_array_equal(
            np.asarray(ekj)[np.asarray(evj)], np.asarray(ekp)[np.asarray(evp)])
    _assert_states_equal(sj, sp, str(policy))


@pytest.mark.parametrize("backend", ["jnp", "pallas", "ref"])
def test_put_returns_landing_slots(backend):
    cfg = KWayConfig(num_sets=8, ways=2, policy=Policy.LRU)
    be = make_backend(backend, cfg)
    st = be.init()
    keys = jnp.asarray(np.arange(10, dtype=np.uint32))
    st, ek, ev, ss, sw = be.put(st, keys, jnp.full(10, 7, jnp.int32))
    ss, sw = np.asarray(ss), np.asarray(sw)
    kn = np.asarray(st.keys)
    assert (ss >= 0).any()
    for i in range(10):
        if ss[i] >= 0:
            assert kn[ss[i], sw[i]] == i        # the key sits where reported


@pytest.mark.parametrize("backend", ["jnp", "pallas", "ref"])
def test_slot_value_put(backend):
    """slot_value=True stores the landing slot id as the payload — the
    engine's page-id convention, in one call."""
    cfg = KWayConfig(num_sets=8, ways=2, policy=Policy.LRU)
    be = make_backend(backend, cfg)
    st = be.init()
    keys = jnp.asarray(np.arange(12, dtype=np.uint32))
    st, _, _, ss, sw = be.put(st, keys, jnp.zeros(12, jnp.int32),
                              slot_value=True)
    st, hit, vals = be.get(st, keys)
    ss, sw = np.asarray(ss), np.asarray(sw)
    vals = np.asarray(vals)
    for i in range(12):
        if ss[i] >= 0:
            assert bool(np.asarray(hit)[i])
            assert vals[i] == ss[i] * cfg.ways + sw[i]


def test_states_interchangeable_between_backends(rng):
    """A state produced by one backend is a valid input to another: every
    backend continues the same warm state to the same result."""
    cfg = KWayConfig(num_sets=8, ways=4, policy=Policy.LFU)
    bj, bp = make_backend("jnp", cfg), make_backend("pallas", cfg)
    warm_state = bj.init()
    ks = rng.integers(0, 100, 64).astype(np.uint32)
    warm_state, *_ = bj.access(
        warm_state, jnp.asarray(ks), jnp.asarray(ks.astype(np.int32)))
    probe = jnp.asarray(rng.integers(0, 100, 16).astype(np.uint32))
    vals = probe.astype(jnp.int32)
    sj, hj, vj, ekj, evj = bj.access(warm_state, probe, vals)
    sp, hp, vp, ekp, evp = bp.access(warm_state, probe, vals)
    np.testing.assert_array_equal(np.asarray(hj), np.asarray(hp))
    np.testing.assert_array_equal(np.asarray(vj), np.asarray(vp))
    np.testing.assert_array_equal(np.asarray(evj), np.asarray(evp))
    _assert_states_equal(sj, sp, "warm-state handoff")
    assert np.asarray(hj).any()  # the warm state actually carried over


@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fused_access_equals_two_phase(policy, backend, rng):
    """The fused single-probe ``access`` == the two-phase get-then-put
    composition, bit-for-bit at any batch size: hits, vals, evictions and
    final state — including duplicate keys, same-set collision ranks,
    enabled masks, and batches that don't tile the kernel."""
    cfg = KWayConfig(num_sets=4, ways=4, policy=policy)
    be = make_backend(backend, cfg)
    sf, st = be.init(), be.init()
    for step in range(8):
        b = [1, 7, 8, 32][step % 4]
        keys = rng.integers(0, 48, b).astype(np.uint32)
        keys[: b // 3] = keys[0]                      # forced duplicates
        en = None if step % 3 else jnp.asarray(rng.random(b) < 0.8)
        k = jnp.asarray(keys)
        v = jnp.asarray(keys.astype(np.int32))
        sf, hf, vf, ekf, evf = be.access(sf, k, v, enabled=en)
        st, ht, vt, ekt, evt = be.access_two_phase(st, k, v, enabled=en)
        np.testing.assert_array_equal(np.asarray(hf), np.asarray(ht))
        np.testing.assert_array_equal(np.asarray(vf), np.asarray(vt))
        np.testing.assert_array_equal(np.asarray(evf), np.asarray(evt))
        np.testing.assert_array_equal(
            np.asarray(ekf)[np.asarray(evf)], np.asarray(ekt)[np.asarray(evt)])
    _assert_states_equal(sf, st, f"{backend}/{policy}: fused vs two-phase")


@pytest.mark.parametrize("policy", [Policy.LRU, Policy.LFU])
def test_fused_access_equals_two_phase_sampled(policy, rng):
    """Sampled-policy configs (jnp only) take the fused path too."""
    cfg = KWayConfig(num_sets=1, ways=64, policy=policy, sample=8)
    be = make_backend("jnp", cfg)
    sf, st = be.init(), be.init()
    for step in range(6):
        keys = rng.integers(0, 200, 16).astype(np.uint32)
        k = jnp.asarray(keys)
        v = jnp.asarray(keys.astype(np.int32))
        sf, hf, *_ = be.access(sf, k, v)
        st, ht, *_ = be.access_two_phase(st, k, v)
        np.testing.assert_array_equal(np.asarray(hf), np.asarray(ht))
    _assert_states_equal(sf, st, f"sampled/{policy}")


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("policy", [Policy.LRU, Policy.LFU])
def test_fused_access_equals_two_phase_tinylfu(backend, policy):
    """±TinyLFU: the fused path under admission gating replays to the same
    hit count and final state as the two-phase path."""
    import jax.numpy as _jnp

    from repro.core import admission, traces
    from repro.core.simulate import SimConfig, _replay_scan
    cfg = KWayConfig(num_sets=8, ways=4, policy=policy)
    tl = admission.for_capacity(32)
    tr = _jnp.asarray(np.asarray(
        traces.generate("zipf", 250, seed=3, catalog=64), np.uint32))
    hf, sf = _replay_scan(SimConfig(cfg, tl, backend=backend), tr)
    ht, st = _replay_scan(
        SimConfig(cfg, tl, backend=backend, two_phase=True), tr)
    assert int(hf) == int(ht)
    _assert_states_equal(sf, st, f"{backend}/{policy}/tinylfu")


def test_ref_access_is_two_phase_and_matches_fused(rng):
    """The ref oracle's ``access`` with TTLs off IS the two-phase
    composition (its override only adds expiry semantics, DESIGN.md §15),
    and the fused jnp path still matches it at B=1."""
    cfg = KWayConfig(num_sets=8, ways=4, policy=Policy.HYPERBOLIC)
    br, bj = make_backend("ref", cfg), make_backend("jnp", cfg)
    sr, s1, s2 = br.init(), bj.init(), bj.init()
    s3 = br.init()
    for t in _zipf(80, seed=9, catalog=40):
        k = jnp.asarray([t], jnp.uint32)
        v = jnp.asarray([int(t)], jnp.int32)
        sr, hr, *_ = br.access(sr, k, v)
        s1, h1, *_ = bj.access(s1, k, v)
        s2, h2, *_ = bj.access_two_phase(s2, k, v)
        s3, h3, *_ = br.access_two_phase(s3, k, v)
        assert bool(hr[0]) == bool(h1[0]) == bool(h2[0]) == bool(h3[0])
    _assert_states_equal(sr, s1, "ref vs jnp fused")
    _assert_states_equal(s1, s2, "jnp fused vs jnp two-phase")
    _assert_states_equal(sr, s3, "ref access vs ref two-phase")


def test_access_donated_matches_and_consumes_state():
    """The donating entry point returns the same result as the plain fused
    path while updating the KWayState buffers in place (the donated input
    is dead afterwards on backends that implement donation)."""
    from repro.core import kway
    cfg = KWayConfig(num_sets=8, ways=4, policy=Policy.LRU)
    keys = jnp.asarray(np.arange(16, dtype=np.uint32))
    vals = keys.astype(jnp.int32)
    s_plain, h1, v1, *_ = kway.access(cfg, kway.make_cache(cfg), keys, vals)
    s0 = kway.make_cache(cfg)
    s_don, h2, v2, *_ = kway.access_donated(cfg, s0, keys, vals)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    _assert_states_equal(s_plain, s_don, "donated")
    # the in-place chaining pattern every replay loop uses
    s_don, *_ = kway.access_donated(cfg, s_don, keys, vals)
    assert int(s_don.clock) == 64
    if hasattr(s0.keys, "is_deleted"):
        # jax with donation support consumed the input buffers
        assert s0.keys.is_deleted()


def test_peek_victims_agree(rng):
    cfg = KWayConfig(num_sets=4, ways=2, policy=Policy.LRU)
    bes = {n: make_backend(n, cfg) for n in ("jnp", "pallas", "ref")}
    st = bes["jnp"].init()
    warm = rng.integers(0, 64, 32).astype(np.uint32)
    for t in warm:  # warm sequentially so all backends see one state
        st, *_ = bes["jnp"].access(
            st, jnp.asarray([t], jnp.uint32), jnp.asarray([int(t)], jnp.int32))
    probes = jnp.asarray(rng.integers(0, 128, 16).astype(np.uint32))
    outs = {n: be.peek_victims(st, probes) for n, be in bes.items()}
    vkj, vvj = (np.asarray(x) for x in outs["jnp"])
    for n in ("pallas", "ref"):
        vk, vv = (np.asarray(x) for x in outs[n])
        np.testing.assert_array_equal(vvj, vv, err_msg=n)
        np.testing.assert_array_equal(vkj[vvj], vk[vv], err_msg=n)
