"""Two-level replay hierarchy (DESIGN.md §14) differential suite.

The hierarchical megakernel (kernels/replay.replay_hierarchical: VMEM L1
over slow-memory L2) must be bit-identical with the jitted chunked-scan
twin (core/hierarchy.replay_l1_over_l2) — per-chunk hits, per-chunk
evictions, BOTH final tier states — across every pallas-supported policy
and both movement switches.  This file pins that contract on the golden
trace, plus:

  * ``l1_sets=0`` disables the hierarchy bit-exactly (flat-path parity);
  * hit-ratio bands against the flat oracles: the hierarchy beats its own
    L2 alone and tracks a flat cache of the same total capacity;
  * the phase-transition unit semantics (promotion clears the L2 slot;
    demotion lands in the victim's own set and counts an eviction only
    when it displaces an occupied entry);
  * sharded replay parity + the one-trace/one-launch-per-shard economy;
  * the loud guards (TinyLFU × hierarchy, config validation) and the
    ``l1_demotion`` degradation event under a VMEM budget breach.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend as backend_mod
from repro.core import hierarchy as H
from repro.core import router, simulate, trace_io, traces
from repro.core.backend import make_backend
from repro.core.kway import KWayConfig
from repro.core.policies import Policy
from repro.core.sharded import ShardedCache, ShardedConfig
from repro.core.simulate import SimConfig
from repro.kernels import replay as kreplay
from repro.kernels.kway_probe import LANES
from repro.robust import events
from tests.test_golden_trace import CONFIG, golden_trace
from tests.test_resident import _assert_state_equal

PALLAS_POLICIES = [Policy.LRU, Policy.LFU, Policy.FIFO, Policy.RANDOM,
                   Policy.HYPERBOLIC]
BATCH = 32
HIER = H.HierarchyConfig(l1_sets=8, l1_ways=16)


def _golden_chunks():
    return router.pad_chunks(golden_trace(), BATCH)


def _assert_hier_equal(a, b, label):
    _assert_state_equal(a.l1, b.l1, f"{label}/L1")
    _assert_state_equal(a.l2, b.l2, f"{label}/L2")


# ---------------------------------------------------------------------------
# kernel == twin, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", PALLAS_POLICIES)
def test_hier_kernel_matches_twin_golden(policy):
    cfg = KWayConfig(policy=policy, **CONFIG)
    chunks, en = _golden_chunks()
    pb = make_backend("pallas", cfg)
    jb = make_backend("jnp", cfg)
    h1, e1, st1, _ = pb.replay(pb.init(), chunks, en, hierarchy=HIER)
    h2, e2, st2, _ = jb.replay(jb.init(), chunks, en, hierarchy=HIER)
    label = f"hier/{policy.name}"
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2),
                                  err_msg=f"{label}: per-chunk hits")
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2),
                                  err_msg=f"{label}: per-chunk evictions")
    _assert_hier_equal(st1, st2, label)


@pytest.mark.parametrize("promote,demote",
                         [(True, False), (False, True), (False, False)],
                         ids=["promote-only", "demote-only", "static"])
def test_hier_kernel_matches_twin_movement_switches(promote, demote):
    cfg = KWayConfig(policy=Policy.LRU, **CONFIG)
    hier = H.HierarchyConfig(l1_sets=8, l1_ways=16, promote=promote,
                             demote=demote)
    chunks, en = _golden_chunks()
    pb = make_backend("pallas", cfg)
    jb = make_backend("jnp", cfg)
    h1, e1, st1, _ = pb.replay(pb.init(), chunks, en, hierarchy=hier)
    h2, e2, st2, _ = jb.replay(jb.init(), chunks, en, hierarchy=hier)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))
    _assert_hier_equal(st1, st2, f"promote={promote},demote={demote}")


def test_hier_state_resumes_midstream():
    """Hierarchy replays compose: half + half from the returned HierState
    equals one whole replay (states are interchangeable mid-stream)."""
    cfg = KWayConfig(policy=Policy.LRU, **CONFIG)
    chunks, en = _golden_chunks()
    pb = make_backend("pallas", cfg)
    half = chunks.shape[0] // 2
    _, _, mid, _ = pb.replay(pb.init(), chunks[:half], en[:half],
                             hierarchy=HIER)
    hb, _, stb, _ = pb.replay(mid, chunks[half:], en[half:], hierarchy=HIER)
    ha, _, sta, _ = pb.replay(pb.init(), chunks, en, hierarchy=HIER)
    assert int(np.sum(np.asarray(ha)[half:])) == int(np.sum(np.asarray(hb)))
    _assert_hier_equal(sta, stb, "midstream resume")


# ---------------------------------------------------------------------------
# l1_sets = 0: the hierarchy disabled is the flat path, exactly
# ---------------------------------------------------------------------------

def test_hier_disabled_is_flat_path_bit_exact():
    cfg = KWayConfig(policy=Policy.LRU, **CONFIG)
    chunks, en = _golden_chunks()
    pb = make_backend("pallas", cfg)
    off = H.HierarchyConfig(l1_sets=0)
    assert not off.enabled
    h0, e0, st0, _ = pb.replay(pb.init(), chunks, en, hierarchy=off)
    h1, e1, st1, _ = pb.replay(pb.init(), chunks, en)
    assert not isinstance(st0, H.HierState)
    np.testing.assert_array_equal(np.asarray(h0), np.asarray(h1))
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))
    _assert_state_equal(st0, st1, "l1_sets=0 flat parity")
    # ... and against the chunked-scan oracle too
    h2, e2, st2, _ = pb.replay_scan(pb.init(), chunks, en)
    np.testing.assert_array_equal(np.asarray(h0), np.asarray(h2))
    _assert_state_equal(st0, st2, "l1_sets=0 scan parity")


# ---------------------------------------------------------------------------
# hit-ratio bands vs the flat oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["zipf", "lirs_two_pools"])
def test_hier_hit_ratio_bands(family):
    """The hierarchy must (a) beat its own L2 running alone — the L1 adds
    capacity and a high-associativity front — and (b) stay within a tight
    band of a flat cache of the same TOTAL capacity (64×12 = 768 =
    512 + 256): tiering costs at most a few points of hit ratio, which is
    the premise of serving past the VMEM budget at resident speed."""
    trace_io.register_fixture_traces()
    kwargs = {"catalog": 4096} if family == "zipf" else {}
    tr = traces.generate(family, 4096, seed=7, **kwargs)
    l2 = KWayConfig(num_sets=64, ways=8, policy=Policy.LRU)
    hier = H.HierarchyConfig(l1_sets=16, l1_ways=16)
    hr_hier = simulate.replay_batched(
        SimConfig(cache=l2, backend="pallas"), tr, batch=64, hierarchy=hier)
    hr_l2 = simulate.replay_batched(
        SimConfig(cache=l2, backend="pallas"), tr, batch=64)
    flat = KWayConfig(num_sets=64, ways=12, policy=Policy.LRU)
    hr_flat = simulate.replay_batched(
        SimConfig(cache=flat, backend="pallas"), tr, batch=64)
    assert hr_hier >= hr_l2 + 0.02, (family, hr_hier, hr_l2)
    assert abs(hr_hier - hr_flat) <= 0.05, (family, hr_hier, hr_flat)


# ---------------------------------------------------------------------------
# phase-transition unit semantics
# ---------------------------------------------------------------------------

def _row(keys, vals=None, ma=None, mb=None, ways=4):
    """Build one packed row from short python lists (rest empty)."""
    from repro.kernels.kway_probe import _fingerprint_i32

    k = np.full(LANES, -1, np.int32)
    f = np.zeros(LANES, np.int32)
    v = np.zeros(LANES, np.int32)
    a = np.zeros(LANES, np.int32)
    b = np.zeros(LANES, np.int32)
    for i, key in enumerate(keys):
        k[i] = key
        f[i] = int(_fingerprint_i32(jnp.uint32(key)))
        v[i] = (vals or keys)[i]
        a[i] = (ma or [0] * len(keys))[i]
        b[i] = (mb or [0] * len(keys))[i]
    sc = np.zeros(LANES, np.int32)
    return jnp.asarray(np.concatenate([k, f, v, a, b, sc])[None, :])


def _fp(key):
    from repro.kernels.kway_probe import _fingerprint_i32
    return _fingerprint_i32(jnp.uint32(key))


def test_promotion_clears_l2_slot_and_carries_metadata():
    """An L2 hit with ``promote`` MOVES the entry: the L2 slot is cleared
    (exclusive tiers) and the hit-updated metadata rides the mailbox for
    the L1 fill."""
    row = _row([7, 9], vals=[70, 90], ma=[3, 5])
    out = H._l2_hit_row(int(Policy.LFU), True, row, jnp.int32(9), _fp(9),
                        jnp.bool_(False), jnp.int32(100), jnp.bool_(True),
                        4)
    out = np.asarray(out)[0]
    assert out[1] == -1                       # way 1 cleared -> EMPTY
    assert out[0] == 7                        # neighbour untouched
    sc = out[5 * LANES:]
    assert sc[H.SC_L2HIT] == 1
    assert sc[H.SC_PVAL] == 90                # promoted payload
    assert sc[H.SC_PA] == 6                   # LFU on_hit: count 5 -> 6
    # without promote: updated in place, slot intact
    out2 = np.asarray(H._l2_hit_row(
        int(Policy.LFU), False, row, jnp.int32(9), _fp(9),
        jnp.bool_(False), jnp.int32(100), jnp.bool_(True), 4))[0]
    assert out2[1] == 9
    assert out2[3 * LANES + 1] == 6           # meta_a bumped in place


def test_l1_fill_reports_displaced_victim():
    """Filling a full L1 set surfaces the displaced entry — key, payload
    and metadata — in the mailbox for the demotion phase."""
    row = _row([1, 2, 3, 4], vals=[10, 20, 30, 40], ma=[50, 20, 60, 70])
    out = H._l1_fill_row(int(Policy.LRU), True, row, jnp.int32(99), _fp(99),
                         jnp.bool_(False), jnp.bool_(False), jnp.int32(0),
                         jnp.int32(0), jnp.int32(0), jnp.int32(200),
                         jnp.bool_(True), 4)
    out = np.asarray(out)[0]
    sc = out[5 * LANES:]
    assert sc[H.SC_DVALID] == 1
    assert sc[H.SC_DK] == 2                   # LRU victim: oldest meta_a
    assert sc[H.SC_DV] == 20
    assert sc[H.SC_DA] == 20                  # metadata carried verbatim
    assert out[1] == 99                       # inserted over the victim way


def test_demotion_counts_eviction_only_on_occupied_victim():
    empty_set = _row([])
    full_set = _row([11, 12, 13, 14], ma=[1, 2, 3, 4])
    args = (jnp.int32(5), _fp(5), jnp.int32(55), jnp.int32(9), jnp.int32(0))
    out1 = H._l2_demote_row(int(Policy.LRU), empty_set, *args,
                            jnp.bool_(True), jnp.int32(300), 4)
    out2 = H._l2_demote_row(int(Policy.LRU), full_set, *args,
                            jnp.bool_(True), jnp.int32(300), 4)
    sc1 = np.asarray(out1)[0, 5 * LANES:]
    sc2 = np.asarray(out2)[0, 5 * LANES:]
    assert sc1[H.SC_EV] == 0                  # landed on an empty way
    assert sc2[H.SC_EV] == 1                  # displaced an occupied entry
    assert np.asarray(out1)[0, 0] == 5        # demoted key inserted
    assert np.asarray(out1)[0, 2 * LANES] == 55   # payload + meta carried
    assert np.asarray(out1)[0, 3 * LANES] == 9
    # an invalid victim (dvalid=False) must leave the row untouched
    out3 = H._l2_demote_row(int(Policy.LRU), full_set, *args,
                            jnp.bool_(False), jnp.int32(300), 4)
    np.testing.assert_array_equal(np.asarray(out3)[0, :5 * LANES],
                                  np.asarray(full_set)[0, :5 * LANES])


# ---------------------------------------------------------------------------
# sharded replay: parity + launch economy
# ---------------------------------------------------------------------------

def test_sharded_hier_parity_and_launch_economy():
    tr = traces.generate("zipf", 2048, seed=3, catalog=1024)
    cfg = KWayConfig(num_sets=64, ways=8, policy=Policy.LRU)
    for d in (1, 2):
        sc_p = ShardedCache(ShardedConfig(cache=cfg, num_shards=d,
                                          backend="pallas"))
        sc_j = ShardedCache(ShardedConfig(cache=cfg, num_shards=d,
                                          backend="jnp"))
        kreplay.reset_trace_counts()
        h1, d1, st1 = sc_p.replay(tr, 128, resident=True, hierarchy=HIER)
        launches = sum(v for k, v in kreplay.trace_counts().items()
                       if k[0] == "launch-hier")
        assert launches == d, f"expected one megakernel launch per shard"
        h2, d2, st2 = sc_j.replay(tr, 128, resident=True, hierarchy=HIER)
        assert (h1, d1) == (h2, d2), (d, h1, h2)
        for tier in ("l1", "l2"):
            for f in ("keys", "vals", "meta_a"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(getattr(st1, tier), f)),
                    np.asarray(getattr(getattr(st2, tier), f)),
                    err_msg=f"sharded D={d} {tier}.{f}")


def test_hier_trace_economy():
    """Same-shape hierarchical replays: ONE trace, one launch each."""
    cfg = KWayConfig(policy=Policy.LRU, **CONFIG)
    # a chunk width no other test uses, so the jit cache is provably cold
    chunks, en = router.pad_chunks(golden_trace(), 16)
    pb = make_backend("pallas", cfg)
    kreplay.reset_trace_counts()
    pb.replay(pb.init(), chunks, en, hierarchy=HIER)
    pb.replay(pb.init(), chunks, en, hierarchy=HIER)
    counts = kreplay.trace_counts()
    assert sum(v for k, v in counts.items() if k[0] == "trace-hier") == 1
    assert sum(v for k, v in counts.items() if k[0] == "launch-hier") == 2


# ---------------------------------------------------------------------------
# guards, budget accounting, degradation
# ---------------------------------------------------------------------------

def test_hier_config_validation():
    with pytest.raises(AssertionError):
        H.HierarchyConfig(l1_sets=6)          # not a power of two
    with pytest.raises(AssertionError):
        H.HierarchyConfig(l1_sets=8, l1_ways=LANES + 1)
    assert H.HierarchyConfig(l1_sets=0).enabled is False
    assert H.HierarchyConfig(l1_sets=8, l1_ways=16).l1_capacity == 128


def test_hier_rejects_tinylfu():
    from repro.core import admission

    cfg = KWayConfig(policy=Policy.LRU, **CONFIG)
    chunks, en = _golden_chunks()
    tl = admission.for_capacity(cfg.capacity)
    for name in ("pallas", "jnp"):
        be = make_backend(name, cfg)
        with pytest.raises(ValueError, match="TinyLFU"):
            be.replay(be.init(), chunks, en, tinylfu=tl, hierarchy=HIER)


def test_hier_vmem_breach_demotes_to_twin_with_event():
    """Over budget the hierarchical kernel is abandoned for the jnp twin —
    same results bit-for-bit, with an ``l1_demotion`` degradation event
    naming the hierarchy option."""
    cfg = KWayConfig(policy=Policy.LRU, **CONFIG)
    chunks, en = _golden_chunks()
    pb = make_backend("pallas", cfg)
    h_ref, e_ref, st_ref, _ = pb.replay(pb.init(), chunks, en,
                                        hierarchy=HIER)
    c0 = events.cursor()
    with backend_mod.vmem_budget(0):
        assert not pb.hier_fits(HIER)
        h, e, st, _ = pb.replay(pb.init(), chunks, en, hierarchy=HIER)
    evs = [ev for ev in events.since(c0) if ev.reason == "l1_demotion"]
    assert len(evs) == 1
    assert evs[0].fallback_to == "jnp-l1l2-scan"
    np.testing.assert_array_equal(np.asarray(h), np.asarray(h_ref))
    np.testing.assert_array_equal(np.asarray(e), np.asarray(e_ref))
    _assert_hier_equal(st, st_ref, "vmem breach twin fallback")


def test_hier_footprint_accounting():
    assert H.hier_footprint_bytes(HIER) == 2 * 8 * H.ROW_W * 4
    cfg = KWayConfig(policy=Policy.LRU, **CONFIG)
    pb = make_backend("pallas", cfg)
    assert pb.hier_fits(HIER)
    # the budget context scales the answer, not just zeroes it
    with backend_mod.vmem_budget(H.hier_footprint_bytes(HIER)):
        assert pb.hier_fits(HIER)
    with backend_mod.vmem_budget(H.hier_footprint_bytes(HIER) - 1):
        assert not pb.hier_fits(HIER)


# ---------------------------------------------------------------------------
# fixture trace registration (satellite: real-trace-style family)
# ---------------------------------------------------------------------------

def test_fixture_trace_registered_and_deterministic():
    names = trace_io.register_fixture_traces()
    assert "lirs_two_pools" in names
    tr = traces.generate("lirs_two_pools", 10_000)
    assert len(tr) == 10_000
    assert trace_io.trace_fingerprint(tr) == "e76f5e99"
    # tiling: n beyond the file length wraps deterministically
    tr2 = traces.generate("lirs_two_pools", 12_000)
    np.testing.assert_array_equal(tr2[:10_000], tr)
    np.testing.assert_array_equal(tr2[10_000:], tr[:2_000])
