"""Hit-ratio regression bands — the paper's qualitative claims as tests.

Driven through ``repro.eval`` (the same stacked sweep path the figures and
CI baselines use), so a regression in either the cache or the measurement
layer trips these:

  * paper's central claim: k=8 sits within 2pp of full associativity on a
    zipf workload (Figs. 4-13);
  * paper's policy ranking on scan workloads: LRU is the loser — FIFO and
    LFU both rank above it on a looping trace (the classic LRU-killer).

Measured margins (pinned seeds, deterministic): band A delta ≈ 0.010 vs the
0.02 gate; band B FIFO-LRU ≈ +0.010, LFU-LRU ≈ +0.38 vs the 0.05 gate.
"""
from repro.core.policies import Policy
from repro.eval import runner
from repro.eval.runner import HitRatioSpec


def _values(spec, key):
    records, skipped = runner.run_hit_ratio_sweep(spec)
    assert not skipped
    return {r[key]: r["value"] for r in records}


def test_k8_within_2pp_of_fully_associative_on_zipf():
    vals = _values(HitRatioSpec(
        families=("zipf",), policies=(Policy.LRU,), assoc=("k8", "full"),
        backends=("jnp",), capacity=512, n=30_000, seeds=(3,),
        trace_kwargs={"zipf": {"catalog": 1 << 13, "alpha": 1.0}},
    ), "assoc")
    assert vals["k8"] > 0.3          # sanity: the trace is cacheable
    assert abs(vals["k8"] - vals["full"]) < 0.02, vals


def test_scan_loop_ranks_fifo_and_lfu_above_lru():
    vals = _values(HitRatioSpec(
        families=("scan_loop",),
        policies=(Policy.LRU, Policy.FIFO, Policy.LFU),
        assoc=("k8",), backends=("jnp",), capacity=1024, n=20_000, seeds=(9,),
        trace_kwargs={"scan_loop": {"working": 1536, "noise": 0.1}},
    ), "policy")
    assert vals["FIFO"] > vals["LRU"], vals
    assert vals["LFU"] > vals["LRU"] + 0.05, vals
