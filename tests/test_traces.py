"""Synthetic trace family tests: determinism, distribution shape, and the
``generate()`` error contract.

The recency tests pin the PR-7 ring-buffer fix: the old reuse read
``recent[(head - 1 - dist[i]) % window]`` wrapped into unwritten zero slots
for ``i < window``, inflating key 0 (≈200 occurrences in a 20k-request
trace); post-fix the distance is clamped to the filled depth and key 0 only
appears when the catalog draw genuinely produces it.
"""
import numpy as np
import pytest

from repro.core import traces

FAMILY_NAMES = ("zipf", "zipf_shift", "scan_loop", "recency", "oltp_mix",
                "ttl_churn")


@pytest.mark.parametrize("family", FAMILY_NAMES)
def test_same_seed_same_trace(family):
    a = traces.generate(family, 4096, seed=1)
    b = traces.generate(family, 4096, seed=1)
    assert a.dtype == np.uint32
    assert a.shape == (4096,)
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("family", FAMILY_NAMES)
def test_different_seeds_differ(family):
    a = traces.generate(family, 4096, seed=1)
    b = traces.generate(family, 4096, seed=2)
    assert not np.array_equal(a, b)


def test_zipf_is_skewed():
    tr = traces.generate("zipf", 30_000, seed=3)
    _, counts = np.unique(tr, return_counts=True)
    # a zipf(0.9) stream concentrates far above uniform: the hottest key
    # alone takes > 1% of requests while the catalog is 2^16
    assert counts.max() > 0.01 * len(tr)


def test_scan_loop_is_cyclic_without_noise():
    tr = traces.generate("scan_loop", 40_000, seed=4, working=1 << 14,
                         noise=0.0)
    np.testing.assert_array_equal(
        tr, np.arange(40_000, dtype=np.uint32) % np.uint32(1 << 14))


def test_recency_no_key0_inflation():
    # Pre-fix, the unfilled ring buffer leaked ~200 zero keys into a
    # 20k-request trace (seed 5); post-fix key 0 can only come from the
    # catalog draw (expected count n/catalog < 0.1).
    tr = traces.generate("recency", 20_000, seed=5)
    assert int((tr == 0).sum()) < 10


def test_recency_is_reuse_heavy():
    # theta=0.8 of accesses re-reference recent keys, so the stream must
    # have far fewer uniques than requests (fresh draws only ~20%).
    tr = traces.generate("recency", 20_000, seed=5)
    assert len(np.unique(tr)) < 0.3 * len(tr)


def test_recency_reuse_always_reads_the_filled_window():
    # With theta=1.0 every access after the first is a reuse, so every key
    # must already appear earlier in the stream.  Pre-fix this fails: early
    # reuse distances wrap into unwritten ring slots and emit key 0 before
    # any fresh draw produced it.
    tr = traces.generate("recency", 3_000, seed=6, theta=1.0)
    seen = {int(tr[0])}
    for k in tr[1:]:
        assert int(k) in seen, "reuse returned a key never emitted before"
        seen.add(int(k))


def test_generate_unknown_family_raises_value_error():
    with pytest.raises(ValueError, match="unknown trace family 'nope'"):
        traces.generate("nope", 100)
    # the error names the available families
    with pytest.raises(ValueError, match="zipf") as ei:
        traces.generate("nope", 100)
    assert "recency" in str(ei.value)


def test_generate_bad_kwargs_raise_value_error():
    with pytest.raises(ValueError, match="bogus"):
        traces.generate("zipf", 100, bogus=3)
    with pytest.raises(ValueError, match="family 'zipf'") as ei:
        traces.generate("zipf", 100, alpha=1.0, working=5)
    assert "working" in str(ei.value)       # the offending kwarg is named
    assert "alpha" in str(ei.value)         # ... and the accepted ones listed


def test_generate_valid_kwargs_still_work():
    tr = traces.generate("zipf", 256, seed=1, catalog=512, alpha=1.0)
    assert tr.dtype == np.uint32 and tr.max() < 512


def test_register_family_rejects_builtin_shadowing():
    with pytest.raises(ValueError, match="shadow"):
        traces.register_family("zipf", lambda rng, n: np.zeros(n, np.uint32))
    with pytest.raises(ValueError, match="built-in"):
        traces.unregister_family("zipf")


def test_register_family_round_trip():
    def fixed(rng, n):
        return np.arange(n, dtype=np.uint32)

    traces.register_family("fixed_test_family", fixed)
    try:
        np.testing.assert_array_equal(
            traces.generate("fixed_test_family", 8),
            np.arange(8, dtype=np.uint32))
        # registered families show up in the unknown-family error listing
        with pytest.raises(ValueError, match="fixed_test_family"):
            traces.generate("nope", 8)
    finally:
        traces.unregister_family("fixed_test_family")
    with pytest.raises(ValueError, match="unknown trace family"):
        traces.generate("fixed_test_family", 8)


def test_ttl_churn_streams_consistent():
    """generate_ttl's keys are bit-identical to generate's (one rng draw
    serves both streams), TTLs are bimodal, and the churn minority lives
    in a disjoint key range from the hot core."""
    keys, ttls = traces.generate_ttl("ttl_churn", 8192, seed=5)
    np.testing.assert_array_equal(keys,
                                  traces.generate("ttl_churn", 8192, seed=5))
    assert ttls.dtype == np.int32
    assert set(np.unique(ttls)) == {48, 4096}
    churn = ttls == 48
    assert 0.2 < churn.mean() < 0.4                 # churn_frac=0.3
    assert (keys[churn] >= 1 << 12).all()           # disjoint churn range
    assert (keys[~churn] < 1 << 12).all()


def test_generate_ttl_unknown_family():
    with pytest.raises(ValueError, match="unknown TTL trace family"):
        traces.generate_ttl("zipf", 8)
