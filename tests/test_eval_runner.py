"""repro.eval.runner — stacked-sweep correctness + compile-count guarantees.

Two properties carry the subsystem:

  * exactness — the stacked, vmapped, policy-dynamic replay produces the
    SAME hit count as core/simulate.replay (the sequential B=1 backend
    semantics), per policy, per associativity (incl. sampled and fully
    associative), with and without TinyLFU, on jnp and pallas;
  * economy — a grid compiles once per cache *shape*, not once per config
    (the acceptance criterion of the sweep design).
"""
import numpy as np
import pytest

from repro.core import admission, traces
from repro.core.kway import KWayConfig
from repro.core.policies import Policy
from repro.core.simulate import SimConfig, replay
from repro.eval import runner
from repro.eval.runner import HitRatioSpec, SweepPoint, assoc_shape

ALL_POLICIES = (Policy.LRU, Policy.LFU, Policy.FIFO, Policy.RANDOM,
                Policy.HYPERBOLIC)


def _sequential(rec, capacity):
    """Ground truth for one record via core/simulate.replay."""
    cfg = KWayConfig(num_sets=rec["num_sets"], ways=rec["ways"],
                     sample=rec["sample"], policy=Policy[rec["policy"]])
    tl = (admission.for_capacity(capacity)
          if rec["admission"] == "tinylfu" else None)
    backend = rec["backend"]
    tr = traces.generate(rec["family"], rec["n"], seed=rec["seeds"][0])
    return replay(SimConfig(cfg, tl, backend=backend), tr)


def test_assoc_shape():
    assert assoc_shape("k8", 1024) == (128, 8, 0)
    assert assoc_shape("full", 1024) == (1, 1024, 0)
    assert assoc_shape("sampled8", 1024) == (1, 1024, 8)
    with pytest.raises(ValueError):
        assoc_shape("k3", 1024)   # capacity not divisible
    with pytest.raises(ValueError):
        assoc_shape("bogus", 1024)


def test_stacked_replay_matches_sequential_all_policies():
    """Every policy through one compiled program == per-policy sequential."""
    spec = HitRatioSpec(
        families=("zipf",), policies=ALL_POLICIES,
        assoc=("k4", "sampled4", "full"), backends=("jnp",),
        capacity=64, n=400, seeds=(5,))
    records, skipped = runner.run_hit_ratio_sweep(spec)
    assert not skipped
    assert len(records) == len(ALL_POLICIES) * 3
    for rec in records:
        assert rec["value"] == pytest.approx(_sequential(rec, 64), abs=1e-9), \
            rec["id"]


def test_stacked_replay_matches_sequential_pallas():
    spec = HitRatioSpec(
        families=("zipf",), policies=(Policy.LRU, Policy.RANDOM),
        assoc=("k4",), backends=("pallas",), capacity=64, n=300, seeds=(6,))
    records, skipped = runner.run_hit_ratio_sweep(spec)
    assert not skipped and len(records) == 2
    for rec in records:
        assert rec["value"] == pytest.approx(_sequential(rec, 64), abs=1e-9), \
            rec["id"]


def test_stacked_replay_matches_sequential_tinylfu():
    spec = HitRatioSpec(
        families=("zipf",), policies=(Policy.LFU,), assoc=("k4",),
        backends=("jnp",), admissions=("tinylfu",),
        capacity=64, n=400, seeds=(7,))
    records, skipped = runner.run_hit_ratio_sweep(spec)
    assert not skipped and len(records) == 1
    assert records[0]["value"] == pytest.approx(
        _sequential(records[0], 64), abs=1e-9)


def test_compiles_once_per_shape_not_per_config():
    """The acceptance criterion: O(shapes) compilations for O(configs) cells.

    2 families × 3 policies × 2 associativities × 2 seeds = 24 replays, but
    only 2 cache shapes — the policy is traced data (policies.*_dyn) and the
    traces are stacked, so exactly 2 programs are built.
    """
    runner.reset_trace_counts()
    spec = HitRatioSpec(
        families=("zipf", "oltp_mix"),
        policies=(Policy.LRU, Policy.LFU, Policy.FIFO),
        assoc=("k4", "k8"), backends=("jnp",),
        capacity=256, n=500, seeds=(1, 2))
    points, _ = spec.expand()
    assert len(points) == 24
    records, _ = runner.run_hit_ratio_sweep(spec)
    assert len(records) == 12          # 24 replays fold to 12 ids x 2 seeds
    counts = runner.trace_counts()
    assert sum(counts.values()) == 2, counts   # one compile per cache shape
    runner.reset_trace_counts()


def test_runner_matches_fused_and_two_phase_replay():
    """The stacked sweep, the fused backend replay, and the two-phase
    backend replay all produce the same hit ratio — the runner's B=1 step is
    the single-probe specialization of the fused access semantics."""
    spec = HitRatioSpec(
        families=("zipf",), policies=(Policy.LRU, Policy.RANDOM),
        assoc=("k4",), backends=("jnp",), capacity=64, n=300, seeds=(8,))
    records, _ = runner.run_hit_ratio_sweep(spec)
    tr = traces.generate("zipf", 300, seed=8)
    for rec in records:
        cfg = KWayConfig(num_sets=rec["num_sets"], ways=rec["ways"],
                         policy=Policy[rec["policy"]])
        fused = replay(SimConfig(cfg), tr)
        two = replay(SimConfig(cfg, two_phase=True), tr)
        assert fused == two == pytest.approx(rec["value"], abs=1e-9), \
            rec["id"]


def test_sweep_asserts_compile_economy():
    """run_hit_ratio_sweep itself enforces <= one compile per shape group
    (the in-driver trace_counts() assertion) — running the same spec twice
    must not trip it (jit cache reuse counts as zero new compiles)."""
    spec = HitRatioSpec(
        families=("zipf",), policies=(Policy.LRU,), assoc=("k4",),
        backends=("jnp",), capacity=64, n=200, seeds=(3,))
    runner.run_hit_ratio_sweep(spec)
    runner.run_hit_ratio_sweep(spec)   # second run: zero fresh traces


def test_skips_are_loud():
    """Unsupported combos are reported, never silently dropped."""
    spec = HitRatioSpec(
        families=("zipf",), policies=(Policy.LRU,),
        assoc=("k4", "sampled8", "full"), backends=("jnp", "pallas", "ref"),
        capacity=256, n=100, seeds=(1,))
    points, skipped = spec.expand()
    run_ids = {p.record_id for p in points}
    assert "zipf/LRU/k4/pallas/none" in run_ids
    assert any("sampled8/pallas" in s for s in skipped)
    assert any("full/pallas" in s for s in skipped)
    assert sum("ref" in s for s in skipped) == 3   # oracle never sweeps


def test_record_ids_are_seed_stable():
    p1 = SweepPoint(family="zipf", policy=Policy.LRU, assoc="k8",
                    capacity=1024, seed=1)
    p2 = SweepPoint(family="zipf", policy=Policy.LRU, assoc="k8",
                    capacity=1024, seed=2)
    assert p1.record_id == p2.record_id == "zipf/LRU/k8/jnp/none"
