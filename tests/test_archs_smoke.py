"""Per-arch smoke tests: reduced config, one forward + one train step + one
decode step on CPU; output shapes and no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.optim import adamw
from repro.train.step import TrainConfig, make_train_step


def _batch_for(cfg, b, s, rng):
    s_tok = s
    batch = {}
    if cfg.frontend == "patch":
        s_tok = s - cfg.frontend_len
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_len, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    if cfg.enc_layers:
        s_tok = s // 2
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((b, s - s_tok, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    batch["tokens"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s_tok)), jnp.int32)
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return batch, s_tok


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward_and_train_step(arch, rng):
    spec = configs.get(arch)
    cfg = spec.smoke
    params = lm.init_params(cfg, jax.random.key(0))
    b, s = 2, 64
    batch, s_tok = _batch_for(cfg, b, s, rng)

    logits = lm.forward(cfg, params, batch["tokens"],
                        prefix_embeds=batch.get("prefix_embeds"),
                        enc_embeds=batch.get("enc_embeds"))
    out_len = s if cfg.frontend == "patch" else s_tok
    assert logits.shape == (b, out_len, lm.padded_vocab(cfg))
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))

    tcfg = TrainConfig(optimizer=adamw.AdamWConfig(lr=1e-3, total_steps=10))
    step = make_train_step(cfg, tcfg)
    opt = adamw.init(params)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # params actually changed
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b_: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                            b_.astype(jnp.float32)))),
        params, params2))
    assert max(delta) > 0


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_decode_step(arch, rng):
    spec = configs.get(arch)
    cfg = spec.smoke
    params = lm.init_params(cfg, jax.random.key(1))
    b, max_seq = 2, 32
    cache = lm.init_cache(cfg, b, max_seq)
    pos = jnp.zeros((b,), jnp.int32)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, b), jnp.int32)
    for i in range(3):
        logits, cache = lm.decode_step(cfg, params, tok, pos, cache)
        assert logits.shape == (b, lm.padded_vocab(cfg))
        assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = pos + 1


@pytest.mark.parametrize("arch", ["gemma2-2b", "deepseek-7b", "mamba2-130m",
                                  "hymba-1.5b"])
def test_prefill_decode_consistency(arch, rng):
    """Decode token-by-token == teacher-forced forward on the same tokens."""
    spec = configs.get(arch)
    cfg = spec.smoke
    params = lm.init_params(cfg, jax.random.key(2))
    b, s = 2, 8
    toks = jnp.asarray(rng.integers(2, cfg.vocab_size, (b, s)), jnp.int32)
    full = lm.forward(cfg, params, toks).astype(jnp.float32)

    cache = lm.init_cache(cfg, b, 16)
    outs = []
    for i in range(s):
        logits, cache = lm.decode_step(
            cfg, params, toks[:, i], jnp.full((b,), i, jnp.int32), cache)
        outs.append(logits.astype(jnp.float32))
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                               atol=6e-2, rtol=6e-2)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_full_config_exact_spec(arch):
    """The full (non-smoke) configs carry the exact assigned hyperparams."""
    c = configs.get(arch).config
    expected = {
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
    }[arch]
    got = (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
           c.vocab_size)
    assert got == expected
    if arch == "mixtral-8x22b":
        assert (c.num_experts, c.top_k) == (8, 2) and c.sliding_window > 0
    if arch == "dbrx-132b":
        assert (c.num_experts, c.top_k) == (16, 4)
    if arch == "gemma2-2b":
        assert c.alt_local_global and c.attn_softcap == 50.0
    if arch == "mamba2-130m":
        assert c.ssm_state == 128
    if arch == "hymba-1.5b":
        assert c.ssm_state == 16 and c.has_attention
