"""K-way cache unit + oracle-agreement tests (the paper's core).

Hypothesis property tests live in tests/test_kway_properties.py, which
skips itself when `hypothesis` is not installed (see requirements-dev.txt).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kway
from repro.core.hashing import EMPTY_KEY
from repro.core.kway import KWayConfig, fully_associative
from repro.core.policies import Policy
from repro.core.refimpl import RefKWay

POLICIES = [Policy.LRU, Policy.LFU, Policy.FIFO, Policy.RANDOM, Policy.HYPERBOLIC]


def _run_trace(cfg, trace):
    st_ = kway.make_cache(cfg)
    hits = []
    for t in trace:
        st_, h, v, ek, ev = kway.access(
            cfg, st_, jnp.array([t], jnp.uint32), jnp.array([int(t)], jnp.int32)
        )
        hits.append(bool(h[0]))
    return st_, hits


@pytest.mark.parametrize("policy", POLICIES)
def test_exact_oracle_agreement(policy, rng):
    """JAX cache at B=1 == serial transcription of the paper's algorithms."""
    trace = rng.integers(0, 150, size=600, dtype=np.uint32)
    cfg = KWayConfig(num_sets=8, ways=4, policy=policy)
    ref = RefKWay(8, 4, policy)
    st_ = kway.make_cache(cfg)
    for t in trace:
        st_, h, _, _, _ = kway.access(
            cfg, st_, jnp.array([t], jnp.uint32), jnp.array([int(t)], jnp.int32)
        )
        rh = ref.access(int(t), int(t))
        assert bool(h[0]) == rh
    jax_keys = {int(x) for x in np.asarray(st_.keys).ravel() if x != 0xFFFFFFFF}
    assert jax_keys == ref.contents()


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("layout", ["soa", "aos"])
def test_capacity_never_exceeded(policy, layout, rng):
    cfg = KWayConfig(num_sets=4, ways=4, policy=policy, layout=layout)
    st_, _ = _run_trace(cfg, rng.integers(0, 1000, 300, dtype=np.uint32))
    assert int(st_.occupancy()) <= cfg.capacity
    # no key stored twice
    keys = [int(x) for x in np.asarray(st_.keys).ravel() if x != 0xFFFFFFFF]
    assert len(keys) == len(set(keys))


def test_hit_implies_present(rng):
    cfg = KWayConfig(num_sets=8, ways=4, policy=Policy.LRU)
    st_ = kway.make_cache(cfg)
    seen = set()
    for t in rng.integers(0, 100, 400, dtype=np.uint32):
        st_, h, v, _, _ = kway.access(
            cfg, st_, jnp.array([t], jnp.uint32), jnp.array([int(t)], jnp.int32)
        )
        if bool(h[0]):
            assert int(t) in seen
            assert int(v[0]) == int(t)  # value integrity
        seen.add(int(t))


def test_fully_associative_is_one_set():
    cfg = fully_associative(16, Policy.LRU)
    assert cfg.num_sets == 1 and cfg.ways == 16
    st_, hits = _run_trace(cfg, np.arange(16, dtype=np.uint32))
    assert int(st_.occupancy()) == 16
    # LRU eviction order: access 16 (evicts 0), then 0 must miss
    st_, h, _, _, _ = kway.access(cfg, st_, jnp.array([16], jnp.uint32),
                                  jnp.array([16], jnp.int32))
    assert not bool(h[0])
    st_, h, _, _, _ = kway.access(cfg, st_, jnp.array([0], jnp.uint32),
                                  jnp.array([0], jnp.int32))
    assert not bool(h[0])  # 0 was the LRU victim


def test_batched_matches_serial_when_sets_distinct(rng):
    """The paper's embarrassing parallelism: requests to different sets
    commute — a batched step equals any serialization."""
    cfg = KWayConfig(num_sets=64, ways=4, policy=Policy.LFU)
    # distinct sets: pick keys with distinct set indices
    from repro.core import hashing
    keys, seen = [], set()
    k = 0
    while len(keys) < 16:
        s = int(hashing.set_index(jnp.array([k], jnp.uint32), 64)[0])
        if s not in seen:
            seen.add(s)
            keys.append(k)
        k += 1
    keys = np.array(keys, np.uint32)

    st_b = kway.make_cache(cfg)
    st_b, hb, _, _, _ = kway.access(cfg, st_b, jnp.asarray(keys),
                                    jnp.asarray(keys.astype(np.int32)))
    st_s = kway.make_cache(cfg)
    for t in keys:
        st_s, _, _, _, _ = kway.access(
            cfg, st_s, jnp.array([t], jnp.uint32), jnp.array([int(t)], jnp.int32)
        )
    jb = {int(x) for x in np.asarray(st_b.keys).ravel() if x != 0xFFFFFFFF}
    js = {int(x) for x in np.asarray(st_s.keys).ravel() if x != 0xFFFFFFFF}
    assert jb == js


def test_batched_conflict_bounded_and_deduped(rng):
    """Same-set collisions: ≤ k admissions per set per batch; duplicate keys
    admitted once (documented CAS-race semantics)."""
    cfg = KWayConfig(num_sets=2, ways=4, policy=Policy.LRU)
    st_ = kway.make_cache(cfg)
    keys = np.array([1, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11], np.uint32)
    st_, _, _, _, _ = kway.access(cfg, st_, jnp.asarray(keys),
                                  jnp.asarray(keys.astype(np.int32)))
    assert int(st_.occupancy()) <= cfg.capacity
    stored = [int(x) for x in np.asarray(st_.keys).ravel() if x != 0xFFFFFFFF]
    assert len(stored) == len(set(stored))


def test_evicted_keys_reported(rng):
    cfg = KWayConfig(num_sets=1, ways=2, policy=Policy.FIFO)
    st_ = kway.make_cache(cfg)
    for k in [1, 2, 3]:
        st_, _, _, ek, ev = kway.access(
            cfg, st_, jnp.array([k], jnp.uint32), jnp.array([k], jnp.int32)
        )
    assert bool(ev[0]) and int(ek[0]) == 1  # FIFO: 1 evicted by 3
