"""The paper's Figure-1 claim, verified on compiled HLO: with requests
routed to the device owning their sets (the paper's hash routing), K-way
cache operations across 8 devices compile to ZERO collectives.

Each device owns an independent sub-cache (sets are independent — §1); the
global cache is their disjoint union, and ``shard_map`` expresses exactly
the "Alice and Bob never synchronize" execution.  Runs in a subprocess
(device count must be fixed before jax initializes).
"""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import re
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import kway
from repro.core.kway import KWayConfig
from repro.core.policies import Policy

NDEV = 8
mesh = jax.make_mesh((NDEV,), ("sets",))
cfg = KWayConfig(num_sets=16, ways=8, policy=Policy.LRU)  # per-device cache

def local_access(keys, vals, *leaves):
    st = kway.KWayState(*[l[0] for l in leaves[:-1]], clock=leaves[-1][0])
    st, hit, out, _, _ = kway.access(cfg, st, keys[0], vals[0])
    new_leaves = (st.keys, st.fprint, st.vals, st.meta_a, st.meta_b)
    return (hit[None], out[None]) + tuple(l[None] for l in new_leaves) + (
        st.clock[None],)

st0 = kway.make_cache(cfg)
leaves = [jnp.broadcast_to(l, (NDEV,) + l.shape) for l in
          (st0.keys, st0.fprint, st0.vals, st0.meta_a, st0.meta_b)]
clock = jnp.zeros((NDEV,), jnp.int32)
keys = jnp.ones((NDEV, 32), jnp.uint32)   # pre-routed per device
vals = jnp.ones((NDEV, 32), jnp.int32)

fn = shard_map(
    local_access, mesh=mesh,
    in_specs=(P("sets"), P("sets")) + (P("sets"),) * 6,
    out_specs=(P("sets"),) * 8,
)
compiled = jax.jit(fn).lower(keys, vals, *leaves, clock).compile()
txt = compiled.as_text()
colls = re.findall(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)",
    txt,
)
assert not colls, f"collectives found: {colls}"
print("ZERO collectives across", NDEV, "devices: OK")
"""


def test_kway_set_axis_zero_collectives():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=600, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "HOME": "/root"},
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ZERO collectives across 8 devices: OK" in r.stdout
