"""Artifact schema, baseline diffing, and the repro.eval CLI gate."""
import copy
import json

import pytest

from repro.eval import artifacts
from repro.eval.__main__ import main as eval_main
from repro.eval.figures import FIGURES


def _records():
    return [
        {"id": "zipf/LRU/k8/jnp/none", "metric": "hit_ratio",
         "value": 0.83, "comparable": True},
        {"id": "zipf/LRU/full/jnp/none", "metric": "hit_ratio",
         "value": 0.85, "comparable": True},
        {"id": "kway-soa/batch64", "metric": "mops_per_s",
         "value": 12.0, "comparable": False},
    ]


def _artifact():
    return artifacts.make_artifact(
        "hit_ratio_vs_associativity", {"n": 100}, _records(), ["sk"])


def test_roundtrip(tmp_path):
    art = _artifact()
    assert art["schema_version"] == artifacts.SCHEMA_VERSION
    assert art["env"]["jax"] and art["env"]["python"]
    path = artifacts.write_artifact(str(tmp_path / "BENCH_x.json"), art)
    loaded = artifacts.load_artifact(path)
    assert loaded["records"] == art["records"]
    assert loaded["skipped"] == ["sk"]


def test_load_rejects_foreign_and_stale(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"kind": "something-else"}))
    with pytest.raises(ValueError, match="not a"):
        artifacts.load_artifact(str(p))
    art = _artifact()
    art["schema_version"] = artifacts.SCHEMA_VERSION + 1
    p.write_text(json.dumps(art))
    with pytest.raises(ValueError, match="schema_version"):
        artifacts.load_artifact(str(p))


def test_compare_passes_on_identical():
    assert artifacts.compare_to_baseline(_artifact(), _artifact()) == []


def test_compare_flags_injected_regression():
    fresh, base = _artifact(), _artifact()
    fresh["records"][0]["value"] -= 0.05     # a real hit-ratio regression
    breaches = artifacts.compare_to_baseline(fresh, base, tol=0.01)
    assert len(breaches) == 1 and "zipf/LRU/k8" in breaches[0]


def test_compare_ignores_timing_records():
    fresh, base = _artifact(), _artifact()
    fresh["records"][2]["value"] = 0.001     # 12000x slower: not a breach
    assert artifacts.compare_to_baseline(fresh, base) == []


def test_compare_flags_missing_coverage():
    fresh, base = _artifact(), _artifact()
    del fresh["records"][1]
    breaches = artifacts.compare_to_baseline(fresh, base)
    assert len(breaches) == 1 and "missing from run" in breaches[0]


def test_compare_respects_per_record_tol():
    fresh, base = _artifact(), _artifact()
    base["records"][0]["tol"] = 0.2
    fresh["records"][0]["value"] -= 0.1
    assert artifacts.compare_to_baseline(fresh, base, tol=0.01) == []


def test_compare_rejects_figure_mismatch():
    fresh, base = _artifact(), _artifact()
    base["figure"] = "throughput_vs_batch"
    assert "figure mismatch" in artifacts.compare_to_baseline(fresh, base)[0]


# ---------------------------------------------------------------------------
# CLI — wired through a stub figure so the test is instant
# ---------------------------------------------------------------------------

@pytest.fixture
def stub_fig(monkeypatch):
    state = {"records": _records()}

    def fake(quick=False, progress=None):
        return {"quick": quick}, copy.deepcopy(state["records"]), ["sk"]

    monkeypatch.setitem(FIGURES, "hit_ratio",
                        (fake, "hit_ratio_vs_associativity"))
    return state


def test_cli_writes_artifact(stub_fig, tmp_path):
    out = tmp_path / "BENCH_hit.json"
    assert eval_main(["--fig", "hit_ratio", "--quick", "--quiet",
                      "--out", str(out)]) == 0
    art = artifacts.load_artifact(str(out))
    assert art["figure"] == "hit_ratio_vs_associativity"
    assert art["spec"] == {"quick": True}
    assert len(art["records"]) == 3


def test_cli_baseline_gate_exits_nonzero_on_regression(
        stub_fig, tmp_path, capsys):
    base = tmp_path / "baseline.json"
    out = tmp_path / "BENCH_hit.json"
    # write the baseline from an identical run -> passes
    assert eval_main(["--fig", "hit_ratio", "--quiet",
                      "--out", str(base)]) == 0
    assert eval_main(["--fig", "hit_ratio", "--quiet", "--out", str(out),
                      "--baseline", str(base)]) == 0
    # inject a hit-ratio regression -> exit 2 and a named breach
    stub_fig["records"][0]["value"] -= 0.5
    assert eval_main(["--fig", "hit_ratio", "--quiet", "--out", str(out),
                      "--baseline", str(base)]) == 2
    err = capsys.readouterr().err
    assert "BASELINE BREACH" in err and "zipf/LRU/k8" in err
