"""Showdown vs production caches (paper Fig. 1 analogue) — CLI.

The measurement lives in ``repro.eval.figures.showdown``: the same uint32
key traces replayed through ``cachetools`` (LRU/LFU behind the documented
global lock) and a lock-striped pure-Python k-way baseline under a thread
pool at thread counts {1, 2, 4, 8}, next to our jnp chunked-scan and
pallas trace-resident replay rows — req/s per library plus deterministic
single-threaded hit-ratio parity records.

    PYTHONPATH=src python -m benchmarks.showdown --quick \
        [--out BENCH_showdown.json] \
        [--hit-ratio-gate benchmarks/baselines/BENCH_showdown_quick.json]

Every invocation writes the schema-versioned BENCH artifact and prints the
req/s-vs-threads table; ``--hit-ratio-gate`` additionally diffs the
``showdown-hr/...`` parity records against the committed baseline through
the shared ``_baseline_gate``/``_run_gate`` contract from
``benchmarks.throughput`` — exit 3 on divergence, dead gate = breach.  This
is the CI perf-smoke entry point; ``run()`` is the CSV section for
``benchmarks/run.py``.

Requires ``cachetools`` (requirements-dev.txt); without it the command
exits 4 with an install hint instead of crashing mid-figure.
"""
import argparse
import sys

from benchmarks.common import emit
from benchmarks.throughput import _baseline_gate, _run_gate
from repro.eval import figures


def showdown_hit_ratio_gate(baseline_path: str, records, tol: float = 1e-6):
    """Diff a fresh run's ``showdown-hr/...`` parity records against the
    committed baseline.  Every library's single-threaded replay is
    deterministic (external Python caches and our batched paths alike), so
    the band is essentially zero — a breach means a library upgrade, a
    trace change, or a cache-semantics regression.  Returns
    ``(checked, breaches)`` under the shared dead-gate contract.
    """
    points = []
    for r in records:
        if not r["id"].startswith("showdown-hr/"):
            continue
        points.append((r["id"],
                       lambda rec, _r=r: [(_r["id"], _r["value"],
                                           rec["value"])]))
    return _baseline_gate(baseline_path, points, tol)


def _compare(args) -> int:
    from repro.eval import artifacts

    spec, records, skipped = figures.showdown(
        quick=args.quick,
        progress=None if args.quiet else
        (lambda m: print(f"  [showdown] {m}", flush=True)))
    art = artifacts.make_artifact("showdown", spec, records, skipped)
    out = args.out or "BENCH_showdown.json"
    artifacts.write_artifact(out, art)

    by_id = {r["id"]: r for r in records}
    print(f"\nshowdown vs production caches (n={spec['n']}, capacity="
          f"{spec['capacity']}, k={spec['ways']}; p50 steady-state req/s):")
    head = " ".join(f"{'t=' + str(t):>10}" for t in spec["threads"])
    print(f"{'family/library':<28} {head} {'ours':>12}")
    for family in spec["families"]:
        for policy in spec["policies"]:
            for lib in ("cachetools", "striped"):
                row = [by_id[f"showdown/{family}/{lib}-{policy}"
                             f"/threads{t}"]["value"]
                       for t in spec["threads"]]
                cells = " ".join(f"{v:>10.0f}" for v in row)
                print(f"{family + '/' + lib + '-' + policy:<28} {cells}")
            for ours in ("jnp-batched", "pallas-resident"):
                r = by_id[f"showdown/{family}/{ours}-{policy}"
                          f"/batch{spec['batch']}"]
                pad = " " * (11 * len(spec["threads"]))
                print(f"{family + '/' + ours + '-' + policy:<28}"
                      f"{pad} {r['value']:>12.0f}")
    print(f"\n{len(records)} records -> {out}")

    if args.hit_ratio_gate:
        checked, breaches = showdown_hit_ratio_gate(args.hit_ratio_gate,
                                                    records)
        return _run_gate("showdown hit-ratio", args.hit_ratio_gate,
                         checked, breaches)
    return 0


def run(quick=False):
    """CSV section for benchmarks/run.py."""
    from repro.showdown import HAVE_CACHETOOLS
    if not HAVE_CACHETOOLS:
        print("showdown,skipped,cachetools not installed")
        return
    print("table,config,req_per_s")
    _, records, _ = figures.showdown(quick=quick)
    for r in records:
        if r["metric"] != "req_per_s":
            continue
        emit("showdown", r["id"], f"{r['value']:.1f}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.showdown",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None,
                    help="artifact path (default BENCH_showdown.json)")
    ap.add_argument("--hit-ratio-gate", default=None, metavar="BASELINE",
                    help="diff the showdown-hr parity records against this "
                         "committed baseline; exit 3 on divergence")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    from repro.showdown import HAVE_CACHETOOLS
    if not HAVE_CACHETOOLS:
        print("cachetools is not installed — pip install -r "
              "requirements-dev.txt to run the showdown harness",
              file=sys.stderr)
        return 4

    return _compare(args)


if __name__ == "__main__":
    sys.exit(main())
