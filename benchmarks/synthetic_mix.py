"""Synthetic hit-ratio mixes (paper Figs. 27-30) — thin shim over
``repro.eval.figures.synthetic_mix``."""
from benchmarks.common import emit
from repro.eval import figures


def run(kinds=("miss100", "hit100", "hit95", "hit90")):
    print("table,config,mops_per_s")
    _, records, _ = figures.synthetic_mix(kinds=kinds)
    for r in records:
        emit("synthetic_mix", r["id"].rsplit("/batch", 1)[0],
             f"{r['value']:.3f}")


if __name__ == "__main__":
    run()
