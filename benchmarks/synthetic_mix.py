"""Synthetic hit-ratio mixes (paper Figs. 27-30): 100% miss, 100% hit,
95% and 90% hit workloads; get/put throughput of each implementation."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_jitted
from repro.core import kway
from repro.core.kway import KWayConfig, fully_associative
from repro.core.policies import Policy

CAPACITY = 4096
BATCH = 512


def _mk_stream(kind, rng, n):
    if kind == "miss100":   # every key unique
        return rng.permutation(np.arange(n, dtype=np.uint32) + (1 << 20))
    resident = rng.integers(0, CAPACITY // 2, n).astype(np.uint32)
    if kind == "hit100":
        return resident
    p_miss = {"hit95": 0.05, "hit90": 0.10}[kind]
    miss = (np.arange(n, dtype=np.uint32) + (1 << 20))
    take_miss = rng.random(n) < p_miss
    return np.where(take_miss, miss, resident).astype(np.uint32)


def run(kinds=("miss100", "hit100", "hit95", "hit90")):
    print("table,config,mops_per_s")
    rng = np.random.default_rng(11)
    impls = {
        "kway-soa": KWayConfig(num_sets=CAPACITY // 8, ways=8, policy=Policy.LRU),
        "sampled": KWayConfig(num_sets=CAPACITY // 128, ways=128,
                              policy=Policy.LRU, sample=8),
        "full": fully_associative(CAPACITY, Policy.LRU),
    }
    for kind in kinds:
        stream = _mk_stream(kind, rng, BATCH)
        for name, cfg in impls.items():
            state = kway.make_cache(cfg)
            resident = jnp.asarray(
                rng.integers(0, CAPACITY // 2, CAPACITY).astype(np.uint32))
            for chunk in resident.reshape(-1, 512):
                state, _, _, _, _ = kway.access(cfg, state, chunk,
                                                chunk.astype(jnp.int32))
            keys = jnp.asarray(stream)
            fn = jax.jit(lambda s, k: kway.access(cfg, s, k,
                                                  k.astype(jnp.int32))[0])
            dt = time_jitted(fn, state, keys)
            emit("synthetic_mix", f"{kind}/{name}", f"{BATCH / dt / 1e6:.3f}")


if __name__ == "__main__":
    run()
