"""Theorem 4.1 table: empirical overflow probability vs the Chernoff bound
for a 2C-sized k-way cache asked to hold C items."""
import math

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import hashing


def run(ks=(8, 16, 32, 64, 128), cprime=1 << 17, trials=30):
    print("table,config,value")
    for k in ks:
        num_sets = cprime // k
        c = cprime // 2
        bound = (cprime / k) * math.exp(-k / 6.0)
        fails = 0
        for seed in range(trials):
            rng = np.random.default_rng(seed)
            items = rng.choice(1 << 31, size=c, replace=False).astype(np.uint32)
            sets = np.asarray(hashing.set_index(jnp.asarray(items), num_sets))
            if (np.bincount(sets, minlength=num_sets) > k).any():
                fails += 1
        emit("theorem41", f"k{k}/empirical_overflow", f"{fails / trials:.3f}")
        emit("theorem41", f"k{k}/chernoff_bound", f"{min(bound, 1.0):.3g}")


if __name__ == "__main__":
    run()
