"""Benchmark runner: one section per paper table/figure family.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only SECTION]

Sections: hit_ratio (Figs 4-13), throughput (Figs 14-26),
synthetic_mix (Figs 27-30), showdown (Fig. 1 analogue: production caches
vs our paths), theorem41 (§4), kernels, serving, robustness (validator /
recovery / degradation ladder, DESIGN.md §13), hierarchy (L1-over-L2
replay, DESIGN.md §14), roofline (reads dryrun_results.json when
present).

The figure sections are thin shims over ``repro.eval`` (DESIGN.md §7) — for
machine-readable, baseline-gated artifacts use
``python -m repro.eval --fig <name> [--quick] [--baseline f.json]``.
"""
import argparse
import json
import os
import sys
import time


def _roofline_section():
    path = "dryrun_results.json"
    if not os.path.exists(path):
        print("roofline,skipped,no dryrun_results.json (run repro.launch.dryrun)")
        return
    print("table,config,value")
    with open(path) as f:
        results = json.load(f)
    for key, rec in sorted(results.items()):
        if rec.get("status") != "ok" or "roofline" not in rec:
            continue
        r = rec["roofline"]
        cell = f"{rec['arch']}/{rec['shape']}"
        step = max(r["t_compute"], r["t_memory"], r["t_collective"])
        print(f"roofline,{cell}/bottleneck,{r['bottleneck']}")
        print(f"roofline,{cell}/step_time_s,{step:.4f}")
        print(f"roofline,{cell}/roofline_fraction,{r['roofline_fraction']:.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--backend", default=None,
                    choices=["jnp", "pallas", "ref"],
                    help="restrict the throughput backend sweep to one "
                         "CacheBackend (default: compare all three)")
    ap.add_argument("--shards", type=int, default=4,
                    help="shard count for the sharded-vs-single throughput "
                         "row (power of two)")
    args = ap.parse_args()
    if args.shards < 1 or args.shards & (args.shards - 1):
        ap.error(f"--shards must be a power of two, got {args.shards}")

    from benchmarks import (hit_ratio, kernels_bench, robustness, serving,
                            showdown, synthetic_mix, theorem41, throughput)

    backends = (args.backend,) if args.backend else ("jnp", "pallas", "ref")
    shards = (1, args.shards) if args.shards > 1 else (1,)

    sections = {
        "hit_ratio": lambda: hit_ratio.run(quick=args.quick),
        "throughput": (lambda: throughput.run(
            quick=args.quick, backends=backends, shards=shards)),
        "synthetic_mix": synthetic_mix.run,
        "showdown": lambda: showdown.run(quick=args.quick),
        "theorem41": (lambda: theorem41.run(ks=(8, 64), trials=10))
        if args.quick else theorem41.run,
        "kernels": kernels_bench.run,
        "serving": serving.run,
        "robustness": lambda: robustness.run(quick=args.quick),
        "hierarchy": lambda: throughput.run_hierarchy(quick=args.quick),
        "roofline": _roofline_section,
    }
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        print(f"\n### {name} ###", flush=True)
        t0 = time.time()
        try:
            fn()
            print(f"### {name} done in {time.time()-t0:.1f}s ###", flush=True)
        except Exception as e:  # noqa: BLE001 — one section must not kill the run
            print(f"### {name} FAILED: {type(e).__name__}: {e} ###", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
