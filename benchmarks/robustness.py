"""Robustness benchmark: validator coverage, recovery cost, ladder, overhead.

The measurement lives in ``repro.eval.figures.robustness`` (DESIGN.md §13):
the golden 512-request trace validated clean across policies and backends
(the zero-false-positive pins), seeded bit-flip → scrub → replay-on
recovery hit ratios, the degradation ladder under a forced VMEM breach,
and the wall-clock overhead of fusing the invariant validator into the
replay scan.

    PYTHONPATH=src python -m benchmarks.robustness --quick [--ttl] \
        [--out BENCH_robustness.json] \
        [--gate benchmarks/baselines/BENCH_robustness_quick.json] \
        [--overhead-gate 5.0]

Every invocation writes the schema-versioned BENCH artifact and prints the
record table; ``--gate`` diffs all deterministic ``robust-*`` records
(clean-violation pins, scrub hit ratios and forced-eviction tallies, the
ladder rung and its parity hit ratio) against the committed baseline via
the shared ``_baseline_gate``/``_run_gate`` contract from
``benchmarks.throughput`` — exit 3 on divergence, dead gate = breach.
``--overhead-gate`` additionally enforces the absolute validator-overhead
ceiling (<5% by default) on ``robust-overhead/validated-replay/pct``; a
missing overhead record is a breach, never a silent pass.  ``--ttl`` adds
the expiry-lane group (DESIGN.md §15): TTL replay pinned clean and
backend-identical, plus the ``clock_skew``/``stale_entry`` expiry-scrub
chaos loop as a deterministic cost band — the ``robust-ttl/*`` records
ride the same ``--gate`` diff.  This is the CI chaos-smoke entry point;
``run()`` is the CSV section for ``benchmarks/run.py``.
"""
import argparse
import sys

from benchmarks.common import emit
from benchmarks.throughput import _baseline_gate, _run_gate
from repro.eval import figures


def robustness_gate(baseline_path: str, records, tol: float = 1e-6):
    """Diff a fresh run's deterministic ``robust-*`` records against the
    committed baseline.  Everything gated here is seeded and replayed
    bit-identically (validator pins, scrub recovery, ladder rung/parity),
    so the band is essentially zero — a breach means the invariant
    catalogue, the scrub semantics, or the ladder's rung selection moved.
    Returns ``(checked, breaches)`` under the shared dead-gate contract.
    """
    points = []
    for r in records:
        if not r["id"].startswith("robust-") or not r.get("comparable"):
            continue
        points.append((r["id"],
                       lambda rec, _r=r: [(_r["id"], _r["value"],
                                           rec["value"])]))
    return _baseline_gate(baseline_path, points, tol)


def overhead_gate(records, ceiling: float):
    """Absolute gate on the validator-overhead record: the fused validator
    must cost < ``ceiling`` percent over the plain replay scan.  Returns
    ``(checked, breaches)`` — no record found is a dead gate, a breach.
    """
    rec = next((r for r in records
                if r["id"] == "robust-overhead/validated-replay/pct"), None)
    if rec is None:
        return 0, ["dead gate: no robust-overhead/validated-replay/pct "
                   "record in this run"]
    if rec["value"] >= ceiling:
        return 1, [f"validator overhead {rec['value']:.2f}% >= "
                   f"ceiling {ceiling:.2f}% (plain p50 "
                   f"{rec['plain_p50_s']}s, validated p50 "
                   f"{rec['validated_p50_s']}s)"]
    return 1, []


def _compare(args) -> int:
    from repro.eval import artifacts

    spec, records, skipped = figures.robustness(
        quick=args.quick, ttl=args.ttl,
        progress=None if args.quiet else
        (lambda m: print(f"  [robustness] {m}", flush=True)))
    art = artifacts.make_artifact("robustness", spec, records, skipped)
    out = args.out or "BENCH_robustness.json"
    artifacts.write_artifact(out, art)

    print(f"\nrobustness (golden n={spec['n']}, {spec['num_sets']}x"
          f"{spec['ways']} cache):")
    print(f"{'record':<44} {'value':>12}")
    for r in records:
        extra = ""
        if "rung" in r:
            extra = f"  ({r['rung']})"
        elif "clean_value" in r:
            extra = f"  (clean {r['clean_value']})"
        print(f"{r['id']:<44} {r['value']:>12.6g}{extra}")
    print(f"\n{len(records)} records -> {out}")

    rc = 0
    if args.gate:
        checked, breaches = robustness_gate(args.gate, records)
        rc = _run_gate("robustness", args.gate, checked, breaches)
    if args.overhead_gate is not None:
        checked, breaches = overhead_gate(records, args.overhead_gate)
        rc = max(rc, _run_gate("validator-overhead",
                               f"<{args.overhead_gate}% ceiling",
                               checked, breaches))
    return rc


def run(quick=False):
    """CSV section for benchmarks/run.py."""
    print("table,config,value")
    _, records, _ = figures.robustness(quick=quick)
    for r in records:
        emit("robustness", r["id"], f"{r['value']:.6g}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.robustness",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--ttl", action="store_true",
                    help="add the expiry-lane record group (TTL replay "
                         "pinned clean + expiry-scrub chaos cost band); "
                         "the robust-ttl/* records ride the --gate diff")
    ap.add_argument("--out", default=None,
                    help="artifact path (default BENCH_robustness.json)")
    ap.add_argument("--gate", default=None, metavar="BASELINE",
                    help="diff the deterministic robust-* records against "
                         "this committed baseline; exit 3 on divergence")
    ap.add_argument("--overhead-gate", type=float, default=None,
                    metavar="PCT", nargs="?", const=5.0,
                    help="enforce the absolute validator-overhead ceiling "
                         "in percent (default 5.0 when given bare); exit 3 "
                         "on breach")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    return _compare(args)


if __name__ == "__main__":
    sys.exit(main())
