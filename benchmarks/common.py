"""Shared benchmark utilities: timing jitted callables, CSV emission."""
import time

import jax


def time_jitted(fn, *args, iters=20, warmup=3):
    """Median wall time per call of an already-jitted fn (seconds)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(table, name, value, extra=""):
    print(f"{table},{name},{value}{',' + extra if extra else ''}", flush=True)
