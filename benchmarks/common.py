"""Shared benchmark utilities: CSV emission.  Timing helpers live in
``repro.eval.timing`` (one measurement path); re-exported for back-compat."""
from repro.eval.timing import time_jitted  # noqa: F401


def emit(table, name, value, extra=""):
    print(f"{table},{name},{value}{',' + extra if extra else ''}", flush=True)
