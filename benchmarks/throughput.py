"""Cache throughput (paper Figs. 14-26 analogue).

Thread count becomes batch size (DESIGN.md §2).  Implementations compared:
  kway-soa  — KW-WFSC analogue (separate fingerprint/counter lanes)
  kway-aos  — KW-WFA analogue (interleaved record array, gathered)
  sampled   — fully associative + sample-8 victim selection (Redis)
  full      — fully associative, exact victim scan
Measured: millions of get+put ops/sec of the jitted access() on a real
zipf trace stream.

Two further sections exercise the unified CacheBackend layer (DESIGN.md §3,
§5):
  backend/* — the same kway-soa configuration driven through the "jnp",
    "pallas" (interpret off-TPU) and "ref" (sequential Python oracle)
    backends;
  sharded/* — the set-sharded execution layer, 1 shard vs N shards
    (shard_map on a real mesh, vmap emulation on a single device),
    including the host-side bucketing cost.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_jitted
from repro.core import kway, traces
from repro.core.backend import make_backend
from repro.core.kway import KWayConfig, fully_associative
from repro.core.policies import Policy
from repro.core.sharded import ShardedCache, ShardedConfig

CAPACITY = 4096


def _impl_configs(policy):
    return {
        "kway-soa": KWayConfig(num_sets=CAPACITY // 8, ways=8, policy=policy,
                               layout="soa"),
        "kway-aos": KWayConfig(num_sets=CAPACITY // 8, ways=8, policy=policy,
                               layout="aos"),
        "sampled": KWayConfig(num_sets=CAPACITY // 128, ways=128, policy=policy,
                              sample=8),  # Redis-like: big buckets, sample 8
        "full": fully_associative(CAPACITY, policy),
    }


def _warm(cfg, tr, n_warm):
    state = kway.make_cache(cfg)
    warm = jnp.asarray(tr[:n_warm].reshape(-1, 512))
    for chunk in warm:
        state, _, _, _, _ = kway.access(cfg, state, chunk,
                                        chunk.astype(jnp.int32))
    return state

def run(batches=(64, 256, 1024), policy=Policy.LRU, n_warm=20_480,
        backends=("jnp", "pallas", "ref"), shards=(1, 4)):
    print("table,config,mops_per_s")
    tr = traces.generate("zipf", n_warm + 4096, seed=7, catalog=1 << 14)
    soa_state = None
    for name, cfg in _impl_configs(policy).items():
        state = _warm(cfg, tr, n_warm)
        if name == "kway-soa":
            soa_state = state   # reused by the backend section below
        for b in batches:
            keys = jnp.asarray(tr[n_warm:n_warm + b])
            vals = keys.astype(jnp.int32)
            fn = jax.jit(lambda s, k, v: kway.access(cfg, s, k, v)[0])
            dt = time_jitted(fn, state, keys, vals)
            emit("throughput", f"{name}/batch{b}", f"{b / dt / 1e6:.3f}")

    # ---- unified backend layer: jnp vs pallas(interpret) vs ref oracle ----
    cfg = _impl_configs(policy)["kway-soa"]
    # states are backend-interchangeable: reuse the warm kway-soa state
    state = soa_state if soa_state is not None else _warm(cfg, tr, n_warm)
    for bname in backends:
        be = make_backend(bname, cfg)
        # interpret-mode pallas compiles slowly at large B; the ref oracle is
        # sequential Python — keep their batches proportionate.
        bl = {"jnp": batches, "pallas": tuple(b for b in batches if b <= 256),
              "ref": (64,)}.get(bname, batches)
        for b in bl:
            keys = jnp.asarray(tr[n_warm:n_warm + b])
            vals = keys.astype(jnp.int32)
            if bname == "ref":
                t0 = time.perf_counter()
                iters = 3
                for _ in range(iters):
                    be.access(state, keys, vals)
                dt = (time.perf_counter() - t0) / iters
            else:
                fn = jax.jit(lambda s, k, v: be.access(s, k, v)[0])
                dt = time_jitted(fn, state, keys, vals)
            emit("throughput", f"backend-{bname}/batch{b}", f"{b / dt / 1e6:.3f}")

    # ---- set-sharded execution: 1 shard vs N shards ----------------------
    b = max(bb for bb in batches)
    for ns in shards:
        sc = ShardedCache(ShardedConfig(cache=cfg, num_shards=ns))
        st = sc.init()
        chunk = np.asarray(tr[:b], np.uint32)
        for _ in range(3):  # warm the jit caches + shard states
            st, *_ = sc.access(st, chunk, chunk.astype(np.int32))
        t0 = time.perf_counter()
        iters = 10
        for i in range(iters):
            off = n_warm + (i * b) % 4096
            chunk = np.asarray(tr[off:off + b], np.uint32)
            if len(chunk) < b:
                chunk = np.asarray(tr[:b], np.uint32)
            st, *_ = sc.access(st, chunk, chunk.astype(np.int32))
        dt = (time.perf_counter() - t0) / iters
        emit("throughput", f"sharded-{ns}shard/batch{b}", f"{b / dt / 1e6:.3f}")


if __name__ == "__main__":
    run()
