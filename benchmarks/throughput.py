"""Cache throughput (paper Figs. 14-26 analogue) — thin shim over repro.eval.

The measurement lives in ``repro.eval.figures.throughput_vs_batch`` (layout /
backend / sharded sections, fused vs two-phase access variants with p50/p90
steady-state timing).  Four surfaces:

  * default: the historical ``table,config,mops_per_s`` CSV;
  * ``--fused-compare``: the fused-vs-two-phase comparison — writes the
    BENCH artifact, prints the comparison table, and (with
    ``--hit-ratio-gate BASELINE``) replays a slice of the baseline grid
    through the fused path and **fails (exit 3)** if any hit ratio diverges
    from the checked-in baseline.  This is the CI perf-smoke entry point.
  * ``--shards-compare``: the shards∈{1,2,4,8} scaling figure
    (``figures.throughput_vs_shards`` — routed serving ticks + single-scan
    no-host-sync rows) — writes its BENCH artifact and (with
    ``--hit-ratio-gate``) band-gates the sharded replay's hit ratios at
    shards ∈ {1, 4} against the checked-in baseline grid (exit 3 on
    breach).  The CI sharded perf-smoke entry point.
  * ``--resident-compare``: the trace-resident replay megakernel vs the
    chunked-scan replay (``figures.throughput_resident`` — whole-trace
    req/s p50/p90 per backend) — writes its BENCH artifact and gates
    resident-vs-scan hit-ratio EQUALITY (the megakernel is bit-identical
    by construction; exit 3 on any divergence).  The CI resident
    perf-smoke entry point.
  * ``--hierarchy-compare``: the two-level L1-over-L2 replay hierarchy vs
    the flat replay (``figures.hierarchy`` — req/s at an in-budget and an
    over-budget L2 capacity, plus hit ratio vs the L1-size knob) — writes
    its BENCH artifact and (with ``--hit-ratio-gate``) gates the ``l1-0``
    parity records exactly, the enabled-knob hit ratios within the 0.02
    band, and the over-budget speedup >= 2x (the capacity-cliff headline;
    exit 3 on breach).  The CI hierarchy perf-smoke entry point.

All the gates share one helper pair (``_baseline_gate`` / ``_run_gate``):
a single baseline-diff implementation and a single exit-code contract
(0 = pass, 3 = divergence, and a gate whose ids match nothing is *dead* —
reported as a breach, never as a silent pass).
"""
import argparse
import sys

from benchmarks.common import emit
from repro.eval import figures


def run(quick=False, backends=("jnp", "pallas", "ref"), shards=(1, 4)):
    print("table,config,mops_per_s")
    _, records, _ = figures.throughput_vs_batch(
        quick=quick, backends=backends, shards=shards)
    for r in records:
        if r["metric"] != "mops_per_s":
            continue        # ratio rows (speedup_x) don't fit the CSV unit
        emit("throughput", r["id"], f"{r['value']:.3f}")


def run_hierarchy(quick=False):
    """CSV section for benchmarks/run.py (L1-over-L2 hierarchy figure)."""
    print("table,config,value")
    _, records, _ = figures.hierarchy(quick=quick)
    for r in records:
        emit("hierarchy", r["id"], f"{r['value']:.6g}")


# ---------------------------------------------------------------------------
# shared gating helpers — the single exit-code contract and the single
# baseline-diff implementation behind --fused-compare, --shards-compare and
# --resident-compare
# ---------------------------------------------------------------------------

def _baseline_gate(baseline_path: str, points, tol: float):
    """The one baseline-diff implementation.

    ``points``: iterable of ``(record_id, eval_fn)``; for every id present
    in the baseline, ``eval_fn(baseline_record)`` returns a list of
    ``(label, got, want)`` comparisons to check within ``tol``.  Returns
    ``(checked, breaches)``.  A gate whose ids match nothing is dead — that
    is a breach (an id-scheme or baseline drift has turned the gate into a
    no-op), never a green pass.
    """
    from repro.eval import artifacts

    base = artifacts.load_artifact(baseline_path)
    by_id = {r["id"]: r for r in base["records"]}
    checked, breaches = 0, []
    for rid, eval_fn in points:
        rec = by_id.get(rid)
        if rec is None:
            continue
        for label, got, want in eval_fn(rec):
            checked += 1
            if abs(got - want) > tol:
                breaches.append(
                    f"{label}: hit ratio {got:.6f} vs baseline "
                    f"{want:.6f} (|delta| > {tol})")
    if checked == 0:
        breaches.append(
            f"no baseline record ids matched in {baseline_path} — id scheme "
            "or baseline drift has turned this gate into a no-op")
    return checked, breaches


def _run_gate(name: str, source: str, checked: int, breaches) -> int:
    """The one exit-code contract: 0 on pass, 3 on divergence."""
    if breaches:
        print(f"{name.upper()} GATE FAILED vs {source}:", file=sys.stderr)
        for b in breaches:
            print(f"  {b}", file=sys.stderr)
        return 3
    print(f"{name} gate ok: {checked} checks within band of {source}")
    return 0


def fused_hit_ratio_gate(baseline_path: str, tol: float = 1e-6):
    """Replay a slice of the baseline hit-ratio grid through the *fused*
    access path (simulate.replay, B=1) and diff against the checked-in
    values.  The fused path is bit-identical to two-phase, so the tolerance
    is essentially zero — any divergence means the fusion broke semantics.

    Returns (checked, breaches).
    """
    from repro.core import traces
    from repro.core.kway import KWayConfig
    from repro.core.policies import Policy
    from repro.core.simulate import SimConfig, replay
    from repro.eval.runner import assoc_shape

    trace_cache = {}

    def eval_fn(rec, _family, _policy, _assoc):
        seed, n = rec["seeds"][0], rec["n"]
        if (_family, seed, n) not in trace_cache:
            trace_cache[(_family, seed, n)] = traces.generate(
                _family, n, seed=seed)
        s, k, sample = assoc_shape(_assoc, rec["capacity"])
        cfg = KWayConfig(num_sets=s, ways=k, policy=_policy, sample=sample)
        hr = replay(SimConfig(cache=cfg), trace_cache[(_family, seed, n)])
        return [(f"{rec['id']} (fused)", hr, rec["per_seed"][0])]

    points = []
    for family in ("zipf", "scan_loop"):
        for policy in (Policy.LRU, Policy.LFU):
            for assoc in ("k8", "full"):
                rid = f"{family}/{policy.name}/{assoc}/jnp/none"
                points.append((rid, lambda rec, _f=family, _p=policy,
                               _a=assoc: eval_fn(rec, _f, _p, _a)))
    return _baseline_gate(baseline_path, points, tol)


def sharded_hit_ratio_gate(baseline_path: str, shards=(1, 4),
                           tol: float = 0.02, records=None):
    """Replay a slice of the baseline hit-ratio grid through the set-sharded
    single-scan path (``replay_batched(shards=D)``) and diff against the
    checked-in B=1 values.  Batched conflict resolution perturbs hit ratios
    slightly (DESIGN.md §2), so the band is ``tol`` — a real routing or
    shard-state bug moves hit ratios by far more.

    ``records`` (optional): a fresh ``throughput_vs_shards`` record list —
    its comparable hit-ratio rows are reused instead of re-running the same
    replays (they carry the same family/policy/assoc/shards/n provenance);
    rows whose ``n`` does not match the baseline record are recomputed.

    Returns (checked, breaches).
    """
    from repro.core import traces
    from repro.core.policies import Policy
    from repro.eval.runner import SweepPoint, replay_sharded_point

    fresh = {}
    for r in records or []:
        if r.get("metric") == "hit_ratio" and "shards" in r:
            # full provenance in the key: a record computed from a different
            # trace (seed/n) or cache shape must never stand in for the
            # baseline's configuration — fall through to a recompute instead
            fresh[(r["family"], r["policy"], r["shards"], r["n"],
                   r.get("seed"), r.get("capacity"), r.get("assoc"))] \
                = r["value"]
    trace_cache = {}

    def eval_fn(rec, _family, _policy):
        seed, n = rec["seeds"][0], rec["n"]
        out = []
        for d in shards:
            hr = fresh.get((_family, _policy.name, d, n, seed,
                            rec["capacity"], "k8"))
            if hr is None:
                if (_family, seed, n) not in trace_cache:
                    trace_cache[(_family, seed, n)] = traces.generate(
                        _family, n, seed=seed)
                p = SweepPoint(family=_family, policy=_policy, assoc="k8",
                               capacity=rec["capacity"], seed=seed, n=n)
                hr = replay_sharded_point(
                    p, shards=d, batch=256,
                    trace=trace_cache[(_family, seed, n)])
            out.append((f"{rec['id']} @shards={d}", hr, rec["per_seed"][0]))
        return out

    points = []
    for family in ("zipf", "scan_loop"):
        for policy in (Policy.LRU, Policy.LFU):
            rid = f"{family}/{policy.name}/k8/jnp/none"
            points.append((rid, lambda rec, _f=family, _p=policy:
                           eval_fn(rec, _f, _p)))
    return _baseline_gate(baseline_path, points, tol)


def resident_equality_gate(records):
    """Gate the trace-resident megakernel's bit-identity: every
    ``resident-eq/...`` record of a fresh ``throughput_resident`` run pairs
    the resident hit ratio (``value``) with the chunked-scan one
    (``scan_value``) over the same trace — the two must be EXACTLY equal.
    The "baseline" here is the scanned replay itself, so no baseline file
    is involved.  Returns (checked, breaches).
    """
    checked, breaches = 0, []
    for r in records:
        if not r["id"].startswith("resident-eq/"):
            continue
        checked += 1
        if r["value"] != r["scan_value"]:
            breaches.append(
                f"{r['id']}: resident hit ratio {r['value']:.6f} != "
                f"chunked-scan {r['scan_value']:.6f} — the megakernel "
                "diverged from the scan semantics")
    if checked == 0:
        breaches.append(
            "no resident-eq records in the throughput_resident run — the "
            "equality gate is a no-op")
    return checked, breaches


def hierarchy_gate(baseline_path: str, records):
    """Gate a fresh ``figures.hierarchy`` run against the checked-in
    baseline (three contracts in one gate):

      * ``hier-hr/.../l1-0`` parity records: exact (tol 0.0) vs the
        baseline AND vs their own fresh ``scan_value`` — the disabled
        hierarchy IS the flat path, bit-for-bit;
      * enabled ``hier-hr/...`` records: within the 0.02 band of the
        baseline — a promotion/demotion bug moves hit ratios by far more;
      * the over-budget ``hier-tp/speedup/...`` record: >= 2x fresh — the
        capacity-cliff headline must hold on every run, not just the one
        that minted the baseline.

    Returns (checked, breaches).
    """
    fresh = {r["id"]: r for r in records}

    def mk(rid, exact):
        def eval_fn(rec):
            fr = fresh.get(rid)
            if fr is None:
                return []
            out = [(rid, fr["value"], rec["value"])]
            if exact:
                out.append((f"{rid} (flat-scan parity)",
                            fr["value"], fr["scan_value"]))
            return out
        return eval_fn

    parity_pts, band_pts = [], []
    for rid, fr in fresh.items():
        if not rid.startswith("hier-hr/"):
            continue
        exact = fr.get("tol") == 0.0
        (parity_pts if exact else band_pts).append((rid, mk(rid, exact)))
    c1, b1 = _baseline_gate(baseline_path, parity_pts, tol=0.0)
    c2, b2 = _baseline_gate(baseline_path, band_pts, tol=0.02)
    checked, breaches = c1 + c2, b1 + b2

    # the capacity-cliff headline rides in the fresh records, not the
    # baseline: past the VMEM budget the hierarchical kernel must beat the
    # flat path's chunked-scan fallback by >= 2x
    headline = 0
    for r in records:
        if r["id"].startswith("hier-tp/speedup/") and r.get("over_budget"):
            headline += 1
            checked += 1
            if r["value"] < 2.0:
                breaches.append(
                    f"{r['id']}: over-budget speedup {r['value']:.2f}x "
                    "< 2x — the hierarchy no longer breaks the capacity "
                    "cliff")
    if headline == 0:
        breaches.append(
            "no over-budget hier-tp/speedup record in the hierarchy run — "
            "the capacity-cliff check is a no-op")
    return checked, breaches


# ---------------------------------------------------------------------------
# CLI modes
# ---------------------------------------------------------------------------

def _shards_compare(args) -> int:
    from repro.eval import artifacts

    spec, records, skipped = figures.throughput_vs_shards(
        quick=args.quick,
        progress=None if args.quiet else
        (lambda m: print(f"  [throughput_shards] {m}", flush=True)))
    art = artifacts.make_artifact("throughput_vs_shards", spec, records,
                                  skipped)
    out = args.out or "BENCH_throughput_vs_shards.json"
    artifacts.write_artifact(out, art)

    by_id = {r["id"]: r for r in records}
    print("\nsharded scaling (fixed per-shard tick batch of "
          f"{spec['tick_batch']}; p50 steady-state):")
    print(f"{'shards':>6} {'tick req/s':>12} {'scan req/s':>12} "
          f"{'tick speedup':>13}")
    for d in spec["shards"]:
        tick = by_id[f"sharded-jnp-shard{d}/batch{d * spec['tick_batch']}"]
        scan = by_id[f"scan-shard{d}/batch{d * spec['tick_batch']}"]
        scale = by_id[f"scaling-shard{d}/batch{d * spec['tick_batch']}"]
        print(f"{d:>6} {tick['p50_req_s']:>12.0f} {scan['p50_req_s']:>12.0f} "
              f"{scale['value']:>12.2f}x")
    print(f"\n{len(records)} records -> {out}")

    if args.hit_ratio_gate:
        checked, breaches = sharded_hit_ratio_gate(args.hit_ratio_gate,
                                                   records=records)
        return _run_gate("sharded hit-ratio", args.hit_ratio_gate,
                         checked, breaches)
    return 0


def _fused_compare(args) -> int:
    from repro.eval import artifacts

    spec, records, skipped = figures.throughput_vs_batch(
        quick=args.quick, backends=("jnp", "pallas"), shards=(1,),
        progress=None if args.quiet else
        (lambda m: print(f"  [throughput] {m}", flush=True)))
    art = artifacts.make_artifact("throughput_vs_batch", spec, records,
                                  skipped)
    out = args.out or "BENCH_throughput_vs_batch.json"
    artifacts.write_artifact(out, art)

    by_id = {r["id"]: r for r in records}
    print("\nfused vs two-phase access (p50 steady-state):")
    print(f"{'backend':<8} {'batch':>6} {'fused Mop/s':>12} "
          f"{'two-phase Mop/s':>16} {'speedup':>8}")
    slowdowns = []
    for r in records:
        if "-fused-speedup/" not in r["id"]:
            continue
        bname = r["id"].split("-")[1]
        b = r["batch"]
        fused = by_id[f"backend-{bname}-fused/batch{b}"]["value"]
        two = by_id[f"backend-{bname}-twophase/batch{b}"]["value"]
        print(f"{bname:<8} {b:>6} {fused:>12.3f} {two:>16.3f} "
              f"{r['value']:>7.2f}x")
        if bname == "jnp" and r["value"] < 1.0:
            slowdowns.append(f"jnp/batch{b}: {r['value']:.2f}x")
    print(f"\n{len(records)} records -> {out}")
    if slowdowns:
        # advisory, not fatal: CI machines are noisy, and the hit-ratio gate
        # below is the correctness contract
        print(f"WARNING: fused path slower than two-phase on "
              f"{', '.join(slowdowns)}", file=sys.stderr)

    if args.hit_ratio_gate:
        checked, breaches = fused_hit_ratio_gate(args.hit_ratio_gate)
        return _run_gate("fused hit-ratio", args.hit_ratio_gate,
                         checked, breaches)
    return 0


def _resident_compare(args) -> int:
    from repro.eval import artifacts

    spec, records, skipped = figures.throughput_resident(
        quick=args.quick,
        progress=None if args.quiet else
        (lambda m: print(f"  [throughput_resident] {m}", flush=True)))
    art = artifacts.make_artifact("throughput_resident", spec, records,
                                  skipped)
    out = args.out or "BENCH_throughput_resident.json"
    artifacts.write_artifact(out, art)

    by_id = {r["id"]: r for r in records}
    print("\ntrace-resident megakernel vs chunked-scan replay "
          f"(whole-trace, n={spec['n']}, batch={spec['batch']}; "
          "p50 steady-state):")
    print(f"{'backend':<8} {'scan req/s':>12} {'resident req/s':>15} "
          f"{'speedup':>8}")
    for bname in spec["backends"]:
        scan = by_id[f"replay-scan-{bname}/batch{spec['batch']}"]
        res = by_id[f"replay-resident-{bname}/batch{spec['batch']}"]
        speed = by_id[f"replay-resident-speedup-{bname}"
                      f"/batch{spec['batch']}"]
        print(f"{bname:<8} {scan['p50_req_s']:>12.0f} "
              f"{res['p50_req_s']:>15.0f} {speed['value']:>7.2f}x")
    print(f"\n{len(records)} records -> {out}")

    # the resident gate is always on: bit-identity is the contract, and
    # the comparison values ride in the fresh records themselves
    checked, breaches = resident_equality_gate(records)
    return _run_gate("resident-vs-scan equality", "chunked-scan replay",
                     checked, breaches)


def _hierarchy_compare(args) -> int:
    from repro.eval import artifacts

    spec, records, skipped = figures.hierarchy(
        quick=args.quick,
        progress=None if args.quiet else
        (lambda m: print(f"  [hierarchy] {m}", flush=True)))
    art = artifacts.make_artifact("hierarchy", spec, records, skipped)
    out = args.out or "BENCH_throughput_hierarchy.json"
    artifacts.write_artifact(out, art)

    by_id = {r["id"]: r for r in records}
    print("\nL1-over-L2 hierarchy vs flat replay (whole-trace, "
          f"n={spec['n']}, batch={spec['batch']}, "
          f"L1 {spec['l1_sets']}x{spec['l1_ways']}; p50 steady-state):")
    print(f"{'L2 sets':>8} {'flat path':>16} {'flat req/s':>12} "
          f"{'l1l2 req/s':>12} {'speedup':>8}")
    b = spec["batch"]
    for s in spec["l2_sets"]:
        flat = by_id[f"hier-tp/flat/s{s}/batch{b}"]
        l1l2 = by_id[f"hier-tp/l1l2/s{s}/batch{b}"]
        speed = by_id[f"hier-tp/speedup/s{s}/batch{b}"]
        print(f"{s:>8} {flat['path']:>16} {flat['p50_req_s']:>12.0f} "
              f"{l1l2['p50_req_s']:>12.0f} {speed['value']:>7.2f}x")
    print("\nhit ratio vs total capacity (L2 fixed at "
          f"{by_id['hier-hr/zipf/l1-0']['l2_capacity']} entries):")
    print(f"{'family':<16} {'L1 sets':>8} {'total cap':>10} "
          f"{'hier':>8} {'flat oracle':>12}")
    for r in records:
        if not r["id"].startswith("hier-hr/"):
            continue
        oracle = r.get("flat_value", r.get("scan_value"))
        print(f"{r['family']:<16} {r['l1_sets']:>8} "
              f"{r['total_capacity']:>10} {r['value']:>8.4f} "
              f"{oracle:>12.4f}")
    print(f"\n{len(records)} records -> {out}")

    if args.hit_ratio_gate:
        checked, breaches = hierarchy_gate(args.hit_ratio_gate, records)
        return _run_gate("hierarchy", args.hit_ratio_gate,
                         checked, breaches)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.throughput",
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--fused-compare", action="store_true",
                    help="fused-vs-two-phase comparison + BENCH artifact "
                         "(the CI perf-smoke mode)")
    ap.add_argument("--shards-compare", action="store_true",
                    help="throughput-vs-shards scaling figure + BENCH "
                         "artifact (the CI sharded perf-smoke mode)")
    ap.add_argument("--resident-compare", action="store_true",
                    help="trace-resident megakernel vs chunked-scan replay "
                         "+ BENCH artifact; gates resident-vs-scan "
                         "hit-ratio equality (the CI resident perf-smoke "
                         "mode)")
    ap.add_argument("--hierarchy-compare", action="store_true",
                    help="two-level L1-over-L2 hierarchy vs flat replay + "
                         "BENCH artifact; with --hit-ratio-gate, gates "
                         "l1-0 parity exactly, enabled hit ratios within "
                         "0.02, and the over-budget speedup >= 2x (the CI "
                         "hierarchy perf-smoke mode)")
    ap.add_argument("--out", default=None,
                    help="artifact path for the --*-compare modes "
                         "(default BENCH_<figure>.json)")
    ap.add_argument("--hit-ratio-gate", default=None, metavar="BASELINE",
                    help="with --fused-compare, --shards-compare or "
                         "--hierarchy-compare: diff this checked-in "
                         "baseline against the fused / sharded / "
                         "hierarchical replay; exit 3 on divergence")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    modes = [m for m, on in (("--fused-compare", args.fused_compare),
                             ("--shards-compare", args.shards_compare),
                             ("--resident-compare", args.resident_compare),
                             ("--hierarchy-compare", args.hierarchy_compare))
             if on]
    if len(modes) > 1:
        ap.error(f"{' and '.join(modes)} are separate modes")
    if args.resident_compare and args.hit_ratio_gate:
        # never accept-and-ignore a gate flag: the resident mode's gate is
        # the always-on resident-vs-scan equality check, not a baseline file
        ap.error("--resident-compare gates resident-vs-scan equality "
                 "unconditionally and takes no --hit-ratio-gate baseline")
    if args.hierarchy_compare:
        return _hierarchy_compare(args)
    if args.resident_compare:
        return _resident_compare(args)
    if args.shards_compare:
        return _shards_compare(args)
    if args.fused_compare:
        return _fused_compare(args)
    run(quick=args.quick)
    return 0


if __name__ == "__main__":
    sys.exit(main())
