"""Cache throughput (paper Figs. 14-26 analogue) — thin shim over repro.eval.

The measurement lives in ``repro.eval.figures.throughput_vs_batch`` (layout /
backend / sharded sections); this script keeps the historical
``table,config,mops_per_s`` CSV surface.
"""
from benchmarks.common import emit
from repro.eval import figures


def run(quick=False, backends=("jnp", "pallas", "ref"), shards=(1, 4)):
    print("table,config,mops_per_s")
    _, records, _ = figures.throughput_vs_batch(
        quick=quick, backends=backends, shards=shards)
    for r in records:
        emit("throughput", r["id"], f"{r['value']:.3f}")


if __name__ == "__main__":
    run()
