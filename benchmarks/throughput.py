"""Cache throughput (paper Figs. 14-26 analogue).

Thread count becomes batch size (DESIGN.md §2).  Implementations compared:
  kway-soa  — KW-WFSC analogue (separate fingerprint/counter lanes)
  kway-aos  — KW-WFA analogue (interleaved record array, gathered)
  sampled   — fully associative + sample-8 victim selection (Redis)
  full      — fully associative, exact victim scan
Measured: millions of get+put ops/sec of the jitted access() on a real
zipf trace stream.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_jitted
from repro.core import kway, traces
from repro.core.kway import KWayConfig, fully_associative
from repro.core.policies import Policy

CAPACITY = 4096


def _impl_configs(policy):
    return {
        "kway-soa": KWayConfig(num_sets=CAPACITY // 8, ways=8, policy=policy,
                               layout="soa"),
        "kway-aos": KWayConfig(num_sets=CAPACITY // 8, ways=8, policy=policy,
                               layout="aos"),
        "sampled": KWayConfig(num_sets=CAPACITY // 128, ways=128, policy=policy,
                              sample=8),  # Redis-like: big buckets, sample 8
        "full": fully_associative(CAPACITY, policy),
    }


def run(batches=(64, 256, 1024), policy=Policy.LRU, n_warm=20_480):
    print("table,config,mops_per_s")
    tr = traces.generate("zipf", n_warm + 4096, seed=7, catalog=1 << 14)
    for name, cfg in _impl_configs(policy).items():
        state = kway.make_cache(cfg)
        # warm the cache
        warm = jnp.asarray(tr[:n_warm].reshape(-1, 512))
        for chunk in warm:
            state, _, _, _, _ = kway.access(cfg, state, chunk,
                                            chunk.astype(jnp.int32))
        for b in batches:
            keys = jnp.asarray(tr[n_warm:n_warm + b])
            vals = keys.astype(jnp.int32)
            fn = jax.jit(lambda s, k, v: kway.access(cfg, s, k, v)[0])
            dt = time_jitted(fn, state, keys, vals)
            emit("throughput", f"{name}/batch{b}", f"{b / dt / 1e6:.3f}")


if __name__ == "__main__":
    run()
