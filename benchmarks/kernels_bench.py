"""Kernel microbenchmarks: kway_probe and paged_attention (interpret mode on
CPU — structural timing; real perf comes from the TPU dry-run roofline)."""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_jitted
from repro.core import hashing
from repro.core.policies import Policy
from repro.kernels import ref
from repro.kernels.kway_probe import kway_probe
from repro.kernels.paged_attention import paged_attention


def run():
    print("table,config,us_per_call")
    rng = np.random.default_rng(0)
    # kway_probe vs jnp oracle
    s, ways, b = 512, 8, 256
    keys = np.full((s, 128), -1, np.int32)
    keys[:, :ways] = rng.integers(0, 50_000, (s, ways))
    fpr = np.asarray(hashing.fingerprint(
        jnp.asarray(keys).astype(jnp.uint32))).astype(np.int32)
    ma = rng.integers(0, 1000, (s, 128)).astype(np.int32)
    mb = np.zeros((s, 128), np.int32)
    sets = rng.integers(0, s, b).astype(np.int32)
    qk = rng.integers(0, 50_000, b).astype(np.int32)
    times = np.arange(b, dtype=np.int32)
    args = [jnp.asarray(a) for a in (keys, fpr, ma, mb, sets, qk, times)]
    dt = time_jitted(
        lambda *a: kway_probe(*a, policy=int(Policy.LRU), ways=ways, qt=8),
        *args)
    emit("kernels", "kway_probe_interp/b256", f"{dt*1e6:.1f}")
    dt = time_jitted(
        lambda *a: ref.kway_probe_ref(*a, policy=int(Policy.LRU), ways=ways),
        *args)
    emit("kernels", "kway_probe_xla_oracle/b256", f"{dt*1e6:.1f}")

    # paged attention vs oracle
    bq, h, kvh, d, page, pages, pps = 4, 8, 2, 64, 16, 64, 8
    q = jnp.asarray(rng.standard_normal((bq, h, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((kvh, pages, page, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((kvh, pages, page, d)), jnp.float32)
    pt = jnp.asarray(rng.integers(0, pages, (bq, pps)), jnp.int32)
    sl = jnp.full((bq,), pps * page, jnp.int32)
    dt = time_jitted(paged_attention, q, kp, vp, pt, sl)
    emit("kernels", "paged_attention_interp/b4", f"{dt*1e6:.1f}")
    dt = time_jitted(ref.paged_attention_ref, q, kp, vp, pt, sl)
    emit("kernels", "paged_attention_xla_oracle/b4", f"{dt*1e6:.1f}")


if __name__ == "__main__":
    run()
