"""Hit ratio vs associativity (paper Figs. 4-13) — thin shim over repro.eval.

The measurement lives in ``repro.eval.figures.hit_ratio_vs_associativity``
(stacked, vmapped sweep; see DESIGN.md §7); this script keeps the historical
``table,config,hit_ratio`` CSV row format for eyeballing and CI smoke.
Values are the figure's grid, not the pre-eval script's: non-quick runs
report 3-seed means over the full family list (including ``recency``),
where the old script printed a single seed-42 replay.
"""
from benchmarks.common import emit
from repro.eval import figures


def run(quick=False, tinylfu=True):
    print("table,config,hit_ratio")
    # jnp only: backend parity is covered by tests + repro.eval artifacts
    _, records, skipped = figures.hit_ratio_vs_associativity(
        quick=quick, backends=("jnp",))
    for r in records:
        emit("hit_ratio", f"{r['family']}/{r['policy']}/{r['assoc']}",
             f"{r['value']:.4f}")
    if tinylfu:
        # tinylfu rows only — the "none" half is the k8 sweep above
        _, records, skipped_adm = figures.admission_ablation(
            quick=quick, admissions=("tinylfu",))
        skipped = skipped + skipped_adm
        for r in records:
            emit("hit_ratio",
                 f"{r['family']}/{r['policy']}/{r['assoc']}+tinylfu",
                 f"{r['value']:.4f}")
    for s in skipped:
        print(f"# skipped {s}")


if __name__ == "__main__":
    run()
