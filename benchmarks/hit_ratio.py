"""Hit ratio vs associativity (paper Figs. 4-13).

For each trace family × policy: k ∈ {4, 8, ..} ways, sampled-8, and fully
associative.  Reproduces the paper's central claim: the k=8 line sits on the
fully-associative line.
"""
import numpy as np

from benchmarks.common import emit
from repro.core import admission, traces
from repro.core.kway import KWayConfig, fully_associative
from repro.core.policies import Policy
from repro.core.simulate import SimConfig, replay

CAPACITY = 1024
DEFAULT_TRACES = ("zipf", "zipf_shift", "scan_loop", "oltp_mix")
DEFAULT_POLICIES = (Policy.LRU, Policy.LFU, Policy.HYPERBOLIC)


def run(n=60_000, ks=(4, 8, 32), trace_families=DEFAULT_TRACES,
        policies=DEFAULT_POLICIES, tinylfu_for=(Policy.LFU,)):
    print("table,config,hit_ratio")
    for fam in trace_families:
        tr = traces.generate(fam, n, seed=42)
        for pol in policies:
            for k in ks:
                cfg = KWayConfig(num_sets=CAPACITY // k, ways=k, policy=pol)
                hr = replay(SimConfig(cfg), tr)
                emit("hit_ratio", f"{fam}/{pol.name}/k{k}", f"{hr:.4f}")
            # sampled-8 on the fully associative cache (Redis style)
            scfg = fully_associative(CAPACITY, pol, sample=8)
            emit("hit_ratio", f"{fam}/{pol.name}/sampled8",
                 f"{replay(SimConfig(scfg), tr):.4f}")
            fcfg = fully_associative(CAPACITY, pol)
            emit("hit_ratio", f"{fam}/{pol.name}/full",
                 f"{replay(SimConfig(fcfg), tr):.4f}")
            if pol in tinylfu_for:
                cfg8 = KWayConfig(num_sets=CAPACITY // 8, ways=8, policy=pol)
                hr = replay(SimConfig(cfg8, admission.for_capacity(CAPACITY)), tr)
                emit("hit_ratio", f"{fam}/{pol.name}/k8+tinylfu", f"{hr:.4f}")


if __name__ == "__main__":
    run()
