"""End-to-end serving benchmark: prefix-cache effect on a shared-prefix
request mix (the framework-level analogue of the paper's trace runs)."""
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro import configs
from repro.core.policies import Policy
from repro.models import lm
from repro.serve.engine import Engine, EngineConfig


def run(requests=12, prefix_len=48):
    print("table,config,value")
    cfg = configs.get("deepseek-7b").smoke
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    shared = rng.integers(2, 400, prefix_len)
    prompts = [np.concatenate([shared, rng.integers(2, 400, 8)])
               for _ in range(requests)]
    for policy in (Policy.LRU, Policy.LFU):
        eng = Engine(cfg, params, EngineConfig(
            page=8, num_sets=32, ways=8, policy=policy, max_batch=4,
            max_seq=256, private_pages=128))
        t0 = time.time()
        for pr in prompts:
            eng.submit(pr, max_new=8)
        fin = eng.run()
        dt = time.time() - t0
        toks = sum(len(r.generated) for r in fin.values())
        emit("serving", f"{policy.name}/tok_per_s", f"{toks/dt:.1f}")
        emit("serving", f"{policy.name}/prefix_hit_ratio",
             f"{eng.hit_ratio():.3f}")
        emit("serving", f"{policy.name}/evictions", eng.stats["evictions"])


if __name__ == "__main__":
    run()
