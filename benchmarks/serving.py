"""End-to-end serving benchmark — thin shim over
``repro.eval.figures.serving`` (prefix-cache effect on a shared-prefix
request mix)."""
from benchmarks.common import emit
from repro.eval import figures


def run(requests=12, prefix_len=48):
    print("table,config,value")
    _, records, _ = figures.serving(requests=requests, prefix_len=prefix_len)
    for r in records:
        emit("serving", r["id"], r["value"])


if __name__ == "__main__":
    run()
