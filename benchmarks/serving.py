"""End-to-end serving benchmarks.

Two modes:

  * default — thin shim over ``repro.eval.figures.serving`` (prefix-cache
    effect on a shared-prefix request mix), CSV to stdout;
  * ``--serving-compare`` — the device-resident jitted serving tick vs the
    host-loop engine (``figures.serving_engine``): req/s + tok/s percentile
    rows and a BENCH artifact, plus an ALWAYS-ON equality gate — the jitted
    engine must emit token-for-token identical generations with an identical
    prefix hit ratio, or the process exits 3 (same contract as
    ``benchmarks.throughput --resident-compare``).  The CI perf-smoke mode.

The committed quick baseline lives at
``benchmarks/baselines/BENCH_serving_engine_quick.json``.
"""
import argparse
import sys

from benchmarks.common import emit
from repro.eval import figures


def run(requests=12, prefix_len=48):
    print("table,config,value")
    _, records, _ = figures.serving(requests=requests, prefix_len=prefix_len)
    for r in records:
        emit("serving", r["id"], r["value"])


def serving_parity_gate(records):
    """(checked, breaches) over the figure's own parity rows.

    Token equality and hit-ratio identity are bit-contracts (tol 0): the
    two engines run the same unified prefix transaction and the same model
    ops, so ANY divergence is a semantics bug, never noise.
    """
    checked, breaches = 0, []
    for r in records:
        if r["metric"] == "tokens_equal":
            checked += 1
            if r["value"] != 1.0:
                breaches.append(
                    f"{r['id']}: jitted engine emitted different tokens "
                    "than the host-loop oracle")
        elif r["metric"] == "prefix_hit_ratio" and "scan_value" in r:
            checked += 1
            if r["value"] != r["scan_value"]:
                breaches.append(
                    f"{r['id']}: jitted hit ratio {r['value']} != host-loop "
                    f"{r['scan_value']}")
    if checked == 0:
        breaches.append("no parity records found — figure id scheme drifted,"
                        " the gate is a no-op")
    return checked, breaches


def _serving_compare(args) -> int:
    from benchmarks.throughput import _run_gate
    from repro.eval import artifacts

    spec, records, skipped = figures.serving_engine(
        quick=args.quick,
        progress=None if args.quiet else
        (lambda m: print(f"  [serving_engine] {m}", flush=True)))
    art = artifacts.make_artifact("serving_engine", spec, records, skipped)
    out = args.out or "BENCH_serving_engine.json"
    artifacts.write_artifact(out, art)

    by_id = {r["id"]: r for r in records}
    print(f"\njitted serving tick vs host-loop engine "
          f"({spec['requests']} requests, max_new={spec['max_new']}; "
          "p50 steady-state):")
    print(f"{'slots':<6} {'hostloop req/s':>14} {'jitted req/s':>13} "
          f"{'speedup':>8} {'jitted tok/s':>13}")
    for s in spec["slots"]:
        host = by_id[f"engine-hostloop-slots{s}/req_per_s"]
        jit = by_id[f"engine-jitted-slots{s}/req_per_s"]
        speed = by_id[f"engine-jitted-speedup-slots{s}"]
        print(f"{s:<6} {host['value']:>14.1f} {jit['value']:>13.1f} "
              f"{speed['value']:>7.2f}x {jit['tok_per_s']:>13.1f}")
    print(f"\n{len(records)} records -> {out}")

    # the parity gate is always on: the speedup rows are only meaningful if
    # the jitted tick is semantically indistinguishable from the oracle
    checked, breaches = serving_parity_gate(records)
    return _run_gate("jitted-vs-hostloop serving parity", "host-loop engine",
                     checked, breaches)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.serving",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--serving-compare", action="store_true",
                    help="jitted-tick vs host-loop comparison + BENCH "
                         "artifact; gates token/hit-ratio parity (the CI "
                         "serving perf-smoke mode)")
    ap.add_argument("--out", default=None,
                    help="artifact path for --serving-compare "
                         "(default BENCH_serving_engine.json)")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    if args.serving_compare:
        return _serving_compare(args)
    run()
    return 0


if __name__ == "__main__":
    sys.exit(main())
